"""Pallas TPU kernel: fused per-embedding-group quantize-dequantize.

The paper's PEG scheme (eq. 5) on TPU: the range-based permutation is folded
into the weights (DESIGN.md §3), so at runtime the embedding axis is already
group-sorted and groups are contiguous, 128-lane-aligned spans. The kernel
tiles (tokens x one group) per program: the group's scalar (scale, zero-point)
lives in SMEM, the block in VMEM, and quant->clip->dequant fuses into one
VPU pass — no HBM round-trip for the integer intermediate.

Grid: (T / block_t, K). Block: (block_t, group_size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _peg_fakequant_kernel(s_ref, z_ref, x_ref, o_ref, *, qmin, qmax):
    s = s_ref[0]
    z = z_ref[0]
    x = x_ref[...].astype(jnp.float32)
    q = jnp.round(x / s) + z
    q = jnp.clip(q, qmin, qmax)
    o_ref[...] = ((q - z) * s).astype(o_ref.dtype)


def _peg_quantize_kernel(s_ref, z_ref, x_ref, o_ref, *, qmin, qmax):
    s = s_ref[0]
    z = z_ref[0]
    x = x_ref[...].astype(jnp.float32)
    q = jnp.round(x / s) + z
    o_ref[...] = jnp.clip(q, qmin, qmax).astype(o_ref.dtype)


def peg_fake_quant(x: jnp.ndarray, scales: jnp.ndarray, zps: jnp.ndarray,
                   *, qmin: int, qmax: int, block_t: int = 256,
                   interpret: bool = False) -> jnp.ndarray:
    """x: (T, d) group-sorted activations; scales/zps: (K,) with d % K == 0.

    Returns fake-quantized x (same shape/dtype).
    """
    t, d = x.shape
    k = scales.shape[0]
    assert d % k == 0, "PEG kernel requires uniform (lane-aligned) groups"
    gs = d // k
    bt = min(block_t, t)
    assert t % bt == 0, f"token count {t} not divisible by block {bt}"

    kernel = functools.partial(_peg_fakequant_kernel, qmin=qmin, qmax=qmax)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        grid=(t // bt, k),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (j,)),            # scale (SMEM-able)
            pl.BlockSpec((1,), lambda i, j: (j,)),            # zero point
            pl.BlockSpec((bt, gs), lambda i, j: (i, j)),      # activations
        ],
        out_specs=pl.BlockSpec((bt, gs), lambda i, j: (i, j)),
        interpret=interpret,
    )(scales.astype(jnp.float32), zps.astype(jnp.float32), x)


def peg_quantize(x: jnp.ndarray, scales: jnp.ndarray, zps: jnp.ndarray,
                 *, qmin: int, qmax: int, out_dtype=jnp.int8,
                 block_t: int = 256, interpret: bool = False) -> jnp.ndarray:
    """Emit the integer tensor (deployment path). Same layout rules."""
    t, d = x.shape
    k = scales.shape[0]
    assert d % k == 0
    gs = d // k
    bt = min(block_t, t)
    assert t % bt == 0

    kernel = functools.partial(_peg_quantize_kernel, qmin=qmin, qmax=qmax)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((t, d), out_dtype),
        grid=(t // bt, k),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (j,)),
            pl.BlockSpec((bt, gs), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bt, gs), lambda i, j: (i, j)),
        interpret=interpret,
    )(scales.astype(jnp.float32), zps.astype(jnp.float32), x)
