"""Static range estimators (paper §2, App. B.2).

Three estimators, matching the paper's search space:
  * current min-max  — full dynamic range of a single calibration batch;
  * running min-max  — EMA (momentum 0.9) of per-batch min/max;
  * MSE              — clipping range that minimizes ||x - q(x)||² via a grid
                       search over symmetric shrink ratios (Choukroun 2019,
                       Banner 2018).

All estimators are granularity-aware: reductions keep the channel/embedding
axis when the config asks for per-channel / per-embedding / PEG parameters.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.quant_config import Granularity, QuantizerConfig, RangeEstimator
from repro.core.quantizer import (QuantParams, fake_quant, params_from_range,
                                  reduce_range)


class RangeState(NamedTuple):
    """Accumulated range statistics across calibration batches (a pytree)."""
    x_min: jnp.ndarray
    x_max: jnp.ndarray
    initialized: jnp.ndarray          # scalar bool


def init_range_state(shape=()) -> RangeState:
    return RangeState(x_min=jnp.zeros(shape), x_max=jnp.zeros(shape),
                      initialized=jnp.asarray(False))


def _group_reduce(mn: jnp.ndarray, mx: jnp.ndarray,
                  group_index: jnp.ndarray, num_groups: int):
    """Per-dim (d,) ranges -> per-group (K,) ranges (min of mins, max of maxs)."""
    gmin = jnp.full((num_groups,), jnp.inf).at[group_index].min(mn)
    gmax = jnp.full((num_groups,), -jnp.inf).at[group_index].max(mx)
    return gmin, gmax


def observe(state: RangeState, x: jnp.ndarray, cfg: QuantizerConfig) -> RangeState:
    """Update range statistics with one calibration batch."""
    if cfg.granularity == Granularity.PER_EMBEDDING_GROUP:
        # Collect per-dim stats; grouping happens in finalize (needs the
        # permutation, which itself is derived from the collected ranges).
        per_dim_cfg = QuantizerConfig(bits=cfg.bits, symmetric=cfg.symmetric,
                                      granularity=Granularity.PER_EMBEDDING,
                                      channel_axis=cfg.channel_axis)
        mn, mx = reduce_range(x, per_dim_cfg)
    else:
        mn, mx = reduce_range(x, cfg)
    mn, mx = mn.astype(jnp.float32), mx.astype(jnp.float32)

    if cfg.estimator == RangeEstimator.RUNNING_MINMAX:
        m = cfg.ema_momentum
        new_min = jnp.where(state.initialized, m * state.x_min + (1 - m) * mn, mn)
        new_max = jnp.where(state.initialized, m * state.x_max + (1 - m) * mx, mx)
    else:
        # current min-max (single batch) and MSE both track the envelope;
        # MSE then shrinks it in finalize().
        new_min = jnp.where(state.initialized, jnp.minimum(state.x_min, mn), mn)
        new_max = jnp.where(state.initialized, jnp.maximum(state.x_max, mx), mx)
    return RangeState(new_min, new_max, jnp.asarray(True))


def mse_search(x: jnp.ndarray, x_min: jnp.ndarray, x_max: jnp.ndarray,
               cfg: QuantizerConfig,
               group_index: Optional[jnp.ndarray] = None) -> QuantParams:
    """Grid search over symmetric shrink ratios of [x_min, x_max].

    Vectorized with vmap over the candidate grid; picks argmin of the
    squared quantization error on the calibration tensor ``x``.
    """
    ratios = jnp.linspace(1.0 / cfg.mse_grid_points, 1.0, cfg.mse_grid_points)

    def err_for(ratio):
        qp = params_from_range(x_min * ratio, x_max * ratio, cfg,
                               group_index=group_index)
        e = jnp.square(x - fake_quant(x, qp, cfg))
        if cfg.granularity == Granularity.PER_TENSOR:
            return jnp.mean(e)                       # scalar
        axis = cfg.channel_axis % x.ndim
        red = tuple(a for a in range(x.ndim) if a != axis)
        per_dim = jnp.mean(e, axis=red)              # (d,) or (C,)
        if group_index is not None:                  # PEG: (d,) -> (K,)
            k = int(qp.scale.shape[0])
            return jnp.zeros((k,)).at[group_index].add(per_dim)
        return per_dim

    errs = jax.vmap(err_for)(ratios)                 # (G,) or (G, C)
    best = jnp.argmin(errs, axis=0)                  # per-channel best ratio
    best_ratio = ratios[best]
    if group_index is not None:
        gmin, gmax = _group_reduce(x_min, x_max, group_index,
                                   int(best_ratio.shape[0]))
        return params_from_range(gmin * best_ratio, gmax * best_ratio, cfg,
                                 group_index=group_index)
    return params_from_range(x_min * best_ratio, x_max * best_ratio, cfg,
                             group_index=group_index)


def finalize(state: RangeState, cfg: QuantizerConfig,
             calib_tensor: Optional[jnp.ndarray] = None,
             group_index: Optional[jnp.ndarray] = None) -> QuantParams:
    """Turn accumulated statistics into QuantParams.

    For PEG, ``group_index`` maps embedding dims to groups (built by
    peg.build_groups from these very statistics). For the MSE estimator a
    representative ``calib_tensor`` must be provided.
    """
    x_min, x_max = state.x_min, state.x_max
    if cfg.granularity == Granularity.PER_EMBEDDING_GROUP:
        if group_index is None:
            raise ValueError("PEG finalize requires group_index")
        if cfg.estimator == RangeEstimator.MSE:
            if calib_tensor is None:
                raise ValueError("MSE estimator needs a calibration tensor")
            return mse_search(calib_tensor, x_min, x_max, cfg, group_index)
        gmin, gmax = _group_reduce(x_min, x_max, group_index,
                                   int(jnp.max(group_index)) + 1)
        return params_from_range(gmin, gmax, cfg, group_index=group_index)

    if cfg.estimator == RangeEstimator.MSE:
        if calib_tensor is None:
            raise ValueError("MSE estimator needs a calibration tensor")
        return mse_search(calib_tensor, x_min, x_max, cfg)
    return params_from_range(x_min, x_max, cfg)


def estimate_weight_params(w: jnp.ndarray, cfg: QuantizerConfig) -> QuantParams:
    """One-shot range estimation for a static weight tensor."""
    mn, mx = reduce_range(w, cfg)
    if cfg.estimator == RangeEstimator.MSE:
        return mse_search(w, mn, mx, cfg)
    return params_from_range(mn, mx, cfg)
