"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python for correctness validation; on TPU the same call lowers to
Mosaic. ``interpret=None`` auto-detects.

Two conventions enforced here (and relied on by repro.core.deploy):

* **Traced scales.** Every scale / zero-point is a traced operand, never a
  ``static_argnames`` entry — serving with freshly calibrated scales (or
  per-layer scales sliced out of a lax.scan) must not recompile per call.
  Only block sizes, activation names and flags are static.

* **Batched + ragged shapes.** Wrappers accept ``(..., K)`` inputs: leading
  dims are flattened into the M/token axis and, when the flattened row count
  does not divide the block size, rows are zero-padded and the result is
  sliced back — so decode-time ``(B, 1, D)`` and ragged prefill shapes all
  hit the same kernels.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import fused_ln_quant as _lnq
from repro.kernels import int8_attend_decode as _iad
from repro.kernels import int8_matmul as _imm
from repro.kernels import paged_attend_decode as _pad
from repro.kernels import peg_quant as _peg
from repro.kernels import ref as _ref


def _interp(flag: Optional[bool]) -> bool:
    if flag is None:
        return jax.default_backend() != "tpu"
    return flag


def _flatten_rows(x, block: int):
    """(..., D) -> ((M_padded, D), lead_shape, M). Pads rows to the block."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    m = x2.shape[0]
    # m <= block runs as one partial block (bm == m); larger ragged row
    # counts are zero-padded to a block multiple and sliced back after.
    pad = (-m) % block if m > block else 0
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    return x2, lead, m


def _unflatten_rows(y, lead, m):
    return y[:m].reshape(*lead, y.shape[-1])


# ---------------------------------------------------------------------------
# Per-embedding-group quantize (paper eq. 5)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "block_t",
                                             "interpret"))
def peg_fake_quant(x, scales, zps, *, qmin: int = 0, qmax: int = 255,
                   block_t: int = 256, interpret: Optional[bool] = None):
    x2, lead, m = _flatten_rows(x, block_t)
    out = _peg.peg_fake_quant(x2, scales, zps, qmin=qmin, qmax=qmax,
                              block_t=block_t, interpret=_interp(interpret))
    return _unflatten_rows(out, lead, m)


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "block_t",
                                             "interpret"))
def peg_quantize(x, scales, zps, *, qmin: int = 0, qmax: int = 255,
                 block_t: int = 256, interpret: Optional[bool] = None):
    x2, lead, m = _flatten_rows(x, block_t)
    out = _peg.peg_quantize(x2, scales, zps, qmin=qmin, qmax=qmax,
                            block_t=block_t, interpret=_interp(interpret))
    return _unflatten_rows(out, lead, m)


# ---------------------------------------------------------------------------
# int8 matmuls (paper eq. 3-5) with fused epilogue
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("activation", "qmin", "qmax",
                                             "block_m", "block_n", "block_k",
                                             "w_bits", "interpret"))
def int8_matmul(a_q, w_q, *, s_a, s_w, z_a=None, w_colsum=None, bias=None,
                mul=None, activation: str = "none", out_scale=None,
                out_zp=None, qmin: int = -128, qmax: int = 127,
                block_m: int = 256, block_n: int = 256, block_k: int = 512,
                w_bits: int = 8, interpret: Optional[bool] = None):
    """Per-tensor int8 matmul (+ fused epilogue) over (..., K) activations.

    s_a/s_w (and the optional z_a/out_scale/out_zp) are traced scalars.
    z_a requires w_colsum (N,) = colsum(w_q) for the zero-point correction.
    ``w_bits=4``: w_q is (K/2, N) pairwise-row-packed nibbles (see
    repro.kernels.nibble) and w_colsum must be supplied pre-computed from
    the unpacked int4 values — summing the packed bytes would be wrong.
    """
    if z_a is not None and w_colsum is None:
        if w_bits == 4:
            raise ValueError("w_bits=4 with z_a requires explicit w_colsum "
                             "(colsum over packed bytes is meaningless)")
        w_colsum = jnp.sum(w_q.astype(jnp.int32), axis=0)
    a2, lead, m = _flatten_rows(a_q, block_m)
    mul2 = None
    if mul is not None:
        mul2, _, _ = _flatten_rows(mul, block_m)
    out = _imm.int8_matmul(a2, w_q, s_a, s_w, z_a=z_a, w_colsum=w_colsum,
                           bias=bias, mul=mul2, activation=activation,
                           out_scale=out_scale, out_zp=out_zp, qmin=qmin,
                           qmax=qmax, block_m=block_m, block_n=block_n,
                           block_k=block_k, w_bits=w_bits,
                           interpret=_interp(interpret))
    return _unflatten_rows(out, lead, m)


@functools.partial(jax.jit, static_argnames=("activation", "qmin", "qmax",
                                             "block_m", "block_n", "w_bits",
                                             "interpret"))
def int8_matmul_peg(a_q, w_q, act_scales, act_zps, *, w_scale,
                    w_colsum=None, bias=None, mul=None,
                    activation: str = "none", out_scale=None, out_zp=None,
                    qmin: int = -128, qmax: int = 127, block_m: int = 256,
                    block_n: int = 256, w_bits: int = 8,
                    interpret: Optional[bool] = None):
    """PEG fixed-point matmul: K re-scalings fused into the MXU k-loop.
    Computes the zero-point correction internally unless ``w_colsum`` (G, N)
    is supplied (deployment pre-packs it next to the int8 weights).
    ``w_bits=4``: w_q is (K/2, N) row-packed nibbles; w_colsum required."""
    g = act_scales.shape[0]
    if w_colsum is None:
        if w_bits == 4:
            raise ValueError("w_bits=4 requires explicit w_colsum")
        w_colsum = _ref.w_colsum_groups(w_q, g)
    a2, lead, m = _flatten_rows(a_q, block_m)
    mul2 = None
    if mul is not None:
        mul2, _, _ = _flatten_rows(mul, block_m)
    out = _imm.int8_matmul_peg(a2, w_q, act_scales, act_zps, w_scale,
                               w_colsum, bias=bias, mul=mul2,
                               activation=activation, out_scale=out_scale,
                               out_zp=out_zp, qmin=qmin, qmax=qmax,
                               block_m=block_m, block_n=block_n,
                               w_bits=w_bits, interpret=_interp(interpret))
    return _unflatten_rows(out, lead, m)


# ---------------------------------------------------------------------------
# int8 KV-cache decode attention (serving hot path)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("window", "logit_softcap",
                                             "sm_qmin", "sm_qmax",
                                             "smo_qmin", "smo_qmax", "chunk",
                                             "kv_bits", "interpret"))
def int8_attend_decode(q_q, q_scale, k_q, k_scale, v_q, v_scale, k_pos,
                       q_pos, *, q_zp=None, k_zp=None, v_zp=None,
                       window: Optional[int] = None,
                       logit_softcap: Optional[float] = None,
                       sm_quant=None, sm_qmin: int = 0, sm_qmax: int = 255,
                       smo_quant=None, smo_qmin: int = 0, smo_qmax: int = 255,
                       chunk: int = 256, kv_bits: int = 8,
                       interpret: Optional[bool] = None):
    """Decode attention over an int8 KV cache (see int8_attend_decode.py).

    q_q (B, KV, G, hd) int8; q_scale (B, KV, G) f32 (attention scale folded
    in); q_zp (B, KV, G) / k_zp, v_zp (B, KV) f32 shifted-grid zero-points
    (None = symmetric); k_q/v_q (B, S, KV, hd) int8; k_scale/v_scale
    (B, S, KV) f32; k_pos (B, S) int32 (-1 = empty); q_pos (B,) int32.
    ``sm_quant``/``smo_quant``: optional (2,) [scale, zp] — the traced
    softmax_in / softmax_out fake-quants (the latter selects the two-pass
    schedule). Ragged S is padded to the chunk size with empty slots.
    ``kv_bits=4``: k_q/v_q are split-half nibble-packed (B, S, KV, hd/2)
    payloads, unpacked in VMEM inside the kernel.
    Returns (B, KV, G, hd) f32.
    """
    if q_zp is None:
        q_zp = jnp.zeros_like(q_scale)
    if k_zp is None:
        k_zp = jnp.zeros(q_scale.shape[:2], jnp.float32)
    if v_zp is None:
        v_zp = jnp.zeros(q_scale.shape[:2], jnp.float32)
    s_len = k_pos.shape[1]
    c = min(chunk, s_len)
    pad = (-s_len) % c
    if pad:
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_q = jnp.pad(k_q, pad4)
        v_q = jnp.pad(v_q, pad4)
        k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    return _iad.int8_attend_decode(
        q_q, q_scale, q_zp, k_zp, v_zp, k_q, k_scale, v_q, v_scale, k_pos,
        q_pos,
        window=window, logit_softcap=logit_softcap, sm_quant=sm_quant,
        sm_qmin=sm_qmin, sm_qmax=sm_qmax, smo_quant=smo_quant,
        smo_qmin=smo_qmin, smo_qmax=smo_qmax, chunk=c, kv_bits=kv_bits,
        interpret=_interp(interpret))


# ---------------------------------------------------------------------------
# Paged KV-cache decode attention (block-pool serving path)
# ---------------------------------------------------------------------------

def _lane_blocks(block_table, s_cap, block_size):
    """Slice the table to the logical blocks this layer can touch: a
    sliding-window layer's capacity (s_cap = min(max_len, window)) needs
    only the first ceil(s_cap / bs) columns, so its kernel grid never walks
    (or DMAs) blocks only global layers use."""
    nb = -(-s_cap // block_size)
    return block_table[:, :nb]


@functools.partial(jax.jit, static_argnames=("s_cap", "window",
                                             "logit_softcap", "sm_qmin",
                                             "sm_qmax", "smo_qmin",
                                             "smo_qmax", "interpret"))
def paged_attend_decode(q, k_arena, v_arena, block_table, q_pos, *,
                        s_cap: int, window: Optional[int] = None,
                        logit_softcap: Optional[float] = None,
                        sm_quant=None, sm_qmin: int = 0, sm_qmax: int = 255,
                        smo_quant=None, smo_qmin: int = 0,
                        smo_qmax: int = 255,
                        interpret: Optional[bool] = None):
    """Decode attention over a paged bf16/f32 KV cache (see
    paged_attend_decode.py). q (B, KV, G, hd) with the attention scale
    folded in; arenas (N, bs, KV, hd); block_table (B, nb) int32; q_pos
    (B,) int32 (-1 = idle lane). ``s_cap`` is the layer's logical capacity.
    Returns (B, KV, G, hd) f32.
    """
    return _pad.paged_attend_decode(
        q, k_arena, v_arena,
        _lane_blocks(block_table, s_cap, k_arena.shape[1]), q_pos,
        s_cap=s_cap, window=window, logit_softcap=logit_softcap,
        sm_quant=sm_quant, sm_qmin=sm_qmin, sm_qmax=sm_qmax,
        smo_quant=smo_quant, smo_qmin=smo_qmin, smo_qmax=smo_qmax,
        interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("s_cap", "window",
                                             "logit_softcap", "sm_qmin",
                                             "sm_qmax", "smo_qmin",
                                             "smo_qmax", "kv_bits",
                                             "interpret"))
def paged_int8_attend_decode(q_q, q_scale, k_arena, k_scale, v_arena,
                             v_scale, block_table, q_pos, *, s_cap: int,
                             q_zp=None, k_zp=None, v_zp=None,
                             window: Optional[int] = None,
                             logit_softcap: Optional[float] = None,
                             sm_quant=None, sm_qmin: int = 0,
                             sm_qmax: int = 255, smo_quant=None,
                             smo_qmin: int = 0, smo_qmax: int = 255,
                             kv_bits: int = 8,
                             interpret: Optional[bool] = None):
    """Decode attention over a paged int8 KV cache — the paged twin of
    :func:`int8_attend_decode` (same zero-point handling; scales traced).
    k_arena/v_arena (N, bs, KV, hd) int8; k_scale/v_scale (N, bs, KV) f32.
    ``kv_bits=4``: arenas are split-half nibble-packed (N, bs, KV, hd/2).
    Returns (B, KV, G, hd) f32.
    """
    if q_zp is None:
        q_zp = jnp.zeros_like(q_scale)
    if k_zp is None:
        k_zp = jnp.zeros(q_scale.shape[:2], jnp.float32)
    if v_zp is None:
        v_zp = jnp.zeros(q_scale.shape[:2], jnp.float32)
    return _pad.paged_int8_attend_decode(
        q_q, q_scale, q_zp, k_zp, v_zp, k_arena, k_scale, v_arena, v_scale,
        _lane_blocks(block_table, s_cap, k_arena.shape[1]), q_pos,
        s_cap=s_cap, window=window, logit_softcap=logit_softcap,
        sm_quant=sm_quant, sm_qmin=sm_qmin, sm_qmax=sm_qmax,
        smo_quant=smo_quant, smo_qmin=smo_qmin, smo_qmax=smo_qmax,
        kv_bits=kv_bits, interpret=_interp(interpret))


# ---------------------------------------------------------------------------
# Fused norm + quantize (paper Fig. 4 hot path)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "eps", "block_t",
                                             "interpret"))
def ln_fake_quant(x, gamma, beta, scale, zp, *, qmin: int = 0,
                  qmax: int = 255, eps: float = 1e-6, block_t: int = 256,
                  interpret: Optional[bool] = None):
    x2, lead, m = _flatten_rows(x, block_t)
    out = _lnq.ln_fake_quant(x2, gamma, beta, scale, zp, qmin=qmin,
                             qmax=qmax, eps=eps, block_t=block_t,
                             interpret=_interp(interpret))
    return _unflatten_rows(out, lead, m)


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "eps", "block_t",
                                             "interpret"))
def ln_quantize(x, gamma, beta, scale, zp, *, qmin: int = 0, qmax: int = 255,
                eps: float = 1e-6, block_t: int = 256,
                interpret: Optional[bool] = None):
    x2, lead, m = _flatten_rows(x, block_t)
    out = _lnq.ln_quantize(x2, gamma, beta, scale, zp, qmin=qmin, qmax=qmax,
                           eps=eps, block_t=block_t,
                           interpret=_interp(interpret))
    return _unflatten_rows(out, lead, m)


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "eps", "block_t",
                                             "interpret"))
def rms_fake_quant(x, gamma, scale, zp, *, qmin: int = 0, qmax: int = 255,
                   eps: float = 1e-6, block_t: int = 256,
                   interpret: Optional[bool] = None):
    x2, lead, m = _flatten_rows(x, block_t)
    out = _lnq.rms_fake_quant(x2, gamma, scale, zp, qmin=qmin, qmax=qmax,
                              eps=eps, block_t=block_t,
                              interpret=_interp(interpret))
    return _unflatten_rows(out, lead, m)


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "eps", "block_t",
                                             "interpret"))
def rms_quantize(x, gamma, scale, zp, *, qmin: int = 0, qmax: int = 255,
                 eps: float = 1e-6, block_t: int = 256,
                 interpret: Optional[bool] = None):
    x2, lead, m = _flatten_rows(x, block_t)
    out = _lnq.rms_quantize(x2, gamma, scale, zp, qmin=qmin, qmax=qmax,
                            eps=eps, block_t=block_t,
                            interpret=_interp(interpret))
    return _unflatten_rows(out, lead, m)
