"""Quantization configuration schema.

Mirrors the paper's experimental setup (§5): uniform affine quantization,
symmetric weights / asymmetric activations, static activation ranges. Every
quantizer in the network is described by a ``QuantizerConfig``; a
``QuantizationPolicy`` maps named tensor sites to configs (this is how the
paper's mixed-precision recipes and the PEG placement — "FFN input, output and
sum only" — are expressed).
"""
from __future__ import annotations

import dataclasses
import enum
import re
from typing import Mapping


class Granularity(enum.Enum):
    PER_TENSOR = "per_tensor"
    PER_CHANNEL = "per_channel"          # weights: one (s, z) per output channel
    PER_EMBEDDING = "per_embedding"      # activations: one (s, z) per embedding dim
    PER_EMBEDDING_GROUP = "per_embedding_group"  # the paper's PEG scheme


class RangeEstimator(enum.Enum):
    CURRENT_MINMAX = "current_minmax"    # min/max of the current batch
    RUNNING_MINMAX = "running_minmax"    # EMA of per-batch min/max
    MSE = "mse"                          # grid-search MSE-optimal clipping


@dataclasses.dataclass(frozen=True)
class QuantizerConfig:
    """Static description of one quantizer."""
    bits: int = 8
    symmetric: bool = False              # paper: weights sym, activations asym
    granularity: Granularity = Granularity.PER_TENSOR
    estimator: RangeEstimator = RangeEstimator.CURRENT_MINMAX
    num_groups: int = 1                  # K for PER_EMBEDDING_GROUP
    use_permutation: bool = False        # range-based permutation ("+P" rows of Table 5)
    ema_momentum: float = 0.9            # paper B.2 for running min-max
    mse_grid_points: int = 100           # candidate clipping ratios for MSE search
    channel_axis: int = -1               # axis carrying channels/embeddings
    enabled: bool = True

    def __post_init__(self):
        if self.bits < 1 or self.bits > 32:
            raise ValueError(f"unsupported bit-width {self.bits}")
        if self.granularity == Granularity.PER_EMBEDDING_GROUP and self.num_groups < 1:
            raise ValueError("PEG requires num_groups >= 1")

    @property
    def qmin(self) -> int:
        if self.symmetric:
            return -(2 ** (self.bits - 1)) + 1   # symmetric, restricted range
        return 0

    @property
    def qmax(self) -> int:
        if self.symmetric:
            return 2 ** (self.bits - 1) - 1
        return 2 ** self.bits - 1

    @property
    def num_levels(self) -> int:
        return self.qmax - self.qmin


# Disabled sentinel — keeps a site in the policy but passes values through.
FP32 = QuantizerConfig(bits=32, enabled=False)

# Paper defaults (§5): W8 symmetric per-tensor, A8 asymmetric per-tensor.
W8_DEFAULT = QuantizerConfig(bits=8, symmetric=True,
                             estimator=RangeEstimator.MSE)
A8_DEFAULT = QuantizerConfig(bits=8, symmetric=False,
                             estimator=RangeEstimator.RUNNING_MINMAX)
A16_DEFAULT = QuantizerConfig(bits=16, symmetric=False,
                              estimator=RangeEstimator.RUNNING_MINMAX)


def peg_config(num_groups: int = 6, *, bits: int = 8,
               use_permutation: bool = True,
               estimator: RangeEstimator = RangeEstimator.RUNNING_MINMAX,
               ) -> QuantizerConfig:
    """The paper's best PEG setting: K=6 with range-based permutation."""
    return QuantizerConfig(
        bits=bits, symmetric=False,
        granularity=Granularity.PER_EMBEDDING_GROUP,
        num_groups=num_groups, use_permutation=use_permutation,
        estimator=estimator)


@dataclasses.dataclass(frozen=True)
class QuantizationPolicy:
    """Maps tensor-site names (regex patterns) to quantizer configs.

    Sites are named hierarchically, e.g. ``layer/ffn_out``, ``layer/residual_ffn``,
    ``embed/tokens``, ``head/logits``. First matching pattern wins; ``default``
    applies otherwise. This is the mechanism behind the paper's recipes:

    - W8A8 baseline:      everything default.
    - MP-PTQ (Table 4):   ``.*residual_ffn|.*ffn_(in|out)|head/logits`` → 16-bit.
    - PEG-PTQ (Table 5):  ``.*ffn_(in|out)|.*residual_ffn`` → peg_config(K).
    """
    weight_default: QuantizerConfig = W8_DEFAULT
    act_default: QuantizerConfig = A8_DEFAULT
    weight_overrides: Mapping[str, QuantizerConfig] = dataclasses.field(default_factory=dict)
    act_overrides: Mapping[str, QuantizerConfig] = dataclasses.field(default_factory=dict)

    def weight_config(self, site: str) -> QuantizerConfig:
        return self._match(site, self.weight_overrides, self.weight_default)

    def act_config(self, site: str) -> QuantizerConfig:
        return self._match(site, self.act_overrides, self.act_default)

    @staticmethod
    def _match(site, overrides, default):
        for pattern, cfg in overrides.items():
            if re.fullmatch(pattern, site):
                return cfg
        return default


def fp32_policy() -> QuantizationPolicy:
    return QuantizationPolicy(weight_default=FP32, act_default=FP32)


def w8a8_policy(**kw) -> QuantizationPolicy:
    """Paper's baseline joint 8-bit PTQ (Table 1, row W8A8)."""
    return QuantizationPolicy(**kw)


def mixed_precision_policy(*, residual_bits: int = 16,
                           ffn_io_16bit: bool = True,
                           output_16bit: bool = True) -> QuantizationPolicy:
    """The paper's MP-PTQ recipe (Table 4: * residual sum, † FFN in/out,
    ‡ final output in 16-bit, MSE for the output)."""
    a16 = dataclasses.replace(A16_DEFAULT, bits=residual_bits)
    overrides = {r".*/residual_ffn": a16}
    if ffn_io_16bit:
        overrides[r".*/ffn_(in|out)"] = a16
    if output_16bit:
        overrides[r"head/.*"] = dataclasses.replace(
            a16, estimator=RangeEstimator.MSE)
    return QuantizationPolicy(act_overrides=overrides)


def peg_policy(num_groups: int = 6, *, use_permutation: bool = True,
               ffn_only: bool = True) -> QuantizationPolicy:
    """The paper's PEG-PTQ recipe (Table 5/6: K=6 + permutation applied to
    FFN's input, output and residual sum; everything else per-tensor)."""
    peg = peg_config(num_groups, use_permutation=use_permutation)
    if ffn_only:
        overrides = {r".*/(ffn_(in|out)|residual_ffn)": peg}
        return QuantizationPolicy(act_overrides=overrides)
    return QuantizationPolicy(act_default=peg)


def low_bit_weight_policy(weight_bits: int, *, act_bits: int = 32,
                          embedding_bits: int | None = None) -> QuantizationPolicy:
    """Table 7: low-bit weights (always MSE estimator per §5) and optional
    ultra-low-bit token embeddings."""
    w = QuantizerConfig(bits=weight_bits, symmetric=True,
                        estimator=RangeEstimator.MSE)
    w_over = {}
    if embedding_bits is not None:
        w_over[r"embed/tokens"] = QuantizerConfig(
            bits=embedding_bits, symmetric=True, estimator=RangeEstimator.MSE)
    act = A8_DEFAULT if act_bits == 8 else FP32
    return QuantizationPolicy(weight_default=w, act_default=act,
                              weight_overrides=w_over)
