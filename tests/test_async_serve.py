"""Async front-end stress tests (runtime/async_serve.py).

A deterministic stub LM (tests/serve_testlib.py: next = (2*tok+1) % 32)
makes every greedy continuation predictable, so the suite can hammer the
AsyncServer with concurrent producers, interleaved consumption and
mid-generation cancellation and still assert exact token streams:

* concurrent producers enqueueing out of order -> every stream still gets
  ITS OWN golden continuation (admission order is whatever the queue saw;
  lanes are computationally independent);
* per-request token-stream ordering: tokens arrive strictly in generation
  order, observable incrementally while decoding is still running;
* cancellation mid-generation frees the lane (host-side release) and the
  stream closes with the golden PREFIX emitted so far;
* a seeded sweep (hypothesis when installed, fixed seeds otherwise)
  asserting async streams == the synchronous continuous Scheduler's
  emissions for identical request sets.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from serve_testlib import VOCAB, golden, next_arr, onehot
from repro.models.attention import KVCache
from repro.runtime import AsyncServer, Request, serve_continuous
from repro.runtime.engine import Engine

try:
    import hypothesis
    import hypothesis.strategies as st
    HAS_HYPOTHESIS = True
except ImportError:            # optional, like tests/test_properties.py
    HAS_HYPOTHESIS = False

pytestmark = [pytest.mark.engine, pytest.mark.serve]

PAD = 12


def _stub_cache(b):
    """Minimal whole-model-shaped dense cache (one KVCache layer) so the
    engine's lane extract/insert jits have a real structure to slice."""
    return {"layers": [KVCache(k=jnp.zeros((b, 2, 1, 1)),
                               v=jnp.zeros((b, 2, 1, 1)),
                               pos=jnp.full((b, 2), -1, jnp.int32))]}


def _stub_admit(tokens, positions, admit_mask, cache):
    return onehot(next_arr(tokens)), cache


def _stub_decode(tokens, pos, cache):
    return onehot(next_arr(tokens)), cache


def _stub_engine(batch_slots=3):
    return Engine(_stub_admit, _stub_decode, _stub_cache,
                  batch_slots=batch_slots, prompt_pad_len=PAD)


def _prompt(rng, n):
    return rng.randint(1, VOCAB, size=n).astype(np.int32)


class TestConcurrentProducers:
    def test_out_of_order_enqueue(self):
        """8 producer threads submit with jittered delays — arrival order
        is scrambled, every stream still gets its own golden tokens."""
        results = {}
        lock = threading.Lock()

        def producer(i, srv, rng):
            time.sleep(rng.uniform(0, 0.02))
            prompt = _prompt(rng, 3 + i % 5)
            s = srv.submit(prompt, 2 + i % 4, rid=i)
            got = s.result(timeout=30)
            with lock:
                results[i] = (prompt, 2 + i % 4, got)

        with AsyncServer(_stub_engine(batch_slots=2)) as srv:
            threads = [threading.Thread(
                target=producer, args=(i, srv, np.random.RandomState(100 + i)))
                for i in range(8)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        assert len(results) == 8
        for i, (prompt, quota, got) in results.items():
            assert got == golden(prompt, quota), f"producer {i}"

    def test_zero_quota_closes_without_admission(self):
        with AsyncServer(_stub_engine()) as srv:
            s = srv.submit(_prompt(np.random.RandomState(0), 4), 0)
            assert s.result(timeout=5) == []
            assert s.done and not s.cancelled


class TestStreamOrdering:
    def test_tokens_arrive_in_generation_order(self):
        """Consume a stream INCREMENTALLY while the scheduler thread is
        still decoding: every observed prefix is the golden prefix."""
        prompt = _prompt(np.random.RandomState(1), 5)
        exp = golden(prompt, 24)
        with AsyncServer(_stub_engine(batch_slots=1)) as srv:
            s = srv.submit(prompt, 24)
            seen = []
            for tok in s:                 # blocks per token, ends at close
                seen.append(tok)
                assert seen == exp[:len(seen)]
        assert seen == exp

    def test_interleaved_streams_stay_ordered(self):
        """Two lanes decode in lockstep; each stream's own ordering is
        untouched by the other lane's emissions."""
        rng = np.random.RandomState(2)
        prompts = [_prompt(rng, 4), _prompt(rng, 6)]
        with AsyncServer(_stub_engine(batch_slots=2)) as srv:
            streams = [srv.submit(p, 16, rid=i)
                       for i, p in enumerate(prompts)]
            outs = [s.result(timeout=30) for s in streams]
        for p, got in zip(prompts, outs):
            assert got == golden(p, 16)


class TestCancellation:
    def test_cancel_mid_generation(self):
        """Cancel a huge-quota request once a few tokens have streamed:
        the stream closes cancelled with a golden PREFIX, and the freed
        lane immediately serves the next request to completion."""
        prompt = _prompt(np.random.RandomState(3), 4)
        with AsyncServer(_stub_engine(batch_slots=1)) as srv:
            s = srv.submit(prompt, 10_000_000, rid="doomed")
            it = iter(s)
            first = [next(it) for _ in range(3)]   # wait for real progress
            srv.cancel(s)
            got = s.result(timeout=30)
            assert s.cancelled
            assert got[:3] == first
            assert got == golden(prompt, len(got))
            # the lane is actually free again — a follow-up request runs
            p2 = _prompt(np.random.RandomState(4), 5)
            s2 = srv.submit(p2, 6, rid="after")
            assert s2.result(timeout=30) == golden(p2, 6)
            assert not s2.cancelled

    def test_cancel_queued_request_never_admits(self):
        """A request cancelled while still queued behind a busy lane
        closes cancelled with ZERO tokens."""
        rng = np.random.RandomState(5)
        with AsyncServer(_stub_engine(batch_slots=1)) as srv:
            busy = srv.submit(_prompt(rng, 4), 10_000_000, rid="busy")
            queued = srv.submit(_prompt(rng, 4), 8, rid="queued")
            iter_busy = iter(busy)
            next(iter_busy)               # busy lane is really decoding
            srv.cancel(queued)
            assert queued.result(timeout=30) == []
            assert queued.cancelled
            srv.cancel(busy)
        assert busy.cancelled

    def test_close_without_drain_cancels_everything(self):
        rng = np.random.RandomState(6)
        srv = AsyncServer(_stub_engine(batch_slots=1))
        a = srv.submit(_prompt(rng, 3), 10_000_000)
        b = srv.submit(_prompt(rng, 3), 10_000_000)
        next(iter(a))                     # a is resident, b queued
        srv.close(drain=False)
        assert a.done and a.cancelled
        assert b.done and b.cancelled
        with pytest.raises(RuntimeError):
            srv.submit(_prompt(rng, 3), 4)


def _sync_scheduler_tokens(reqs, batch_slots):
    serve_continuous(_stub_admit, _stub_decode, _stub_cache, reqs,
                     batch_slots=batch_slots, prompt_pad_len=PAD)
    return {r.rid: r.tokens_out for r in reqs}


def _async_vs_sync_sweep(seed, n_requests, batch_slots):
    """One sweep case: identical request sets through the AsyncServer and
    the synchronous continuous Scheduler must emit identical streams."""
    rng = np.random.RandomState(seed)
    spec = [(int(rng.randint(1, PAD + 1)), int(rng.randint(1, 9)))
            for _ in range(n_requests)]
    reqs = [Request(rid=i, prompt=_prompt(rng, n), max_new_tokens=q)
            for i, (n, q) in enumerate(spec)]
    sync = _sync_scheduler_tokens(
        [Request(rid=r.rid, prompt=r.prompt,
                 max_new_tokens=r.max_new_tokens) for r in reqs],
        batch_slots)
    with AsyncServer(_stub_engine(batch_slots=batch_slots)) as srv:
        streams = [srv.submit(r.prompt, r.max_new_tokens, rid=r.rid)
                   for r in reqs]
        outs = {s.rid: s.result(timeout=60) for s in streams}
    assert outs == sync, f"seed {seed}: async != sync scheduler"


class TestAsyncMatchesSyncScheduler:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
    def test_seeded_sweep(self, seed):
        _async_vs_sync_sweep(seed, n_requests=1 + seed % 7,
                             batch_slots=1 + seed % 3)

    if HAS_HYPOTHESIS:
        @hypothesis.given(seed=st.integers(0, 2**16),
                          n_requests=st.integers(1, 8),
                          batch_slots=st.integers(1, 4))
        @hypothesis.settings(max_examples=20, deadline=None)
        def test_hypothesis_sweep(self, seed, n_requests, batch_slots):
            _async_vs_sync_sweep(seed, n_requests, batch_slots)
