"""Pallas TPU kernel: fused int8 decode attention over a quantized KV cache.

The serving decode step is HBM-bandwidth-bound: every new token re-reads the
whole KV cache. With the cache stored int8 (per-head, per-slot symmetric
scales — see ``repro.models.attention.QuantKVCache``) this kernel computes
the (B, 1, H, S) step as

    s[g, c]  = (q_q[g] . k_q[c]) * q_scale[g] * k_scale[c]     s8 x s8 -> s32
    s        = softcap(s);  s = fake_quant_{softmax_in}(s)     (optional)
    s        = mask(s)              causal + sliding-window from positions
    p        = online_softmax(s)    flash-style running (m, l) over S chunks
    p        = fake_quant_{softmax_out}(p)                     (optional)
    acc     += (p * v_scale) @ v_q                              dequant-on-read

so the int8 payloads and their f32 scales are the ONLY cache bytes read from
HBM — roughly half the traffic of a bf16 cache — and the q.k product runs on
the MXU in int8.

Layout: one program per (batch, kv-head, kv-chunk); the grid's last axis
walks the S chunks so the running max / denominator / accumulator live in
VMEM scratch across chunk steps (same accumulation pattern as the int8
matmul kernels). GQA is free: the q block for a kv head is its (G, hd) group
of query heads.

The paper's Fig.-1 attention quantization sites are applied IN-KERNEL with
traced scale / zero-point operands (no recompile per calibration), matching
the simulate path bit-for-bit:

  * ``softmax_in`` — fake-quant on the (soft-capped) logits, one VPU pass.
  * ``softmax_out`` — fake-quant on the *normalized* probabilities. This is
    impossible in one streaming pass (the denominator is only known after
    the last chunk), so when the site is calibrated the grid walks S twice:
    pass 1 accumulates the running (m, l), pass 2 recomputes the logits,
    quantizes ``exp(s - m) / l`` on the site grid and accumulates against
    V. The V block index is pinned during pass 1, so V still streams from
    HBM once; only K is read twice — ~1.5x the single-pass cache bytes.

The mask is causal-decode fixed (valid slot, k_pos <= q_pos, optional
sliding window). Non-causal configs and sites that need more than a
per-tensor scalar fall back to dequantize-then-flash
(repro.models.attention) — the simulate-path rule.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.nibble import unpack_nibbles

NEG_INF = -1e30


def _attend_decode_kernel(*refs, n_chunks: int, window: Optional[int],
                          logit_softcap: Optional[float], has_smq: bool,
                          has_smo: bool, sm_qmin: int, sm_qmax: int,
                          smo_qmin: int, smo_qmax: int, kv_bits: int):
    refs = list(refs)
    smq_ref = refs.pop(0) if has_smq else None
    smo_ref = refs.pop(0) if has_smo else None
    (q_ref, qs_ref, qz_ref, kz_ref, vz_ref, k_ref, ks_ref, v_ref, vs_ref,
     kp_ref, qp_ref, o_ref, m_ref, l_ref, acc_ref) = refs

    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # logits for this chunk (recomputed in the second pass when two-pass)
    q = q_ref[0, 0]                                    # (G, hd) int8
    hd = q.shape[-1]
    k = k_ref[0, :, 0, :]                              # (C, hd[/2]) int8
    if kv_bits == 4:
        # nibble extract in VMEM before the MXU q.k^T: the packed (C, hd/2)
        # block sign-extends to the full (C, hd) int4 values; the rowsum /
        # colsum zero-point corrections below are computed from the
        # UNPACKED values, so they are exact on the 4-bit grid.
        k = unpack_nibbles(k, hd)
    s32 = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)
    # zero-point corrections (asymmetric q grid / static per-head k grid):
    #   sum (q - zq)(k - zk) = q.k - zq colsum(k) - zk rowsum(q) + hd zq zk
    # colsum/rowsum come from ints already in VMEM — no extra HBM traffic,
    # and the per-slot payload stays zero-point-free.
    zq = qz_ref[0, 0][:, None]                         # (G, 1)
    zk = kz_ref[0, 0]                                  # scalar (this head)
    kcol = jnp.sum(k.astype(jnp.int32), axis=-1).astype(jnp.float32)
    qrow = jnp.sum(q.astype(jnp.int32), axis=-1).astype(jnp.float32)
    acc32 = (s32.astype(jnp.float32) - zq * kcol[None, :]
             - zk * qrow[:, None] + hd * zq * zk)
    s = (acc32 * qs_ref[0, 0][:, None]
         * ks_ref[0, :, 0][None, :])                   # (G, C)
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    if has_smq:
        sm_s = smq_ref[0]
        sm_z = smq_ref[1]
        sq = jnp.clip(jnp.round(s / sm_s) + sm_z, sm_qmin, sm_qmax)
        s = (sq - sm_z) * sm_s
    kp = kp_ref[0]                                     # (C,) int32
    qp = qp_ref[0, 0]
    valid = (kp >= 0) & (kp <= qp)
    if window is not None:
        valid &= kp > qp - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    def _v():
        v = v_ref[0, :, 0, :]
        if kv_bits == 4:
            v = unpack_nibbles(v, hd)
        return v.astype(jnp.float32)

    @pl.when(c_idx < n_chunks)
    def _stats_pass():
        # online max / denominator (flash accumulation); in single-pass mode
        # the numerator accumulates alongside.
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(jnp.maximum(m_prev, jnp.max(s, axis=-1)),
                            NEG_INF)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[:, 0] = m_new
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
        if not has_smo:
            # fold the per-slot v scales into p (G x C muls < C x hd);
            # static v zero-point corrects with a per-row scalar
            pv = p * vs_ref[0, :, 0][None, :]
            zv = vz_ref[0, 0]
            acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
                pv, _v(),
                (((1,), (0,)), ((), ()))) - zv * jnp.sum(pv, axis=-1)[:, None]

    if has_smo:
        @pl.when(c_idx >= n_chunks)
        def _emit_pass():
            # second pass: (m, l) are final — quantize the normalized
            # probabilities on the softmax_out grid exactly like the
            # simulate path (which does NOT renormalize after fake-quant).
            p = jnp.exp(s - m_ref[:, 0][:, None]) / \
                jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
            so_s = smo_ref[0]
            so_z = smo_ref[1]
            pq = jnp.clip(jnp.round(p / so_s) + so_z, smo_qmin, smo_qmax)
            p = (pq - so_z) * so_s
            pv = p * vs_ref[0, :, 0][None, :]
            zv = vz_ref[0, 0]
            acc_ref[...] += jax.lax.dot_general(
                pv, _v(),
                (((1,), (0,)), ((), ()))) - zv * jnp.sum(pv, axis=-1)[:, None]

        @pl.when(c_idx == 2 * n_chunks - 1)
        def _done_two_pass():
            o_ref[0, 0] = acc_ref[...]
    else:
        @pl.when(c_idx == n_chunks - 1)
        def _done():
            o_ref[0, 0] = acc_ref[...] / \
                jnp.maximum(l_ref[:, 0], 1e-30)[:, None]


def int8_attend_decode(q_q: jnp.ndarray, q_scale: jnp.ndarray,
                       q_zp: jnp.ndarray, k_zp: jnp.ndarray,
                       v_zp: jnp.ndarray,
                       k_q: jnp.ndarray, k_scale: jnp.ndarray,
                       v_q: jnp.ndarray, v_scale: jnp.ndarray,
                       k_pos: jnp.ndarray, q_pos: jnp.ndarray, *,
                       window: Optional[int] = None,
                       logit_softcap: Optional[float] = None,
                       sm_quant: Optional[jnp.ndarray] = None,
                       sm_qmin: int = 0, sm_qmax: int = 255,
                       smo_quant: Optional[jnp.ndarray] = None,
                       smo_qmin: int = 0, smo_qmax: int = 255,
                       chunk: int = 256, kv_bits: int = 8,
                       interpret: bool = False) -> jnp.ndarray:
    """One decode step of attention against an int8 KV cache.

    q_q: (B, KV, G, hd) int8 queries, grouped per kv head (GQA);
    q_scale: (B, KV, G) f32 per-query-head scales with the attention
    1/sqrt(hd) factor already folded in; q_zp: (B, KV, G) f32 zero-points on
    the shifted int8 grid (0 = symmetric); k_zp/v_zp: (B, KV) f32 static
    per-head zero-points of the cache grids (0 = symmetric). All three are
    corrected in-kernel with rowsum/colsum scalars computed from the int8
    payloads already in VMEM, so affine site grids dequantize exactly with
    zero extra HBM traffic and a zero-point-free per-slot payload.
    k_q/v_q: (B, S, KV, hd) int8 cache; k_scale/v_scale: (B, S, KV) f32
    per-head per-slot scales; k_pos: (B, S) absolute positions (-1 = empty
    slot); q_pos: (B,) query positions. sm_quant / smo_quant: optional (2,) f32 [scale, zero_point]
    for the in-kernel ``softmax_in`` / ``softmax_out`` fake-quant on their
    [qmin, qmax] grids (softmax_out switches to the two-pass schedule).
    ``kv_bits=4`` reads a nibble-packed cache — k_q/v_q (B, S, KV, hd//2)
    int8 with two int4 cells per byte (split-half layout) — and unpacks
    each chunk in VMEM before the MXU q.k^T; scales/zero-points keep their
    8-bit shapes. Returns (B, KV, G, hd) f32. S must be a multiple of
    ``chunk`` (the ops wrapper pads with k_pos = -1 slots).
    """
    b, kv, g, hd = q_q.shape
    hd_kv = hd
    if kv_bits == 4:
        assert hd % 2 == 0, f"kv_bits=4 needs an even head_dim, got {hd}"
        hd_kv = hd // 2
    assert k_q.shape[-1] == hd_kv, (k_q.shape, hd_kv)
    s_len = k_q.shape[1]
    c = min(chunk, s_len)
    assert s_len % c == 0, f"S={s_len} not a multiple of chunk={c}"
    n_chunks = s_len // c
    has_smq = sm_quant is not None
    has_smo = smo_quant is not None
    n_steps = 2 * n_chunks if has_smo else n_chunks

    operands = []
    in_specs = []
    if has_smq:
        operands.append(sm_quant.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((2,), lambda i, j, kk: (0,)))
    if has_smo:
        operands.append(smo_quant.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((2,), lambda i, j, kk: (0,)))
    operands += [q_q, q_scale.astype(jnp.float32),
                 q_zp.astype(jnp.float32), k_zp.astype(jnp.float32),
                 v_zp.astype(jnp.float32), k_q,
                 k_scale.astype(jnp.float32), v_q,
                 v_scale.astype(jnp.float32), k_pos,
                 q_pos.reshape(b, 1)]
    # the chunk axis folds modulo n_chunks so the two-pass schedule re-walks
    # the same S blocks for K; V pins to block 0 during the stats pass (its
    # block index then doesn't change, so the pipeline fetches it only once
    # per program there — V streams from HBM once overall, K twice)
    ck = (lambda kk: kk % n_chunks) if has_smo else (lambda kk: kk)
    cv = (lambda kk: jnp.maximum(kk - n_chunks, 0)) if has_smo \
        else (lambda kk: kk)
    in_specs += [
        pl.BlockSpec((1, 1, g, hd), lambda i, j, kk: (i, j, 0, 0)),    # q_q
        pl.BlockSpec((1, 1, g), lambda i, j, kk: (i, j, 0)),           # q_s
        pl.BlockSpec((1, 1, g), lambda i, j, kk: (i, j, 0)),           # q_z
        pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),                 # k_z
        pl.BlockSpec((1, 1), lambda i, j, kk: (i, j)),                 # v_z
        pl.BlockSpec((1, c, 1, hd_kv),
                     lambda i, j, kk: (i, ck(kk), j, 0)),              # k_q
        pl.BlockSpec((1, c, 1), lambda i, j, kk: (i, ck(kk), j)),      # k_s
        pl.BlockSpec((1, c, 1, hd_kv),
                     lambda i, j, kk: (i, cv(kk), j, 0)),              # v_q
        pl.BlockSpec((1, c, 1), lambda i, j, kk: (i, cv(kk), j)),      # v_s
        pl.BlockSpec((1, c), lambda i, j, kk: (i, ck(kk))),            # k_pos
        pl.BlockSpec((1, 1), lambda i, j, kk: (i, 0)),                 # q_pos
    ]

    kernel = functools.partial(
        _attend_decode_kernel, n_chunks=n_chunks, window=window,
        logit_softcap=logit_softcap, has_smq=has_smq, has_smo=has_smo,
        sm_qmin=sm_qmin, sm_qmax=sm_qmax, smo_qmin=smo_qmin,
        smo_qmax=smo_qmax, kv_bits=kv_bits)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), jnp.float32),
        grid=(b, kv, n_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda i, j, kk: (i, j, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),   # running max
                        pltpu.VMEM((g, 1), jnp.float32),   # running denom
                        pltpu.VMEM((g, hd), jnp.float32)], # numerator
        interpret=interpret,
    )(*operands)
