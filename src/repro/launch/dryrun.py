import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without real
hardware.

For every (architecture x input-shape) cell and mesh (single-pod 16x16,
multi-pod 2x16x16):  build ShapeDtypeStruct stand-ins (no allocation), lower
the train/prefill/serve step with pjit shardings, ``.compile()``, and record:

  * memory_analysis()  — bytes per device (proves it fits 16 GB HBM)
  * cost_analysis()    — per-device HLO FLOPs / bytes for the roofline
  * collective bytes   — parsed from the post-SPMD HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)

Results land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json;
EXPERIMENTS.md §Dry-run and §Roofline are generated from them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-20b \
      --shape train_4k [--multi-pod] [--all] [--out DIR]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, input_specs, shape_cells
from repro.configs.base import ModelConfig
from repro.launch.mesh import make_production_mesh
from repro.models import encdec as encdec_lib
from repro.models import transformer as tfm
from repro.optim import linear_warmup_linear_decay
from repro.parallel import (make_batch_shardings, make_cache_shardings,
                            make_dist, make_param_shardings)
from repro.runtime.steps import (make_decode_step, make_prefill_step,
                                 make_train_step)

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|s8|u8|u32|pred|s64|u64|f64)"
                      r"\[([0-9,]*)\]")
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8}


def microbatches_for(cfg: ModelConfig, shape_name: str, dp: int) -> int:
    """Gradient-accumulation factor so per-device activations fit 16 GB.
    Heuristic by model size; validated against memory_analysis()."""
    if SHAPES[shape_name]["kind"] != "train":
        return 1
    B = SHAPES[shape_name]["global_batch"]
    per_dev = B // dp
    n = cfg.num_params
    if n > 1e10:
        want = 16          # B_local = 1 at dp=16
    elif n > 2e9:
        want = 8
    else:
        want = 4
    # M must divide B with B/M still divisible by dp
    m = min(want, per_dev)
    while B % m or (B // m) % dp:
        m -= 1
    return max(m, 1)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes of every collective op in the (post-SPMD,
    per-device) HLO. The result type sits between '=' and the op name, so we
    parse shapes in line[:op_match.start()]. For all-reduce /
    reduce-scatter / collective-permute result size == wire payload; for
    all-gather the result is the gathered tensor (a ~1x upper bound on
    per-device ring traffic) — a standard approximation, noted in
    EXPERIMENTS.md. NOTE: ops inside scan bodies appear once; the roofline
    uses the cost-extrapolation variants to scale them by trip count."""
    totals: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        nbytes = 0
        for dt, dims in SHAPE_RE.findall(line[:m.start()]):
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        totals[kind] = totals.get(kind, 0) + nbytes
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return totals


def _sds_like(tree, shardings):
    return jax.tree.map(
        lambda leaf, sh: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                              sharding=sh), tree, shardings)


WEIGHT_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_in", "w_out",
                "embed", "lm_head", "w_r", "w_k", "w_v", "w_g", "w_o",
                "w_ck", "w_cv", "w_cr", "w_rnn_in", "w_gate_in")


def _int8_param_sds(params_sds):
    """W8 serving variant: big weight leaves become {"q": int8, "s": f32}
    (repro.models.common.resolve_weight dequantizes at the use site, fused
    into the consuming matmul -> HBM reads 2x fewer weight bytes)."""
    flat = jax.tree_util.tree_flatten_with_path(params_sds)
    out = []
    for path, leaf in flat[0]:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        big = int(np.prod(leaf.shape)) >= (1 << 20)
        if name in WEIGHT_NAMES and big and leaf.ndim >= 2:
            s_shape = leaf.shape[:-2] + (1,) + leaf.shape[-1:]
            out.append({
                "q": jax.ShapeDtypeStruct(leaf.shape, jnp.int8,
                                          sharding=leaf.sharding),
                "s": jax.ShapeDtypeStruct(s_shape, jnp.float32),
            })
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(flat[1], out)


def build_cell(cfg: ModelConfig, shape_name: str, mesh, *,
               microbatches: Optional[int] = None, remat: bool = True,
               chunked=None, stacked: bool = True,
               weights_int8: bool = False, onehot_embed: bool = False,
               quantized_gathers: bool = False):
    """Returns (step_fn, arg_sds tuple) ready to lower. ``stacked=False``
    builds the UNROLLED layout (cost variants: no scan -> every layer's
    work visible to cost_analysis)."""
    dist = make_dist(mesh)
    if onehot_embed or quantized_gathers:
        import dataclasses as _dc
        dist = _dc.replace(dist, onehot_embed=onehot_embed,
                           quantized_gathers=quantized_gathers)
    dp = int(np.prod([mesh.shape[a] for a in dist.dp_axes]))
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    B, T = sh["global_batch"], sh["seq_len"]
    dtype = jnp.bfloat16

    if cfg.encoder_layers:
        params_shape = jax.eval_shape(
            lambda: encdec_lib.init_params(cfg, jax.random.PRNGKey(0),
                                           dtype=dtype))
    else:
        params_shape = jax.eval_shape(
            lambda: tfm.init_params(cfg, jax.random.PRNGKey(0),
                                    stacked=stacked, dtype=dtype))
    p_shard = make_param_shardings(params_shape, dist)
    params_sds = _sds_like(params_shape, p_shard)
    if weights_int8:
        params_sds = _int8_param_sds(params_sds)

    if kind == "train":
        m = microbatches if microbatches is not None \
            else microbatches_for(cfg, shape_name, dp)
        from jax.sharding import NamedSharding, PartitionSpec as P
        # 8-bit Adam (int8 moments, row scales) for the 100B+ models — the
        # paper's grouped quantization applied to optimizer state; without
        # it their moments alone overflow 16 GB/chip (DESIGN.md §4).
        use_8bit = cfg.num_params > 1e11
        if use_8bit:
            from repro.optim.quantized_adam import (QAdamState, qadam_init,
                                                    qadam_shardings)
            opt_shape = jax.eval_shape(qadam_init, params_shape)
            for_leaf = qadam_shardings(p_shard)

            def _m_shard(sh, m):
                if isinstance(m, dict):
                    return for_leaf(sh)
                return sh
            opt_sharding = QAdamState(
                step=NamedSharding(mesh, P()),
                mu=jax.tree.map(_m_shard, p_shard, opt_shape.mu),
                nu=jax.tree.map(_m_shard, p_shard, opt_shape.nu))
        else:
            from repro.optim.adam import AdamState, adam_init
            opt_shape = jax.eval_shape(adam_init, params_shape)
            opt_sharding = AdamState(
                step=NamedSharding(mesh, P()),
                mu=p_shard, nu=jax.tree.map(lambda s: s, p_shard))
        opt_sds = _sds_like(opt_shape, opt_sharding)
        batch = _train_batch_sds(cfg, B, T, mesh, dist)
        lr = linear_warmup_linear_decay(1e-4, 10_000)
        step = make_train_step(cfg, lr_schedule=lr, microbatches=m,
                               dist=dist, remat=remat, chunked=chunked,
                               optimizer="adam8bit" if use_8bit else "adam",
                               accum_dtype=jnp.bfloat16 if use_8bit
                               else jnp.float32)
        # donate params+opt: the optimizer update reuses their buffers
        # in-place instead of double-buffering the Adam moments
        return step, (params_sds, opt_sds, batch), {"microbatches": m,
                                                    "donate": (0, 1)}

    if kind == "prefill":
        if cfg.encoder_layers:
            frames = jax.ShapeDtypeStruct((B, T, cfg.d_model), dtype)
            bos = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            fr_sh = make_batch_shardings({"f": jnp.zeros((B, 1))}, dist)["f"]
            frames = jax.ShapeDtypeStruct((B, T, cfg.d_model), dtype,
                                          sharding=fr_sh)
            from repro.runtime.steps import make_encoder_forward
            step = make_encoder_forward(cfg, dist=dist)
            return step, (params_sds, frames, bos), {}
        cache_shape = jax.eval_shape(
            lambda: tfm.init_cache(cfg, B, T, stacked=stacked, dtype=dtype))
        c_shard = make_cache_shardings(cache_shape, dist)
        cache_sds = _sds_like(cache_shape, c_shard)
        toks = _tokens_sds(cfg, B, T, dist, with_embeds=bool(cfg.frontend))
        step = make_prefill_step(cfg, dist=dist, chunked=chunked)
        if cfg.frontend:
            def step_fe(params, tokens, cache, embeds):
                return step(params, tokens, cache, embeds=embeds)
            return step_fe, (params_sds, toks["tokens"], cache_sds,
                             toks["embeds"]), {"donate": (2,)}
        return step, (params_sds, toks["tokens"], cache_sds), {"donate": (2,)}

    # decode
    if cfg.encoder_layers:
        cache_shape = jax.eval_shape(
            lambda: encdec_lib.init_decoder_cache(cfg, B, T, T, dtype))
        c_shard = make_cache_shardings(cache_shape, dist)
        cache_sds = _sds_like(cache_shape, c_shard)
    else:
        cache_shape = jax.eval_shape(
            lambda: tfm.init_cache(cfg, B, T, stacked=stacked, dtype=dtype))
        c_shard = make_cache_shardings(cache_shape, dist)
        cache_sds = _sds_like(cache_shape, c_shard)
    tok_sh = make_batch_shardings(
        {"t": jnp.zeros((B, 1), jnp.int32)}, dist)["t"]
    toks = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_sh)
    pos = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_sh)
    step = make_decode_step(cfg, dist=dist)
    # donate the cache: the decode step updates it in place
    return step, (params_sds, toks, pos, cache_sds), {"donate": (3,)}


def _train_batch_sds(cfg, B, T, mesh, dist):
    from jax.sharding import NamedSharding
    batch = {}
    host = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    sh = make_batch_shardings(host, dist)["tokens"]
    if cfg.encoder_layers:
        # enc-dec train: frames take half the cell's seq budget, tokens half
        S = T // 2
        batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16, sharding=sh)
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=sh)
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=sh)
        return batch
    T_text = T - cfg.num_frontend_tokens if cfg.frontend else T
    batch["tokens"] = jax.ShapeDtypeStruct((B, T_text), jnp.int32,
                                           sharding=sh)
    batch["labels"] = jax.ShapeDtypeStruct((B, T_text), jnp.int32,
                                           sharding=sh)
    if cfg.frontend:
        batch["embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16,
            sharding=sh)
    return batch


def _tokens_sds(cfg, B, T, dist, with_embeds=False):
    sh = make_batch_shardings({"t": jnp.zeros((B, 1), jnp.int32)}, dist)["t"]
    T_text = T - cfg.num_frontend_tokens if with_embeds else T
    out = {"tokens": jax.ShapeDtypeStruct((B, T_text), jnp.int32,
                                          sharding=sh)}
    if with_embeds:
        out["embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16,
            sharding=sh)
    return out


def _lower_compile(cfg, shape_name, mesh, **kw):
    t0 = time.time()
    step, args, info = build_cell(cfg, shape_name, mesh, **kw)
    donate = info.pop("donate", ())
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes(hlo),
        "mem": mem, "lower_s": t_lower, "compile_s": t_compile,
        "info": info,
    }


def _extrapolate(c1: dict, c2: dict, n_super: int, keys=("flops", "bytes")):
    """Linear-in-layers extrapolation: f(L) = A + B*L from samples at 1, 2.

    cost_analysis counts scan bodies ONCE; the cost variants are lowered
    with 1 and 2 pattern repeats + NO grad accumulation + dense (loop-free)
    attention so every layer's work is visible, then scaled to the real
    depth. (rwkv's small inter-chunk state scan remains undercounted —
    <~10% of its wkv flops — noted in EXPERIMENTS.md.)"""
    out = {}
    for k in keys:
        b = c2[k] - c1[k]
        a = c1[k] - b
        out[k] = a + b * n_super
    coll = {}
    for kind in set(c1["coll"]) | set(c2["coll"]):
        b = c2["coll"].get(kind, 0) - c1["coll"].get(kind, 0)
        a = c1["coll"].get(kind, 0) - b
        coll[kind] = max(a + b * n_super, 0)
    out["coll"] = coll
    return out


VARIANT_FLAGS = {
    "baseline": {},
    "banded": {"chunked": "banded"},          # O(T*W) sliding-window attn
    "w8": {"weights_int8": True},             # int8 weight storage (serve)
    "w8_banded": {"weights_int8": True, "chunked": "banded"},
    "ohembed": {"onehot_embed": True},        # vocab-sharded decode lookup
    "serve8": {"weights_int8": True, "onehot_embed": True},
    "q8gather": {"quantized_gathers": True},  # int8 FSDP weight gathers
}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = "benchmarks/results/dryrun",
             microbatches: Optional[int] = None,
             variant: str = "baseline", with_cost: bool = True) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    vflags = VARIANT_FLAGS[variant]

    # 1) EXEC lowering: the real config — proves it compiles and fits.
    ex = _lower_compile(cfg, shape_name, mesh, microbatches=microbatches,
                        **vflags)
    mem = ex["mem"]

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant,
        "kind": SHAPES[shape_name]["kind"],
        "num_params": cfg.num_params,
        "active_params": cfg.active_params(),
        "exec_raw": {"flops_per_device": ex["flops"],
                     "bytes_per_device": ex["bytes"],
                     "collective_bytes_per_device": ex["coll"],
                     "note": "scan bodies counted once (see *_extrapolated)"},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_hbm_estimate": mem.argument_size_in_bytes +
            mem.output_size_in_bytes + mem.temp_size_in_bytes -
            mem.alias_size_in_bytes,
        },
        "lower_s": round(ex["lower_s"], 1),
        "compile_s": round(ex["compile_s"], 1),
        **ex["info"],
    }

    # 2) COST lowerings at 1 and 2 pattern repeats -> per-device totals.
    if with_cost:
        cost_flags = dict(vflags)
        if cost_flags.get("chunked") != "banded":
            cost_flags["chunked"] = False
        c1 = _lower_compile(cfg.with_supers(1), shape_name, mesh,
                            microbatches=1, stacked=False, **cost_flags)
        c2 = _lower_compile(cfg.with_supers(2), shape_name, mesh,
                            microbatches=1, stacked=False, **cost_flags)
        ext = _extrapolate(c1, c2, cfg.n_super)
        result["flops_per_device"] = ext["flops"]
        result["bytes_per_device"] = ext["bytes"]
        result["collective_bytes_per_device"] = ext["coll"]
        result["cost_samples"] = {
            "n1": {"flops": c1["flops"], "bytes": c1["bytes"],
                   "coll_total": c1["coll"].get("total", 0)},
            "n2": {"flops": c2["flops"], "bytes": c2["bytes"],
                   "coll_total": c2["coll"].get("total", 0)},
            "n_super": cfg.n_super,
        }

    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) for the chosen mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--variant", default="baseline",
                    choices=list(VARIANT_FLAGS))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS
    archs = [args.arch] if args.arch else \
        [a for a in ARCH_IDS if a != "bert-base"]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else shape_cells(cfg)
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                sfx = "" if args.variant == "baseline" else \
                    f"__{args.variant}"
                path = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}{sfx}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {arch} {shape} {mesh_name}")
                    continue
                try:
                    r = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                                 microbatches=args.microbatches,
                                 variant=args.variant)
                    print(f"[ok] {arch} {shape} {mesh_name}: "
                          f"{r.get('flops_per_device', 0):.3e} flops/dev, "
                          f"{r['memory']['peak_hbm_estimate']/2**30:.2f} GiB,"
                          f" compile {r['compile_s']}s", flush=True)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mesh_name, str(e)[:200]))
                    print(f"[FAIL] {arch} {shape} {mesh_name}: {e}",
                          file=sys.stderr)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("\nall dry-run cells compiled")


if __name__ == "__main__":
    main()
