"""Adam / AdamW from scratch (no optax in this environment).

Matches the paper's fine-tuning setup (App. B.1): Adam with linear warmup +
linear decay, gradient clipping optional. State is a pytree mirroring the
parameter tree, so it shards with the parameters under pjit (FSDP-style:
moments inherit the param sharding).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray       # scalar int32
    mu: Any                 # first moment, pytree like params
    nu: Any                 # second moment, pytree like params


def adam_init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.copy, zeros))


adamw_init = adam_init


# Leaves bigger than this (elements) may update slice-by-slice over their
# leading (layer-stack) dim via lax.map (chunked=True). NOTE: measured with
# memory_analysis, the while-loop breaks XLA's donation aliasing of the
# moment buffers and costs MORE peak HBM than the fused elementwise chain —
# kept as an option, default off (EXPERIMENTS.md perf log).
CHUNKED_UPDATE_MIN_ELEMS = 1 << 27


def adam_update(grads, state: AdamState, params, *, lr,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.0, grad_scale=None,
                chunked: bool = False):
    """Returns (updates, new_state). ``lr`` may be a scalar or a callable
    step -> scalar (schedule). ``weight_decay`` is decoupled (AdamW).
    ``grad_scale`` (e.g. a global-norm clip factor) is fused into the moment
    update instead of materializing a scaled gradient tree."""
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    b1t = 1.0 - b1 ** step.astype(jnp.float32)
    b2t = 1.0 - b2 ** step.astype(jnp.float32)

    def leaf(g, m, v, p):
        g = g.astype(jnp.float32)
        if grad_scale is not None:
            g = g * grad_scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        u = (m2 / b1t) / (jnp.sqrt(v2 / b2t) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return m2, v2, (-lr_t * u).astype(p.dtype)

    def apply_leaf(g, m, v, p):
        if chunked and p.size >= CHUNKED_UPDATE_MIN_ELEMS and p.ndim >= 2 \
                and p.shape[0] > 1:
            return jax.lax.map(lambda a: leaf(*a), (g, m, v, p))
        return leaf(g, m, v, p)

    out = jax.tree.map(apply_leaf, grads, state.mu, state.nu, params)
    mu = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    updates = jax.tree.map(lambda t: t[2], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return updates, AdamState(step=step, mu=mu, nu=nu)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm
