"""Production training launcher.

Examples (real cluster; on this CPU container use reduced configs):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
      --shape train_4k --steps 1000 --checkpoint-dir /ckpt/gemma2 \
      [--mesh 16x16] [--multi-pod] [--grad-compression] [--resume]

  # CPU smoke (reduced config, tiny mesh):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
      --steps 5 --batch 4 --seq 32
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.data import DataPipeline, LMTaskConfig, SyntheticLM, shard_batch
from repro.launch.mesh import make_mesh_from_spec, make_production_mesh
from repro.models import transformer as tfm
from repro.optim import linear_warmup_linear_decay
from repro.optim.adam import adam_init
from repro.parallel import (make_batch_shardings, make_dist,
                            make_param_shardings)
from repro.runtime import TrainLoopConfig, make_train_step, run_train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--mesh", default=None, help="e.g. 16x16 or 2x16x16")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config on the local device (CPU smoke)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        mesh = None
        dist = None
        B = args.batch or 4
        T = args.seq or 32
        dtype = jnp.float32
    else:
        mesh = (make_mesh_from_spec(args.mesh) if args.mesh
                else make_production_mesh(multi_pod=args.multi_pod))
        dist = make_dist(mesh)
        B = args.batch or SHAPES[args.shape]["global_batch"]
        T = args.seq or SHAPES[args.shape]["seq_len"]
        dtype = jnp.bfloat16

    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(cfg, key, stacked=True, dtype=dtype)
    if dist is not None:
        shardings = make_param_shardings(params, dist)
        params = jax.tree.map(jax.device_put, params, shardings)
    opt_state = adam_init(params)

    lr = linear_warmup_linear_decay(args.lr, args.steps)
    step = make_train_step(cfg, lr_schedule=lr,
                           microbatches=args.microbatches, dist=dist)
    jit_step = jax.jit(step, donate_argnums=(0, 1))

    src = SyntheticLM(LMTaskConfig(vocab_size=cfg.vocab_size, seq_len=T),
                      seed=args.seed)
    pipe = DataPipeline(src, batch_size=B, seed=args.seed)

    def put(batch):
        batch = {"tokens": batch["tokens"], "labels": batch["labels"]}
        if dist is not None:
            return shard_batch(batch, mesh, dist.dp_axes)
        return jax.tree.map(jnp.asarray, batch)

    loop_cfg = TrainLoopConfig(
        total_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
        resume=not args.no_resume)
    out = run_train_loop(jit_step, params, opt_state, pipe, loop_cfg,
                         put_batch=put)
    print(f"[train] finished at step {out['step']}; "
          f"{len(out['straggler_events'])} straggler events")
    return out


if __name__ == "__main__":
    main()
