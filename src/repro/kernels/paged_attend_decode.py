"""Pallas TPU kernels: decode attention over a BLOCK-PAGED KV cache.

The paged serving cache (models/attention.py: ``PagedKVCache`` /
``PagedQuantKVCache``) stores each layer's K/V as one shared arena of
``num_blocks`` blocks of ``block_size`` token cells — no batch axis; a
``(B, nb)`` int32 block table (-1 = unmapped) says which physical blocks
back which decode lane. These kernels are the paged twins of the dense
decode paths: same online-softmax accumulation, GQA layout, sliding-window
/ soft-capping semantics, in-kernel ``softmax_in`` / ``softmax_out``
fake-quant sites (the latter via the same two-pass S schedule), and — for
the int8 variant — the same zero-point rowsum/colsum corrections as
``int8_attend_decode``.

Two things are paged-specific:

* **Block gather via scalar prefetch.** The grid's last axis walks the
  lane's logical blocks; the block table rides in SMEM as a scalar-prefetch
  operand so each K/V BlockSpec index map picks the *physical* arena block
  ``table[b, step]`` for the DMA. Unmapped entries clip to block 0 and are
  fully masked, so only mapped blocks contribute.

* **Derived positions.** Cell validity is NOT read from stored per-cell
  positions (a freshly grown block may carry a previous owner's stale
  cells). Because a lane writes positions 0..q_pos contiguously and cell
  ``L`` of the logical view holds position ``p = q_pos - ((q_pos - L) mod
  S)`` (S = the layer's logical capacity, ``min(max_len, window)`` for
  ring layers), the kernel reconstructs every position from (L, q_pos, S)
  alone: ``valid = (L < S) & (p >= 0) [& window]``. Stale cells derive
  ``p < 0`` or ``L >= S`` and can never be read — allocation order, not
  memset, provides isolation. An idle lane (q_pos = -1) derives an
  all-invalid mask and contributes nothing.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.nibble import unpack_nibbles

NEG_INF = -1e30


def _paged_kernel(*refs, nb: int, bs: int, s_cap: int,
                  window: Optional[int], logit_softcap: Optional[float],
                  quantized: bool, has_smq: bool, has_smo: bool,
                  sm_qmin: int, sm_qmax: int, smo_qmin: int, smo_qmax: int,
                  kv_bits: int = 8):
    refs = list(refs)
    tbl_ref = refs.pop(0)                   # (B, nb) scalar-prefetch
    qp_ref = refs.pop(0)                    # (B,)   scalar-prefetch
    smq_ref = refs.pop(0) if has_smq else None
    smo_ref = refs.pop(0) if has_smo else None
    if quantized:
        (q_ref, qs_ref, qz_ref, kz_ref, vz_ref, k_ref, ks_ref, v_ref,
         vs_ref, o_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref) = refs

    b = pl.program_id(0)
    kk = pl.program_id(2)
    blk = jax.lax.rem(kk, nb)               # logical block (2-pass folds)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # logits for this block (recomputed in the second pass when two-pass)
    k = k_ref[0, :, 0, :]                              # (bs, hd[/2])
    if quantized:
        q = q_ref[0, 0]                                # (G, hd) int8
        hd = q.shape[-1]
        if kv_bits == 4:
            # nibble extract in VMEM before the MXU q.k^T; the rowsum /
            # colsum corrections below see the unpacked int4 values
            k = unpack_nibbles(k, hd)
        s32 = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        # zero-point corrections, identical to int8_attend_decode:
        #   sum (q - zq)(k - zk) = q.k - zq colsum(k) - zk rowsum(q)
        #                          + hd zq zk
        zq = qz_ref[0, 0][:, None]                     # (G, 1)
        zk = kz_ref[0, 0]                              # scalar (this head)
        kcol = jnp.sum(k.astype(jnp.int32), axis=-1).astype(jnp.float32)
        qrow = jnp.sum(q.astype(jnp.int32), axis=-1).astype(jnp.float32)
        acc32 = (s32.astype(jnp.float32) - zq * kcol[None, :]
                 - zk * qrow[:, None] + hd * zq * zk)
        s = (acc32 * qs_ref[0, 0][:, None]
             * ks_ref[0, :, 0][None, :])               # (G, bs)
    else:
        q = q_ref[0, 0].astype(jnp.float32)            # (G, hd), scale folded
        s = jax.lax.dot_general(q, k.astype(jnp.float32),
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    if has_smq:
        sm_s = smq_ref[0]
        sm_z = smq_ref[1]
        sq = jnp.clip(jnp.round(s / sm_s) + sm_z, sm_qmin, sm_qmax)
        s = (sq - sm_z) * sm_s

    # derived positions: cell L of the logical view holds position
    # q_pos - ((q_pos - L) mod S) — exact for written cells, invalid
    # (p < 0 or L >= S) for everything a lane has not written.
    qp = qp_ref[b]
    cell = jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    L = blk * bs + cell                                # (1, bs)
    p = qp - jnp.mod(qp - L, s_cap)
    valid = (L < s_cap) & (p >= 0) & (tbl_ref[b, blk] >= 0)
    if window is not None:
        valid &= p > qp - window
    s = jnp.where(valid, s, NEG_INF)                   # (1,bs) -> (G,bs)

    def _pv(pmat):
        """p @ V with the variant's dequant: per-slot v scales + static
        zero-point row correction for int8, plain f32 for bf16."""
        vblk = v_ref[0, :, 0, :]
        if quantized and kv_bits == 4:
            vblk = unpack_nibbles(vblk, q_ref.shape[-1])
        vblk = vblk.astype(jnp.float32)
        if quantized:
            pv = pmat * vs_ref[0, :, 0][None, :]
            zv = vz_ref[0, 0]
            return (jax.lax.dot_general(pv, vblk, (((1,), (0,)), ((), ())))
                    - zv * jnp.sum(pv, axis=-1)[:, None])
        return jax.lax.dot_general(pmat, vblk, (((1,), (0,)), ((), ())))

    @pl.when(kk < nb)
    def _stats_pass():
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(jnp.maximum(m_prev, jnp.max(s, axis=-1)),
                            NEG_INF)
        pmat = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[:, 0] = m_new
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(pmat, axis=-1)
        if not has_smo:
            acc_ref[...] = acc_ref[...] * corr[:, None] + _pv(pmat)

    if has_smo:
        @pl.when(kk >= nb)
        def _emit_pass():
            # second pass: (m, l) final — quantize the normalized probs on
            # the softmax_out grid (not renormalized, as in simulate).
            pmat = jnp.exp(s - m_ref[:, 0][:, None]) / \
                jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
            so_s = smo_ref[0]
            so_z = smo_ref[1]
            pq = jnp.clip(jnp.round(pmat / so_s) + so_z, smo_qmin, smo_qmax)
            pmat = (pq - so_z) * so_s
            acc_ref[...] += _pv(pmat)

        @pl.when(kk == 2 * nb - 1)
        def _done_two_pass():
            o_ref[0, 0] = acc_ref[...]
    else:
        @pl.when(kk == nb - 1)
        def _done():
            o_ref[0, 0] = acc_ref[...] / \
                jnp.maximum(l_ref[:, 0], 1e-30)[:, None]


def _paged_call(kernel_operands, in_specs, *, b, kv, g, hd, nb, bs, s_cap,
                window, logit_softcap, quantized, sm_quant, smo_quant,
                sm_qmin, sm_qmax, smo_qmin, smo_qmax, block_table, q_pos,
                kv_bits=8, interpret=False):
    has_smq = sm_quant is not None
    has_smo = smo_quant is not None
    n_steps = 2 * nb if has_smo else nb
    operands = []
    specs = []
    if has_smq:
        operands.append(sm_quant.astype(jnp.float32))
        specs.append(pl.BlockSpec((2,), lambda i, j, kk, tbl, qp: (0,)))
    if has_smo:
        operands.append(smo_quant.astype(jnp.float32))
        specs.append(pl.BlockSpec((2,), lambda i, j, kk, tbl, qp: (0,)))
    operands += kernel_operands
    specs += in_specs
    kernel = functools.partial(
        _paged_kernel, nb=nb, bs=bs, s_cap=s_cap, window=window,
        logit_softcap=logit_softcap, quantized=quantized, has_smq=has_smq,
        has_smo=has_smo, sm_qmin=sm_qmin, sm_qmax=sm_qmax,
        smo_qmin=smo_qmin, smo_qmax=smo_qmax, kv_bits=kv_bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, n_steps),
        in_specs=specs,
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda i, j, kk, tbl, qp: (i, j, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),   # running max
                        pltpu.VMEM((g, 1), jnp.float32),   # running denom
                        pltpu.VMEM((g, hd), jnp.float32)])  # numerator
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), jnp.float32),
        grid_spec=grid_spec,
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), jnp.asarray(q_pos, jnp.int32),
      *operands)


def _arena_maps(nb, has_smo):
    """K/V arena index maps: physical block = table[lane, logical step];
    the two-pass schedule re-walks K while V pins to the first block during
    the stats pass (fetched once per program there), exactly as in
    int8_attend_decode. Unmapped (-1) entries clip to block 0 — their cells
    all derive invalid, so the garbage is masked."""
    if has_smo:
        ck = lambda kk: jax.lax.rem(kk, nb)
        cv = lambda kk: jnp.maximum(kk - nb, 0)
    else:
        ck = cv = lambda kk: kk
    k_map = lambda i, j, kk, tbl, qp: (jnp.maximum(tbl[i, ck(kk)], 0),
                                       0, j, 0)
    v_map = lambda i, j, kk, tbl, qp: (jnp.maximum(tbl[i, cv(kk)], 0),
                                       0, j, 0)
    ks_map = lambda i, j, kk, tbl, qp: (jnp.maximum(tbl[i, ck(kk)], 0), 0, j)
    vs_map = lambda i, j, kk, tbl, qp: (jnp.maximum(tbl[i, cv(kk)], 0), 0, j)
    return k_map, v_map, ks_map, vs_map


def paged_attend_decode(q: jnp.ndarray, k_arena: jnp.ndarray,
                        v_arena: jnp.ndarray, block_table: jnp.ndarray,
                        q_pos: jnp.ndarray, *, s_cap: int,
                        window: Optional[int] = None,
                        logit_softcap: Optional[float] = None,
                        sm_quant: Optional[jnp.ndarray] = None,
                        sm_qmin: int = 0, sm_qmax: int = 255,
                        smo_quant: Optional[jnp.ndarray] = None,
                        smo_qmin: int = 0, smo_qmax: int = 255,
                        interpret: bool = False) -> jnp.ndarray:
    """One decode step over a paged bf16/f32 KV cache.

    q: (B, KV, G, hd) queries grouped per kv head, attention scale already
    folded in; k_arena/v_arena: (N, bs, KV, hd) shared arenas; block_table:
    (B, nb) int32 physical block per logical block (-1 = unmapped), where
    ``nb * bs`` covers ``s_cap`` (the layer's logical capacity =
    min(max_len, window) for ring layers); q_pos: (B,) query positions
    (-1 = idle lane -> zero contribution). Returns (B, KV, G, hd) f32.
    """
    b, kv, g, hd = q.shape
    bs = k_arena.shape[1]
    nb = block_table.shape[1]
    assert nb * bs >= s_cap, f"table covers {nb * bs} < s_cap={s_cap}"
    k_map, v_map, _, _ = _arena_maps(nb, smo_quant is not None)
    operands = [q.astype(jnp.float32), k_arena, v_arena]
    in_specs = [
        pl.BlockSpec((1, 1, g, hd),
                     lambda i, j, kk, tbl, qp: (i, j, 0, 0)),      # q
        pl.BlockSpec((1, bs, 1, hd), k_map),                       # k arena
        pl.BlockSpec((1, bs, 1, hd), v_map),                       # v arena
    ]
    return _paged_call(
        operands, in_specs, b=b, kv=kv, g=g, hd=hd, nb=nb, bs=bs,
        s_cap=s_cap, window=window, logit_softcap=logit_softcap,
        quantized=False, sm_quant=sm_quant, smo_quant=smo_quant,
        sm_qmin=sm_qmin, sm_qmax=sm_qmax, smo_qmin=smo_qmin,
        smo_qmax=smo_qmax, block_table=block_table, q_pos=q_pos,
        interpret=interpret)


def paged_int8_attend_decode(q_q: jnp.ndarray, q_scale: jnp.ndarray,
                             q_zp: jnp.ndarray, k_zp: jnp.ndarray,
                             v_zp: jnp.ndarray, k_arena: jnp.ndarray,
                             k_scale: jnp.ndarray, v_arena: jnp.ndarray,
                             v_scale: jnp.ndarray,
                             block_table: jnp.ndarray,
                             q_pos: jnp.ndarray, *, s_cap: int,
                             window: Optional[int] = None,
                             logit_softcap: Optional[float] = None,
                             sm_quant: Optional[jnp.ndarray] = None,
                             sm_qmin: int = 0, sm_qmax: int = 255,
                             smo_quant: Optional[jnp.ndarray] = None,
                             smo_qmin: int = 0, smo_qmax: int = 255,
                             kv_bits: int = 8,
                             interpret: bool = False) -> jnp.ndarray:
    """One decode step over a paged int8 KV cache (the paged twin of
    :func:`repro.kernels.int8_attend_decode.int8_attend_decode`).

    q_q: (B, KV, G, hd) int8; q_scale/q_zp: (B, KV, G) f32 (attention scale
    folded into q_scale; zero-points corrected in-kernel from rowsum/colsum
    scalars); k_zp/v_zp: (B, KV) f32 static per-head cache-grid zero-points;
    k_arena/v_arena: (N, bs, KV, hd) int8 arenas; k_scale/v_scale:
    (N, bs, KV) f32 per-head per-cell scales; block_table/q_pos as in
    :func:`paged_attend_decode`. With ``kv_bits=4`` the arenas hold
    split-half nibble-packed payloads (N, bs, KV, hd/2), unpacked in VMEM
    per block. Returns (B, KV, G, hd) f32.
    """
    b, kv, g, hd = q_q.shape
    hd_kv = hd
    if kv_bits == 4:
        assert hd % 2 == 0, f"kv_bits=4 needs even head_dim, got {hd}"
        hd_kv = hd // 2
        assert k_arena.shape[-1] == hd_kv, (
            f"packed arena last dim {k_arena.shape[-1]} != hd/2 = {hd_kv}")
    bs = k_arena.shape[1]
    nb = block_table.shape[1]
    assert nb * bs >= s_cap, f"table covers {nb * bs} < s_cap={s_cap}"
    k_map, v_map, ks_map, vs_map = _arena_maps(nb, smo_quant is not None)
    operands = [q_q, q_scale.astype(jnp.float32), q_zp.astype(jnp.float32),
                k_zp.astype(jnp.float32), v_zp.astype(jnp.float32),
                k_arena, k_scale.astype(jnp.float32), v_arena,
                v_scale.astype(jnp.float32)]
    in_specs = [
        pl.BlockSpec((1, 1, g, hd),
                     lambda i, j, kk, tbl, qp: (i, j, 0, 0)),      # q_q
        pl.BlockSpec((1, 1, g), lambda i, j, kk, tbl, qp: (i, j, 0)),  # q_s
        pl.BlockSpec((1, 1, g), lambda i, j, kk, tbl, qp: (i, j, 0)),  # q_z
        pl.BlockSpec((1, 1), lambda i, j, kk, tbl, qp: (i, j)),        # k_z
        pl.BlockSpec((1, 1), lambda i, j, kk, tbl, qp: (i, j)),        # v_z
        pl.BlockSpec((1, bs, 1, hd_kv), k_map),                    # k arena
        pl.BlockSpec((1, bs, 1), ks_map),                          # k scales
        pl.BlockSpec((1, bs, 1, hd_kv), v_map),                    # v arena
        pl.BlockSpec((1, bs, 1), vs_map),                          # v scales
    ]
    return _paged_call(
        operands, in_specs, b=b, kv=kv, g=g, hd=hd, nb=nb, bs=bs,
        s_cap=s_cap, window=window, logit_softcap=logit_softcap,
        quantized=True, sm_quant=sm_quant, smo_quant=smo_quant,
        sm_qmin=sm_qmin, sm_qmax=sm_qmax, smo_qmin=smo_qmin,
        smo_qmax=smo_qmax, block_table=block_table, q_pos=q_pos,
        kv_bits=kv_bits, interpret=interpret)
