"""End-to-end training driver: pretrain a decoder LM on the synthetic
Markov LM task for a few hundred steps with checkpoint/resume, then run a
short QAT fine-tune (quantization in the training graph).

CPU default is a ~1M-param reduced model; pass --preset 100m on real
hardware for the 100M-parameter configuration.

Run:  PYTHONPATH=src python examples/train_e2e.py --steps 60
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import Mode, QuantCtx, w8a8_policy
from repro.data import DataPipeline, LMTaskConfig, SyntheticLM
from repro.models import transformer as tfm
from repro.optim import linear_warmup_linear_decay
from repro.optim.adam import adam_init
from repro.runtime import TrainLoopConfig, make_train_step, run_train_loop


def preset_cfg(preset: str):
    base = get_config("h2o-danube3-4b")
    if preset == "100m":
        return dataclasses.replace(
            base, name="danube-100m", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32000, window=1024)
    return dataclasses.replace(
        base.reduced(), name="danube-1m", vocab_size=512)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="1m", choices=["1m", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--qat-steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_e2e")
    args = ap.parse_args(argv)

    cfg = preset_cfg(args.preset)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = adam_init(params)
    lr = linear_warmup_linear_decay(3e-3, args.steps)
    src = SyntheticLM(LMTaskConfig(vocab_size=cfg.vocab_size,
                                   seq_len=args.seq), seed=0)
    pipe = DataPipeline(src, batch_size=args.batch, seed=0)

    step = jax.jit(make_train_step(cfg, lr_schedule=lr),
                   donate_argnums=(0, 1))
    out = run_train_loop(
        step, params, opt, pipe,
        TrainLoopConfig(total_steps=args.steps, checkpoint_every=20,
                        log_every=10, checkpoint_dir=args.checkpoint_dir),
        put_batch=lambda b: {"tokens": jnp.asarray(b["tokens"]),
                             "labels": jnp.asarray(b["labels"])})
    params = out["params"]
    print(f"pretraining done at step {out['step']} "
          f"(loss {out['history'][-1]['loss']:.3f})")

    # ---- QAT phase: PTQ-initialized ranges, fake-quant in the graph -------
    print("\nQAT fine-tune (W8A8 in the training graph):")
    from repro.core.pipeline import ptq
    from repro.core.calibration import build_weight_state
    from repro.core.qat import init_qat_params
    pol = w8a8_policy()
    flat = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=False,
                           dtype=jnp.float32)
    calib = [pipe.source.batch(4, 900_000 + i) for i in range(2)]
    calib = [{"tokens": jnp.asarray(b["tokens"])} for b in calib]

    def fwd(p, b, ctx):
        logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
        return logits

    qm = ptq(fwd, flat, calib, pol)
    shared = {}
    for site, qp in qm.act_state.items():
        base = ("layer/" + site.split("/", 1)[1]
                if site.startswith("layer") else site)
        shared.setdefault(base, qp)
    qat_p = init_qat_params(shared, {})

    def ctx_factory(qat_params=None):
        return QuantCtx(policy=pol, mode=Mode.QAT, act_state=dict(shared),
                        weight_state={}, qat_params=qat_params)

    trainable = {"model": params, "quant": qat_p}
    qopt = adam_init(trainable)
    qlr = linear_warmup_linear_decay(5e-4, args.qat_steps)

    def loss(tr, batch):
        ctx = ctx_factory(tr["quant"])
        return tfm.train_loss(cfg, tr["model"], batch, ctx=ctx, remat=False)

    from repro.optim import adam_update, apply_updates

    @jax.jit
    def qstep(tr, qopt, batch):
        l, g = jax.value_and_grad(loss)(tr, batch)
        upd, qopt = adam_update(g, qopt, tr, lr=qlr)
        return apply_updates(tr, upd), qopt, l

    for i in range(args.qat_steps):
        b = next(pipe)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        trainable, qopt, l = qstep(trainable, qopt, batch)
        if i % 5 == 0:
            print(f"  qat step {i}: loss {float(l):.4f}")
    print("done — quantization-aware training converged alongside the "
          "learnable ranges (paper §4).")


if __name__ == "__main__":
    main()
