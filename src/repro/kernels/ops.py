"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python for correctness validation; on TPU the same call lowers to
Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import fused_ln_quant as _lnq
from repro.kernels import int8_matmul as _imm
from repro.kernels import peg_quant as _peg
from repro.kernels import ref as _ref


def _interp(flag: Optional[bool]) -> bool:
    if flag is None:
        return jax.default_backend() != "tpu"
    return flag


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "block_t",
                                             "interpret"))
def peg_fake_quant(x, scales, zps, *, qmin: int = 0, qmax: int = 255,
                   block_t: int = 256, interpret: Optional[bool] = None):
    return _peg.peg_fake_quant(x, scales, zps, qmin=qmin, qmax=qmax,
                               block_t=block_t, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "block_t",
                                             "interpret"))
def peg_quantize(x, scales, zps, *, qmin: int = 0, qmax: int = 255,
                 block_t: int = 256, interpret: Optional[bool] = None):
    return _peg.peg_quantize(x, scales, zps, qmin=qmin, qmax=qmax,
                             block_t=block_t, interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("s_a", "s_w", "block_m",
                                             "block_n", "block_k",
                                             "interpret"))
def int8_matmul(a_q, w_q, *, s_a: float, s_w: float, block_m: int = 256,
                block_n: int = 256, block_k: int = 512,
                interpret: Optional[bool] = None):
    return _imm.int8_matmul(a_q, w_q, s_a, s_w, block_m=block_m,
                            block_n=block_n, block_k=block_k,
                            interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("w_scale", "block_m", "block_n",
                                             "interpret"))
def int8_matmul_peg(a_q, w_q, act_scales, act_zps, *, w_scale: float,
                    block_m: int = 256, block_n: int = 256,
                    interpret: Optional[bool] = None):
    """PEG fixed-point matmul: K re-scalings fused into the MXU k-loop.
    Computes the zero-point correction internally."""
    g = act_scales.shape[0]
    w_colsum = _ref.w_colsum_groups(w_q, g)
    return _imm.int8_matmul_peg(a_q, w_q, act_scales, act_zps, w_scale,
                                w_colsum, block_m=block_m, block_n=block_n,
                                interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "eps", "block_t",
                                             "interpret"))
def ln_fake_quant(x, gamma, beta, scale, zp, *, qmin: int = 0,
                  qmax: int = 255, eps: float = 1e-6, block_t: int = 256,
                  interpret: Optional[bool] = None):
    return _lnq.ln_fake_quant(x, gamma, beta, scale, zp, qmin=qmin, qmax=qmax,
                              eps=eps, block_t=block_t,
                              interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "eps", "block_t",
                                             "interpret"))
def ln_quantize(x, gamma, beta, scale, zp, *, qmin: int = 0, qmax: int = 255,
                eps: float = 1e-6, block_t: int = 256,
                interpret: Optional[bool] = None):
    return _lnq.ln_quantize(x, gamma, beta, scale, zp, qmin=qmin, qmax=qmax,
                            eps=eps, block_t=block_t,
                            interpret=_interp(interpret))
