"""Paper Table 2: leave-one-out analysis of activation quantizers.

All activations quantized at 8-bit except one named group kept FP32.
Expected reproduction: excluding the residual-FFN path recovers by far the
most metric (the paper's headline diagnosis)."""
from __future__ import annotations

from benchmarks.common import (cached_table, eval_task, glue_average,
                               quantize_and_eval, train_task)
from repro.core import FP32, QuantizationPolicy, w8a8_policy
from repro.data.synthetic import GLUE_SUITE

# Table-2 row patterns (site regexes)
GROUPS = {
    "none (FP32 acts)": None,
    "all": "",
    "all, except softmax input": r".*/softmax_in",
    "all, except sum of embeddings": r"embed/.*",
    "all, except self-attention output": r".*/ctx_out",
    "all, except softmax output": r".*/softmax_out",
    "all, except residual+FFN path": r".*/(ffn_(in|out)|residual_ffn)",
}

# the paper runs this on its 4 problematic tasks; ours: the 4 best learners
TASKS = [t for t in GLUE_SUITE if t.name in
         ("syn-sst2", "syn-mnli", "syn-qnli", "syn-qqp")]


def compute():
    rows = {}
    for label, pattern in GROUPS.items():
        rows[label] = {}
        for task in TASKS:
            params = train_task(task)
            if label == "none (FP32 acts)":
                rows[label][task.name] = eval_task(task, params)
                continue
            overrides = {pattern: FP32} if pattern else {}
            pol = QuantizationPolicy(weight_default=FP32,
                                     act_overrides=overrides)
            rows[label][task.name] = quantize_and_eval(task, params, pol)
    return rows


def run():
    return cached_table("table2_ablation", compute)


def report(rows):
    tasks = [t.name for t in TASKS]
    lines = ["excluded_group," + ",".join(tasks)]
    for label, scores in rows.items():
        lines.append(f"\"{label}\"," +
                     ",".join(f"{scores[t]:.2f}" for t in tasks))
    return "\n".join(lines)


if __name__ == "__main__":
    print(report(run()))
