"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)                  (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                  (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

TPU adaptation: training/prefill uses jax.lax.associative_scan (parallel
prefix over T — log-depth on the VPU) instead of a sequential loop; decode is
the O(1) single-step update. The full Griffin recurrent block wraps the LRU
with a linear in-projection, a short causal temporal conv (width 4), a gated
GeLU branch and a linear out-projection.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, gelu, split_keys

LRU_C = 8.0


class RGLRUState(NamedTuple):
    h: jnp.ndarray        # (B, D_rnn) recurrent state
    conv: jnp.ndarray     # (B, W-1, D_rnn) last conv inputs


def _gates(p, x):
    r = jax.nn.sigmoid(x @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(x @ p["w_x"] + p["b_x"])
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r          # log decay <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i * x)
    return a, gated


def rg_lru_scan(p, x, h0: Optional[jnp.ndarray] = None):
    """x: (B, T, D_rnn) -> (y (B,T,D_rnn), h_T). Parallel associative scan."""
    a, b = _gates(p, x.astype(jnp.float32))
    if h0 is not None:
        # fold the initial state in as a virtual step 0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None].astype(jnp.float32), b], axis=1)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    acc_a, acc_b = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = acc_b if h0 is None else acc_b[:, 1:]
    return y.astype(x.dtype), acc_b[:, -1].astype(jnp.float32)


def rg_lru_step(p, x_t, h):
    """Single decode step. x_t: (B, D_rnn), h: (B, D_rnn)."""
    a, b = _gates(p, x_t.astype(jnp.float32)[:, None])
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(x_t.dtype), h_new


def _causal_conv(p, x, conv_state=None):
    """Width-4 causal depthwise conv. x: (B, T, D)."""
    w = p["conv_w"]                    # (4, D)
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):].astype(jnp.float32)
    return out + p["conv_b"], new_state


def recurrent_block(p, x, *, state: Optional[RGLRUState] = None, ctx=None,
                    prefix="rec") -> Tuple[jnp.ndarray, Optional[RGLRUState]]:
    """Griffin recurrent block. x: (B, T, D_model)."""
    def w(name):
        return ctx.weight(f"{prefix}/{name}", p[name]) if ctx is not None else p[name]

    rnn_in = x @ w("w_rnn_in")                      # (B, T, D_rnn)
    gate = gelu(x @ w("w_gate_in"))                 # (B, T, D_rnn)
    conv_state = state.conv if state is not None else None
    rnn_in, new_conv = _causal_conv(p, rnn_in, conv_state)
    if x.shape[1] == 1 and state is not None:
        y, h_new = rg_lru_step(p, rnn_in[:, 0], state.h)
        y = y[:, None]
    else:
        h0 = state.h if state is not None else None
        y, h_new = rg_lru_scan(p, rnn_in, h0)
    if ctx is not None:
        y = ctx.act(f"{prefix}/lru_out", y)
    out = (y * gate) @ w("w_out")
    new_state = RGLRUState(h=h_new, conv=new_conv) if state is not None else None
    return out, new_state


def init_rglru_state(batch: int, d_rnn: int, conv_width: int = 4) -> RGLRUState:
    return RGLRUState(h=jnp.zeros((batch, d_rnn), jnp.float32),
                      conv=jnp.zeros((batch, conv_width - 1, d_rnn), jnp.float32))


def init_recurrent_params(key, d_model: int, d_rnn: int, dtype=jnp.float32,
                          conv_width: int = 4):
    ks = split_keys(key, 6)
    return {
        "w_rnn_in": dense_init(ks[0], d_model, d_rnn, dtype),
        "w_gate_in": dense_init(ks[1], d_model, d_rnn, dtype),
        "w_out": dense_init(ks[2], d_rnn, d_model, dtype),
        "conv_w": (jax.random.normal(ks[3], (conv_width, d_rnn)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_rnn,), dtype),
        "w_a": dense_init(ks[4], d_rnn, d_rnn, dtype),
        "b_a": jnp.zeros((d_rnn,), dtype),
        "w_x": dense_init(ks[5], d_rnn, d_rnn, dtype),
        "b_x": jnp.zeros((d_rnn,), dtype),
        "lam": jnp.linspace(0.5, 4.0, d_rnn).astype(dtype),   # per-channel Λ
    }
