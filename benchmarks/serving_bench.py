"""Serving-scheduler benchmark: static group batching vs continuous
(slot-scheduled) batching on a skewed-quota workload.

The workload is the scheduling worst case the paper's deployment story runs
into in production: ``max_new_tokens`` drawn from {SHORT_QUOTA, LONG_QUOTA}
(interleaved), so under static batching every group decodes in lockstep at
the pace of its slowest request while the short requests' lanes idle.
Continuous batching retires those lanes immediately and admits queued
requests mid-flight, so the measured tokens/s ratio is (mostly) the
slot-utilization ratio.

Both schedulers serve the IDENTICAL request set through the same jitted
steps (warmed up before timing) on gemma2-2b-reduced, for the f32 KV cache
and the int8 QuantKVCache (``kv_bits=8``, dynamic per-slot scales +
``int8_attend_decode``). Greedy parity between the schedulers is asserted
as part of the bench — a speedup with diverging tokens would be a bug, not
a result.

A second section benches PAGED vs dense caches on a skewed-LENGTH
workload (most requests short, a few long): dense lanes must each carry
the worst-case ``max_len`` segment, so peak cache bytes are
``batch_slots x max_len`` regardless of what is actually live, while the
block pool (``runtime.block_pool``) maps blocks per LIVE token — the
paged rows record peak allocated bytes + tokens/s for both the f32 and
int8 block pools, with paged == dense greedy parity asserted in-bench.

A third section benches CHUNKED prefill on a long-prompt/short-quota
mixed workload: short-prompt residents decode while a long-prompt request
is admitted mid-flight. Unchunked, that admission is one monolithic
prefill call and every resident decode lane stalls for its full wall
time; chunked, the prompt lands in ``CHUNK``-token chunk steps
interleaved 1:1 with resident decode steps. The rows record the max /
mean wall-clock gap between consecutive decode steps (the resident-lane
stall this PR removes) and the long request's time-to-first-token in
model-call steps, with chunked == unchunked greedy parity asserted
in-bench.

A fourth section benches the PREFIX CACHE on the workload it targets: N
requests sharing a K-token prompt prefix (system-prompt traffic), served
sequentially through a small lane pool. Unshared, every admission
prefills its full prompt and allocates its full block span; with the
radix cache, retiring lanes donate their prompt blocks and every
admission after the first wave maps the shared K_aligned tokens read-only
and prefills only its novel suffix — the rows assert prefill tokens
processed == N * (prompt - K_aligned) + first_wave * K_aligned and that
fresh block allocations scale with the suffix only, with shared ==
unshared greedy parity asserted in-bench.

A fifth section benches OVER-COMMIT admission on a priority-skewed
workload: long low-tier decodes arrive ahead of short high-tier requests,
through a pool far below the workload's summed worst-case block demand.
The FIFO worst-case-reservation baseline strands the high tier behind the
low tier's reservations; over-commit admits against actual first-chunk
need, grows lanes at block boundaries, and preempts low-tier victims
(drop mode recomputes via chunked prefill, swap mode spills blocks to a
host buffer) when growth runs dry. The rows record preemptions /
swapped_blocks / recomputed_tokens / queue_wait_steps and per-tier
first-token percentiles, with preempted == unpreempted greedy parity
asserted in-bench for both the f32 cache and the calibrated deploy-int8
path (kv_bits=8), and the high tier's p99 first-token asserted to beat
the FIFO baseline's.

A sixth section benches the INT4 KV cache as a capacity feature: the
nibble-packed arena roughly halves the per-block HBM bytes of the int8
pool (scales stay f32), so a fixed byte budget holds ~2x the resident
decode lanes. Both bit-widths serve the same workload through the
calibrated deploy path on the paged continuous scheduler; the rows
record per-block bytes, resident lanes per MiB, and the int4 rows
quantify the drift vs int8 in-bench (greedy-token match rate — int4 is
lossy by construction, so drift is reported, not asserted away).

A seventh section benches TELEMETRY overhead: the identical section-one
workload served untraced and with the full observability stack armed
(lifecycle tracer + periodic metrics snapshots,
``runtime/telemetry.py``). Tracing must be observational only — traced
== untraced greedy parity is asserted in-bench, the trace's request
spans are reconciled against ``ServeStats`` (every request retired),
and the overhead is reported as a tokens/s ratio. Rows come from
``ServeStats.to_json()``, the same machine-readable form
``serve.py --stats-json`` writes.

``python -m benchmarks.serving_bench`` (or benchmarks/run.py --sections
serving) also writes machine-readable ``BENCH_serving.json``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.runtime import (BlockPool, RadixCache, Request, blocks_for_tokens,
                           serve)
from repro.runtime.steps import (make_admit_step, make_chunk_prefill_step,
                                 make_decode_step, make_prefill_step)

JSON_PATH = "BENCH_serving.json"

BATCH_SLOTS = 8
N_REQUESTS = 16
PROMPT_LEN = 8
SHORT_QUOTA = 4
LONG_QUOTA = 96
MAX_LEN = 128
REPEATS = 3          # timed repeats; best tokens/s wins (CPU wall jitter)

# paged-vs-dense section: skewed LENGTHS — every 4th request is long, so
# dense worst-case sizing (every lane carries PAGED_MAX_LEN slots) is ~4x
# the live footprint the block pool actually maps
PAGED_BLOCK_SIZE = 8
PAGED_MAX_LEN = 96
PAGED_SHORT = (6, 10)        # (prompt_len, quota) for short requests
PAGED_LONG = (48, 40)
PAGED_NUM_BLOCKS = 40        # vs dense worst case 8 * ceil(96/8) = 96

# chunked-prefill section: residents with short prompts decode long quotas
# while a LONG prompt is admitted into the lane a quota-CHUNK_EARLY
# request frees — unchunked, its monolithic prefill stalls every resident
# decode lane for the call's full wall time
CHUNK_SLOTS = 4
CHUNK_MAX_LEN = 320
CHUNK_RESIDENT = (8, 80)     # (prompt_len, quota) for the 3 residents
CHUNK_EARLY = (8, 4)         # retires early, freeing a lane mid-flight
CHUNK_LONG = (256, 16)       # the long-prompt late arrival
CHUNK = 16                   # tokens per chunk step

# prefix-cache section: N requests opening with the SAME system prefix,
# drained through a small lane pool so later admissions hit the blocks the
# first wave donated. Sizes keep every request under the reduced local
# window (prompt + quota - 2 < 16), so retiring lanes are donation-eligible
PREFIX_SLOTS = 2
PREFIX_N = 10
PREFIX_BLOCK_SIZE = 4
PREFIX_MAX_LEN = 16
PREFIX_PROMPT = 12           # tokens; first PREFIX_SHARED are common
PREFIX_SHARED = 8            # == K_aligned (block-aligned by construction)
PREFIX_QUOTA = 4
PREFIX_NUM_BLOCKS = 12       # small enough to exercise LRU eviction

# over-commit section: long low-tier decodes ahead of short high-tier
# arrivals, on a pool far below the summed worst-case demand (4 * 8 + 4 * 5
# = 52 blocks worst case vs OC_NUM_BLOCKS) — growth must preempt, and the
# high tier must jump the FIFO queue
OC_SLOTS = 4
OC_BLOCK_SIZE = 8
OC_MAX_LEN = 96
OC_LOW = (16, 48)            # (prompt, quota): worst case 8 blocks/lane
OC_HIGH = (32, 8)            # tier 1: worst case 5 blocks/lane
OC_N_LOW = 4
OC_N_HIGH = 4
OC_NUM_BLOCKS = 20           # < 4 resident lanes' combined worst case (32)
OC_CHUNK = 16

# deploy twin, sized down for interpret-mode Pallas kernels: 2 + 2
# requests at worst case 3 blocks each on a 4-block pool still preempts
OC_DEPLOY_SLOTS = 2
OC_DEPLOY_MAX_LEN = 32
OC_DEPLOY_LOW = (8, 16)
OC_DEPLOY_HIGH = (16, 4)
OC_DEPLOY_BLOCKS = 4

# telemetry section: section-one workload, untraced vs fully armed
# tracer + metrics — the overhead claim must be measured on the same
# jitted steps (tracing adds host-side bookkeeping only, no retrace)
TEL_METRICS_EVERY = 8

# int4-KV section: same deploy-path workload at kv-bits 8 and 4 — the
# capacity claim is per-block bytes, the cost claim is greedy drift
KV4_SLOTS = 2
KV4_MAX_LEN = 32
KV4_BLOCK_SIZE = 8
KV4_SPEC = [(4, 4), (8, 6), (6, 4), (3, 2)]      # (prompt_len, quota)


def _requests(cfg):
    rng = np.random.RandomState(0)
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size,
                                       size=PROMPT_LEN).astype(np.int32),
                    max_new_tokens=LONG_QUOTA if i % 2 else SHORT_QUOTA)
            for i in range(N_REQUESTS)]


def _serve(cfg, params, steps, reqs, scheduler, kv_bits):
    admit, decode, prefill = steps

    def init(b):
        return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                              kv_bits=kv_bits)

    return serve(prefill, admit, decode, init, params, reqs,
                 scheduler=scheduler, batch_slots=BATCH_SLOTS,
                 max_len=MAX_LEN)


def bench():
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    rows = []
    for kv_bits in (16, 8):
        # donate the cache operand exactly as launch/serve.py does, so the
        # bench measures the in-place-update configuration production runs
        steps = (jax.jit(make_admit_step(cfg), donate_argnums=(4,)),
                 jax.jit(make_decode_step(cfg), donate_argnums=(3,)),
                 jax.jit(make_prefill_step(cfg)))
        # warm-up: compile admit/prefill/decode outside the timed runs, at
        # the SAME shapes the timed runs use (a full group of batch_slots);
        # fresh Request objects per run — serving mutates done/tokens_out
        def warm():
            return [Request(rid=0, prompt=np.ones(PROMPT_LEN, np.int32),
                            max_new_tokens=2)
                    for _ in range(BATCH_SLOTS)]
        _serve(cfg, params, steps, warm(), "continuous", kv_bits)
        _serve(cfg, params, steps, warm(), "static", kv_bits)

        outs = {}
        for scheduler in ("static", "continuous"):
            stats = None
            for _ in range(REPEATS):
                reqs = _requests(cfg)
                s = _serve(cfg, params, steps, reqs, scheduler, kv_bits)
                if stats is None or s.tokens_per_s > stats.tokens_per_s:
                    stats = s
            outs[scheduler] = [r.tokens_out for r in reqs]
            rows.append({
                "name": f"serve_{scheduler}_kv{kv_bits}",
                "scheduler": scheduler,
                "kv_bits": kv_bits,
                "batch_slots": BATCH_SLOTS,
                "requests": N_REQUESTS,
                "quotas": [SHORT_QUOTA, LONG_QUOTA],
                "tokens": stats.tokens_generated,
                "prefill_calls": stats.prefill_calls,
                "decode_steps": stats.decode_steps,
                "wall_s": round(stats.wall_s, 3),
                "tokens_per_s": round(stats.tokens_per_s, 1),
                "slot_utilization": round(stats.slot_utilization, 3),
                "peak_cache_bytes": stats.cache_bytes,
            })
        assert outs["static"] == outs["continuous"], \
            "scheduler parity violated under benchmark workload"
        stat, cont = rows[-2], rows[-1]
        cont["speedup_vs_static"] = round(
            cont["tokens_per_s"] / max(stat["tokens_per_s"], 1e-9), 2)
    rows += bench_paged()
    rows += bench_chunked()
    rows += bench_prefix()
    rows += bench_overcommit()
    rows += bench_kv4_lanes()
    rows += bench_telemetry()
    return rows


def _paged_requests(cfg):
    rng = np.random.RandomState(1)
    reqs = []
    for i in range(N_REQUESTS):
        plen, quota = PAGED_LONG if i % 4 == 3 else PAGED_SHORT
        reqs.append(Request(
            rid=i,
            prompt=rng.randint(1, cfg.vocab_size, size=plen)
            .astype(np.int32),
            max_new_tokens=quota))
    return reqs


def bench_paged():
    """Paged vs dense caches, continuous scheduler, skewed-length
    workload. Records peak cache bytes (dense: the whole pytree; paged:
    allocated blocks only) + tokens/s for f32 and int8 pools."""
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    nb_lane = blocks_for_tokens(PAGED_MAX_LEN, PAGED_BLOCK_SIZE)
    rows = []
    for kv_bits in (16, 8):
        steps = (jax.jit(make_admit_step(cfg), donate_argnums=(4,)),
                 jax.jit(make_decode_step(cfg), donate_argnums=(3,)),
                 jax.jit(make_prefill_step(cfg)))
        admit, decode, prefill = steps

        def run(reqs, paged):
            pool = None
            if paged:
                pool = BlockPool(PAGED_NUM_BLOCKS, PAGED_BLOCK_SIZE,
                                 BATCH_SLOTS, nb_lane)

            def init(b):
                if not paged:
                    return tfm.init_cache(cfg, b, PAGED_MAX_LEN,
                                          dtype=jnp.float32,
                                          kv_bits=kv_bits)
                return tfm.init_cache(cfg, b, PAGED_MAX_LEN,
                                      dtype=jnp.float32, kv_bits=kv_bits,
                                      paged=True,
                                      block_size=PAGED_BLOCK_SIZE,
                                      num_blocks=PAGED_NUM_BLOCKS,
                                      mapped=False)
            return serve(prefill, admit, decode, init, params, reqs,
                         scheduler="continuous", batch_slots=BATCH_SLOTS,
                         max_len=PAGED_MAX_LEN, block_pool=pool)

        def warm(paged):
            reqs = [Request(rid=0, prompt=np.ones(4, np.int32),
                            max_new_tokens=2) for _ in range(BATCH_SLOTS)]
            run(reqs, paged)

        outs = {}
        for paged in (False, True):
            warm(paged)
            stats = None
            for _ in range(REPEATS):
                reqs = _paged_requests(cfg)
                s = run(reqs, paged)
                if stats is None or s.tokens_per_s > stats.tokens_per_s:
                    stats = s
            name = "paged" if paged else "dense"
            outs[name] = [r.tokens_out for r in reqs]
            rows.append({
                "name": f"serve_{name}_cache_kv{kv_bits}",
                "cache": name,
                "kv_bits": kv_bits,
                "batch_slots": BATCH_SLOTS,
                "requests": N_REQUESTS,
                "prompt_lens": [PAGED_SHORT[0], PAGED_LONG[0]],
                "quotas": [PAGED_SHORT[1], PAGED_LONG[1]],
                "max_len": PAGED_MAX_LEN,
                "tokens": stats.tokens_generated,
                "decode_steps": stats.decode_steps,
                "wall_s": round(stats.wall_s, 3),
                "tokens_per_s": round(stats.tokens_per_s, 1),
                "slot_utilization": round(stats.slot_utilization, 3),
                "peak_cache_bytes": stats.cache_bytes,
                **({"block_size": PAGED_BLOCK_SIZE,
                    "num_blocks": PAGED_NUM_BLOCKS,
                    "peak_blocks_in_use": stats.blocks_in_use,
                    "block_fragmentation":
                        round(stats.block_fragmentation, 3)}
                   if paged else {}),
            })
        assert outs["dense"] == outs["paged"], \
            "paged == dense greedy parity violated under benchmark workload"
        dense_row, paged_row = rows[-2], rows[-1]
        paged_row["cache_bytes_vs_dense"] = round(
            paged_row["peak_cache_bytes"]
            / max(dense_row["peak_cache_bytes"], 1), 3)
    return rows


def _chunk_requests(cfg):
    rng = np.random.RandomState(2)

    def req(rid, plen, quota):
        return Request(rid=rid,
                       prompt=rng.randint(1, cfg.vocab_size, size=plen)
                       .astype(np.int32),
                       max_new_tokens=quota)
    reqs = [req(0, *CHUNK_EARLY)]
    reqs += [req(1 + i, *CHUNK_RESIDENT) for i in range(CHUNK_SLOTS - 1)]
    reqs.append(req(CHUNK_SLOTS, *CHUNK_LONG))       # queued long arrival
    return reqs


def bench_chunked():
    """Chunked vs monolithic prefill, continuous scheduler, long-prompt
    arrival into a busy slot pool. Records the max/mean wall gap between
    consecutive decode steps (resident-lane stall) and the long request's
    first-token latency in model-call steps."""
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    admit = jax.jit(make_admit_step(cfg), donate_argnums=(4,))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(3,))
    chunkstep = jax.jit(make_chunk_prefill_step(cfg), donate_argnums=(4,))
    long_rid = CHUNK_SLOTS

    def run(reqs, chunk, decode_times):
        def timed_decode(params_, t, p, c):
            out = decode(params_, t, p, c)
            jax.block_until_ready(out[0])
            decode_times.append(time.perf_counter())
            return out

        def init(b):
            return tfm.init_cache(cfg, b, CHUNK_MAX_LEN, dtype=jnp.float32)

        return serve(None, admit, timed_decode, init, params, reqs,
                     scheduler="continuous", batch_slots=CHUNK_SLOTS,
                     max_len=CHUNK_MAX_LEN,
                     chunk_step=chunkstep if chunk else None,
                     prefill_chunk=chunk or None)

    def warm(chunk):
        reqs = [Request(rid=0, prompt=np.ones(CHUNK_LONG[0], np.int32),
                        max_new_tokens=2) for _ in range(CHUNK_SLOTS)]
        run(reqs, chunk, [])

    rows, outs = [], {}
    for chunk in (0, CHUNK):
        warm(chunk)
        best = None
        for _ in range(REPEATS):
            times = []
            reqs = _chunk_requests(cfg)
            stats = run(reqs, chunk, times)
            gaps = np.diff(np.asarray(times)) * 1e3          # ms
            if best is None or stats.tokens_per_s > best[0].tokens_per_s:
                best = (stats, gaps, reqs)
        stats, gaps, reqs = best
        name = f"chunk{chunk}" if chunk else "monolithic"
        outs[name] = [r.tokens_out for r in reqs]
        rows.append({
            "name": f"serve_prefill_{name}",
            "prefill_chunk": chunk,
            "batch_slots": CHUNK_SLOTS,
            "requests": len(reqs),
            "resident": list(CHUNK_RESIDENT),
            "long_request": list(CHUNK_LONG),
            "tokens": stats.tokens_generated,
            "prefill_calls": stats.prefill_calls,
            "chunk_steps": stats.chunk_steps,
            "decode_steps": stats.decode_steps,
            "wall_s": round(stats.wall_s, 3),
            "tokens_per_s": round(stats.tokens_per_s, 1),
            # resident-lane stall: wall gap between consecutive decode
            # steps — the monolithic long prefill sits inside one gap
            "max_decode_gap_ms": round(float(gaps.max()), 2),
            "mean_decode_gap_ms": round(float(gaps.mean()), 2),
            "long_req_first_token_step":
                stats.request_latency[long_rid].first_token_step,
        })
    assert outs["monolithic"] == outs[f"chunk{CHUNK}"], \
        "chunked == unchunked greedy parity violated under benchmark workload"
    mono, chk = rows[-2], rows[-1]
    chk["stall_reduction_vs_monolithic"] = round(
        mono["max_decode_gap_ms"] / max(chk["max_decode_gap_ms"], 1e-9), 2)
    return rows


def _prefix_requests(cfg):
    rng = np.random.RandomState(3)
    shared = rng.randint(1, cfg.vocab_size, size=PREFIX_SHARED)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [shared,
                         rng.randint(1, cfg.vocab_size,
                                     size=PREFIX_PROMPT - PREFIX_SHARED)]
                    ).astype(np.int32),
                    max_new_tokens=PREFIX_QUOTA)
            for i in range(PREFIX_N)]


class _CountingPool(BlockPool):
    """BlockPool that counts fresh block draws (novel allocations + COW
    copies) — the bench's O(suffix) allocation evidence."""

    def reset(self):
        self.popped = 0
        super().reset()

    def _pop_free(self, n):
        self.popped += n
        return super()._pop_free(n)


def bench_prefix():
    """Radix prefix cache vs unshared paged serving on a shared-prefix
    workload. Asserts the O(suffix) claims in-bench: after the first wave
    of misses, every admission maps K_aligned shared tokens and prefills /
    allocates its novel suffix only."""
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    admit = jax.jit(make_admit_step(cfg), donate_argnums=(4,))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(3,))
    chunkstep = jax.jit(make_chunk_prefill_step(cfg), donate_argnums=(4,))
    copyblock = jax.jit(tfm.cache_copy_block, donate_argnums=(0,))
    nb_lane = tfm.paged_lane_blocks(cfg, PREFIX_MAX_LEN, PREFIX_BLOCK_SIZE)
    caps = tfm.attn_write_caps(cfg, PREFIX_MAX_LEN, PREFIX_BLOCK_SIZE)

    def run(reqs, prefix):
        pool = _CountingPool(PREFIX_NUM_BLOCKS, PREFIX_BLOCK_SIZE,
                             PREFIX_SLOTS, nb_lane)

        def init(b):
            return tfm.init_cache(cfg, b, PREFIX_MAX_LEN, dtype=jnp.float32,
                                  paged=True, block_size=PREFIX_BLOCK_SIZE,
                                  num_blocks=PREFIX_NUM_BLOCKS, mapped=False)
        stats = serve(None, admit, decode, init, params, reqs,
                      scheduler="continuous", batch_slots=PREFIX_SLOTS,
                      max_len=PREFIX_MAX_LEN, block_pool=pool,
                      chunk_step=chunkstep,
                      radix_cache=RadixCache(PREFIX_BLOCK_SIZE) if prefix
                      else None,
                      write_caps=caps, copy_block_fn=copyblock)
        return stats, pool.popped

    def warm(prefix):
        reqs = [Request(rid=0, prompt=np.ones(PREFIX_PROMPT, np.int32),
                        max_new_tokens=2) for _ in range(PREFIX_SLOTS)]
        run(reqs, prefix)

    total_cols = blocks_for_tokens(PREFIX_PROMPT + PREFIX_QUOTA - 1,
                                   PREFIX_BLOCK_SIZE)
    k_blocks = PREFIX_SHARED // PREFIX_BLOCK_SIZE
    rows, outs = [], {}
    for prefix in (False, True):
        warm(prefix)
        best = None
        for _ in range(REPEATS):
            reqs = _prefix_requests(cfg)
            stats, popped = run(reqs, prefix)
            if best is None or stats.tokens_per_s > best[0].tokens_per_s:
                best = (stats, popped, reqs)
        stats, popped, reqs = best
        name = "shared" if prefix else "unshared"
        outs[name] = [r.tokens_out for r in reqs]
        prompt_tokens = PREFIX_N * PREFIX_PROMPT
        prefilled = prompt_tokens - stats.prefill_tokens_saved
        rows.append({
            "name": f"serve_prefix_{name}",
            "prefix_cache": prefix,
            "batch_slots": PREFIX_SLOTS,
            "requests": PREFIX_N,
            "prompt_len": PREFIX_PROMPT,
            "shared_prefix_tokens": PREFIX_SHARED,
            "quota": PREFIX_QUOTA,
            "block_size": PREFIX_BLOCK_SIZE,
            "num_blocks": PREFIX_NUM_BLOCKS,
            "tokens": stats.tokens_generated,
            "decode_steps": stats.decode_steps,
            "wall_s": round(stats.wall_s, 3),
            "tokens_per_s": round(stats.tokens_per_s, 1),
            "prefill_tokens_processed": prefilled,
            "prefill_tokens_saved": stats.prefill_tokens_saved,
            "prefix_hit_tokens": stats.prefix_hit_tokens,
            "prefix_hit_rate": round(stats.prefix_hit_rate, 3),
            "peak_shared_blocks": stats.shared_blocks,
            "blocks_allocated": popped,
            "peak_blocks_in_use": stats.blocks_in_use,
        })
    assert outs["unshared"] == outs["shared"], \
        "shared == unshared greedy parity violated under benchmark workload"
    unshared, shared = rows[-2], rows[-1]
    # O(suffix) prefill: the first wave (PREFIX_SLOTS misses on an empty
    # cache) prefills fully; every later admission hits K_aligned tokens
    hits = PREFIX_N - PREFIX_SLOTS
    assert shared["prefill_tokens_saved"] == hits * PREFIX_SHARED, \
        "every post-first-wave admission should hit the shared prefix"
    assert shared["prefill_tokens_processed"] == \
        PREFIX_N * (PREFIX_PROMPT - PREFIX_SHARED) \
        + PREFIX_SLOTS * PREFIX_SHARED, \
        "prefill tokens should be N * suffix + first_wave * K_aligned"
    # O(suffix) allocation: misses draw their full span, hits only their
    # novel suffix columns (the K_aligned columns are mapped, not drawn)
    assert unshared["blocks_allocated"] == PREFIX_N * total_cols
    assert shared["blocks_allocated"] == \
        PREFIX_SLOTS * total_cols + hits * (total_cols - k_blocks), \
        "hit admissions should allocate suffix blocks only"
    shared["prefill_tokens_vs_unshared"] = round(
        shared["prefill_tokens_processed"]
        / max(unshared["prefill_tokens_processed"], 1), 3)
    shared["blocks_allocated_vs_unshared"] = round(
        shared["blocks_allocated"]
        / max(unshared["blocks_allocated"], 1), 3)
    return rows


def _oc_requests(cfg, seed, low, high, n_low, n_high):
    """Low-tier long decodes FIRST (rids 0..n_low-1), high-tier (priority
    1) short requests queued behind them — the FIFO head-of-line case the
    priority queue exists to fix."""
    rng = np.random.RandomState(seed)

    def req(rid, plen, quota, pri):
        return Request(rid=rid,
                       prompt=rng.randint(1, cfg.vocab_size, size=plen)
                       .astype(np.int32),
                       max_new_tokens=quota, priority=pri)
    reqs = [req(i, *low, 0) for i in range(n_low)]
    reqs += [req(n_low + i, *high, 1) for i in range(n_high)]
    return reqs


def _tier_fields(stats):
    out = {"preemptions": stats.preemptions,
           "swapped_blocks": stats.swapped_blocks,
           "recomputed_tokens": stats.recomputed_tokens,
           "queue_wait_steps": stats.queue_wait_steps}
    for tier, tl in sorted(stats.tier_latency.items()):
        out[f"tier{tier}_first_token_p50"] = round(tl.first_token_p50, 1)
        out[f"tier{tier}_first_token_p99"] = round(tl.first_token_p99, 1)
        out[f"tier{tier}_inter_token_p99"] = round(tl.inter_token_p99, 2)
    return out


def bench_overcommit():
    """Over-commit admission + preemption vs FIFO worst-case reservation
    on the priority-skewed workload. Asserts in-bench: the constrained
    pool preempts (> 0), preempted == unpreempted greedy parity holds for
    drop mode, swap mode, and the calibrated deploy-int8 kv8 path, and
    the high tier's p99 first-token beats the FIFO baseline's."""
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    from repro.runtime.steps import make_swap_steps

    def build_steps(ctx_factory=None):
        so, si = make_swap_steps()
        return (jax.jit(make_admit_step(cfg, ctx_factory=ctx_factory),
                        donate_argnums=(4,)),
                jax.jit(make_decode_step(cfg, ctx_factory=ctx_factory),
                        donate_argnums=(3,)),
                jax.jit(make_chunk_prefill_step(cfg,
                                                ctx_factory=ctx_factory),
                        donate_argnums=(4,)),
                jax.jit(so), jax.jit(si, donate_argnums=(0,)))

    def run(steps, reqs, *, over_commit, swap=False, kv_bits=16,
            slots=OC_SLOTS, max_len=OC_MAX_LEN, num_blocks=OC_NUM_BLOCKS,
            chunk=OC_CHUNK, model=None):
        model = params if model is None else model
        admit, decode, chunkstep, so, si = steps
        width = tfm.paged_lane_blocks(cfg, max_len, OC_BLOCK_SIZE)
        pool = BlockPool(num_blocks, OC_BLOCK_SIZE, slots, width)

        def init(b):
            return tfm.init_cache(cfg, b, max_len, dtype=jnp.float32,
                                  kv_bits=kv_bits, paged=True,
                                  block_size=OC_BLOCK_SIZE,
                                  num_blocks=num_blocks, mapped=False)
        return serve(None, admit, decode, init, model, reqs,
                     scheduler="continuous", batch_slots=slots,
                     max_len=max_len, block_pool=pool,
                     chunk_step=chunkstep, prefill_chunk=chunk,
                     over_commit=over_commit,
                     swap_out_fn=so if swap else None,
                     swap_in_fn=si if swap else None,
                     write_caps=tfm.attn_write_caps(cfg, max_len,
                                                    OC_BLOCK_SIZE),
                     ring_tokens=tfm.paged_ring_tokens(cfg, max_len,
                                                       OC_BLOCK_SIZE))

    steps = build_steps()
    warm = [Request(rid=i, prompt=np.ones(OC_CHUNK, np.int32),
                    max_new_tokens=2) for i in range(OC_SLOTS)]
    run(steps, warm, over_commit=True)

    rows, outs = [], {}
    modes = [("fifo_baseline", dict(over_commit=False)),
             ("drop", dict(over_commit=True)),
             ("swap", dict(over_commit=True, swap=True))]
    for name, kw in modes:
        reqs = _oc_requests(cfg, 4, OC_LOW, OC_HIGH, OC_N_LOW, OC_N_HIGH)
        stats = run(steps, reqs, **kw)
        outs[name] = [r.tokens_out for r in reqs]
        rows.append({
            "name": f"serve_overcommit_{name}_kv16",
            "over_commit": kw.get("over_commit", False),
            "swap_blocks": kw.get("swap", False),
            "kv_bits": 16,
            "batch_slots": OC_SLOTS,
            "requests": len(reqs),
            "low_tier": list(OC_LOW) + [OC_N_LOW],
            "high_tier": list(OC_HIGH) + [OC_N_HIGH],
            "block_size": OC_BLOCK_SIZE,
            "num_blocks": OC_NUM_BLOCKS,
            "tokens": stats.tokens_generated,
            "decode_steps": stats.decode_steps,
            "chunk_steps": stats.chunk_steps,
            "wall_s": round(stats.wall_s, 3),
            "tokens_per_s": round(stats.tokens_per_s, 1),
            "peak_blocks_in_use": stats.blocks_in_use,
            **_tier_fields(stats),
        })
    assert outs["fifo_baseline"] == outs["drop"] == outs["swap"], \
        "preempted == unpreempted greedy parity violated (f32)"
    base, drop, swap = rows[-3], rows[-2], rows[-1]
    assert base["preemptions"] == 0
    assert drop["preemptions"] > 0 and drop["recomputed_tokens"] > 0
    assert swap["preemptions"] > 0 and swap["swapped_blocks"] > 0
    assert swap["recomputed_tokens"] == 0
    # the headline: priority admission + preemption beats FIFO worst-case
    # reservation on high-tier first-token latency
    for r in (drop, swap):
        assert r["tier1_first_token_p99"] < base["tier1_first_token_p99"], \
            "high-tier p99 first-token should beat the FIFO baseline"
        r["tier1_p99_vs_fifo"] = round(
            r["tier1_first_token_p99"]
            / max(base["tier1_first_token_p99"], 1e-9), 3)

    # calibrated deploy-int8 path (kv8): int8 KV round-trips storage
    # exactly, so preempted parity is bit-level here too
    from repro.core import Mode, QuantCtx, build_deploy, peg_policy
    from repro.core.pipeline import ptq
    pol = peg_policy(4)
    flat = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=False,
                           dtype=jnp.float32)
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10), (2, 8),
                                           0, cfg.vocab_size)}]

    def fwd(p, b, ctx):
        logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
        return logits

    qm = ptq(fwd, flat, calib, pol, collect_inputs=True)
    shared = {}
    for site, qp in qm.act_state.items():
        base_site = ("layer/" + site.split("/", 1)[1]
                     if site.startswith("layer") else site)
        shared.setdefault(base_site, qp)
    packed, acts = build_deploy(cfg, params, pol, shared)

    def ctx_factory():
        return QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=shared,
                        deploy_acts=acts)
    dsteps = build_steps(ctx_factory)
    deploy_outs = {}
    for name, kw in [("fifo_baseline", dict(over_commit=False)),
                     ("drop", dict(over_commit=True))]:
        reqs = _oc_requests(cfg, 5, OC_DEPLOY_LOW, OC_DEPLOY_HIGH, 2, 2)
        stats = run(dsteps, reqs, kv_bits=8, slots=OC_DEPLOY_SLOTS,
                    max_len=OC_DEPLOY_MAX_LEN, model=packed,
                    num_blocks=OC_DEPLOY_BLOCKS, chunk=8, **kw)
        deploy_outs[name] = [r.tokens_out for r in reqs]
        rows.append({
            "name": f"serve_overcommit_{name}_deploy_kv8",
            "over_commit": kw.get("over_commit", False),
            "kv_bits": 8,
            "deploy_int8": True,
            "batch_slots": OC_DEPLOY_SLOTS,
            "requests": len(reqs),
            "low_tier": list(OC_DEPLOY_LOW) + [2],
            "high_tier": list(OC_DEPLOY_HIGH) + [2],
            "block_size": OC_BLOCK_SIZE,
            "num_blocks": OC_DEPLOY_BLOCKS,
            "tokens": stats.tokens_generated,
            "decode_steps": stats.decode_steps,
            "wall_s": round(stats.wall_s, 3),
            "tokens_per_s": round(stats.tokens_per_s, 1),
            **_tier_fields(stats),
        })
    assert deploy_outs["fifo_baseline"] == deploy_outs["drop"], \
        "preempted == unpreempted greedy parity violated (deploy-int8 kv8)"
    assert rows[-1]["preemptions"] > 0
    return rows


def bench_kv4_lanes():
    """Int4 vs int8 KV cache on the calibrated deploy path: per-block HBM
    bytes (the capacity lever — lanes per byte budget) and greedy drift
    (the cost — quantified, not asserted away).

    head_dim is widened to 64 (vs the smoke default 16): the per-slot f32
    scales are a fixed per-token cost, so at hd=16 they are ~1/3 of the
    block bytes and the payload halving can't show — at hd=64 the ratio
    lands at its production-shape value (~0.54, vs 0.52 at hd=128 in
    BENCH_kernels.json)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("gemma2-2b").reduced(),
                              head_dim=64)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    from repro.core import Mode, QuantCtx, build_deploy, peg_policy
    from repro.core.pipeline import ptq
    pol = peg_policy(4)
    flat = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=False,
                           dtype=jnp.float32)
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10), (2, 8),
                                           0, cfg.vocab_size)}]

    def fwd(p, b, ctx):
        logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
        return logits

    qm = ptq(fwd, flat, calib, pol, collect_inputs=True)
    shared = {}
    for site, qp in qm.act_state.items():
        base_site = ("layer/" + site.split("/", 1)[1]
                     if site.startswith("layer") else site)
        shared.setdefault(base_site, qp)
    packed, acts = build_deploy(cfg, params, pol, shared)

    def ctx_factory():
        return QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=shared,
                        deploy_acts=acts)

    nb_lane = tfm.paged_lane_blocks(cfg, KV4_MAX_LEN, KV4_BLOCK_SIZE)
    rng = np.random.RandomState(6)
    prompts = [rng.randint(1, cfg.vocab_size, size=p).astype(np.int32)
               for p, _ in KV4_SPEC]

    def reqs_for():
        return [Request(rid=i, prompt=prompts[i], max_new_tokens=q)
                for i, (_, q) in enumerate(KV4_SPEC)]

    rows, outs = [], {}
    for kv_bits in (8, 4):
        admit = jax.jit(make_admit_step(cfg, ctx_factory=ctx_factory),
                        donate_argnums=(4,))
        decode = jax.jit(make_decode_step(cfg, ctx_factory=ctx_factory),
                         donate_argnums=(3,))
        prefill = jax.jit(make_prefill_step(cfg, ctx_factory=ctx_factory))

        def init(b):
            return tfm.init_cache(cfg, b, KV4_MAX_LEN, dtype=jnp.float32,
                                  kv_bits=kv_bits, paged=True,
                                  block_size=KV4_BLOCK_SIZE,
                                  num_blocks=KV4_SLOTS * nb_lane,
                                  mapped=False)
        block_bytes = tfm.paged_block_bytes(init(KV4_SLOTS))
        pool = BlockPool(KV4_SLOTS * nb_lane, KV4_BLOCK_SIZE, KV4_SLOTS,
                         nb_lane)
        reqs = reqs_for()
        stats = serve(prefill, admit, decode, init, packed, reqs,
                      scheduler="continuous", batch_slots=KV4_SLOTS,
                      max_len=KV4_MAX_LEN, block_pool=pool)
        outs[kv_bits] = [r.tokens_out for r in reqs]
        lane_bytes = nb_lane * block_bytes
        rows.append({
            "name": f"serve_resident_lanes_kv{kv_bits}",
            "kv_bits": kv_bits,
            "deploy_int8": True,
            "batch_slots": KV4_SLOTS,
            "requests": len(reqs),
            "max_len": KV4_MAX_LEN,
            "block_size": KV4_BLOCK_SIZE,
            "tokens": stats.tokens_generated,
            "decode_steps": stats.decode_steps,
            "wall_s": round(stats.wall_s, 3),
            "tokens_per_s": round(stats.tokens_per_s, 1),
            "peak_cache_bytes": stats.cache_bytes,
            "block_bytes": block_bytes,
            "lane_worst_case_bytes": lane_bytes,
            "resident_lanes_per_mib": round(2 ** 20 / lane_bytes, 1),
        })
    kv8_row, kv4_row = rows[-2], rows[-1]
    ratio = kv4_row["block_bytes"] / kv8_row["block_bytes"]
    kv4_row["block_bytes_vs_kv8"] = round(ratio, 3)
    kv4_row["resident_lanes_vs_kv8"] = round(1 / ratio, 2)
    assert ratio <= 0.55, \
        f"int4 arena should be <= 0.55x the int8 block bytes, got {ratio}"
    # drift, quantified in-bench: int4 is lossy vs int8 by construction
    matched = sum(1 for a, b in zip(outs[4], outs[8])
                  for t4, t8 in zip(a, b) if t4 == t8)
    total = sum(min(len(a), len(b)) for a, b in zip(outs[4], outs[8]))
    kv4_row["greedy_match_vs_kv8"] = round(matched / max(total, 1), 3)
    kv4_row["requests_identical_vs_kv8"] = sum(
        1 for a, b in zip(outs[4], outs[8]) if a == b)
    return rows


def bench_telemetry():
    """Traced vs untraced continuous serving on the section-one workload.
    Telemetry must be observational only: traced == untraced greedy
    parity and span/stats reconciliation are asserted in-bench, and the
    overhead lands in the rows as a tokens/s ratio. Rows are built from
    ``ServeStats.to_json()`` — the same machine-readable form behind
    ``serve.py --stats-json``."""
    import io

    from repro.runtime import ServeTelemetry

    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    admit = jax.jit(make_admit_step(cfg), donate_argnums=(4,))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(3,))
    prefill = jax.jit(make_prefill_step(cfg))

    def init(b):
        return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32)

    def run(reqs, tel):
        return serve(prefill, admit, decode, init, params, reqs,
                     scheduler="continuous", batch_slots=BATCH_SLOTS,
                     max_len=MAX_LEN, telemetry=tel)

    warm = [Request(rid=0, prompt=np.ones(PROMPT_LEN, np.int32),
                    max_new_tokens=2) for _ in range(BATCH_SLOTS)]
    run(warm, None)

    rows, outs, tel = [], {}, None
    for traced in (False, True):
        best = None
        for _ in range(REPEATS):
            reqs = _requests(cfg)
            t = (ServeTelemetry.create(trace=True,
                                       metrics_every=TEL_METRICS_EVERY,
                                       metrics_sink=io.StringIO())
                 if traced else None)
            s = run(reqs, t)
            if best is None or s.tokens_per_s > best[0].tokens_per_s:
                best = (s, t, reqs)
        stats, t, reqs = best
        name = "traced" if traced else "untraced"
        if traced:
            tel = t
        outs[name] = [r.tokens_out for r in reqs]
        sj = stats.to_json()
        rows.append({
            "name": f"serve_telemetry_{name}",
            "telemetry": traced,
            "batch_slots": BATCH_SLOTS,
            "requests": N_REQUESTS,
            "quotas": [SHORT_QUOTA, LONG_QUOTA],
            "tokens": sj["tokens_generated"],
            "prefill_calls": sj["prefill_calls"],
            "decode_steps": sj["decode_steps"],
            "wall_s": round(sj["wall_s"], 3),
            "tokens_per_s": round(sj["tokens_per_s"], 1),
            "slot_utilization": round(sj["slot_utilization"], 3),
        })
    assert outs["untraced"] == outs["traced"], \
        "telemetry must be observational: traced greedy parity violated"
    # reconcile the winning trace against its ServeStats: every request
    # enqueued, admitted, and retired, on the scheduler's step budget
    spans = tel.tracer.request_spans()
    assert len(spans) == N_REQUESTS
    assert all(s["retired"] for s in spans.values()), \
        "trace spans must show every request retired"
    base, trow = rows[-2], rows[-1]
    hists = tel.tracer.latency_histograms()
    trow["trace_events"] = len(tel.tracer.events)
    trow["metrics_every"] = TEL_METRICS_EVERY
    trow["decode_batch_p50_ms"] = round(hists["decode_batch"]["p50"], 3)
    trow["decode_batch_p99_ms"] = round(hists["decode_batch"]["p99"], 3)
    trow["tokens_per_s_vs_untraced"] = round(
        trow["tokens_per_s"] / max(base["tokens_per_s"], 1e-9), 3)
    trow["overhead_pct"] = round(
        (1 - trow["tokens_per_s_vs_untraced"]) * 100, 1)
    return rows


def report(rows) -> str:
    hdr = ("name,kv_bits,tokens,decode_steps,wall_s,tokens_per_s,"
           "slot_utilization,peak_cache_bytes,speedup_vs_static,"
           "cache_bytes_vs_dense,max_decode_gap_ms,"
           "stall_reduction_vs_monolithic,prefill_tokens_processed,"
           "blocks_allocated,preemptions,swapped_blocks,recomputed_tokens,"
           "queue_wait_steps,tier1_first_token_p99,"
           "tokens_per_s_vs_untraced")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r['name']},{r.get('kv_bits', '')},{r['tokens']},"
            f"{r['decode_steps']},"
            f"{r['wall_s']},{r['tokens_per_s']},"
            f"{r.get('slot_utilization', '')},"
            f"{r.get('peak_cache_bytes', '')},"
            f"{r.get('speedup_vs_static', '')},"
            f"{r.get('cache_bytes_vs_dense', '')},"
            f"{r.get('max_decode_gap_ms', '')},"
            f"{r.get('stall_reduction_vs_monolithic', '')},"
            f"{r.get('prefill_tokens_processed', '')},"
            f"{r.get('blocks_allocated', '')},"
            f"{r.get('preemptions', '')},"
            f"{r.get('swapped_blocks', '')},"
            f"{r.get('recomputed_tokens', '')},"
            f"{r.get('queue_wait_steps', '')},"
            f"{r.get('tier1_first_token_p99', '')},"
            f"{r.get('tokens_per_s_vs_untraced', '')}")
    return "\n".join(lines)


def write_json(rows, path=JSON_PATH):
    with open(path, "w") as f:
        json.dump({"workload": {
            "batch_slots": BATCH_SLOTS, "requests": N_REQUESTS,
            "prompt_len": PROMPT_LEN,
            "max_new_tokens": [SHORT_QUOTA, LONG_QUOTA],
            "arch": "gemma2-2b-reduced"}, "rows": rows}, f, indent=1)
        f.write("\n")
    return path


if __name__ == "__main__":
    rows = bench()
    print(report(rows))
    print(f"# wrote {write_json(rows)}")
