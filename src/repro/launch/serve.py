"""Serving launcher: batched requests against a (optionally W8A8-quantized)
model — prefill + decode with KV cache.

``--quantize`` serves with *simulated* quantization (fake-quant, f32
matmuls). ``--quantize --deploy-int8`` serves the true fixed-point path:
weights are pre-packed to int8 in the param pytree and the FFN / attention
projections run on the Pallas kernels (``ln/rms_quantize ->
int8_matmul_peg(+fused epilogue) -> int8_matmul``); a parity check against
the fake-quant reference is printed at startup.

``--kv-bits 8`` additionally stores the KV cache int8 (per-head per-slot
scales) and decodes through the fused ``int8_attend_decode`` kernel; a
multi-step decode parity check against the bf16-cache path is printed at
startup.

CPU smoke:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
      --requests 8 --new-tokens 8 [--quantize [--deploy-int8 [--kv-bits 8]]]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Mode, QuantCtx, w8a8_policy
from repro.core.pipeline import ptq
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.parallel import make_dist, make_param_shardings
from repro.runtime import Request, serve_batch
from repro.runtime.steps import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--quantize", action="store_true",
                    help="W8A8 PTQ (PEG on the FFN path) before serving")
    ap.add_argument("--deploy-int8", action="store_true",
                    help="serve the integer path: packed int8 weights + "
                         "Pallas kernels (requires --quantize)")
    ap.add_argument("--kv-bits", type=int, default=16, choices=(8, 16),
                    help="8: int8 KV cache + fused int8 decode attention "
                         "(requires --deploy-int8); 16: bf16/f32 cache")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.deploy_int8 and not args.quantize:
        ap.error("--deploy-int8 requires --quantize")
    if args.kv_bits == 8 and not args.deploy_int8:
        ap.error("--kv-bits 8 requires --deploy-int8")

    cfg = get_config(args.arch)
    dist = None
    if args.reduced:
        cfg = cfg.reduced()
        dtype = jnp.float32
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        dist = make_dist(mesh)
        dtype = jnp.bfloat16

    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(cfg, key, stacked=True, dtype=dtype)
    if dist is not None:
        params = jax.tree.map(jax.device_put, params,
                              make_param_shardings(params, dist))

    ctx_factory = None
    if args.quantize:
        # calibrate on a few synthetic prompts using the unrolled layout,
        # then serve with layer-shared quant params (DESIGN.md §4)
        from repro.core import peg_policy
        import dataclasses
        pol = peg_policy(4)
        flat_params = tfm.init_params(cfg, key, stacked=False, dtype=dtype)
        calib = [{"tokens": jax.random.randint(
            jax.random.PRNGKey(10 + i), (2, args.prompt_len), 0,
            cfg.vocab_size)} for i in range(2)]

        def fwd(p, b, ctx):
            logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
            return logits
        qm = ptq(fwd, flat_params, calib, pol,
                 collect_inputs=args.deploy_int8)
        # collapse per-layer sites to shared "layer/..." names (median scale)
        shared = {}
        for site, qp in qm.act_state.items():
            base = "layer/" + site.split("/", 1)[1] if site.startswith("layer") \
                else site
            shared.setdefault(base, qp)
        state = dict(shared)

        if args.deploy_int8:
            from repro.core import build_deploy
            fp_params = params
            params, deploy_acts = build_deploy(cfg, params, pol, state)

            def ctx_factory():
                return QuantCtx(policy=pol, mode=Mode.DEPLOY,
                                act_state=state, deploy_acts=deploy_acts)

            # parity: integer path vs the fake-quant reference it replaces
            toks = jax.random.randint(jax.random.PRNGKey(99),
                                      (2, args.prompt_len), 0, cfg.vocab_size)
            ref_ctx = QuantCtx(policy=pol, mode=Mode.APPLY, act_state=state)
            logits_ref, _ = tfm.forward(cfg, fp_params, toks, ctx=ref_ctx)
            logits_int, _ = tfm.forward(cfg, params, toks, ctx=ctx_factory())
            diff = float(jnp.max(jnp.abs(logits_ref - logits_int)))
            scale = float(jnp.max(jnp.abs(logits_ref)) + 1e-9)
            print(f"[deploy-int8] max |fake-quant - int8| logits diff "
                  f"{diff:.5f} (rel {diff / scale:.4%})")

            if args.kv_bits == 8:
                # multi-step decode parity: int8 KV cache (fused decode
                # kernel) vs the bf16/f32-cache integer path it replaces
                B, steps = 2, 4
                c16 = tfm.init_cache(cfg, B, args.max_len, dtype=dtype)
                c8 = tfm.init_cache(cfg, B, args.max_len, dtype=dtype,
                                    kv_bits=8)
                l16, c16 = tfm.prefill(cfg, params, toks, c16,
                                       ctx=ctx_factory())
                l8, c8 = tfm.prefill(cfg, params, toks, c8,
                                     ctx=ctx_factory())
                worst = float(jnp.max(jnp.abs(l16 - l8)) /
                              (jnp.max(jnp.abs(l16)) + 1e-9))
                cur = jnp.argmax(l16, axis=-1).astype(jnp.int32)
                pos = jnp.full((B, 1), toks.shape[1], jnp.int32)
                for _ in range(steps):
                    l16, c16 = tfm.decode_step(cfg, params, cur, pos, c16,
                                               ctx=ctx_factory())
                    l8, c8 = tfm.decode_step(cfg, params, cur, pos, c8,
                                             ctx=ctx_factory())
                    rel = float(jnp.max(jnp.abs(l16 - l8)) /
                                (jnp.max(jnp.abs(l16)) + 1e-9))
                    worst = max(worst, rel)
                    cur = jnp.argmax(l16, axis=-1).astype(jnp.int32)
                    pos = pos + 1
                print(f"[kv-int8] max rel logits diff over prefill + "
                      f"{steps} decode steps vs bf16 cache: {worst:.4%}")
        else:
            def ctx_factory():
                return QuantCtx(policy=pol, mode=Mode.APPLY, act_state=state)

    prefill = jax.jit(make_prefill_step(cfg, dist=dist,
                                        ctx_factory=ctx_factory))
    decode = jax.jit(make_decode_step(cfg, dist=dist,
                                      ctx_factory=ctx_factory),
                     donate_argnums=(3,))

    rng = np.random.RandomState(args.seed)
    requests = [Request(rid=i,
                        prompt=rng.randint(10, cfg.vocab_size,
                                           size=args.prompt_len),
                        max_new_tokens=args.new_tokens)
                for i in range(args.requests)]

    def init_cache(batch):
        return tfm.init_cache(cfg, batch, args.max_len, dtype=dtype,
                              kv_bits=args.kv_bits)

    stats = serve_batch(lambda t, c: prefill(params, t, c),
                        lambda t, p, c: decode(params, t, p, c),
                        init_cache, requests,
                        batch_slots=args.batch_slots)
    print(f"[serve] {stats.tokens_generated} tokens, "
          f"{stats.decode_steps} decode steps, "
          f"{stats.prefill_calls} prefills, {stats.wall_s:.2f}s "
          f"({stats.tokens_per_s:.1f} tok/s), "
          f"kv-cache {stats.cache_bytes / 1024:.0f} KiB/group "
          f"(kv-bits {args.kv_bits})")
    return stats


if __name__ == "__main__":
    main()
