"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so wall
times are NOT TPU-representative; we therefore report (a) interpret-mode
correctness timings for regression tracking and (b) the analytically derived
TPU-roofline time per call (bytes / HBM bw for the memory-bound quant
kernels; max(flops/peak, bytes/bw) for the matmuls) — the number a v5e
deployment would be judged against.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6     # us


def bench():
    rows = []
    key = jax.random.PRNGKey(0)

    # PEG fake-quant: (4096 tokens, 4096 dims, K=8)
    t, d, k = 4096, 4096, 8
    x = jax.random.normal(key, (t, d), jnp.float32)
    s = jnp.full((k,), 0.05)
    z = jnp.full((k,), 128.0)
    us = _time(lambda a: ops.peg_fake_quant(a, s, z), x)
    bytes_moved = t * d * 4 * 2
    rows.append(("peg_fake_quant_4kx4k", us,
                 f"tpu_roofline_us={bytes_moved / HBM_BW * 1e6:.1f}"))

    # int8 matmul per-tensor: 1024x4096x4096
    m, kk, n = 1024, 4096, 4096
    a = jax.random.randint(key, (m, kk), -127, 128, jnp.int8)
    w = jax.random.randint(key, (kk, n), -127, 128, jnp.int8)
    us = _time(lambda a_: ops.int8_matmul(a_, w, s_a=0.02, s_w=0.01,
                                          block_m=256, block_n=256,
                                          block_k=512), a)
    flops = 2 * m * kk * n
    bytes_moved = m * kk + kk * n + m * n * 4
    tpu_us = max(flops / (2 * PEAK_FLOPS),        # int8 ~2x bf16 MXU rate
                 bytes_moved / HBM_BW) * 1e6
    rows.append(("int8_matmul_1kx4kx4k", us, f"tpu_roofline_us={tpu_us:.1f}"))

    # PEG int8 matmul (K=8 groups fused rescale)
    g = 8
    sg = jax.random.uniform(key, (g,), minval=0.01, maxval=0.05)
    zg = jnp.zeros((g,))
    us = _time(lambda a_: ops.int8_matmul_peg(a_, w, sg, zg, w_scale=0.01,
                                              block_m=256, block_n=256), a)
    rows.append(("int8_matmul_peg_k8", us, f"tpu_roofline_us={tpu_us:.1f}"))

    # fused LN+quant: 4096 x 4096
    gma = jnp.ones((d,))
    beta = jnp.zeros((d,))
    us = _time(lambda a_: ops.ln_fake_quant(a_, gma, beta, 0.05, 128.0), x)
    bytes_moved = t * d * 4 * 2
    rows.append(("fused_ln_quant_4kx4k", us,
                 f"tpu_roofline_us={bytes_moved / HBM_BW * 1e6:.1f}"))
    return rows


def report(rows):
    return "\n".join(f"{n},{us:.1f},{d}" for n, us, d in rows)


if __name__ == "__main__":
    print(report(bench()))
