"""Shared harness for the paper-table benchmarks.

Trains a reduced BERT per synthetic-GLUE task (CPU-sized) with
OUTLIER-SCALED INITIALIZATION: a few designated FFN-output columns start
~40x larger, so training builds genuinely functional structured outliers in
the residual stream — the same qualitative regime the paper diagnoses in
pre-trained BERT (Fig. 2): per-tensor activation quantization then damages
the task metric, and PEG / MP / QAT recover it.

Checkpoints and table results are cached under benchmarks/results/.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import QuantizationPolicy, QuantCtx, Mode
from repro.core.pipeline import ptq
from repro.data.synthetic import GLUE_SUITE, GLUETaskConfig, SyntheticGLUE
from repro.models import bert
from repro.optim import (adam_init, adam_update, apply_updates,
                         linear_warmup_linear_decay)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
CKPT_DIR = os.path.join(RESULTS_DIR, "bert_ckpts")

# Benchmark-scale BERT (CPU single-core budget).
BENCH_CFG = dict(num_layers=3, d_model=64, num_heads=4, d_ff=256,
                 vocab_size=1024, max_positions=64)
OUTLIER_DIMS = (5, 21, 40, 59)          # spread over all 4 natural chunks
OUTLIER_SCALE = 40.0
TRAIN_STEPS = 250
BATCH = 32
SEQ = 32
TRAIN_LR = 3e-3
EVAL_EXAMPLES = 256


def bench_cfg(task: GLUETaskConfig) -> bert.BertConfig:
    return bert.BertConfig(num_labels=task.num_labels,
                           regression=task.regression, **BENCH_CFG)


def _task_src(task: GLUETaskConfig) -> SyntheticGLUE:
    import dataclasses
    return SyntheticGLUE(dataclasses.replace(task, seq_len=SEQ,
                                             vocab_size=BENCH_CFG["vocab_size"]),
                         seed=0)


def init_with_outliers(cfg: bert.BertConfig, key):
    params = bert.init_params(cfg, key)
    for p in params["layers"]:
        for j, dim in enumerate(OUTLIER_DIMS):
            p["w_out"] = p["w_out"].at[:, dim].multiply(
                OUTLIER_SCALE - 4.0 * j)
    return params


def train_task(task: GLUETaskConfig, *, steps: int = TRAIN_STEPS,
               seed: int = 0, log=None) -> dict:
    """Train (or load cached) tiny BERT for one task. Returns params."""
    os.makedirs(CKPT_DIR, exist_ok=True)
    path = os.path.join(CKPT_DIR, f"{task.name}_s{seed}.npz")
    cfg = bench_cfg(task)
    if os.path.exists(path):
        raw = np.load(path, allow_pickle=True)
        template = init_with_outliers(cfg, jax.random.PRNGKey(seed))
        flat, treedef = jax.tree_util.tree_flatten(template)
        leaves = [jnp.asarray(raw[f"leaf_{i}"]) for i in range(len(flat))]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    src = _task_src(task)
    params = init_with_outliers(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)
    lr = linear_warmup_linear_decay(TRAIN_LR, steps)

    @jax.jit
    def step_fn(params, opt, batch):
        loss, g = jax.value_and_grad(
            lambda p: bert.loss_fn(cfg, p, batch))(params)
        from repro.optim import clip_by_global_norm
        g, _ = clip_by_global_norm(g, 1.0)   # outlier init needs clipping
        upd, opt = adam_update(g, opt, params, lr=lr)
        return apply_updates(params, upd), opt, loss

    for i in range(steps):
        b = src.batch(BATCH, i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step_fn(params, opt, batch)
        if log and i % 50 == 0:
            log(f"  [{task.name}] step {i} loss {float(loss):.4f}")

    flat, _ = jax.tree_util.tree_flatten(params)
    np.savez(path, **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(flat)})
    return params


def eval_task(task: GLUETaskConfig, params,
              ctx: Optional[QuantCtx] = None) -> float:
    """Task metric (0-100) on held-out synthetic dev data."""
    cfg = bench_cfg(task)
    src = _task_src(task)
    preds, labels = [], []
    n_batches = EVAL_EXAMPLES // 64
    for i in range(n_batches):
        b = src.batch(64, 100_000 + i)     # disjoint index range from train
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        preds.append(np.asarray(bert.predict(cfg, params, batch, ctx=ctx)))
        labels.append(b["labels"])
    return src.metric(np.concatenate(preds), np.concatenate(labels))


def calib_batches(task: GLUETaskConfig, n: int = 4, batch_size: int = 16):
    src = _task_src(task)
    out = []
    for i in range(n):
        b = src.batch(batch_size, 200_000 + i)
        out.append({k: jnp.asarray(v) for k, v in b.items()})
    return out


def quantize_and_eval(task: GLUETaskConfig, params,
                      policy: QuantizationPolicy,
                      adaround_ffn: bool = False) -> float:
    """Full PTQ pipeline -> dev metric."""
    cfg = bench_cfg(task)
    batches = calib_batches(task)

    def fwd(p, b, ctx):
        return bert.classify(cfg, p, b["tokens"],
                             type_ids=b.get("type_ids"),
                             pad_mask=b.get("pad_mask"), ctx=ctx)

    adaround_sites = None
    if adaround_ffn:
        from repro.core.calibration import collect_ranges
        states, tensors = collect_ranges(fwd, params, batches, policy)
        adaround_sites = {}
        for i, p in enumerate(params["layers"]):
            x_in = tensors.get(f"layer{i}/ffn_in")
            if x_in is not None:
                adaround_sites[f"layer{i}/ffn/w_in"] = \
                    (p["w_in"], x_in.reshape(-1, x_in.shape[-1]))

    from repro.core.adaround import AdaRoundConfig
    qm = ptq(fwd, params, batches, policy,
             named_weights=bert.named_weight_sites(cfg, params),
             adaround_sites=adaround_sites,
             adaround_cfg=AdaRoundConfig(iterations=300, batch_size=128))
    if qm.adarounded_weights:
        import copy
        params = jax.tree.map(lambda x: x, params)   # shallow copy tree
        for site, w in qm.adarounded_weights.items():
            i = int(site.split("/")[0].removeprefix("layer"))
            params["layers"][i]["w_in"] = w
            # adarounded weights are pre-quantized: drop their weight state
            qm.weight_state.pop(site, None)
    return eval_task(task, params, qm.ctx())


def qat_finetune(task: GLUETaskConfig, params, policy: QuantizationPolicy,
                 *, steps: int = 80, lr_max: float = 1e-3):
    """Paper §4 QAT: init quant params from PTQ, fine-tune weights + ranges
    jointly with STE. Returns (params, qat_params, states) for eval."""
    from repro.core.calibration import build_weight_state
    from repro.core.qat import init_qat_params
    cfg = bench_cfg(task)
    batches = calib_batches(task)

    def fwd(p, b, ctx):
        return bert.classify(cfg, p, b["tokens"],
                             type_ids=b.get("type_ids"),
                             pad_mask=b.get("pad_mask"), ctx=ctx)

    qm = ptq(fwd, params, batches, policy,
             named_weights=bert.named_weight_sites(cfg, params))
    wstate = qm.weight_state
    qat_p = init_qat_params(qm.act_state, wstate)
    src = _task_src(task)
    lr = linear_warmup_linear_decay(lr_max, steps)
    trainable = {"model": params, "quant": qat_p}
    opt = adam_init(trainable)

    def loss(tr, batch):
        ctx = QuantCtx(policy=policy, mode=Mode.QAT,
                       act_state=qm.act_state, weight_state=wstate,
                       qat_params=tr["quant"])
        return bert.loss_fn(cfg, tr["model"], batch, ctx=ctx)

    @jax.jit
    def step_fn(tr, opt, batch):
        l, g = jax.value_and_grad(loss)(tr, batch)
        upd, opt = adam_update(g, opt, tr, lr=lr)
        return apply_updates(tr, upd), opt, l

    for i in range(steps):
        b = src.batch(BATCH, 300_000 + i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        trainable, opt, _ = step_fn(trainable, opt, batch)

    def ctx_factory():
        return QuantCtx(policy=policy, mode=Mode.QAT,
                        act_state=qm.act_state, weight_state=wstate,
                        qat_params=trainable["quant"])
    return trainable["model"], ctx_factory


def eval_qat(task, params, ctx_factory) -> float:
    return eval_task(task, params, ctx_factory())


def cached_table(name: str, compute):
    """JSON-cache a table computation under benchmarks/results/."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    result = compute()
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def glue_average(scores: Dict[str, float]) -> float:
    return float(np.mean(list(scores.values())))
