from repro.runtime.fault_tolerance import (PreemptionGuard, RestartPolicy,
                                           StragglerWatchdog)
from repro.runtime.serve_loop import Request, ServeStats, serve_batch
from repro.runtime.steps import (make_decode_step, make_encoder_forward,
                                 make_prefill_step, make_train_step)
from repro.runtime.train_loop import TrainLoopConfig, run_train_loop
