"""Paper Table 1: standard 8-bit post-training quantization.

Rows: FP32 / W8A8 / W32A8 / W8A32 on every synthetic-GLUE task + average.
Expected qualitative reproduction: W8A32 ~ FP32 (weights are robust),
W8A8 and W32A8 degrade (activations are the bottleneck).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import (cached_table, eval_task, glue_average,
                               quantize_and_eval, train_task)
from repro.core import FP32, QuantizationPolicy, w8a8_policy
from repro.data.synthetic import GLUE_SUITE


def policies():
    return {
        "W8A8": w8a8_policy(),
        "W32A8": QuantizationPolicy(weight_default=FP32),
        "W8A32": QuantizationPolicy(act_default=FP32),
    }


def compute():
    rows = {"FP32": {}}
    for name in policies():
        rows[name] = {}
    for task in GLUE_SUITE:
        params = train_task(task)
        rows["FP32"][task.name] = eval_task(task, params)
        for name, pol in policies().items():
            rows[name][task.name] = quantize_and_eval(task, params, pol)
    for name in rows:
        rows[name]["GLUE"] = glue_average(
            {k: v for k, v in rows[name].items() if k != "GLUE"})
    return rows


def run():
    return cached_table("table1_ptq", compute)


def report(rows):
    tasks = [t.name for t in GLUE_SUITE] + ["GLUE"]
    lines = ["config," + ",".join(tasks)]
    for cfg_name, scores in rows.items():
        lines.append(cfg_name + "," +
                     ",".join(f"{scores[t]:.2f}" for t in tasks))
    return "\n".join(lines)


if __name__ == "__main__":
    print(report(run()))
