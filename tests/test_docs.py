"""Docs-drift tests: the flag sets in README.md / docs/*.md and the
``repro.launch.serve`` argparser must not diverge.

Two directions:

* every ``--flag`` token the docs mention (minus a small allowlist of
  flags that belong to OTHER tools, e.g. benchmarks/run.py) must exist in
  the serve argparser — docs cannot reference removed/renamed flags;
* every serve argparser flag (minus ``--help``) must be mentioned in at
  least one of the docs — new flags cannot ship undocumented.

Plus structural checks that the documented entry points / bench artifacts
the docs point at actually exist.
"""
import json
import re
from pathlib import Path

import pytest

from repro.launch.serve import build_parser

pytestmark = pytest.mark.docs

REPO = Path(__file__).resolve().parent.parent
DOCS = ["README.md", "docs/serving.md", "docs/kernels.md",
        "docs/observability.md"]

# flags mentioned in the docs that belong to other CLIs, not serve.py
FOREIGN_FLAGS = {
    "--sections",       # benchmarks/run.py
    "--xla",            # --xla_force_host_platform_device_count: an
                        # XLA_FLAGS value (the --tp docs), not a CLI flag
}
# serve.py flags exempt from the must-be-documented rule
UNDOCUMENTED_OK = {
    "--help",           # argparse built-in
}

FLAG_RE = re.compile(r"(?<![\w-])--[a-z][a-z0-9-]*")


def _doc_text(name):
    path = REPO / name
    assert path.exists(), f"documented file {name} is missing"
    return path.read_text()


def _doc_flags():
    flags = {}
    for name in DOCS:
        for flag in FLAG_RE.findall(_doc_text(name)):
            flags.setdefault(flag, set()).add(name)
    return flags


def _serve_flags():
    return {opt for action in build_parser()._actions
            for opt in action.option_strings if opt.startswith("--")}


def test_doc_flags_exist_in_serve_parser():
    """Docs may only reference serve flags that actually exist."""
    serve = _serve_flags()
    unknown = {f: sorted(where)
               for f, where in _doc_flags().items()
               if f not in serve and f not in FOREIGN_FLAGS}
    assert not unknown, (
        f"docs mention flags the serve argparser does not define: "
        f"{unknown} — fix the doc, or add the flag to FOREIGN_FLAGS if it "
        f"belongs to another tool")


def test_serve_flags_are_documented():
    """Every serve flag must appear in README.md or docs/ (add genuinely
    internal/debug flags to UNDOCUMENTED_OK — deliberately)."""
    documented = set(_doc_flags())
    missing = sorted(_serve_flags() - documented - UNDOCUMENTED_OK)
    assert not missing, (
        f"serve flags missing from README.md/docs: {missing} — document "
        f"them (docs/serving.md has the flag reference table)")


def test_foreign_flags_are_actually_foreign():
    """The allowlist must not mask real serve flags."""
    overlap = sorted(FOREIGN_FLAGS & _serve_flags())
    assert not overlap, f"FOREIGN_FLAGS shadow real serve flags: {overlap}"


def test_docs_exist_and_crosslink():
    readme = _doc_text("README.md")
    assert "docs/serving.md" in readme and "docs/kernels.md" in readme
    assert "scripts/tier1.sh" in readme, "README must name the tier-1 command"


def test_bench_rows_named_in_kernel_docs_exist():
    """docs/kernels.md references BENCH_kernels.json rows by name; those
    rows must exist (section map cannot rot)."""
    rows = {r["name"] for r in json.loads(_doc_text("BENCH_kernels.json"))}
    text = _doc_text("docs/kernels.md")
    # every backticked token shaped like a bench row name must be one
    bench_like = {n for n in re.findall(r"`([a-z0-9_]+)`", text)
                  if re.search(r"_(b\d+|\d+x\d+|k\d+|s\d+)", n)}
    missing = sorted(bench_like - rows)
    assert not missing, (
        f"docs/kernels.md references BENCH_kernels.json rows that do not "
        f"exist: {missing}")


def test_serving_docs_name_real_stats_fields():
    """The ServeStats glossary in docs/serving.md must list exactly the
    dataclass's fields."""
    from repro.runtime import ServeStats
    import dataclasses
    text = _doc_text("docs/serving.md")
    fields = {f.name for f in dataclasses.fields(ServeStats)}
    # table rows look like: | `field` | ...
    documented = set(re.findall(r"\|\s*`([a-z_]+)`(?:,\s*`([a-z_]+)`)?",
                                text))
    documented = {n for pair in documented for n in pair if n}
    missing = sorted(fields - documented)
    assert not missing, (
        f"ServeStats fields missing from the docs/serving.md glossary: "
        f"{missing}")
