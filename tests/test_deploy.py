"""Integer deployment path (Mode.DEPLOY): the fused int8 kernels must match
the fake-quant reference within int8 rounding tolerance (interpret mode).

Covers the fused epilogue (bias + GELU + re-quantize), non-divisible (B, T)
shapes, the fused norm+quantize entry, whole-model parity (prefill + decode)
on the gemma2 reduced config, and the traced-scale no-recompile guarantee.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (Mode, QuantCtx, build_deploy, deploy, peg_policy,
                        w8a8_policy)
from repro.core.pipeline import ptq
from repro.core.quant_config import W8_DEFAULT
from repro.kernels import ops, ref
from repro.models import ffn as ffn_lib
from repro.models import transformer as tfm
from repro.models.common import layer_norm, rms_norm


def _group_act_quant(x, g):
    """ActQuant from data: per-group asymmetric int8 (shifted uint8 grid)."""
    d = x.shape[-1]
    xg = x.reshape(-1, g, d // g)
    mn = jnp.minimum(jnp.min(xg, axis=(0, 2)), 0.0)
    mx = jnp.maximum(jnp.max(xg, axis=(0, 2)), 0.0)
    s = jnp.maximum((mx - mn) / 255.0, 1e-8)
    z = jnp.clip(jnp.round(-mn / s), 0, 255) - 128.0
    return deploy.ActQuant(scales=s, zps=z, qmin=-128, qmax=127, perm=None)


def _dequant(q: deploy.QTensor):
    d = q.q.shape[-1]
    g = q.scales.shape[0]
    s = jnp.repeat(q.scales, d // g)
    z = jnp.repeat(q.zps, d // g)
    return (q.q.astype(jnp.float32) - z) * s


class TestFusedEpilogue:
    @pytest.mark.parametrize("m", [37, 64, 300])      # ragged + divisible
    def test_peg_bias_gelu_requant_matches_oracle(self, m):
        k, n, g = 64, 96, 4
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        a = jax.random.randint(ks[0], (m, k), -128, 128, jnp.int8)
        w = jax.random.randint(ks[1], (k, n), -127, 128, jnp.int8)
        sg = jax.random.uniform(ks[2], (g,), minval=0.01, maxval=0.05)
        zg = jnp.round(jax.random.uniform(ks[3], (g,), minval=-20.0,
                                          maxval=20.0))
        bias = jax.random.normal(ks[4], (n,)) * 0.2
        got = ops.int8_matmul_peg(a, w, sg, zg, w_scale=0.02, bias=bias,
                                  activation="gelu", out_scale=0.04,
                                  out_zp=-7.0, block_m=32, block_n=32)
        want = ref.int8_matmul_peg_fused_ref(a, w, sg, zg, 0.02, bias=bias,
                                             activation="gelu",
                                             out_scale=0.04, out_zp=-7.0)
        assert got.dtype == jnp.int8
        # off-by-one on round-to-grid ties is legitimate
        assert int(jnp.max(jnp.abs(got.astype(jnp.int32) -
                                   want.astype(jnp.int32)))) <= 1

    def test_pertensor_zero_point_and_mul(self):
        m, k, n = 45, 64, 32                          # ragged M
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        a = jax.random.randint(ks[0], (m, k), -128, 128, jnp.int8)
        w = jax.random.randint(ks[1], (k, n), -127, 128, jnp.int8)
        mul = jax.random.normal(ks[2], (m, n))
        got = ops.int8_matmul(a, w, s_a=0.03, s_w=0.01, z_a=5.0, mul=mul,
                              activation="silu", block_m=16, block_n=16,
                              block_k=32)
        want = ref.int8_matmul_fused_ref(a, w, 0.03, 0.01, z_a=5.0, mul=mul,
                                         activation="silu")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_batched_3d_input(self):
        b, t, k, n = 3, 11, 64, 32                    # B*T = 33, ragged
        ks = jax.random.split(jax.random.PRNGKey(2), 2)
        a = jax.random.randint(ks[0], (b, t, k), -128, 128, jnp.int8)
        w = jax.random.randint(ks[1], (k, n), -127, 128, jnp.int8)
        got = ops.int8_matmul(a, w, s_a=0.02, s_w=0.01, block_m=16,
                              block_n=16, block_k=32)
        want = ref.int8_matmul_ref(a.reshape(-1, k), w, 0.02,
                                   0.01).reshape(b, t, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestNormQuantize:
    def test_rms_matches_model_norm(self):
        d, g = 64, 4
        ks = jax.random.split(jax.random.PRNGKey(3), 2)
        x = jax.random.normal(ks[0], (2, 9, d)) * 2.0
        gamma = jax.random.normal(ks[1], (d,)) * 0.1
        aq = _group_act_quant(rms_norm(x, gamma), g)
        q = deploy.norm_quantize("rmsnorm", {"g": gamma}, x, aq)
        # compare against direct quantization of the model's own norm output
        y = rms_norm(x, gamma).reshape(-1, d).astype(jnp.float32)
        s = jnp.repeat(aq.scales, d // g)[None, :]
        z = jnp.repeat(aq.zps, d // g)[None, :]
        direct = jnp.clip(jnp.round(y / s) + z, -128, 127).astype(jnp.int8)
        diff = jnp.abs(q.q.reshape(-1, d).astype(jnp.int32) -
                       direct.astype(jnp.int32))
        assert int(jnp.max(diff)) <= 1

    def test_ln_with_permutation(self):
        d, g = 64, 4
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        x = jax.random.normal(ks[0], (1, 7, d)) * 3.0
        gamma = 1.0 + jax.random.normal(ks[1], (d,)) * 0.1
        beta = jax.random.normal(ks[2], (d,)) * 0.1
        perm = jnp.asarray(np.random.RandomState(0).permutation(d))
        base = _group_act_quant(layer_norm(x, gamma, beta), g)
        aq = deploy.ActQuant(scales=base.scales, zps=base.zps, qmin=-128,
                             qmax=127, perm=perm)
        q = deploy.norm_quantize("layernorm", {"g": gamma, "b": beta}, x, aq)
        y = jnp.take(layer_norm(x, gamma, beta), perm,
                     axis=-1).reshape(-1, d).astype(jnp.float32)
        s = jnp.repeat(aq.scales, d // g)[None, :]
        z = jnp.repeat(aq.zps, d // g)[None, :]
        direct = jnp.clip(jnp.round(y / s) + z, -128, 127)
        diff = jnp.abs(q.q.reshape(-1, d).astype(jnp.int32) -
                       direct.astype(jnp.int32))
        assert int(jnp.max(diff)) <= 1


class TestIntegerFFN:
    def test_mlp_bias_gelu_requant_parity(self):
        """Integer MLP (fused epilogue) vs the f32 fake-quant computation
        on identical quantized operands — non-divisible (B, T)."""
        d, f = 64, 96
        b, t = 2, 11
        ks = jax.random.split(jax.random.PRNGKey(5), 5)
        x = jax.random.normal(ks[0], (b, t, d))
        p = {"w_in": jax.random.normal(ks[1], (d, f)) * 0.2,
             "b_in": jax.random.normal(ks[2], (f,)) * 0.1,
             "w_out": jax.random.normal(ks[3], (f, d)) * 0.2,
             "b_out": jax.random.normal(ks[4], (d,)) * 0.1}

        in_aq = _group_act_quant(x, 4)
        x_q = deploy.quantize_act(x, in_aq)
        x_dq = _dequant(x_q)                      # exactly what deploy sees

        h_ref = jax.nn.gelu(x_dq @ _pack_dequant(p["w_in"], 4)[0] +
                            p["b_in"], approximate=True)
        hid_aq = _group_act_quant(h_ref, 1)

        packed = {"w_in": deploy.pack_linear(p["w_in"], W8_DEFAULT, 4),
                  "w_out": deploy.pack_linear(p["w_out"], W8_DEFAULT, 1),
                  "b_in": p["b_in"], "b_out": p["b_out"]}
        ctx = QuantCtx(policy=w8a8_policy(), mode=Mode.DEPLOY,
                       deploy_acts={"ffn/hidden": hid_aq})
        got = ffn_lib.mlp(packed, x_q, activation="gelu", ctx=ctx)

        # fake-quant reference on the same integer operands
        w1, _ = _pack_dequant(p["w_in"], 4)
        w2, _ = _pack_dequant(p["w_out"], 1)
        h = jax.nn.gelu(x_dq @ w1 + p["b_in"], approximate=True)
        s_h, z_h = hid_aq.scales[0], hid_aq.zps[0]
        h_fq = (jnp.clip(jnp.round(h / s_h) + z_h, -128, 127) - z_h) * s_h
        want = h_fq @ w2 + p["b_out"]
        tol = float(s_h) * float(jnp.max(jnp.sum(jnp.abs(w2), axis=0))) * 0.5
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=max(tol, 1e-3) , rtol=1e-2)

    def test_glu_parity(self):
        d, f = 64, 96
        ks = jax.random.split(jax.random.PRNGKey(6), 4)
        x = jax.random.normal(ks[0], (1, 13, d))
        p = {"w_gate": jax.random.normal(ks[1], (d, f)) * 0.2,
             "w_up": jax.random.normal(ks[2], (d, f)) * 0.2,
             "w_out": jax.random.normal(ks[3], (f, d)) * 0.2}
        in_aq = _group_act_quant(x, 1)
        x_q = deploy.quantize_act(x, in_aq)
        x_dq = _dequant(x_q)
        wg, _ = _pack_dequant(p["w_gate"], 1)
        wu, _ = _pack_dequant(p["w_up"], 1)
        wo, _ = _pack_dequant(p["w_out"], 1)
        h_ref = jax.nn.silu(x_dq @ wg) * (x_dq @ wu)
        hid_aq = _group_act_quant(h_ref, 1)
        packed = {k: deploy.pack_linear(v, W8_DEFAULT, 1)
                  for k, v in p.items()}
        ctx = QuantCtx(policy=w8a8_policy(), mode=Mode.DEPLOY,
                       deploy_acts={"ffn/hidden": hid_aq})
        got = ffn_lib.glu_mlp(packed, x_q, activation="silu", ctx=ctx)
        s_h, z_h = hid_aq.scales[0], hid_aq.zps[0]
        h_fq = (jnp.clip(jnp.round(h_ref / s_h) + z_h, -128, 127) - z_h) * s_h
        want = h_fq @ wo
        tol = float(s_h) * float(jnp.max(jnp.sum(jnp.abs(wo), axis=0))) * 0.5
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=max(tol, 1e-3), rtol=1e-2)


def _pack_dequant(w, g):
    """(dequantized weight, packed payload) with the deployment quantizer."""
    pk = deploy.pack_linear(w, W8_DEFAULT, g)
    return pk["q"].astype(jnp.float32) * pk["s"], pk


# ---------------------------------------------------------------------------
# Whole-model parity on the gemma2 reduced config (GLU + RMSNorm + PEG)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gemma_deploy():
    cfg = get_config("gemma2-2b").reduced()
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key, stacked=True, dtype=jnp.float32)
    pol = peg_policy(4)
    flat = tfm.init_params(cfg, key, stacked=False, dtype=jnp.float32)
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10), (2, 8), 0,
                                           cfg.vocab_size)}]

    def fwd(p, b, ctx):
        logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
        return logits

    qm = ptq(fwd, flat, calib, pol, collect_inputs=True)
    shared = {}
    for site, qp in qm.act_state.items():
        base = "layer/" + site.split("/", 1)[1] if site.startswith("layer") \
            else site
        shared.setdefault(base, qp)
    packed, acts = build_deploy(cfg, params, pol, shared)
    return cfg, params, packed, shared, acts, pol


def _ctxs(shared, acts, pol):
    ref_ctx = QuantCtx(policy=pol, mode=Mode.APPLY, act_state=shared)
    dep_ctx = QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=shared,
                       deploy_acts=acts)
    return ref_ctx, dep_ctx


class TestModelParity:
    def test_packed_pytree(self, gemma_deploy):
        cfg, params, packed, shared, acts, pol = gemma_deploy
        ffn = packed["scan"][0]["ffn"]
        assert deploy.is_packed(ffn["w_gate"])
        assert ffn["w_gate"]["q"].dtype == jnp.int8
        assert ffn["w_gate"]["colsum"].shape[-2] == 4          # PEG groups
        assert deploy.is_packed(packed["scan"][0]["attn"]["wq"])
        # PEG input site carries the range-based permutation
        assert acts["layer/ffn_in"].perm is not None

    def test_prefill_logits_match_fake_quant(self, gemma_deploy):
        cfg, params, packed, shared, acts, pol = gemma_deploy
        toks = jax.random.randint(jax.random.PRNGKey(7), (2, 9), 0,
                                  cfg.vocab_size)
        ref_ctx, dep_ctx = _ctxs(shared, acts, pol)
        l_ref, _ = tfm.forward(cfg, params, toks, ctx=ref_ctx)
        l_int, _ = tfm.forward(cfg, packed, toks, ctx=dep_ctx)
        scale = float(jnp.max(jnp.abs(l_ref)) + 1e-9)
        diff = float(jnp.max(jnp.abs(l_ref - l_int)))
        assert diff <= 0.05 * scale + 1e-3, diff

    def test_decode_step_parity_ragged_batch(self, gemma_deploy):
        cfg, params, packed, shared, acts, pol = gemma_deploy
        B = 3                                                  # ragged M = 3
        toks = jax.random.randint(jax.random.PRNGKey(8), (B, 1), 0,
                                  cfg.vocab_size)
        pos = jnp.zeros((B, 1), jnp.int32)
        cache_r = tfm.init_cache(cfg, B, 16, dtype=jnp.float32)
        cache_d = tfm.init_cache(cfg, B, 16, dtype=jnp.float32)
        ref_ctx, dep_ctx = _ctxs(shared, acts, pol)
        l_ref, _ = tfm.decode_step(cfg, params, toks, pos, cache_r,
                                   ctx=ref_ctx)
        l_int, _ = tfm.decode_step(cfg, packed, toks, pos, cache_d,
                                   ctx=dep_ctx)
        scale = float(jnp.max(jnp.abs(l_ref)) + 1e-9)
        assert float(jnp.max(jnp.abs(l_ref - l_int))) <= 0.05 * scale + 1e-3

    def test_multi_token_decode_parity(self, gemma_deploy):
        """Prefill -> N greedy decode steps: the deploy path (packed weights
        + int8 kernels) must track the fake-quant reference at every step —
        this pins the parity check serve.py prints at startup."""
        cfg, params, packed, shared, acts, pol = gemma_deploy
        B, T, steps = 2, 9, 4
        toks = jax.random.randint(jax.random.PRNGKey(11), (B, T), 0,
                                  cfg.vocab_size)
        ref_ctx, dep_ctx = _ctxs(shared, acts, pol)
        cache_r = tfm.init_cache(cfg, B, 32, dtype=jnp.float32)
        cache_d = tfm.init_cache(cfg, B, 32, dtype=jnp.float32)
        l_ref, cache_r = tfm.prefill(cfg, params, toks, cache_r, ctx=ref_ctx)
        l_int, cache_d = tfm.prefill(cfg, packed, toks, cache_d, ctx=dep_ctx)
        cur = jnp.argmax(l_ref, axis=-1).astype(jnp.int32)
        pos = jnp.full((B, 1), T, jnp.int32)
        for _ in range(steps):
            l_ref, cache_r = tfm.decode_step(cfg, params, cur, pos, cache_r,
                                             ctx=ref_ctx)
            l_int, cache_d = tfm.decode_step(cfg, packed, cur, pos, cache_d,
                                             ctx=dep_ctx)
            scale = float(jnp.max(jnp.abs(l_ref)) + 1e-9)
            diff = float(jnp.max(jnp.abs(l_ref - l_int)))
            assert diff <= 0.05 * scale + 1e-3, diff
            cur = jnp.argmax(l_ref, axis=-1).astype(jnp.int32)
            pos = pos + 1

    @pytest.mark.deploy
    def test_multi_token_decode_parity_int8_kv(self, gemma_deploy):
        """Same multi-step decode with the int8 KV cache (fused decode
        kernel): parity vs the f32-cache deploy path within the fake-quant
        tolerance — the ``--kv-bits 8`` startup check, pinned by CI."""
        cfg, params, packed, shared, acts, pol = gemma_deploy
        assert isinstance(acts.get("layer/attn/kv"), deploy.KVQuant)
        B, T, steps = 2, 9, 4
        toks = jax.random.randint(jax.random.PRNGKey(12), (B, T), 0,
                                  cfg.vocab_size)
        _, dep_ctx = _ctxs(shared, acts, pol)
        c16 = tfm.init_cache(cfg, B, 32, dtype=jnp.float32)
        c8 = tfm.init_cache(cfg, B, 32, dtype=jnp.float32, kv_bits=8)
        l16, c16 = tfm.prefill(cfg, packed, toks, c16, ctx=dep_ctx)
        l8, c8 = tfm.prefill(cfg, packed, toks, c8, ctx=dep_ctx)
        # prefill attends over the fresh K/V: identical in both paths
        np.testing.assert_allclose(np.asarray(l16), np.asarray(l8),
                                   rtol=1e-5, atol=1e-5)
        cur = jnp.argmax(l16, axis=-1).astype(jnp.int32)
        pos = jnp.full((B, 1), T, jnp.int32)
        for _ in range(steps):
            l16, c16 = tfm.decode_step(cfg, packed, cur, pos, c16,
                                       ctx=dep_ctx)
            l8, c8 = tfm.decode_step(cfg, packed, cur, pos, c8, ctx=dep_ctx)
            scale = float(jnp.max(jnp.abs(l16)) + 1e-9)
            diff = float(jnp.max(jnp.abs(l16 - l8)))
            assert diff <= 0.05 * scale + 1e-3, diff
            cur = jnp.argmax(l16, axis=-1).astype(jnp.int32)
            pos = pos + 1
        # the int8 cache halves the attention-cache bytes
        def kv_bytes(c):
            from repro.runtime.serve_loop import _tree_bytes
            return _tree_bytes(c)
        assert kv_bytes(c8) < 0.6 * kv_bytes(c16)


def test_traced_scales_do_not_recompile():
    """Satellite: calibration scales are traced operands — new scale values
    must reuse the compiled kernel (the seed recompiled per scale)."""
    a = jnp.ones((16, 64), jnp.int8)
    w = jnp.ones((64, 32), jnp.int8)
    kw = dict(block_m=16, block_n=16, block_k=32)
    ops.int8_matmul(a, w, s_a=0.5, s_w=0.25, **kw).block_until_ready()
    n0 = ops.int8_matmul._cache_size()
    for s in (0.1, 0.01, 0.007):
        ops.int8_matmul(a, w, s_a=s, s_w=s, **kw).block_until_ready()
    assert ops.int8_matmul._cache_size() == n0

    sg = jnp.full((4,), 0.1)
    zg = jnp.zeros((4,))
    ops.int8_matmul_peg(a, w, sg, zg, w_scale=0.3, block_m=16,
                        block_n=16).block_until_ready()
    n1 = ops.int8_matmul_peg._cache_size()
    ops.int8_matmul_peg(a, w, sg * 3, zg + 1, w_scale=0.7, block_m=16,
                        block_n=16).block_until_ready()
    assert ops.int8_matmul_peg._cache_size() == n1
