"""PEG-int8 gradient compression for cross-pod data parallelism.

Beyond-paper application of the paper's core machinery: gradients, like
transformer activations, have per-channel dynamic-range structure, so we
quantize each gradient tensor to int8 with per-group scales (the PEG scheme
applied along the last axis) before the inter-pod exchange, with an error-
feedback accumulator (Seide et al. 2014 style) so the quantization noise is
compensated on the next step instead of biasing the update.

Exchange pattern under ``shard_map`` over the ``pod`` axis:
    q, s   = peg_quantize(g + err)           # int8 payload + f32 group scales
    qs, ss = all_gather(q), all_gather(s)    # int8 on the wire (DCN)
    g_avg  = mean_k dequant(qs[k], ss[k])
    err'   = (g + err) - dequant(q, s)       # local error feedback

For P pods this moves P*X int8 bytes per device versus ~2*X bf16 bytes for a
ring all-reduce — a 4x wire-byte saving at P=2 and still >2x at P=4 when the
pod axis is small (inter-pod DCN is the scarce resource, per DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _group_scales(x: jnp.ndarray, group_size: int) -> jnp.ndarray:
    flat = x.reshape(-1)
    pad = (-flat.size) % group_size
    flat = jnp.pad(flat, (0, pad))
    g = flat.reshape(-1, group_size)
    return jnp.max(jnp.abs(g), axis=1) / 127.0


def quantize_grad(g: jnp.ndarray, group_size: int = 256
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 quantization with per-group scales along flattened g."""
    scales = jnp.maximum(_group_scales(g, group_size),
                         jnp.finfo(jnp.float32).tiny)
    flat = g.reshape(-1)
    pad = (-flat.size) % group_size
    flat = jnp.pad(flat, (0, pad)).reshape(-1, group_size)
    q = jnp.clip(jnp.round(flat / scales[:, None]), -127, 127).astype(jnp.int8)
    return q, scales


def dequantize_grad(q: jnp.ndarray, scales: jnp.ndarray, shape, dtype
                    ) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(g: jnp.ndarray, err: jnp.ndarray, axis_name: str,
                    group_size: int = 256):
    """Inside shard_map: int8 all-gather + local dequant-mean over axis_name.

    Returns (averaged_grad, new_error_feedback). Must be called with
    identically-shaped g on every member of the axis.
    """
    g_comp = (g + err).astype(jnp.float32)
    q, s = quantize_grad(g_comp, group_size)
    qs = jax.lax.all_gather(q, axis_name)        # (P, G, group) int8 on wire
    ss = jax.lax.all_gather(s, axis_name)        # (P, G) f32 (tiny)
    deq = jax.vmap(lambda qq, sc: dequantize_grad(qq, sc, g.shape, jnp.float32)
                   )(qs, ss)
    g_avg = jnp.mean(deq, axis=0).astype(g.dtype)
    new_err = g_comp - dequantize_grad(q, s, g.shape, jnp.float32)
    return g_avg, new_err.astype(jnp.float32)


def make_crosspod_allreduce(mesh, grad_specs, *, group_size: int = 256,
                            compressed: bool = True):
    """Build f(grads, err) -> (avg_grads, err') reducing over the 'pod' axis.

    ``grad_specs`` is a pytree of PartitionSpec matching the grads tree; the
    specs must not use the 'pod' axis (each pod holds a full replica of its
    intra-pod-sharded gradient, so reducing over 'pod' is exactly the
    cross-pod data-parallel all-reduce).

    Error-feedback buffers are PER-POD state: leaves carry a leading pod dim
    (see init_error_feedback) sharded P('pod', ...). The averaged gradients
    are mathematically replicated across pods (every pod gathers the same
    int8 payloads and reduces locally) — the VMA checker cannot infer this
    through the quantized gather, hence check_vma=False.
    """
    if "pod" not in mesh.axis_names:
        def identity(grads, err):
            return jax.tree.map(lambda g: g, grads), err
        return identity

    from jax.sharding import PartitionSpec
    err_specs = jax.tree.map(lambda s: PartitionSpec("pod", *s), grad_specs,
                             is_leaf=lambda x: isinstance(x, PartitionSpec))

    def local_fn(grads, err):
        def reduce_leaf(g, e):
            e = e[0]                                # squeeze local pod dim
            if not compressed:
                return jnp.mean(jax.lax.all_gather(g, "pod"), axis=0), \
                    e[None]
            avg, new_e = compressed_psum(g, e, "pod", group_size)
            return avg, new_e[None]
        pairs = jax.tree.map(reduce_leaf, grads, err)
        avg = jax.tree.map(lambda t: t[0], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return avg, new_err

    def allreduce(grads, err):
        from repro.core.jax_compat import shard_map
        return shard_map(
            local_fn, mesh=mesh,
            in_specs=(grad_specs, err_specs),
            out_specs=(grad_specs, err_specs),
            check_vma=False,
        )(grads, err)

    return allreduce


def init_error_feedback(grads, n_pod: int = 1):
    """Per-pod error-feedback buffers: leaves (n_pod, *grad_shape) f32,
    to be sharded P('pod', ...) on multi-pod meshes."""
    return jax.tree.map(
        lambda g: jnp.zeros((n_pod,) + g.shape, jnp.float32), grads)
