"""AdaRound — adaptive rounding for post-training quantization
(Nagel et al. 2020; paper Table 7 "W4A32 AdaRound").

Per linear layer: instead of round-to-nearest, learn a per-weight rounding
direction by optimizing the layer-wise reconstruction loss

    L(V) = || X W - X W_q(V) ||_F^2 + lam * sum(1 - |2 h(V) - 1|^beta)

where h(V) = clip(sigmoid(V) * (zeta - gamma) + gamma, 0, 1) is the rectified
sigmoid and beta is annealed high -> low so the regularizer first lets h move
freely, then forces it to {0, 1}. Final weights use hard rounding
floor(W/s) + (h(V) > 0.5).

The paper uses 1024 random sequences, 1e4 iterations, default hyper-params.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.quant_config import QuantizerConfig
from repro.core.quantizer import QuantParams, _expand
from repro.optim.adam import adam_init, adam_update, apply_updates

ZETA, GAMMA = 1.1, -0.1


@dataclasses.dataclass(frozen=True)
class AdaRoundConfig:
    iterations: int = 10_000
    lr: float = 1e-2
    reg_lambda: float = 0.01
    beta_start: float = 20.0
    beta_end: float = 2.0
    warmup_frac: float = 0.2     # no regularization for the first 20%
    batch_size: int = 256


def _rectified_sigmoid(v):
    return jnp.clip(jax.nn.sigmoid(v) * (ZETA - GAMMA) + GAMMA, 0.0, 1.0)


def _soft_quant(w, v, qp: QuantParams, cfg: QuantizerConfig):
    s, z = _expand(qp, w.ndim, cfg.channel_axis)
    wq = jnp.floor(w / s) + _rectified_sigmoid(v) + z
    return (jnp.clip(wq, cfg.qmin, cfg.qmax) - z) * s


def init_v(w, qp: QuantParams, cfg: QuantizerConfig):
    """Initialize V so that h(V) equals the float rounding residual, i.e. the
    soft-quantized weight starts at the real-valued weight."""
    s, _ = _expand(qp, w.ndim, cfg.channel_axis)
    rest = w / s - jnp.floor(w / s)
    rest = jnp.clip(rest, 1e-4, 1.0 - 1e-4)
    p = (rest - GAMMA) / (ZETA - GAMMA)
    return -jnp.log(1.0 / p - 1.0)                   # logit


def optimize_rounding(w: jnp.ndarray, x_calib: jnp.ndarray,
                      qp: QuantParams, cfg: QuantizerConfig,
                      ar_cfg: AdaRoundConfig = AdaRoundConfig(),
                      seed: int = 0):
    """Run AdaRound for one linear layer  y = x @ w  (w: [d_in, d_out]).

    x_calib: (N, d_in) calibration inputs to this layer (FP32 activations).
    Returns QuantParams-compatible hard-rounded weight (dequantized) plus the
    learned rounding mask for inspection.
    """
    v0 = init_v(w, qp, cfg)
    total = ar_cfg.iterations
    warm = int(total * ar_cfg.warmup_frac)

    def beta_at(i):
        t = jnp.clip((i - warm) / jnp.maximum(total - warm, 1), 0.0, 1.0)
        return ar_cfg.beta_end + (ar_cfg.beta_start - ar_cfg.beta_end) * \
            0.5 * (1 + jnp.cos(jnp.pi * t))

    y_ref_full = x_calib @ w

    def loss_fn(v, xb, yb, i):
        wq = _soft_quant(w, v, qp, cfg)
        rec = jnp.mean(jnp.square(xb @ wq - yb))
        h = _rectified_sigmoid(v)
        reg = jnp.sum(1.0 - jnp.abs(2.0 * h - 1.0) ** beta_at(i))
        reg = jnp.where(i < warm, 0.0, reg)
        return rec + ar_cfg.reg_lambda * reg

    opt_state = adam_init(v0)
    n = x_calib.shape[0]
    bs = min(ar_cfg.batch_size, n)

    @jax.jit
    def step(v, opt_state, key, i):
        idx = jax.random.randint(key, (bs,), 0, n)
        xb, yb = x_calib[idx], y_ref_full[idx]
        g = jax.grad(loss_fn)(v, xb, yb, i)
        upd, opt_state = adam_update(g, opt_state, v, lr=ar_cfg.lr)
        return apply_updates(v, upd), opt_state

    key = jax.random.PRNGKey(seed)
    v = v0
    for i in range(total):
        key, sub = jax.random.split(key)
        v, opt_state = step(v, opt_state, sub, jnp.asarray(i, jnp.float32))

    # Hard rounding.
    s, z = _expand(qp, w.ndim, cfg.channel_axis)
    hard = jnp.floor(w / s) + (_rectified_sigmoid(v) > 0.5).astype(w.dtype) + z
    w_hard = (jnp.clip(hard, cfg.qmin, cfg.qmax) - z) * s
    return w_hard.astype(w.dtype), _rectified_sigmoid(v)
