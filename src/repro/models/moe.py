"""Mixture-of-Experts with top-k routing and capacity-based, sort-ordered
dispatch.

Two execution paths share the routing math:

  * ``moe_apply`` — single-device path (smoke tests, calibration, quant
    integration). Sort-based dispatch into an (E, C, D) buffer, batched
    expert matmuls, weighted combine.
  * ``moe_apply_sharded`` — expert-parallel production path, to be called
    INSIDE ``shard_map``: activations are data-sharded and replicated over the
    ``model`` axis; each model shard owns E/ep experts and processes all local
    tokens routed to them, so NO all-to-all is required — the only collective
    is the psum over ``model`` that TP needs anyway (DESIGN.md §4).

Router logits are range-sensitive (softmax input — mirrors the paper's
Table-2 finding); the quant policy keeps them ≥16-bit via site
``{prefix}/router_logits``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ACTIVATIONS, dense_init, split_keys


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    capacity_factor: float = 1.25
    activation: str = "silu"
    norm_topk: bool = True         # qwen3 normalizes top-k probs
    min_capacity: int = 8          # decode-time floor (no drops at tiny t)


def _capacity(t: int, cfg: MoEConfig) -> int:
    """Per-expert slot count: capacity-factor based, floored for tiny token
    counts (decode must never drop), never above t (an expert can receive at
    most one row per token)."""
    cap = int(t * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(1, min(t, max(cap, cfg.min_capacity)))


def router_probs(p, x, cfg: MoEConfig, ctx=None, prefix="moe"):
    """x: (t, D) -> (probs (t, E), top_p (t, k), top_e (t, k))."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    if ctx is not None:
        logits = ctx.act(f"{prefix}/router_logits", logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk:
        top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    return probs, top_p, top_e


def _dispatch_indices(top_e: jnp.ndarray, num_experts: int, capacity: int,
                      e_lo: int = 0, e_hi: Optional[int] = None):
    """Sort-based dispatch bookkeeping.

    top_e: (t, k) expert ids. Returns (order, slot, keep, token_of_row) where
    rows are the t*k (token, choice) pairs in expert-sorted order; ``slot`` is
    the destination row in the local (E_local*C) buffer (overflow -> trash).
    """
    t, k = top_e.shape
    e_hi = num_experts if e_hi is None else e_hi
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)                 # (t*k,)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts))
    pos = jnp.arange(t * k) - starts[sorted_e]               # rank within expert
    local_e = sorted_e - e_lo
    keep = (sorted_e >= e_lo) & (sorted_e < e_hi) & (pos < capacity)
    trash = (e_hi - e_lo) * capacity
    slot = jnp.where(keep, local_e * capacity + pos, trash)
    token_of_row = order // k
    return order, slot, keep, token_of_row


def _expert_ffn(p, buf, cfg: MoEConfig):
    """buf: (E_local, C, D) -> (E_local, C, D) through per-expert gated MLP."""
    from repro.models.common import resolve_weight
    act = ACTIVATIONS[cfg.activation]
    wg = resolve_weight(p["w_gate"])
    wu = resolve_weight(p["w_up"])
    wo = resolve_weight(p["w_out"])
    h = act(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
        jnp.einsum("ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _dispatch_compute_combine(p, x, top_p, top_e, cfg: MoEConfig,
                              capacity: int, e_lo: int, e_hi: int):
    t, d = x.shape
    e_local = e_hi - e_lo
    order, slot, keep, token_of_row = _dispatch_indices(
        top_e, cfg.num_experts, capacity, e_lo, e_hi)
    # Scatter token rows into the expert buffer (+1 trash row).
    buf = jnp.zeros((e_local * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(x[token_of_row] * keep[:, None].astype(x.dtype))
    y = _expert_ffn(p, buf[:-1].reshape(e_local, capacity, d), cfg)
    # Gather each routed row's output and combine weighted by router prob.
    y_rows = y.reshape(e_local * capacity, d)
    y_rows = jnp.concatenate([y_rows, jnp.zeros((1, d), y.dtype)], 0)
    contrib = y_rows[slot] * top_p.reshape(-1)[order][:, None].astype(y.dtype)
    out = jnp.zeros((t, d), x.dtype).at[token_of_row].add(
        jnp.where(keep[:, None], contrib, 0).astype(x.dtype))
    return out


def moe_apply(p, x, cfg: MoEConfig, *, ctx=None, prefix="moe"):
    """Single-shard MoE. x: (t, D) flattened tokens."""
    t = x.shape[0]
    capacity = _capacity(t, cfg)
    _, top_p, top_e = router_probs(p, x, cfg, ctx, prefix)
    return _dispatch_compute_combine(p, x, top_p, top_e, cfg, capacity,
                                     0, cfg.num_experts)


def moe_apply_sharded(p, x, cfg: MoEConfig, *, ep_axis: str, ep_size: int,
                      expert_parallel: bool = True):
    """Expert-parallel MoE body — call inside shard_map.

    x: (t_local, D) tokens of this data shard, replicated over ``ep_axis``.
    expert_parallel=True: p carries E/ep experts per shard (EP); the psum
    combines disjoint expert outputs.
    expert_parallel=False (hybrid, for E < ep_size e.g. grok-1's 8 experts
    on 16 TP shards): every shard carries ALL experts but only a d_ff slice;
    the SAME psum then reduces the partial-F contributions (TP inside
    experts) — the nonlinearity is elementwise over F so slicing F is exact.
    """
    t = x.shape[0]
    e_local = cfg.num_experts // ep_size if expert_parallel \
        else cfg.num_experts
    idx = jax.lax.axis_index(ep_axis) if expert_parallel else 0
    capacity = _capacity(t, cfg)
    _, top_p, top_e = router_probs(p, x, cfg)
    # Static shard ranges differ per device; use dynamic offset via where.
    e_lo = idx * e_local
    order, slot, keep, token_of_row = _dispatch_indices(
        top_e, cfg.num_experts, capacity, 0, cfg.num_experts)
    # re-localize: keep only experts in [e_lo, e_lo + e_local)
    sorted_e = top_e.reshape(-1)[order]
    local = (sorted_e >= e_lo) & (sorted_e < e_lo + e_local) & keep
    local_slot = jnp.where(local, (sorted_e - e_lo) * capacity +
                           (slot % capacity), e_local * capacity)
    d = x.shape[1]
    buf = jnp.zeros((e_local * capacity + 1, d), x.dtype)
    buf = buf.at[local_slot].set(x[token_of_row] * local[:, None].astype(x.dtype))
    y = _expert_ffn(p, buf[:-1].reshape(e_local, capacity, d), cfg)
    y_rows = jnp.concatenate([y.reshape(e_local * capacity, d),
                              jnp.zeros((1, d), y.dtype)], 0)
    contrib = y_rows[local_slot] * top_p.reshape(-1)[order][:, None].astype(y.dtype)
    out = jnp.zeros((t, d), x.dtype).at[token_of_row].add(
        jnp.where(local[:, None], contrib, 0).astype(x.dtype))
    return jax.lax.psum(out, ep_axis)


def aux_load_balance_loss(probs: jnp.ndarray, top_e: jnp.ndarray,
                          cfg: MoEConfig) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss."""
    t = probs.shape[0]
    me = jnp.mean(probs, axis=0)                                   # (E,)
    counts = jnp.zeros((cfg.num_experts,)).at[top_e.reshape(-1)].add(1.0)
    ce = counts / jnp.maximum(t * cfg.top_k, 1)
    return cfg.num_experts * jnp.sum(me * ce)


def init_moe_params(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32,
                    num_local_experts: Optional[int] = None):
    e = num_local_experts or cfg.num_experts
    k1, k2, k3, k4 = split_keys(key, 4)
    std = 1.0 / jnp.sqrt(d_model)
    return {
        "router": dense_init(k1, d_model, cfg.num_experts, dtype),
        "w_gate": (jax.random.normal(k2, (e, d_model, cfg.d_ff)) * std).astype(dtype),
        "w_up": (jax.random.normal(k3, (e, d_model, cfg.d_ff)) * std).astype(dtype),
        "w_out": (jax.random.normal(k4, (e, cfg.d_ff, d_model)) *
                  (1.0 / jnp.sqrt(cfg.d_ff))).astype(dtype),
    }
