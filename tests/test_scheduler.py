"""Continuous-batching scheduler tests (runtime.serve_loop.Scheduler).

Three layers of coverage:

* Golden stub-model tests mirroring tests/test_serve_loop.py: the
  continuous scheduler emits exactly the greedy continuation per request,
  retires requests immediately, and admits queued requests into freed
  lanes (observable through prefill_calls / decode_steps / utilization).
* Property tests — a seeded random sweep that always runs, plus hypothesis
  versions (skipped when hypothesis is absent): no token lost or
  duplicated, every request retires, continuous == static token-for-token.
* Real-model invariants on gemma2-2b-reduced for BOTH cache types
  (KVCache and int8 QuantKVCache): a slot-insert prefill never perturbs
  the other lanes' caches (lane-hash compare), a short prompt packed with
  longer ones decodes exactly as served alone (the pad dead-cell
  contract), scheduler parity incl. the deploy-int8 path, and a
  recompile guard across admissions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.runtime import (Request, Scheduler, ServeStats, serve,
                           serve_batch, serve_continuous)
from repro.runtime.steps import (make_admit_step, make_decode_step,
                                 make_prefill_step)
from serve_testlib import golden as _golden
from serve_testlib import next_arr as _next_arr
from serve_testlib import onehot as _onehot

pytestmark = pytest.mark.serve


class StubModel:
    """Deterministic next_token = (2 * tok + 1) % VOCAB, with admit/decode
    call recording so scheduling decisions are observable."""

    def __init__(self):
        self.admit_masks = []
        self.decode_calls = 0

    def init_cache(self, batch):
        return {"kv": jnp.zeros((batch, 4), jnp.float32)}

    def admit(self, tokens, positions, admit_mask, cache):
        self.admit_masks.append(np.asarray(admit_mask).copy())
        return _onehot(_next_arr(tokens)), cache

    def decode(self, tokens, pos, cache):
        self.decode_calls += 1
        return _onehot(_next_arr(tokens)), cache


def _serve_cont(requests, batch_slots=4, prompt_pad_len=None):
    m = StubModel()
    stats = serve_continuous(m.admit, m.decode, m.init_cache, requests,
                             batch_slots=batch_slots,
                             prompt_pad_len=prompt_pad_len)
    return m, stats


def _stub_static(requests, batch_slots):
    def prefill(tokens, positions, cache):
        return _onehot(_next_arr(tokens)), cache

    def decode(tokens, pos, cache):
        return _onehot(_next_arr(tokens)), cache

    return serve_batch(prefill, decode,
                       lambda b: {"kv": jnp.zeros((b, 4), jnp.float32)},
                       requests, batch_slots=batch_slots)


class TestGoldenContinuous:
    def test_greedy_continuation_matches_golden(self):
        reqs = [Request(rid=i, prompt=np.asarray([3 + i, 5 + i]),
                        max_new_tokens=6) for i in range(3)]
        _, stats = _serve_cont(reqs)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 6)
            assert r.done
        assert stats.tokens_generated == 18

    def test_single_slot_serializes_fifo(self):
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=4),
                Request(rid=1, prompt=np.asarray([4]), max_new_tokens=4)]
        m, stats = _serve_cont(reqs, batch_slots=1)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 4)
        assert stats.prefill_calls == 2           # one admit per request
        assert stats.decode_steps == 6            # 3 each (tok 1 = prefill)
        assert stats.slot_utilization == 1.0
        # FIFO: request 0 finishes before request 1 starts
        lat = stats.request_latency
        assert lat[0].finish_step < lat[1].first_token_step

    def test_admission_into_freed_lane_midflight(self):
        """2 lanes, 3 requests: the third request is admitted into the lane
        the 1-quota request frees, while the 5-quota request keeps decoding
        — no lockstep group barrier."""
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=1),
                Request(rid=1, prompt=np.asarray([4]), max_new_tokens=5),
                Request(rid=2, prompt=np.asarray([5]), max_new_tokens=3)]
        m, stats = _serve_cont(reqs, batch_slots=2)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
            assert r.done
        # admit #1 fills both lanes; r0 retires off its prefill token, so
        # admit #2 slots r2 into the freed lane before the first decode
        assert stats.prefill_calls == 2
        np.testing.assert_array_equal(m.admit_masks[0], [True, True])
        np.testing.assert_array_equal(m.admit_masks[1], [True, False])
        # r1 needs 4 decode steps; r2 rides along in 2 of them
        assert stats.decode_steps == 4
        assert stats.slot_utilization == pytest.approx(6 / 8)
        # static lockstep on the same workload pays more idle cells
        static = _stub_static(
            [Request(rid=i, prompt=r.prompt, max_new_tokens=r.max_new_tokens)
             for i, r in enumerate(reqs)], batch_slots=2)
        assert stats.decode_steps < static.decode_steps \
            or stats.slot_utilization > static.slot_utilization

    def test_zero_quota_never_occupies_a_lane(self):
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=0),
                Request(rid=1, prompt=np.asarray([4]), max_new_tokens=2)]
        m, stats = _serve_cont(reqs, batch_slots=2)
        assert reqs[0].tokens_out == [] and reqs[0].done
        assert reqs[1].tokens_out == _golden([4], 2)
        assert stats.tokens_generated == 2
        np.testing.assert_array_equal(m.admit_masks[0], [True, False])

    def test_invalid_batch_slots_raises(self):
        reqs = [Request(rid=0, prompt=np.asarray([1]), max_new_tokens=1)]
        with pytest.raises(ValueError, match="batch_slots"):
            _serve_cont(reqs, batch_slots=0)

    def test_prompt_longer_than_pad_len_raises(self):
        reqs = [Request(rid=0, prompt=np.asarray([1, 2, 3]),
                        max_new_tokens=1)]
        with pytest.raises(ValueError, match="exceeds"):
            _serve_cont(reqs, batch_slots=1, prompt_pad_len=2)

    def test_cache_capacity_guard(self):
        """With max_len given, both schedulers reject a request whose decode
        would write past the cache (writes would be silently dropped);
        the boundary case — last write at slot max_len-1 — is accepted."""
        from repro.runtime import serve_continuous
        m = StubModel()

        def run(quota, max_len):
            return serve_continuous(
                m.admit, m.decode, m.init_cache,
                [Request(rid=0, prompt=np.asarray([3, 4]),
                         max_new_tokens=quota)],
                batch_slots=1, max_len=max_len)

        run(7, 8)                               # 2 + 7 - 1 == 8: fits
        with pytest.raises(ValueError, match="silently dropped"):
            run(8, 8)                           # last write at slot 8
        with pytest.raises(ValueError, match="silently dropped"):
            serve_batch(lambda t, pm, c: (_onehot(_next_arr(t)), c),
                        lambda t, p, c: (_onehot(_next_arr(t)), c),
                        m.init_cache,
                        [Request(rid=0, prompt=np.asarray([3, 4]),
                                 max_new_tokens=8)],
                        batch_slots=1, max_len=8)

    def test_empty_prompt_raises(self):
        """An empty prompt has no last-token logits to decode from — both
        schedulers must reject it instead of emitting garbage."""
        with pytest.raises(ValueError, match="empty prompt"):
            _serve_cont([Request(rid=0, prompt=np.asarray([], np.int32),
                                 max_new_tokens=2)], batch_slots=1)
        with pytest.raises(ValueError, match="empty prompt"):
            _stub_static([Request(rid=0, prompt=np.asarray([], np.int32),
                                  max_new_tokens=2),
                          Request(rid=1, prompt=np.asarray([4]),
                                  max_new_tokens=2)], batch_slots=2)

    def test_zero_quota_empty_prompt_consistent_across_schedulers(self):
        """A zero-quota request never needs a lane, so an empty prompt on
        it is NOT an error — in either scheduler (they must agree for
        --parity to be meaningful)."""
        def reqs():
            return [Request(rid=0, prompt=np.asarray([], np.int32),
                            max_new_tokens=0),
                    Request(rid=1, prompt=np.asarray([4]),
                            max_new_tokens=2)]
        c = reqs()
        _serve_cont(c, batch_slots=2)
        s = reqs()
        _stub_static(s, batch_slots=2)
        for rc, rs in zip(c, s):
            assert rc.done and rs.done
            assert rc.tokens_out == rs.tokens_out
        assert c[1].tokens_out == _golden([4], 2)


def _run_property(reqspecs, batch_slots):
    """Shared property body: serve the spec'd workload continuously and
    check token conservation + golden outputs + full retirement."""
    reqs = [Request(rid=i, prompt=np.arange(1, plen + 1, dtype=np.int32),
                    max_new_tokens=quota)
            for i, (plen, quota) in enumerate(reqspecs)]
    _, stats = _serve_cont(reqs, batch_slots=batch_slots)
    for r in reqs:
        assert r.done
        assert r.tokens_out == _golden(r.prompt, max(r.max_new_tokens, 0))
    assert stats.tokens_generated == sum(len(r.tokens_out) for r in reqs)
    assert len(stats.request_latency) == sum(
        1 for r in reqs if r.max_new_tokens > 0)
    # continuous == static, token for token
    static_reqs = [Request(rid=r.rid, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens) for r in reqs]
    _stub_static(static_reqs, batch_slots)
    for r, s in zip(reqs, static_reqs):
        assert r.tokens_out == s.tokens_out


class TestSchedulerProperties:
    def test_seeded_random_sweep(self):
        """Hypothesis-free sweep so the properties run everywhere."""
        rng = np.random.RandomState(0)
        for _ in range(25):
            n = rng.randint(1, 9)
            specs = [(rng.randint(1, 6), rng.randint(0, 7))
                     for _ in range(n)]
            _run_property(specs, batch_slots=rng.randint(1, 5))


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                # pragma: no cover - dev-only dependency
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    class TestSchedulerHypothesis:
        @settings(max_examples=60, deadline=None)
        @given(st.lists(st.tuples(st.integers(1, 5), st.integers(0, 8)),
                        min_size=1, max_size=10),
               st.integers(1, 5))
        def test_no_token_lost_or_duplicated(self, specs, slots):
            _run_property(specs, batch_slots=slots)
else:                              # keep the skip visible in test reports
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(see requirements-dev.txt)")
    def test_no_token_lost_or_duplicated():
        pass


# ---------------------------------------------------------------------------
# Real-model invariants (gemma2-2b-reduced: GLU, RMSNorm, softcap, and a
# ring-buffer sliding-window cache on the local_attn layers)
# ---------------------------------------------------------------------------

MAX_LEN = 32


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    return cfg, params


_STEP_CACHE = {}


def _steps(cfg, ctx_factory=None):
    """Jitted (admit, decode, prefill), memoized per (arch, ctx) so repeated
    _serve calls inside a test reuse compilations instead of re-jitting."""
    key = (cfg.name, ctx_factory)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = (
            jax.jit(make_admit_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_decode_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_prefill_step(cfg, ctx_factory=ctx_factory)))
    return _STEP_CACHE[key]


def _serve(cfg, params, reqs, *, scheduler, kv_bits, batch_slots,
           ctx_factory=None):
    admit, decode, prefill = _steps(cfg, ctx_factory)

    def init(b):
        return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                              kv_bits=kv_bits)

    return serve(prefill, admit, decode, init, params, reqs,
                 scheduler=scheduler, batch_slots=batch_slots)


def _mk_reqs(rng, cfg, lens_quotas):
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, size=n)
                    .astype(np.int32),
                    max_new_tokens=q)
            for i, (n, q) in enumerate(lens_quotas)]


def _lane_bytes(cache, lane):
    """Concatenated raw bytes of one batch lane across every cache leaf
    (scan leaves carry batch on axis 1, tail leaves on axis 0)."""
    parts = []
    for c in cache["scan"]:
        parts.extend(np.asarray(leaf[:, lane]).tobytes() for leaf in c)
    for c in cache["tail"]:
        parts.extend(np.asarray(leaf[lane]).tobytes() for leaf in c)
    return b"".join(parts)


class TestLaneInvariants:
    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_slot_insert_preserves_other_lanes(self, tiny, kv_bits):
        """Admitting into lane 1 leaves lanes 0 and 2 BIT-IDENTICAL across
        every cache leaf (k/v payloads, int8 scales, positions) — for the
        f32 cache and the int8 QuantKVCache."""
        cfg, params = tiny
        admit, decode, _ = _steps(cfg)
        B, T = 3, 6
        rng = np.random.RandomState(1)
        cache = tfm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32,
                               kv_bits=kv_bits)
        toks = rng.randint(1, cfg.vocab_size, size=(B, T)).astype(np.int32)
        posm = np.tile(np.arange(T, dtype=np.int32), (B, 1))
        logits, cache = admit(params, toks, posm,
                              np.ones((B,), bool), cache)
        cur = np.asarray(jnp.argmax(logits[:, -1:], -1), np.int32)
        pos = np.full((B, 1), T, np.int32)
        for _ in range(2):      # give lanes non-trivial decode state
            logits, cache = decode(params, cur, pos, cache)
            cur = np.asarray(jnp.argmax(logits, -1), np.int32)
            pos = pos + 1
        before = {i: _lane_bytes(cache, i) for i in range(B)}

        toks2 = np.zeros((B, T), np.int32)
        posm2 = np.full((B, T), -1, np.int32)
        toks2[1, 2:] = rng.randint(1, cfg.vocab_size, size=4)
        posm2[1, 2:] = np.arange(4)
        _, cache2 = admit(params, toks2, posm2,
                          np.asarray([False, True, False]), cache)
        after = {i: _lane_bytes(cache2, i) for i in range(B)}
        assert after[0] == before[0]
        assert after[2] == before[2]
        assert after[1] != before[1]            # the admitted lane changed

    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_short_prompt_packed_with_longer_matches_alone(self, tiny,
                                                           kv_bits):
        """The left-pad regression: a short prompt packed next to longer
        ones must produce the same greedy tokens as serving it alone (pads
        are dead cells — no attention, no cache writes, real positions)."""
        cfg, params = tiny
        rng = np.random.RandomState(2)
        packed = _mk_reqs(rng, cfg, [(3, 6), (9, 6), (7, 6)])
        alone = [Request(rid=r.rid, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens) for r in packed]
        _serve(cfg, params, packed, scheduler="static", kv_bits=kv_bits,
               batch_slots=3)
        for r in alone:
            _serve(cfg, params, [r], scheduler="static", kv_bits=kv_bits,
                   batch_slots=1)
        for p, a in zip(packed, alone):
            assert p.tokens_out == a.tokens_out, f"rid {p.rid}"

    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_continuous_matches_static_greedy(self, tiny, kv_bits):
        """Scheduler parity on a skewed ragged workload that forces
        mid-flight admissions and ring-buffer slot reuse (positions cross
        the local_attn window)."""
        cfg, params = tiny
        rng = np.random.RandomState(3)
        spec = [(5, 2), (9, 12), (3, 1), (7, 4), (4, 8), (6, 2)]
        static = _mk_reqs(rng, cfg, spec)
        cont = [Request(rid=r.rid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens) for r in static]
        s_stats = _serve(cfg, params, static, scheduler="static",
                         kv_bits=kv_bits, batch_slots=2)
        c_stats = _serve(cfg, params, cont, scheduler="continuous",
                         kv_bits=kv_bits, batch_slots=2)
        for s, c in zip(static, cont):
            assert s.tokens_out == c.tokens_out, f"rid {s.rid}"
            assert c.done
        assert c_stats.tokens_generated == s_stats.tokens_generated
        assert c_stats.slot_utilization >= s_stats.slot_utilization

    def test_no_recompiles_across_admissions(self, tiny):
        """The jitted admit / decode steps trace exactly once for the whole
        run even though requests with ragged prompts and skewed quotas are
        admitted mid-flight (fixed shapes + traced slot data)."""
        cfg, params = tiny
        traces = {"admit": 0, "decode": 0}
        base_admit = make_admit_step(cfg)
        base_decode = make_decode_step(cfg)

        def admit_fn(params, t, pm, m, c):
            traces["admit"] += 1
            return base_admit(params, t, pm, m, c)

        def decode_fn(params, t, p, c):
            traces["decode"] += 1
            return base_decode(params, t, p, c)

        admit_j = jax.jit(admit_fn)
        decode_j = jax.jit(decode_fn)
        rng = np.random.RandomState(4)
        reqs = _mk_reqs(rng, cfg, [(4, 2), (6, 5), (2, 1), (5, 3), (3, 4)])
        stats = serve_continuous(
            lambda t, pm, m, c: admit_j(params, t, pm, m, c),
            lambda t, p, c: decode_j(params, t, p, c),
            lambda b: tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32),
            reqs, batch_slots=2)
        assert stats.prefill_calls >= 3         # several admission rounds
        assert traces == {"admit": 1, "decode": 1}


@pytest.mark.deploy
class TestDeploySchedulerParity:
    """Scheduler parity on the integer deployment path: packed int8 weights
    + Pallas kernels, with the f32 cache and the int8 KV cache (fused
    decode kernel). Mirrors the gemma_deploy setup in tests/test_deploy.py.
    """

    @pytest.fixture(scope="class")
    def deployed(self):
        from repro.core import Mode, QuantCtx, build_deploy, peg_policy
        from repro.core.pipeline import ptq
        cfg = get_config("gemma2-2b").reduced()
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(cfg, key, stacked=True, dtype=jnp.float32)
        pol = peg_policy(4)
        flat = tfm.init_params(cfg, key, stacked=False, dtype=jnp.float32)
        calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10),
                                               (2, 8), 0, cfg.vocab_size)}]

        def fwd(p, b, ctx):
            logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
            return logits

        qm = ptq(fwd, flat, calib, pol, collect_inputs=True)
        shared = {}
        for site, qp in qm.act_state.items():
            base = ("layer/" + site.split("/", 1)[1]
                    if site.startswith("layer") else site)
            shared.setdefault(base, qp)
        packed, acts = build_deploy(cfg, params, pol, shared)

        def ctx_factory():
            return QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=shared,
                            deploy_acts=acts)
        return cfg, packed, ctx_factory

    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_continuous_matches_static_int8(self, deployed, kv_bits):
        cfg, packed, ctx_factory = deployed
        rng = np.random.RandomState(5)
        spec = [(4, 2), (8, 6), (3, 1), (6, 4)]
        static = _mk_reqs(rng, cfg, spec)
        cont = [Request(rid=r.rid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens) for r in static]
        _serve(cfg, packed, static, scheduler="static", kv_bits=kv_bits,
               batch_slots=2, ctx_factory=ctx_factory)
        _serve(cfg, packed, cont, scheduler="continuous", kv_bits=kv_bits,
               batch_slots=2, ctx_factory=ctx_factory)
        for s, c in zip(static, cont):
            assert s.tokens_out == c.tokens_out, f"rid {s.rid}"
