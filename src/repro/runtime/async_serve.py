"""Async serving front-end over the decomposed Engine triad.

:class:`AsyncServer` turns the pull-style :func:`~repro.runtime.engine.
serve_engine` loop into a push-style service: producers ``submit()``
prompts from any thread and read tokens back through a per-request
:class:`TokenStream`, while ONE scheduler thread owns the engine and its
:class:`~repro.runtime.engine.DecodeState` and drives the
prefill -> insert -> generate cycle.

Threading contract:

* The engine and every device buffer are touched ONLY by the scheduler
  thread — producers never hold a jax object, so no device-side locking
  is needed. Submissions cross over through a thread-safe inbox queue;
  tokens cross back through each stream's internal condition variable.
* FIFO admission in ARRIVAL order (the inbox's order), whatever thread
  races produced it: two producers submitting concurrently get whichever
  interleave the queue saw, but each request's OWN tokens arrive on its
  stream strictly in generation order and equal the synchronous
  Scheduler's greedy emissions for the same prompt (lanes are
  computationally independent — see docs/serving.md).
* ``cancel()`` retires a request at the next scheduler iteration:
  resident lanes are released (host-side pos sentinel — no device call),
  queued requests never admit. The stream closes with ``cancelled=True``
  and keeps the tokens emitted so far.
* ``close()`` drains by default (every accepted request finishes), then
  joins the thread; ``close(drain=False)`` cancels everything pending.
"""
from __future__ import annotations

import collections
import queue as _queue
import threading
from typing import Any, List, Optional

import numpy as np

from repro.runtime.engine import DecodeState, Engine


class TokenStream:
    """One request's token stream. The scheduler thread appends tokens;
    any number of consumers iterate (blocking) or poll. Iteration yields
    each token exactly once per iterator, in generation order, and ends
    when the request retires (quota reached or cancelled)."""

    def __init__(self, rid: Any = None):
        self.rid = rid
        self._cv = threading.Condition()
        self._toks: List[int] = []
        self._closed = False
        self.cancelled = False

    # -- scheduler-thread side ---------------------------------------------

    def _put(self, tok: int) -> None:
        with self._cv:
            self._toks.append(int(tok))
            self._cv.notify_all()

    def _close(self, cancelled: bool = False) -> None:
        with self._cv:
            self._closed = True
            self.cancelled = self.cancelled or cancelled
            self._cv.notify_all()

    # -- consumer side ------------------------------------------------------

    @property
    def done(self) -> bool:
        with self._cv:
            return self._closed

    def tokens_so_far(self) -> List[int]:
        with self._cv:
            return list(self._toks)

    def result(self, timeout: Optional[float] = None) -> List[int]:
        """Block until the stream closes, then return ALL its tokens."""
        with self._cv:
            if not self._cv.wait_for(lambda: self._closed, timeout):
                raise TimeoutError(f"stream {self.rid!r} still open "
                                   f"after {timeout}s")
            return list(self._toks)

    def __iter__(self):
        i = 0
        while True:
            with self._cv:
                self._cv.wait_for(
                    lambda: i < len(self._toks) or self._closed)
                if i >= len(self._toks):
                    return
                tok = self._toks[i]
            i += 1
            yield tok


class _Pending:
    __slots__ = ("stream", "prompt", "quota")

    def __init__(self, stream: TokenStream, prompt: np.ndarray, quota: int):
        self.stream = stream
        self.prompt = prompt
        self.quota = quota


class AsyncServer:
    """Push-style serving front-end: one scheduler thread drives an
    :class:`~repro.runtime.engine.Engine`'s decomposed triad over a
    thread-safe submission queue. See the module docstring for the
    threading contract."""

    # scheduler-thread poll period while lanes are idle and the inbox is
    # empty — bounds cancel/close latency without spinning
    _IDLE_WAIT = 0.005

    def __init__(self, engine: Engine):
        self._engine = engine
        self._inbox: _queue.Queue = _queue.Queue()
        self._cancelled: set = set()        # id(stream) marks
        self._lock = threading.Lock()       # guards _cancelled / _closing
        self._closing = False
        self._drain = True
        self._thread = threading.Thread(
            target=self._loop, name="async-serve-scheduler", daemon=True)
        self._thread.start()

    # -- producer side ------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               rid: Any = None) -> TokenStream:
        """Enqueue one request; returns its stream immediately. Safe from
        any thread. Quota <= 0 closes the stream without ever admitting."""
        with self._lock:
            if self._closing:
                raise RuntimeError("AsyncServer is closed")
        stream = TokenStream(rid)
        prompt = np.asarray(prompt, np.int32)
        if max_new_tokens <= 0:
            stream._close()
            return stream
        self._inbox.put(_Pending(stream, prompt, max_new_tokens))
        return stream

    def cancel(self, stream: TokenStream) -> None:
        """Retire ``stream``'s request at the next scheduler iteration —
        free whether it is still queued or already generating (lane
        release is a host-side sentinel write). Idempotent; a no-op on an
        already-finished stream."""
        with self._lock:
            self._cancelled.add(id(stream))

    def close(self, drain: bool = True) -> None:
        """Stop the scheduler thread. ``drain=True`` (default) finishes
        every accepted request first; ``drain=False`` cancels all queued
        AND resident requests. Further submits raise."""
        with self._lock:
            self._closing = True
            self._drain = drain
        self._thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- scheduler thread ---------------------------------------------------

    def _is_cancelled(self, stream: TokenStream) -> bool:
        with self._lock:
            return id(stream) in self._cancelled

    def _loop(self) -> None:
        eng = self._engine
        B = eng.batch_slots
        state = eng.init_state()
        lanes: List[Optional[_Pending]] = [None] * B
        pending: collections.deque = collections.deque()
        while True:
            # drain the inbox (non-blocking — arrival order preserved)
            while True:
                try:
                    pending.append(self._inbox.get_nowait())
                except _queue.Empty:
                    break
            with self._lock:
                closing, drain = self._closing, self._drain
            if closing and not drain:
                for item in pending:
                    item.stream._close(cancelled=True)
                pending.clear()
                for slot in range(B):
                    if lanes[slot] is not None:
                        lanes[slot].stream._close(cancelled=True)
                        state = eng.release(slot, state)
                        lanes[slot] = None
            # cancellation sweep: queued requests never admit, resident
            # lanes release (host-side only — generation just stops)
            for item in list(pending):
                if self._is_cancelled(item.stream):
                    pending.remove(item)
                    item.stream._close(cancelled=True)
            for slot in range(B):
                item = lanes[slot]
                if item is not None and self._is_cancelled(item.stream):
                    lanes[slot] = None
                    state = eng.release(slot, state)
                    item.stream._close(cancelled=True)
            # admission: decomposed prefill+insert into every free slot
            for slot in range(B):
                if lanes[slot] is not None or not pending:
                    continue
                item = pending.popleft()
                first, payload = eng.prefill(item.prompt)
                state = eng.insert(payload, slot, state)
                item.stream._put(first)
                if item.quota <= 1:
                    item.stream._close()
                    state = eng.release(slot, state)
                else:
                    lanes[slot] = item
            live = [s for s in range(B) if lanes[s] is not None]
            if not live:
                if closing and self._inbox.empty() and not pending:
                    return
                # idle: park briefly on the inbox so submit() wakes us
                try:
                    pending.append(self._inbox.get(timeout=self._IDLE_WAIT))
                except _queue.Empty:
                    pass
                continue
            # one generate step over every lane; idle lanes emit garbage
            # the loop ignores (dead-cell sentinel drops their writes)
            toks, cache = eng.generate(state)
            tokens, pos = state.tokens.copy(), state.pos.copy()
            for slot in live:
                item = lanes[slot]
                tokens[slot, 0] = toks[slot, 0]
                pos[slot, 0] += 1
                item.stream._put(int(toks[slot, 0]))
                if len(item.stream._toks) >= item.quota:
                    item.stream._close()
                    lanes[slot] = None
                    pos[slot, 0] = -1
            state = DecodeState(tokens, pos, cache)
