"""Reproduction of the paper's problem investigation (Fig. 2 / App. D):
visualize per-embedding-dimension activation ranges of the FFN input vs
output, count outlier dims (>6 sigma), and show the correlation with
separator tokens.

Run:  PYTHONPATH=src python examples/outlier_analysis.py
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "benchmarks")


def main():
    from common import train_task, bench_cfg, _task_src, OUTLIER_DIMS
    from repro.core import fp32_policy
    from repro.core.calibration import collect_ranges
    from repro.core.quant_config import QuantizationPolicy, A8_DEFAULT
    from repro.data.synthetic import GLUE_SUITE
    from repro.models import bert

    task = GLUE_SUITE[5]        # syn-mnli (the paper uses MNLI for Fig. 2)
    print(f"training/loading {task.name} ...")
    params = train_task(task)
    cfg = bench_cfg(task)
    src = _task_src(task)

    batches = []
    for i in range(4):
        b = src.batch(16, 500_000 + i)
        batches.append({k: jnp.asarray(v) for k, v in b.items()})

    def fwd(p, b, ctx):
        return bert.encode(cfg, p, b["tokens"], type_ids=b.get("type_ids"),
                           pad_mask=b.get("pad_mask"), ctx=ctx)

    pol = QuantizationPolicy(act_default=A8_DEFAULT)
    states, tensors = collect_ranges(fwd, params, batches, pol)

    L = cfg.num_layers
    print("\nper-layer FFN input vs output dynamic range (paper Fig. 2a):")
    print(f"{'layer':>5} {'in_range':>9} {'out_range':>9} {'ratio':>6} "
          f"{'outlier dims (>6 std)':<30}")
    for i in range(L):
        rin = states[f"layer{i}/ffn_in"]
        rout = states[f"layer{i}/ffn_out"]
        in_rng = float(jnp.max(rin.x_max - rin.x_min))
        out_rng = float(jnp.max(rout.x_max - rout.x_min))
        x = tensors[f"layer{i}/ffn_out"]
        std = float(jnp.std(x))
        per_dim_amax = np.asarray(jnp.max(jnp.abs(x), axis=(0, 1)))
        outliers = np.where(per_dim_amax > 6 * std)[0]
        print(f"{i:>5} {in_rng:>9.2f} {out_rng:>9.2f} "
              f"{out_rng / max(in_rng, 1e-9):>6.1f} {outliers.tolist()!s:<30}")

    print(f"\nplanted outlier dims at init: {list(OUTLIER_DIMS)}")
    x = tensors[f"layer{L - 1}/residual_ffn"]
    std = float(jnp.std(x))
    per_dim = np.asarray(jnp.max(jnp.abs(x), axis=(0, 1)))
    top = np.argsort(per_dim)[-6:][::-1]
    print("top residual_ffn dims by |activation| (should contain the "
          f"planted dims): {top.tolist()}")

    # paper Fig. 2b: outliers consistent ACROSS sequences
    hits = (np.abs(np.asarray(x)) > 6 * std)      # (B, T, d)
    per_seq_dims = [set(np.where(hits[b].any(0))[0]) for b in
                    range(hits.shape[0])]
    common = set.intersection(*per_seq_dims) if per_seq_dims else set()
    print(f"outlier dims shared by ALL {hits.shape[0]} sequences: "
          f"{sorted(common)}")

    # [SEP]-token correlation (paper §3): range at separator positions
    toks = np.asarray(batches[-1]["tokens"])
    sep_pos = toks == 2
    x_np = np.asarray(x)
    sep_amax = float(np.max(np.abs(x_np[sep_pos]))) if sep_pos.any() else 0.0
    other_amax = float(np.max(np.abs(x_np[~sep_pos])))
    print(f"max |residual_ffn| at [SEP] positions: {sep_amax:.2f} vs "
          f"elsewhere: {other_amax:.2f}")


if __name__ == "__main__":
    main()
