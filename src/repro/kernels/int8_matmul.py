"""Pallas TPU kernel: s8 x s8 -> s32 matmul with fused PEG re-scaling.

Realizes the paper's eq. (4)->(5) on the MXU: with per-embedding-group
activation scales, the accumulator must be re-scaled once per GROUP rather
than once per element. We align the K-grid of the matmul to the PEG group
boundaries, so each k-step contributes  s_g * (A_g @ W_g)  into an f32 VMEM
scratch accumulator — exactly K re-scalings per output tile, fused with the
matmul (no extra HBM traffic).

Grid: (M/bm, N/bn, K/bk) with bk == group_size (lane-aligned multiple of 128).
Weights are symmetric per-tensor int8 (paper setup), activations asymmetric
per-group int8: A_hat = s_g (A_q - z_g), W_hat = s_w W_q, so

  out = s_w * sum_g s_g [ (A_q,g @ W_q,g) - z_g * colsum(W_q,g) ]

The zero-point correction term colsum(W_q,g) is precomputed by the wrapper
(ops.py) and added per group — the standard fixed-point trick.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _vmem_scratch(shape, dtype):
    """VMEM scratch accumulator (TPU target; interpret mode emulates it)."""
    return pltpu.VMEM(shape, dtype)


def _int8_matmul_kernel(sa_ref, za_ref, wcs_ref, a_ref, w_ref, o_ref,
                        acc_ref, *, n_k: int, s_w: float):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    w = w_ref[...]
    part = jax.lax.dot_general(a, w, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)
    s_g = sa_ref[0]
    z_g = za_ref[0]
    # zero-point correction: z_g * colsum(W_q,g), precomputed per (group, n)
    corr = wcs_ref[0, :].astype(jnp.float32)
    acc_ref[...] += s_g * (part.astype(jnp.float32) - z_g * corr[None, :])

    @pl.when(k_idx == n_k - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * s_w).astype(o_ref.dtype)


def int8_matmul_peg(a_q: jnp.ndarray, w_q: jnp.ndarray,
                    act_scales: jnp.ndarray, act_zps: jnp.ndarray,
                    w_scale: float, w_colsum_g: jnp.ndarray, *,
                    out_dtype=jnp.float32, block_m: int = 256,
                    block_n: int = 256, interpret: bool = False
                    ) -> jnp.ndarray:
    """a_q: (M, K) int8 group-sorted; w_q: (K, N) int8; act_scales/zps: (G,);
    w_colsum_g: (G, N) int32 = per-group column sums of w_q.
    K % G == 0 and group_size = K // G (the k-block)."""
    m, k = a_q.shape
    k2, n = w_q.shape
    assert k == k2
    g = act_scales.shape[0]
    assert k % g == 0
    bk = k // g
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0

    kernel = functools.partial(_int8_matmul_kernel, n_k=g, s_w=float(w_scale))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        grid=(m // bm, n // bn, g),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, kk: (kk,)),        # s_g
            pl.BlockSpec((1,), lambda i, j, kk: (kk,)),        # z_g
            pl.BlockSpec((1, bn), lambda i, j, kk: (kk, j)),   # colsum slice
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),  # A tile
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),  # W tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(act_scales.astype(jnp.float32), act_zps.astype(jnp.float32),
      w_colsum_g, a_q, w_q)


def _int8_matmul_pertensor_kernel(a_ref, w_ref, o_ref, acc_ref, *,
                                  n_k: int, s_out: float):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _done():
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * s_out
                      ).astype(o_ref.dtype)


def int8_matmul(a_q: jnp.ndarray, w_q: jnp.ndarray, s_a: float, s_w: float,
                *, out_dtype=jnp.float32, block_m: int = 256,
                block_n: int = 256, block_k: int = 512,
                interpret: bool = False) -> jnp.ndarray:
    """Per-tensor symmetric path (paper eq. 3): one rescale at the end.
    a_q: (M, K) int8, w_q: (K, N) int8."""
    m, k = a_q.shape
    _, n = w_q.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0

    kernel = functools.partial(_int8_matmul_pertensor_kernel,
                               n_k=k // bk, s_out=float(s_a) * float(s_w))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_q, w_q)
