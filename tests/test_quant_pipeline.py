"""Integration tests: the full PTQ / QAT pipeline on the paper's BERT model.

These exercise the exact flow of the paper's §5 experiments at smoke scale:
calibrate activation ranges -> build PEG groups -> quantized inference,
plus QAT parameter learning and AdaRound refinement.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Mode, QuantCtx, fp32_policy, mixed_precision_policy,
                        peg_policy, ptq, w8a8_policy)
from repro.core.calibration import build_act_state, collect_ranges
from repro.core.qat import init_qat_params
from repro.models import bert


OUTLIER_DIMS = (5, 40, 77, 100)    # spread over all 4 natural d/K chunks


@pytest.fixture(scope="module")
def tiny_bert():
    cfg = bert.tiny()
    params = bert.init_params(cfg, jax.random.PRNGKey(0))
    # plant paper-style structured outliers: scale up a few columns of every
    # FFN output projection so residual_ffn develops outlier embedding dims
    for p in params["layers"]:
        for j, dim in enumerate(OUTLIER_DIMS):
            p["w_out"] = p["w_out"].at[:, dim].multiply(100.0 - 10.0 * j)
    batches = []
    for i in range(4):
        toks = jax.random.randint(jax.random.PRNGKey(10 + i), (8, 32), 0,
                                  cfg.vocab_size)
        batches.append({"tokens": toks})
    return cfg, params, batches


def _forward(cfg):
    def fwd(params, batch, ctx):
        return bert.classify(cfg, params, batch["tokens"], ctx=ctx)
    return fwd


class TestCalibration:
    def test_collect_covers_all_sites(self, tiny_bert):
        cfg, params, batches = tiny_bert
        states, tensors = collect_ranges(_forward(cfg), params, batches,
                                         w8a8_policy())
        expected = set(bert.activation_sites(cfg))
        assert expected.issubset(set(states.keys()))

    def test_apply_changes_outputs_bounded(self, tiny_bert):
        cfg, params, batches = tiny_bert
        qm = ptq(_forward(cfg), params, batches, w8a8_policy())
        out_fp = _forward(cfg)(params, batches[0], None)
        out_q = _forward(cfg)(params, batches[0], qm.ctx())
        # quantization adds noise but keeps outputs in the same regime
        assert not np.allclose(np.asarray(out_fp), np.asarray(out_q))
        assert np.all(np.isfinite(np.asarray(out_q)))

    def test_fp32_policy_is_identity(self, tiny_bert):
        cfg, params, batches = tiny_bert
        qm = ptq(_forward(cfg), params, batches, fp32_policy())
        out_fp = _forward(cfg)(params, batches[0], None)
        out_q = _forward(cfg)(params, batches[0], qm.ctx())
        np.testing.assert_allclose(np.asarray(out_fp), np.asarray(out_q))


FFN_PAT = r".*/(ffn_(in|out)|residual_ffn)"


def _ffn_only_policy(act_cfg):
    """Quantize ONLY the FFN residual path (everything else FP32): isolates
    the paper's bottleneck so policies separate decisively at smoke scale."""
    from repro.core import FP32, QuantizationPolicy
    return QuantizationPolicy(weight_default=FP32, act_default=FP32,
                              act_overrides={FFN_PAT: act_cfg})


class TestPaperOrdering:
    """The paper's qualitative claims, as orderings of hidden-state error
    with quantization isolated to the FFN residual path (Table 2's
    bottleneck)."""

    def _hidden_err(self, cfg, params, batches, policy):
        def fwd(p, b, ctx):
            return bert.encode(cfg, p, b["tokens"], ctx=ctx)
        qm = ptq(fwd, params, batches, policy)
        out_fp = fwd(params, batches[0], None)
        out_q = fwd(params, batches[0], qm.ctx())
        return float(jnp.mean(jnp.square(out_fp - out_q)) /
                     jnp.mean(jnp.square(out_fp)))

    def test_peg_beats_per_tensor(self, tiny_bert):
        from repro.core import A8_DEFAULT, peg_config
        cfg, params, batches = tiny_bert
        e_pt = self._hidden_err(cfg, params, batches,
                                _ffn_only_policy(A8_DEFAULT))
        e_peg = self._hidden_err(cfg, params, batches,
                                 _ffn_only_policy(peg_config(4)))
        assert e_peg < e_pt / 2

    def test_permutation_beats_no_permutation(self, tiny_bert):
        """Table 5 '+P' rows, asserted at the bottleneck tensor: outliers
        spread over all natural chunks make un-permuted grouping pollute
        every group, while the range-based permutation isolates them."""
        from repro.core import fake_quant, peg_config
        from repro.core.calibration import build_act_state, collect_ranges
        cfg, params, batches = tiny_bert

        def fwd(p, b, ctx):
            return bert.encode(cfg, p, b["tokens"], ctx=ctx)

        site = "layer0/residual_ffn"
        errs = {}
        for use_p in (True, False):
            pol = _ffn_only_policy(peg_config(4, use_permutation=use_p))
            states, tensors = collect_ranges(fwd, params, batches, pol)
            act_state, specs = build_act_state(states, tensors, pol)
            x = tensors[site]
            xq = fake_quant(x, act_state[site], pol.act_config(site))
            # error restricted to CLEAN dims (the paper's damage mechanism)
            clean = np.ones(x.shape[-1], bool)
            clean[list(OUTLIER_DIMS)] = False
            errs[use_p] = float(jnp.mean(jnp.square(x - xq)[..., clean]))
            if use_p:   # all outliers must share one group
                gi_nat = specs[site].group_index[
                    specs[site].inverse_permutation]
                assert len({int(gi_nat[d]) for d in OUTLIER_DIMS}) == 1
        # noP pollutes all 4 groups (124 clean dims coarse) vs P's single
        # polluted group (28 clean dims coarse) — but the un-permuted groups
        # carry slightly smaller per-group scales, so expect ~2x, not 4x.
        # Measured on this fixture: ~1.7x. The property under test is a
        # MULTIPLE-factor win (not a few percent), so assert > 1.5x —
        # above noise, with headroom under the fixture's 1.7x.
        assert errs[True] < errs[False] / 1.5

    def test_mixed_precision_16bit_recovers(self, tiny_bert):
        """Table 4: 16-bit on the FFN residual path ~= FP32."""
        from repro.core import A16_DEFAULT, A8_DEFAULT
        cfg, params, batches = tiny_bert
        e_pt = self._hidden_err(cfg, params, batches,
                                _ffn_only_policy(A8_DEFAULT))
        e_16 = self._hidden_err(cfg, params, batches,
                                _ffn_only_policy(A16_DEFAULT))
        assert e_16 < e_pt / 100

    def test_peg_specs_built_for_ffn_sites_only(self, tiny_bert):
        cfg, params, batches = tiny_bert
        qm = ptq(_forward(cfg), params, batches, peg_policy(4))
        assert len(qm.peg_specs) > 0
        for site in qm.peg_specs:
            assert ("ffn_in" in site or "ffn_out" in site
                    or "residual_ffn" in site)


class TestQAT:
    def test_qat_recovers_from_perturbed_scales(self, tiny_bert):
        """PTQ-initialized scales are already near-MSE-optimal (flat loss —
        that's the point of good init, paper §5 'initialize from PTQ').
        Perturb them 4x and verify learnable-range QAT descends back."""
        cfg, params, batches = tiny_bert
        qm = ptq(_forward(cfg), params, batches, w8a8_policy())
        from repro.core.calibration import build_weight_state
        wstate = build_weight_state(bert.named_weight_sites(cfg, params),
                                    qm.policy)
        qat_p = init_qat_params(qm.act_state, wstate)
        # sabotage: all activation scales x4 (coarse), log-space +log(4)
        qat_p["act"] = jax.tree.map(lambda v: v + np.log(4.0),
                                    {k: {"log_scale": d["log_scale"]}
                                     for k, d in qat_p["act"].items()})
        for k in qat_p["act"]:
            qat_p["act"][k]["offset"] = \
                init_qat_params(qm.act_state, wstate)["act"][k]["offset"]
        out_fp = _forward(cfg)(params, batches[0], None)

        def loss(qat_params):
            ctx = QuantCtx(policy=qm.policy, mode=Mode.QAT,
                           act_state=qm.act_state, weight_state=wstate,
                           qat_params=qat_params)
            out = _forward(cfg)(params, batches[0], ctx)
            return jnp.mean(jnp.square(out - out_fp))

        from repro.optim import adam_init, adam_update, apply_updates
        l0 = float(loss(qat_p))
        opt = adam_init(qat_p)

        # lr matters: the log-scale loss surface here is badly conditioned
        # (STE kinks at the clip boundaries), and lr >= 1e-2 makes Adam
        # oscillate around the basin without settling (measured final/l0
        # of 0.97-1.9 across 40-150 steps). 3e-3 descends monotonically
        # to ~0.63 in 150 steps; longer runs start oscillating again, so
        # the step count is part of the contract.
        @jax.jit
        def step(qp, opt):
            g = jax.grad(loss)(qp)
            upd, opt = adam_update(g, opt, qp, lr=3e-3)
            return apply_updates(qp, upd), opt

        for _ in range(150):
            qat_p, opt = step(qat_p, opt)
        l1 = float(loss(qat_p))
        assert np.isfinite(l1)
        assert l1 < l0 * 0.7


class TestAdaRound:
    def test_adaround_beats_nearest_rounding(self):
        from repro.core import QuantizerConfig, RangeEstimator, fake_quant
        from repro.core.adaround import AdaRoundConfig, optimize_rounding
        from repro.core.range_estimation import estimate_weight_params
        key = jax.random.PRNGKey(0)
        d_in, d_out, n = 64, 32, 256
        w = jax.random.normal(key, (d_in, d_out)) / 8.0
        x = jax.random.normal(jax.random.PRNGKey(1), (n, d_in))
        cfg = QuantizerConfig(bits=4, symmetric=True,
                              estimator=RangeEstimator.MSE)
        qp = estimate_weight_params(w, cfg)
        w_nearest = fake_quant(w, qp, cfg)
        err_nearest = float(jnp.mean(jnp.square(x @ w - x @ w_nearest)))
        w_ada, h = optimize_rounding(
            w, x, qp, cfg, AdaRoundConfig(iterations=300, batch_size=128))
        err_ada = float(jnp.mean(jnp.square(x @ w - x @ w_ada)))
        assert err_ada < err_nearest
        # the learned h must be (near-)binary after annealing pressure
        assert np.all((np.asarray(h) < 0.45) | (np.asarray(h) > 0.55) |
                      np.isclose(np.asarray(h), 0.5, atol=0.2))

    def test_adaround_stays_on_grid(self):
        """AdaRound only moves weights to ADJACENT grid points."""
        from repro.core import QuantizerConfig, RangeEstimator
        from repro.core.adaround import AdaRoundConfig, optimize_rounding
        from repro.core.range_estimation import estimate_weight_params
        w = jax.random.normal(jax.random.PRNGKey(2), (32, 16)) / 4
        x = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
        cfg = QuantizerConfig(bits=4, symmetric=True,
                              estimator=RangeEstimator.MSE)
        qp = estimate_weight_params(w, cfg)
        w_ada, _ = optimize_rounding(w, x, qp, cfg,
                                     AdaRoundConfig(iterations=100))
        grid = np.round(np.asarray(w_ada) / float(qp.scale))
        np.testing.assert_allclose(np.asarray(w_ada),
                                   grid * float(qp.scale), atol=1e-5)
        # adjacent to floor/ceil of the real weight (modulo grid clipping —
        # MSE-shrunk ranges clip tail weights to qmin/qmax)
        lo = np.floor(np.asarray(w) / float(qp.scale))
        cand_lo = np.clip(lo, cfg.qmin, cfg.qmax)
        cand_hi = np.clip(lo + 1, cfg.qmin, cfg.qmax)
        assert np.all((grid == cand_lo) | (grid == cand_hi))
