"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,                 # wkv heads = d_model / rwkv_head_size
    num_kv_heads=32,              # unused (attention-free)
    d_ff=7168,
    vocab_size=65536,
    block_pattern=("rwkv",),
    rwkv_head_size=64,
    rope_theta=None,
    norm="layernorm",
    act="relu",
    ffn_type="mlp",               # channel-mix handles its own shape
    tie_embeddings=False,
    sub_quadratic=True,           # O(1) state: runs long_500k
    source="arXiv:2404.05892; unverified",
)
