"""Fault-tolerance primitives: preemption handling, straggler watchdog,
restart bookkeeping (DESIGN.md §4).

On a real cluster the watchdog feeds the control plane (drain + re-mesh from
the last checkpoint); here it exposes the same interface and is exercised by
unit tests and the train loop.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, List, Optional


class PreemptionGuard:
    """SIGTERM/SIGINT -> set a flag; the train loop checkpoints and exits
    cleanly instead of dying mid-step."""

    def __init__(self, install: bool = True):
        self.preempted = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM,):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:       # not main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.preempted = True

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


@dataclasses.dataclass
class StragglerWatchdog:
    """EMA step-time monitor. A step slower than ``threshold`` x EMA is a
    straggler event; ``trip_after`` consecutive events trips the watchdog
    (real deployment: triggers elastic re-mesh from checkpoint)."""
    threshold: float = 2.5
    momentum: float = 0.9
    trip_after: int = 3
    warmup_steps: int = 5

    ema: float = 0.0
    steps: int = 0
    consecutive: int = 0
    events: List[int] = dataclasses.field(default_factory=list)
    tripped: bool = False

    def observe(self, step_time: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        self.steps += 1
        if self.steps <= self.warmup_steps:
            self.ema = step_time if self.ema == 0.0 else \
                self.momentum * self.ema + (1 - self.momentum) * step_time
            return False
        flagged = step_time > self.threshold * self.ema
        if flagged:
            self.events.append(self.steps)
            self.consecutive += 1
            if self.consecutive >= self.trip_after:
                self.tripped = True
        else:
            self.consecutive = 0
            self.ema = self.momentum * self.ema + \
                (1 - self.momentum) * step_time
        return flagged


@dataclasses.dataclass
class RestartPolicy:
    """Bounded-retry restart bookkeeping for the outer supervisor."""
    max_restarts: int = 10
    window_s: float = 3600.0
    restarts: List[float] = dataclasses.field(default_factory=list)

    def should_restart(self, now: Optional[float] = None) -> bool:
        now = now if now is not None else time.time()
        self.restarts = [t for t in self.restarts if now - t < self.window_s]
        if len(self.restarts) >= self.max_restarts:
            return False
        self.restarts.append(now)
        return True
