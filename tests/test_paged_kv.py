"""Paged KV-cache subsystem: block-pool allocator, paged decode kernels
(bf16 + int8) vs their oracles, block-table / derived-position properties,
and the pool-managed continuous scheduler.

Layers of coverage (mirroring tests/test_kv_quant.py + test_scheduler.py):

* BlockPool unit tests — prefix mapping, reservation backpressure, growth
  within reservation, free-list accounting (leak check).
* Kernel-vs-oracle for ``paged_attend_decode`` and
  ``paged_int8_attend_decode`` across window / softcap / GQA / partially
  mapped lanes / idle lanes / in-kernel softmax sites.
* Write-path + derived-position properties: stored positions equal derived
  positions on every written cell, and a reallocated block's STALE cells
  are never readable (allocation order, not memset, provides isolation).
* Stub-model scheduler properties with a constrained pool: golden tokens
  under backpressure, FIFO admission, all blocks returned.
* Real-model invariants on gemma2-2b-reduced: paged == dense greedy
  parity across schedulers (kv 16 + int8 kv 8, plus the deploy-int8
  integer path), slot-insert admission leaves other lanes' *blocks*
  bit-identical, capacity validation errors match the dense path's, and
  the jitted steps trace exactly once across paged admissions + growth.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models import attention as att
from repro.models import transformer as tfm
from repro.runtime import (BlockPool, Request, blocks_for_tokens, serve,
                           serve_continuous)
from repro.runtime.steps import (make_admit_step, make_decode_step,
                                 make_prefill_step)
from serve_testlib import golden as _golden
from serve_testlib import next_arr as _next_arr
from serve_testlib import onehot as _onehot

pytestmark = pytest.mark.paged


# ---------------------------------------------------------------------------
# BlockPool allocator
# ---------------------------------------------------------------------------

class TestBlockPool:
    def test_prefix_mapping_and_free(self):
        pool = BlockPool(8, 4, batch_slots=2, max_blocks_per_lane=4)
        assert pool.reserve_and_alloc(0, n_alloc=2, n_reserve=3)
        assert list(pool.table[0, :2]) == [0, 1]
        assert pool.table[0, 2] == -1
        assert pool.blocks_in_use == 2 and pool.blocks_reserved == 3
        pool.grow(0, 3)
        assert pool.table[0, 2] == 2
        pool.grow(0, 3)                      # idempotent
        assert pool.blocks_in_use == 3
        assert pool.free_lane(0) == 3
        assert pool.blocks_in_use == 0 and pool.blocks_reserved == 0
        assert (pool.table == -1).all()

    def test_reservation_backpressure(self):
        pool = BlockPool(4, 4, batch_slots=2, max_blocks_per_lane=4)
        assert pool.reserve_and_alloc(0, 1, 3)
        # only 1 block mapped, but the RESERVATION gates admission
        assert pool.blocks_in_use == 1
        assert not pool.can_reserve(2)
        assert pool.can_reserve(1)
        assert not pool.reserve_and_alloc(1, 1, 2)   # no state change
        assert pool.blocks_reserved == 3
        pool.free_lane(0)
        assert pool.reserve_and_alloc(1, 1, 2)

    def test_growth_beyond_reservation_raises(self):
        pool = BlockPool(8, 4, batch_slots=1, max_blocks_per_lane=8)
        pool.reserve_and_alloc(0, 1, 2)
        pool.grow(0, 2)
        with pytest.raises(RuntimeError, match="reservation"):
            pool.grow(0, 3)

    def test_double_reserve_raises(self):
        pool = BlockPool(8, 4, batch_slots=1, max_blocks_per_lane=8)
        pool.reserve_and_alloc(0, 1, 1)
        with pytest.raises(RuntimeError, match="still holds"):
            pool.reserve_and_alloc(0, 1, 1)

    def test_fragmentation_gauge(self):
        pool = BlockPool(8, 4, batch_slots=1, max_blocks_per_lane=8)
        pool.reserve_and_alloc(0, 2, 2)      # 8 cells allocated
        assert pool.fragmentation(live_tokens=6) == pytest.approx(0.25)
        assert pool.fragmentation(live_tokens=8) == 0.0

    def test_blocks_for_tokens(self):
        assert blocks_for_tokens(0, 4) == 0
        assert blocks_for_tokens(1, 4) == 1
        assert blocks_for_tokens(4, 4) == 1
        assert blocks_for_tokens(5, 4) == 2


# ---------------------------------------------------------------------------
# Paged kernels vs oracles
# ---------------------------------------------------------------------------

def _paged_operands(key, N=10, bs=8, KV=2, G=2, hd=16, s_cap=40, B=3):
    """Arenas + a block table with one deep lane, one shallow lane and one
    idle lane (tests the partially-mapped/unmapped masking)."""
    nb = -(-s_cap // bs)
    ks = jax.random.split(key, 4)
    k_arena = jax.random.normal(ks[0], (N, bs, KV, hd), jnp.float32)
    v_arena = jax.random.normal(ks[1], (N, bs, KV, hd), jnp.float32)
    tbl = np.full((B, nb), -1, np.int32)
    tbl[0, :4] = [7, 2, 9, 0]
    tbl[1, :1] = [5]
    q_pos = jnp.asarray([25, 3, -1][:B], jnp.int32)
    q = jax.random.normal(ks[2], (B, KV, G, hd), jnp.float32)
    return q, k_arena, v_arena, jnp.asarray(tbl), q_pos


class TestPagedKernelVsOracle:
    @pytest.mark.parametrize("window,softcap", [
        (None, None), (16, None), (None, 50.0), (8, 30.0)])
    def test_bf16_matches_ref(self, window, softcap):
        q, k_a, v_a, tbl, q_pos = _paged_operands(jax.random.PRNGKey(0))
        got = ops.paged_attend_decode(q, k_a, v_a, tbl, q_pos, s_cap=40,
                                      window=window, logit_softcap=softcap)
        want = ref.paged_attend_decode_ref(q, k_a, v_a, tbl, q_pos,
                                           s_cap=40, window=window,
                                           logit_softcap=softcap)
        np.testing.assert_allclose(np.asarray(got)[:2], np.asarray(want)[:2],
                                   rtol=3e-5, atol=3e-5)

    def test_bf16_softmax_sites_in_kernel(self):
        """softmax_in (one-pass) and softmax_out (two-pass over the lane's
        blocks) match the oracle's fake-quant placement."""
        q, k_a, v_a, tbl, q_pos = _paged_operands(jax.random.PRNGKey(1))
        smq = jnp.asarray([0.02, 100.0])
        smo = jnp.asarray([1.0 / 255.0, 0.0])
        got = ops.paged_attend_decode(q, k_a, v_a, tbl, q_pos, s_cap=40,
                                      logit_softcap=50.0, sm_quant=smq,
                                      smo_quant=smo)
        want = ref.paged_attend_decode_ref(q, k_a, v_a, tbl, q_pos,
                                           s_cap=40, logit_softcap=50.0,
                                           sm_quant=smq, smo_quant=smo)
        np.testing.assert_allclose(np.asarray(got)[:2], np.asarray(want)[:2],
                                   rtol=3e-5, atol=3e-5)

    def test_idle_lane_and_unmapped_blocks_are_masked(self):
        """An idle lane (q_pos = -1) and unmapped table entries must not
        poison the output: the mapped lanes' results are unchanged when
        arena blocks outside their tables hold garbage."""
        q, k_a, v_a, tbl, q_pos = _paged_operands(jax.random.PRNGKey(2))
        got = ops.paged_attend_decode(q, k_a, v_a, tbl, q_pos, s_cap=40)
        poison = jnp.full_like(k_a[0], 1e9)
        mapped = set(np.asarray(tbl)[np.asarray(tbl) >= 0].tolist())
        for blk in range(k_a.shape[0]):
            if blk not in mapped:
                k_a = k_a.at[blk].set(poison)
                v_a = v_a.at[blk].set(poison)
        got2 = ops.paged_attend_decode(q, k_a, v_a, tbl, q_pos, s_cap=40)
        np.testing.assert_array_equal(np.asarray(got)[:2],
                                      np.asarray(got2)[:2])

    @pytest.mark.deploy
    @pytest.mark.parametrize("window,softcap,sites", [
        (None, None, False), (16, 50.0, False), (None, None, True)])
    def test_int8_matches_ref(self, window, softcap, sites):
        key = jax.random.PRNGKey(3)
        ks = jax.random.split(key, 8)
        N, bs, KV, G, hd, B, s_cap = 10, 8, 2, 2, 16, 3, 40
        nb = -(-s_cap // bs)
        k_a = jax.random.randint(ks[0], (N, bs, KV, hd), -127, 128, jnp.int8)
        v_a = jax.random.randint(ks[1], (N, bs, KV, hd), -127, 128, jnp.int8)
        k_s = jax.random.uniform(ks[2], (N, bs, KV), minval=.01, maxval=.05)
        v_s = jax.random.uniform(ks[3], (N, bs, KV), minval=.01, maxval=.05)
        q_q = jax.random.randint(ks[4], (B, KV, G, hd), -128, 128, jnp.int8)
        q_s = jax.random.uniform(ks[5], (B, KV, G), minval=.01, maxval=.05)
        q_z = jnp.round(jax.random.uniform(ks[6], (B, KV, G), minval=-20.,
                                           maxval=20.))
        k_z = jnp.round(jax.random.uniform(ks[7], (B, KV), minval=-5.,
                                           maxval=5.))
        v_z = -k_z
        tbl = np.full((B, nb), -1, np.int32)
        tbl[0, :4] = [7, 2, 9, 0]
        tbl[1, :1] = [5]
        q_pos = jnp.asarray([25, 3, -1], jnp.int32)
        kw = dict(s_cap=s_cap, q_zp=q_z, k_zp=k_z, v_zp=v_z, window=window,
                  logit_softcap=softcap)
        if sites:
            kw.update(sm_quant=jnp.asarray([0.02, 100.0]),
                      smo_quant=jnp.asarray([1 / 255.0, 0.0]))
        got = ops.paged_int8_attend_decode(q_q, q_s, k_a, k_s, v_a, v_s,
                                           jnp.asarray(tbl), q_pos, **kw)
        want = ref.paged_int8_attend_decode_ref(q_q, q_s, k_a, k_s, v_a,
                                                v_s, jnp.asarray(tbl),
                                                q_pos, **kw)
        np.testing.assert_allclose(np.asarray(got)[:2], np.asarray(want)[:2],
                                   rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Write path + derived positions (block-table properties)
# ---------------------------------------------------------------------------

class TestDerivedPositions:
    @pytest.mark.parametrize("window", [None, 6])
    def test_stored_pos_equals_derived_on_written_cells(self, window):
        """After writing positions 0..p through the block table, the arena's
        stored positions on every derived-valid cell equal the derived
        positions — for global and ring (window < capacity) layers."""
        cfg = att.AttnConfig(num_heads=2, num_kv_heads=2, head_dim=4,
                             window=window)
        bs, nb, N = 4, 4, 8
        cache = att.init_paged_kv_cache(N, bs, cfg, jnp.float32)
        # poison the stored positions to prove stale cells are invisible
        cache = cache._replace(pos=jnp.full_like(cache.pos, 5))
        tbl = jnp.asarray([[3, 1, 6, 0]], jnp.int32)
        s_cap = att.paged_capacity(tbl, bs, window)
        rng = np.random.RandomState(0)
        for p in range(12):
            kv = jnp.asarray(rng.randn(1, 1, 2, 4).astype(np.float32))
            pw = jnp.asarray([[p]], jnp.int32)
            cache = att._write_paged_kv(cache, kv, kv, pw, tbl, window,
                                        None)
            derived = att.paged_key_positions(tbl, jnp.asarray([p]), s_cap,
                                              bs)
            nb_cap = -(-s_cap // bs)       # window layers touch a prefix
            stored = ref.paged_gather_ref(cache.pos, tbl[:, :nb_cap])
            valid = np.asarray(derived)[0] >= 0
            np.testing.assert_array_equal(
                np.asarray(stored)[0][valid], np.asarray(derived)[0][valid])
            # the derived-valid set is exactly the live window
            want_n = min(p + 1, s_cap)
            assert valid.sum() == want_n

    def test_dead_cells_and_unmapped_blocks_drop_writes(self):
        cfg = att.AttnConfig(num_heads=1, num_kv_heads=1, head_dim=4)
        cache = att.init_paged_kv_cache(4, 4, cfg, jnp.float32)
        before = np.asarray(cache.pos).copy()
        tbl = jnp.asarray([[2, -1]], jnp.int32)
        kv = jnp.ones((1, 2, 1, 4), jnp.float32)
        # position -1 (dead) and position 5 (block 1: unmapped) both drop
        pw = jnp.asarray([[-1, 5]], jnp.int32)
        cache = att._write_paged_kv(cache, kv, kv, pw, tbl, None, None)
        np.testing.assert_array_equal(np.asarray(cache.pos), before)
        assert float(jnp.abs(cache.k).sum()) == 0.0

    def test_reset_paged_lanes_empties_only_masked_lanes_blocks(self):
        cfg = att.AttnConfig(num_heads=1, num_kv_heads=1, head_dim=4)
        cache = att.init_paged_kv_cache(6, 4, cfg, jnp.float32)
        tbl = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
        kv = jnp.ones((2, 1, 1, 4), jnp.float32)
        for p in range(6):
            cache = att._write_paged_kv(cache, kv, kv,
                                        jnp.full((2, 1), p, jnp.int32),
                                        tbl, None, None)
        cache = att.reset_paged_lanes(cache, jnp.asarray([True, False]),
                                      tbl)
        pos = np.asarray(cache.pos)
        assert (pos[[0, 1]] == -1).all()          # lane 0's blocks emptied
        assert (pos[2, :4] >= 0).sum() == 4       # lane 1 untouched
        assert (pos[3, :2] >= 0).sum() == 2


# ---------------------------------------------------------------------------
# Stub-model scheduler with a constrained pool (backpressure properties)
# ---------------------------------------------------------------------------

class PoolStub:
    def __init__(self):
        self.admit_masks = []

    def init_cache(self, batch):
        return {"kv": jnp.zeros((batch, 4), jnp.float32)}

    def admit(self, tokens, positions, admit_mask, cache):
        self.admit_masks.append(np.asarray(admit_mask).copy())
        return _onehot(_next_arr(tokens)), cache

    def decode(self, tokens, pos, cache):
        return _onehot(_next_arr(tokens)), cache


@pytest.mark.serve
class TestPoolScheduler:
    def _run(self, specs, *, slots, num_blocks, bs=4, max_blocks=8):
        reqs = [Request(rid=i, prompt=np.arange(1, n + 1, dtype=np.int32),
                        max_new_tokens=q) for i, (n, q) in enumerate(specs)]
        pool = BlockPool(num_blocks, bs, slots, max_blocks)
        m = PoolStub()
        stats = serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                                 batch_slots=slots, block_pool=pool)
        return reqs, pool, stats, m

    def test_golden_under_backpressure_and_no_leak(self):
        """A pool too small to admit every request at once still serves the
        exact golden tokens FIFO, and every block returns to the free list."""
        specs = [(3, 6), (4, 5), (2, 7), (3, 2)]
        # worst case per request <= 3 blocks; pool of 4 forces waiting
        reqs, pool, stats, m = self._run(specs, slots=4, num_blocks=4)
        for r in reqs:
            assert r.done
            assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
        assert pool.blocks_in_use == 0 and pool.blocks_reserved == 0
        assert stats.blocks_in_use <= 4
        # backpressure visible: not all four admitted in the first round
        assert m.admit_masks[0].sum() < 4

    def test_unconstrained_pool_matches_dense_schedule(self):
        """With the dense worst case of blocks, pool admission decisions
        equal the dense scheduler's (same masks, same step counts)."""
        specs = [(3, 2), (4, 6), (2, 1), (3, 4), (1, 3)]
        reqs, pool, stats, m = self._run(specs, slots=2, num_blocks=16)
        dense = [Request(rid=r.rid, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens) for r in reqs]
        md = PoolStub()
        dstats = serve_continuous(md.admit, md.decode, md.init_cache, dense,
                                  batch_slots=2)
        for r, d in zip(reqs, dense):
            assert r.tokens_out == d.tokens_out
        assert stats.decode_steps == dstats.decode_steps
        assert stats.prefill_calls == dstats.prefill_calls
        assert [tuple(x) for x in m.admit_masks] == \
            [tuple(x) for x in md.admit_masks]

    def test_seeded_random_sweep_conserves_tokens_and_blocks(self):
        rng = np.random.RandomState(1)
        for _ in range(15):
            n = rng.randint(1, 7)
            specs = [(rng.randint(1, 6), rng.randint(0, 7))
                     for _ in range(n)]
            slots = rng.randint(1, 4)
            num_blocks = rng.randint(3, 10)
            reqs, pool, stats, _ = self._run(specs, slots=slots,
                                             num_blocks=num_blocks)
            for r in reqs:
                assert r.done
                assert r.tokens_out == _golden(
                    r.prompt, max(r.max_new_tokens, 0))
            assert pool.blocks_in_use == 0 and pool.blocks_reserved == 0

    def test_capacity_error_matches_dense_phrasing(self):
        """A prompt+quota whose worst case exceeds the pool raises the same
        up-front 'silently dropped' error as the dense max_len check."""
        m = PoolStub()
        pool = BlockPool(2, 4, 1, 8)
        with pytest.raises(ValueError, match="silently dropped"):
            serve_continuous(
                m.admit, m.decode, m.init_cache,
                [Request(rid=0, prompt=np.asarray([1, 2, 3]),
                         max_new_tokens=8)],      # needs 3 blocks > 2
                batch_slots=1, block_pool=pool)

    def test_pool_slots_mismatch_raises(self):
        m = PoolStub()
        with pytest.raises(ValueError, match="batch_slots"):
            serve_continuous(m.admit, m.decode, m.init_cache,
                             [Request(rid=0, prompt=np.asarray([1]),
                                      max_new_tokens=1)],
                             batch_slots=2,
                             block_pool=BlockPool(4, 4, 1, 4))


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                # pragma: no cover - dev-only dependency
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @pytest.mark.serve
    class TestPoolSchedulerHypothesis:
        @settings(max_examples=40, deadline=None)
        @given(st.lists(st.tuples(st.integers(1, 5), st.integers(0, 8)),
                        min_size=1, max_size=8),
               st.integers(1, 4), st.integers(3, 12))
        def test_tokens_and_blocks_conserved(self, specs, slots, blocks):
            reqs = [Request(rid=i,
                            prompt=np.arange(1, n + 1, dtype=np.int32),
                            max_new_tokens=q)
                    for i, (n, q) in enumerate(specs)]
            pool = BlockPool(blocks, 4, slots, 8)
            m = PoolStub()
            try:
                serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                                 batch_slots=slots, block_pool=pool)
            except ValueError:
                # workload exceeds pool capacity: rejected up-front is the
                # contract (never a mid-flight stall)
                assert any(
                    blocks_for_tokens(n + q - 1, 4) > blocks
                    for n, q in specs if q > 0)
                return
            for r in reqs:
                assert r.done
                assert r.tokens_out == _golden(
                    r.prompt, max(r.max_new_tokens, 0))
            assert pool.blocks_in_use == 0 and pool.blocks_reserved == 0
else:                              # keep the skip visible in test reports
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(see requirements-dev.txt)")
    def test_tokens_and_blocks_conserved():
        pass


# ---------------------------------------------------------------------------
# Real-model invariants (gemma2-2b-reduced: GQA, RMSNorm, softcap, and a
# ring-buffer sliding-window cache on the local_attn layers)
# ---------------------------------------------------------------------------

MAX_LEN = 32
BS = 8
NB_LANE = -(-MAX_LEN // BS)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    return cfg, params


_STEP_CACHE = {}


def _steps(cfg, ctx_factory=None):
    key = (cfg.name, ctx_factory)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = (
            jax.jit(make_admit_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_decode_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_prefill_step(cfg, ctx_factory=ctx_factory)))
    return _STEP_CACHE[key]


def _serve(cfg, params, reqs, *, scheduler, kv_bits, batch_slots,
           paged=False, num_blocks=None, ctx_factory=None):
    admit, decode, prefill = _steps(cfg, ctx_factory)
    pool = None
    if paged and scheduler == "continuous":
        pool = BlockPool(num_blocks or batch_slots * NB_LANE, BS,
                         batch_slots, NB_LANE)

    def init(b):
        if not paged:
            return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                                  kv_bits=kv_bits)
        return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                              kv_bits=kv_bits, paged=True, block_size=BS,
                              num_blocks=num_blocks,
                              mapped=scheduler == "static")

    stats = serve(prefill, admit, decode, init, params, reqs,
                  scheduler=scheduler, batch_slots=batch_slots,
                  max_len=MAX_LEN, block_pool=pool)
    return stats, pool


def _mk_reqs(seed, cfg, lens_quotas):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, size=n)
                    .astype(np.int32),
                    max_new_tokens=q)
            for i, (n, q) in enumerate(lens_quotas)]


@pytest.mark.serve
class TestPagedServingParity:
    SPEC = [(5, 2), (9, 12), (3, 1), (7, 4), (4, 8), (6, 2)]

    @pytest.mark.parametrize("kv_bits", [16, 8])
    @pytest.mark.parametrize("scheduler", ["continuous", "static"])
    def test_paged_matches_dense_greedy(self, tiny, kv_bits, scheduler):
        """Paged == dense greedy tokens under both schedulers, with the
        continuous pool CONSTRAINED so admissions hit backpressure and
        lanes grow + free mid-flight."""
        cfg, params = tiny
        dense = _mk_reqs(3, cfg, self.SPEC)
        paged = _mk_reqs(3, cfg, self.SPEC)
        _serve(cfg, params, dense, scheduler=scheduler, kv_bits=kv_bits,
               batch_slots=2)
        nb = 5 if scheduler == "continuous" else None   # worst case = 3
        stats, pool = _serve(cfg, params, paged, scheduler=scheduler,
                             kv_bits=kv_bits, batch_slots=2, paged=True,
                             num_blocks=nb)
        for d, p in zip(dense, paged):
            assert d.tokens_out == p.tokens_out, f"rid {d.rid}"
            assert p.done
        if pool is not None:
            assert pool.blocks_in_use == 0, "block leak after retirement"
            assert stats.blocks_in_use <= 5

    def test_paged_cache_bytes_scale_with_live_tokens(self, tiny):
        """The paged stat reports ALLOCATED block bytes: with a constrained
        pool it stays well under the dense worst-case footprint."""
        cfg, params = tiny
        dense = _mk_reqs(4, cfg, self.SPEC)
        paged = _mk_reqs(4, cfg, self.SPEC)
        d_stats, _ = _serve(cfg, params, dense, scheduler="continuous",
                            kv_bits=16, batch_slots=2)
        p_stats, _ = _serve(cfg, params, paged, scheduler="continuous",
                            kv_bits=16, batch_slots=2, paged=True,
                            num_blocks=5)
        assert p_stats.blocks_in_use > 0
        assert p_stats.cache_bytes < d_stats.cache_bytes
        # exact accounting: peak bytes == peak mapped blocks x per-block
        # bytes (summed over every layer's arena) — allocated blocks, not
        # batch_slots x max_len, set the footprint
        bpb = tfm.paged_block_bytes(
            tfm.init_cache(cfg, 2, MAX_LEN, dtype=jnp.float32, paged=True,
                           block_size=BS, num_blocks=5, mapped=False))
        assert p_stats.cache_bytes == p_stats.blocks_in_use * bpb


@pytest.mark.serve
class TestPagedLaneInvariants:
    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_slot_insert_preserves_other_lanes_blocks(self, tiny, kv_bits):
        """Admitting into lane 1 leaves the blocks mapped by lanes 0 and 2
        BIT-IDENTICAL across every arena leaf — the paged version of the
        dense lane-hash invariant."""
        cfg, params = tiny
        admit, decode, _ = _steps(cfg)
        B = 3
        pool = BlockPool(B * NB_LANE, BS, B, NB_LANE)
        for i in range(B):
            assert pool.reserve_and_alloc(i, NB_LANE, NB_LANE)
        cache = tfm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32,
                               kv_bits=kv_bits, paged=True, block_size=BS,
                               num_blocks=B * NB_LANE, mapped=False)
        cache["block_table"] = jnp.asarray(pool.table)
        rng = np.random.RandomState(1)
        T = 6
        toks = rng.randint(1, cfg.vocab_size, size=(B, T)).astype(np.int32)
        posm = np.tile(np.arange(T, dtype=np.int32), (B, 1))
        logits, cache = admit(params, toks, posm, np.ones((B,), bool),
                              cache)
        cur = np.asarray(jnp.argmax(logits[:, -1:], -1), np.int32)
        pos = np.full((B, 1), T, np.int32)
        for _ in range(2):
            logits, cache = decode(params, cur, pos, cache)
            cur = np.asarray(jnp.argmax(logits, -1), np.int32)
            pos = pos + 1

        # stacked leaves are (n_super, N, bs, ...), tail leaves (N, bs, ...)
        def lane_bytes(c, lane):
            blocks = pool.lane_blocks(lane)
            parts = []
            for node in list(c["scan"]):
                parts.extend(np.asarray(leaf[:, blocks]).tobytes()
                             for leaf in node)
            for node in list(c["tail"]):
                parts.extend(np.asarray(leaf[blocks]).tobytes()
                             for leaf in node)
            return b"".join(parts)

        before = {i: lane_bytes(cache, i) for i in range(B)}
        toks2 = np.zeros((B, T), np.int32)
        posm2 = np.full((B, T), -1, np.int32)
        toks2[1, 2:] = rng.randint(1, cfg.vocab_size, size=4)
        posm2[1, 2:] = np.arange(4)
        _, cache2 = admit(params, toks2, posm2,
                          np.asarray([False, True, False]), cache)
        after = {i: lane_bytes(cache2, i) for i in range(B)}
        assert after[0] == before[0]
        assert after[2] == before[2]
        assert after[1] != before[1]            # the admitted lane changed

    def test_no_recompiles_across_paged_admissions(self, tiny):
        """Jitted admit/decode trace exactly once across pool-managed
        admissions, growth and frees — block tables are data, not shape."""
        cfg, params = tiny
        traces = {"admit": 0, "decode": 0}
        base_admit = make_admit_step(cfg)
        base_decode = make_decode_step(cfg)

        def admit_fn(params, t, pm, m, c):
            traces["admit"] += 1
            return base_admit(params, t, pm, m, c)

        def decode_fn(params, t, p, c):
            traces["decode"] += 1
            return base_decode(params, t, p, c)

        admit_j = jax.jit(admit_fn)
        decode_j = jax.jit(decode_fn)
        reqs = _mk_reqs(4, cfg, [(4, 2), (6, 5), (2, 1), (5, 3), (3, 4)])
        pool = BlockPool(4, BS, 2, NB_LANE)
        stats = serve_continuous(
            lambda t, pm, m, c: admit_j(params, t, pm, m, c),
            lambda t, p, c: decode_j(params, t, p, c),
            lambda b: tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                                     paged=True, block_size=BS,
                                     num_blocks=4, mapped=False),
            reqs, batch_slots=2, block_pool=pool)
        assert stats.prefill_calls >= 3         # several admission rounds
        assert traces == {"admit": 1, "decode": 1}
        assert pool.blocks_in_use == 0

    def test_prompt_exceeding_pool_raises_like_dense(self, tiny):
        """Capacity validation: a prompt alone larger than the pool fails
        up-front with the dense path's error, not via silent drops."""
        cfg, params = tiny
        reqs = _mk_reqs(5, cfg, [(10, 30)])     # needs 39 slots > 32
        with pytest.raises(ValueError, match="silently dropped"):
            _serve(cfg, params, reqs, scheduler="continuous", kv_bits=16,
                   batch_slots=1, paged=True, num_blocks=3)

    def test_cache_reset_slots_empties_paged_lane(self, tiny):
        """cache_reset_slots on a paged model cache empties exactly the
        masked lane's mapped blocks (every layer), and the pool's free-list
        accounting shows no leak when the scheduler then frees the lane."""
        cfg, params = tiny
        _, _, prefill = _steps(cfg)
        B = 2
        cache = tfm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32,
                               paged=True, block_size=BS)
        toks = np.ones((B, 5), np.int32)
        posm = np.tile(np.arange(5, dtype=np.int32), (B, 1))
        _, cache = prefill(params, toks, cache, posm)
        cache = tfm.cache_reset_slots(cache, np.asarray([True, False]))
        tbl = np.asarray(cache["block_table"])
        for node in list(cache["scan"]) + list(cache["tail"]):
            pos = np.asarray(node.pos)
            lane0 = tbl[0][tbl[0] >= 0]
            lane1 = tbl[1][tbl[1] >= 0]
            assert (pos[..., lane0, :] == -1).all()
            assert (pos[..., lane1, :] >= 0).any()


@pytest.mark.deploy
class TestPagedDeployParity:
    """Paged == dense on the integer deployment path (packed int8 weights,
    int8 KV cache, paged int8 decode kernel)."""

    @pytest.fixture(scope="class")
    def deployed(self):
        from repro.core import Mode, QuantCtx, build_deploy, peg_policy
        from repro.core.pipeline import ptq
        cfg = get_config("gemma2-2b").reduced()
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(cfg, key, stacked=True, dtype=jnp.float32)
        pol = peg_policy(4)
        flat = tfm.init_params(cfg, key, stacked=False, dtype=jnp.float32)
        calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10),
                                               (2, 8), 0, cfg.vocab_size)}]

        def fwd(p, b, ctx):
            logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
            return logits

        qm = ptq(fwd, flat, calib, pol, collect_inputs=True)
        shared = {}
        for site, qp in qm.act_state.items():
            base = ("layer/" + site.split("/", 1)[1]
                    if site.startswith("layer") else site)
            shared.setdefault(base, qp)
        packed, acts = build_deploy(cfg, params, pol, shared)

        def ctx_factory():
            return QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=shared,
                            deploy_acts=acts)
        return cfg, packed, ctx_factory

    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_paged_matches_dense_int8(self, deployed, kv_bits):
        cfg, packed, ctx_factory = deployed
        spec = [(4, 2), (8, 6), (3, 1), (6, 4)]
        dense = _mk_reqs(5, cfg, spec)
        paged = _mk_reqs(5, cfg, spec)
        _serve(cfg, packed, dense, scheduler="continuous", kv_bits=kv_bits,
               batch_slots=2, ctx_factory=ctx_factory)
        _, pool = _serve(cfg, packed, paged, scheduler="continuous",
                         kv_bits=kv_bits, batch_slots=2, paged=True,
                         num_blocks=4, ctx_factory=ctx_factory)
        for d, p in zip(dense, paged):
            assert d.tokens_out == p.tokens_out, f"rid {d.rid}"
        assert pool.blocks_in_use == 0
