"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, QK-norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                    # per-expert hidden
    vocab_size=151936,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff=1536, norm_topk=True),
    qk_norm=True,
    rope_theta=1000000.0,
    norm="rmsnorm",
    act="silu",
    ffn_type="glu",
    tie_embeddings=False,
    sub_quadratic=False,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
