"""JetStream-style serving engine: every jitted device call of the serving
stack behind one explicit interface.

The continuous scheduler (runtime.serve_loop) used to call its jitted steps
directly; this module is the seam that separates *policy* (which requests to
admit or preempt — the Scheduler's job) from *mechanism* (the fixed-shape
device calls and decode-state transitions — the Engine's job). The engine
exposes two granularities over the same compiled steps:

* the **fused path** the continuous scheduler's hot loop drives —
  ``admit`` (slot-insert prefill: reset + prefill + insert in ONE model
  call), ``chunk`` (append-mode chunked prefill) and ``generate`` (one
  greedy decode step over every lane), plus the paged plumbing
  (``swap_out`` / ``swap_in`` / ``copy_block``);

* the **decomposed path** — ``prefill(request) -> (first_token,
  LanePayload)`` runs a request's prefill into a private scratch cache and
  extracts its lane as a transferable payload; ``insert(payload, slot,
  state)`` lands that payload in any decode slot (a full lane overwrite, so
  no separate reset and bit-isolation for every other lane);
  ``generate(state)`` then decodes as usual. This is the JetStream seam:
  prefill and decode need not share a cache — or, eventually, a host — and
  the async front-end (runtime.async_serve) and the decode microbenchmark
  (benchmarks/engine_bench.py) drive exactly this triad.

The fused ``admit`` and the decomposed ``prefill``+``insert`` are
semantically the same operation (the engine conformance suite asserts
greedy-token equality between a Scheduler run and a bare-engine run), and
each of prefill / insert / generate traces exactly once — shapes are fixed
(prompts pad to ``prompt_pad_len``, decode is always (B, 1)) and slots /
block ids are data.

**Mesh-aware serving**: pass ``dist`` (parallel.sharding.make_dist over a
mesh with a ``model`` axis) to :func:`make_engine` and the steps are built
with tensor-parallel sharding constraints threaded through every matmul,
parameters and cache are placed with the sharding rules, and every host
input (tokens, positions, the admission mask) is *broadcast* — replicated
across the mesh with an explicit all-device sharding — so a host-local
admission decision drives all N devices in lockstep. Works on simulated CPU
devices (``XLA_FLAGS=--xla_force_host_platform_device_count=N``) exactly as
on a real mesh.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class DecodeState(NamedTuple):
    """Fixed-shape per-slot decode state threaded through the jitted steps:
    one row per lane. ``pos`` == -1 marks an idle lane (its decode output is
    discarded and its cache writes are position-dropped). ``tokens`` and
    ``pos`` are host numpy arrays — the policy layer mutates them between
    device calls; only ``cache`` lives on device."""
    tokens: np.ndarray          # (B, 1) int32 current token per lane
    pos: np.ndarray             # (B, 1) int32 its absolute position (-1 idle)
    cache: Any                  # model cache pytree with B lanes


class LanePayload(NamedTuple):
    """The transferable result of a decomposed ``prefill``: one lane's
    complete KV payload (dense lane slices, or the gathered block rows of a
    paged lane) plus the host-side decode seed. ``insert`` lands ``kv`` in a
    slot and seeds the lane with (``first_token``, ``next_pos``)."""
    kv: Any                     # single-lane cache payload pytree
    first_token: int            # greedy token from the prefill's last logits
    next_pos: int               # len(prompt): the first decode write position


def _lane_rows(prompt: np.ndarray, width: int):
    """Left-pad one prompt into a (width,) row pair (tokens, positions) with
    real positions 0..len-1 and the -1 dead-cell sentinel on pads."""
    n = len(prompt)
    if n == 0:
        raise ValueError("empty prompt (an all-dead lane has no last-token "
                         "logits to decode from)")
    if n > width:
        raise ValueError(f"prompt length {n} exceeds the engine's "
                         f"prompt_pad_len {width}")
    toks = np.zeros((width,), np.int32)
    posm = np.full((width,), -1, np.int32)
    toks[width - n:] = prompt
    posm[width - n:] = np.arange(n)
    return toks, posm


class Engine:
    """Fixed-shape serving engine over jitted step functions.

    admit_fn: (tokens (B,P), positions (B,P), admit_mask (B,), cache)
              -> (last_logits (B,1,V) | (B,P,V), cache)
    decode_fn: (tokens (B,1), pos (B,1), cache) -> (logits (B,1,V), cache)
    chunk_fn:  (tokens (B,C), positions (B,C), reset_mask (B,), cache)
              -> (last_logits (B,1,V), cache)       [chunked prefill only]
    init_cache_fn: (batch,) -> model cache pytree

    Steps built with ``quant_telemetry=True`` return an extra telemetry
    dict; the engine folds it into ``telemetry_sink`` (when given) and
    hands back the plain outputs, so callers never see the arity change.

    Only greedy (argmax) decoding is implemented — the parity property
    "continuous == static == async == served alone, token for token" is
    only well-defined for deterministic sampling. Every op returns decoded
    tokens as HOST numpy (the conversion synchronizes on the device
    result), and the decomposed ops lazily build two engine-internal jits
    (payload extract / insert) that each trace exactly once.
    """

    def __init__(self, admit_fn: Callable, decode_fn: Callable,
                 init_cache_fn: Callable, *, batch_slots: int,
                 prompt_pad_len: Optional[int] = None,
                 max_len: Optional[int] = None,
                 chunk_fn: Optional[Callable] = None,
                 swap_out_fn: Optional[Callable] = None,
                 swap_in_fn: Optional[Callable] = None,
                 copy_block_fn: Optional[Callable] = None,
                 dist=None,
                 telemetry_sink: Optional[Callable[[Dict], None]] = None):
        if batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
        self.admit_fn = admit_fn
        self.decode_fn = decode_fn
        self.chunk_fn = chunk_fn
        self.init_cache_fn = init_cache_fn
        self.swap_out_fn = swap_out_fn
        self.swap_in_fn = swap_in_fn
        self.copy_block_fn = copy_block_fn
        self.batch_slots = batch_slots
        self.prompt_pad_len = prompt_pad_len
        self.max_len = max_len
        self.dist = dist
        self.telemetry_sink = telemetry_sink
        # trace-time counters: engine-internal jits bump these from inside
        # the traced python body, so a recompile is observable as a count
        # > 1 (make_engine extends this to the step functions themselves)
        self.trace_counts: Dict[str, int] = {}
        self._scratch = None            # decomposed-prefill scratch cache
        self._extract_jit = None
        self._insert_jit = None
        self._scratch_ids = None        # paged scratch: lane 0's block ids

    # -- host -> device placement ------------------------------------------

    def _put(self, x):
        """Host input placement. On a mesh this is the admit-mask broadcast:
        an explicit fully-replicated sharding, so the host-local admission
        decision reaches every device instead of relying on implicit
        single-device placement."""
        if self.dist is None:
            return jnp.asarray(x)
        from jax.sharding import NamedSharding, PartitionSpec
        return jax.device_put(
            jnp.asarray(x), NamedSharding(self.dist.mesh, PartitionSpec()))

    def _unwrap(self, out):
        """Steps built with quant_telemetry=True return (logits, cache,
        telemetry_dict); fold the extra output into the sink and hand back
        the plain pair."""
        if len(out) == 3:
            logits, cache, tel = out
            if self.telemetry_sink is not None:
                self.telemetry_sink(tel)
            return logits, cache
        return out

    @staticmethod
    def _greedy(logits) -> np.ndarray:
        """(B, 1) int32 greedy tokens from the LAST position's logits —
        np conversion blocks on the device computation."""
        return np.asarray(jnp.argmax(logits[:, -1:], axis=-1), np.int32)

    # -- state -------------------------------------------------------------

    def init_state(self) -> DecodeState:
        """A fresh all-idle decode state: every lane dead (pos -1)."""
        B = self.batch_slots
        return DecodeState(tokens=np.zeros((B, 1), np.int32),
                           pos=np.full((B, 1), -1, np.int32),
                           cache=self.init_cache_fn(B))

    # -- fused ops (the continuous Scheduler's hot loop) --------------------

    def admit(self, tokens, positions, admit_mask, cache):
        """Fused prefill+insert: reset the masked lanes and prefill their
        packed prompts in one model call. Returns ((B,1) greedy first
        tokens, cache) — semantically ``insert(prefill(r), slot)`` for every
        masked lane, in one step."""
        logits, cache = self._unwrap(self.admit_fn(
            self._put(tokens), self._put(positions), self._put(admit_mask),
            cache))
        return self._greedy(logits), cache

    def chunk(self, tokens, positions, reset_mask, cache):
        """One append-mode chunked-prefill step (see
        runtime.steps.make_chunk_prefill_step). Returns ((B,1) greedy
        tokens from the chunk's final position, cache)."""
        if self.chunk_fn is None:
            raise ValueError("engine was built without a chunk_fn")
        logits, cache = self._unwrap(self.chunk_fn(
            self._put(tokens), self._put(positions), self._put(reset_mask),
            cache))
        return self._greedy(logits), cache

    def generate(self, state: DecodeState):
        """One greedy decode step over every lane. Returns ((B,1) per-lane
        next tokens, cache); idle (pos -1) lanes produce garbage tokens the
        policy layer ignores, and their cache writes are position-dropped."""
        logits, cache = self._unwrap(self.decode_fn(
            self._put(state.tokens), self._put(state.pos), state.cache))
        return self._greedy(logits), cache

    # -- paged plumbing (over-commit preemption, prefix COW) ----------------

    def swap_out(self, cache, ids) -> Any:
        """Gather the payload of physical blocks ``ids`` into a HOST spill
        buffer (device_get included — preemption's swap-out half)."""
        if self.swap_out_fn is None:
            raise ValueError("engine was built without swap steps")
        return jax.device_get(self.swap_out_fn(cache, jnp.asarray(ids)))

    def swap_in(self, cache, ids, payload):
        """Re-upload a host spill payload into newly allocated blocks
        ``ids`` (resume's swap-in half) — bit-exact."""
        if self.swap_in_fn is None:
            raise ValueError("engine was built without swap steps")
        return self.swap_in_fn(cache, jnp.asarray(ids),
                               jax.device_put(payload))

    def copy_block(self, cache, src: int, dst: int):
        """Clone physical block ``src`` into ``dst`` across every paged
        arena (the device half of copy-on-write)."""
        if self.copy_block_fn is None:
            raise ValueError("engine was built without a copy_block_fn")
        return self.copy_block_fn(cache, jnp.asarray(src, jnp.int32),
                                  jnp.asarray(dst, jnp.int32))

    # -- decomposed path: prefill -> insert -> generate ---------------------

    def _is_paged(self, cache) -> bool:
        return isinstance(cache, dict) and "block_table" in cache

    def _ensure_scratch(self):
        """Lazily build the decomposed-prefill scratch cache: a private
        cache of the engine's own shape (so the ONE admit trace serves it
        too). Paged scratches identity-map lane 0 to blocks 0..nb-1 — the
        payload gather then reads a fixed id vector, one trace forever."""
        if self._scratch is not None:
            return
        scratch = self.init_cache_fn(self.batch_slots)
        if self._is_paged(scratch):
            table = np.array(scratch["block_table"])   # mutable host copy
            nb = table.shape[1]
            num_blocks = self._arena_blocks(scratch)
            if nb > num_blocks:
                raise ValueError(
                    f"decomposed prefill needs {nb} scratch blocks for one "
                    f"lane but the paged arena holds {num_blocks}")
            table[0] = np.arange(nb, dtype=np.int32)
            scratch["block_table"] = jnp.asarray(table)
            self._scratch_ids = np.arange(nb, dtype=np.int32)
        self._scratch = scratch

    @staticmethod
    def _arena_blocks(cache) -> int:
        from repro.models import transformer as tfm
        for node in tfm._cache_nodes(cache):
            pos = node.pos if hasattr(node, "pos") else None
            if pos is not None:
                return pos.shape[-2]
        raise ValueError("paged cache holds no attention arenas")

    def _ensure_payload_jits(self, paged: bool):
        from repro.models import transformer as tfm
        if self._extract_jit is None:
            self.trace_counts.setdefault("extract", 0)
            if paged:
                ids = jnp.asarray(self._scratch_ids)

                def extract(cache):
                    self.trace_counts["extract"] += 1
                    return tfm.cache_gather_blocks(cache, ids)
            else:
                def extract(cache):
                    self.trace_counts["extract"] += 1
                    return tfm.cache_extract_lane(cache, 0)
            self._extract_jit = jax.jit(extract)
        if self._insert_jit is None:
            self.trace_counts.setdefault("insert", 0)
            if paged:
                def insert(cache, ids, payload):
                    self.trace_counts["insert"] += 1
                    return tfm.cache_scatter_blocks(cache, ids, payload)
            else:
                def insert(cache, lane, payload):
                    self.trace_counts["insert"] += 1
                    return tfm.cache_insert_lane(cache, lane, payload)
            self._insert_jit = jax.jit(insert, donate_argnums=(0,))

    def prefill(self, request) -> (int, LanePayload):
        """Prefill ONE request into the engine's private scratch cache and
        extract its lane as a transferable payload. ``request`` is a
        serve_loop.Request or a raw (T,) int32 prompt array. Returns
        (first_token, LanePayload) — the first token is already decoded
        from the prefill's last-position logits (the admit-path contract),
        so a quota-1 request never needs a decode step.

        Reuses the engine's ONE admit trace (the scratch cache has the
        live cache's exact structure); the payload extract is an
        engine-internal jit that also traces exactly once."""
        prompt = np.asarray(getattr(request, "prompt", request), np.int32)
        width = self.prompt_pad_len or len(prompt)
        row_t, row_p = _lane_rows(prompt, width)
        B = self.batch_slots
        toks = np.zeros((B, width), np.int32)
        posm = np.full((B, width), -1, np.int32)
        toks[0], posm[0] = row_t, row_p
        mask = np.zeros((B,), bool)
        mask[0] = True
        self._ensure_scratch()
        first, self._scratch = self.admit(toks, posm, mask, self._scratch)
        self._ensure_payload_jits(self._is_paged(self._scratch))
        kv = self._extract_jit(self._scratch)
        tok = int(first[0, 0])
        return tok, LanePayload(kv=kv, first_token=tok,
                                next_pos=len(prompt))

    def insert(self, payload: LanePayload, slot: int,
               state: DecodeState) -> DecodeState:
        """Land a prefilled lane payload in decode slot ``slot``: a FULL
        lane overwrite (prompt KV plus dead-cell padding), so the slot's
        previous occupant needs no separate reset and every other lane's
        bytes pass through bit-identical. Seeds the lane's host decode row
        with (first_token, next_pos). Paged decode caches route the write
        through the slot's block-table row, which must be fully mapped
        (the bare engine serves paged caches with the identity-mapped
        drop-in dense layout — pool-managed admission uses the fused
        ``admit`` instead)."""
        if not 0 <= slot < self.batch_slots:
            raise ValueError(f"slot {slot} outside 0..{self.batch_slots - 1}")
        cache = state.cache
        paged = self._is_paged(cache)
        self._ensure_payload_jits(paged)
        if paged:
            row = np.asarray(cache["block_table"])[slot]
            if (row < 0).any():
                raise ValueError(
                    f"slot {slot}'s block-table row is not fully mapped — "
                    "decomposed insert needs the identity-mapped paged "
                    "layout (init_cache(paged=True) default)")
            cache = self._insert_jit(cache, jnp.asarray(row), payload.kv)
        else:
            cache = self._insert_jit(cache, jnp.asarray(slot, jnp.int32),
                                     payload.kv)
        tokens, pos = state.tokens.copy(), state.pos.copy()
        tokens[slot, 0] = payload.first_token
        pos[slot, 0] = payload.next_pos
        return DecodeState(tokens, pos, cache)

    def release(self, slot: int, state: DecodeState) -> DecodeState:
        """Host-side lane retirement: mark ``slot`` idle (pos -1). The
        cache lane's stale bytes are unreadable behind the dead-cell
        sentinel and the next ``insert`` fully overwrites them, so no
        device call is needed — cancellation mid-generation is free."""
        tokens, pos = state.tokens.copy(), state.pos.copy()
        pos[slot, 0] = -1
        return DecodeState(tokens, pos, state.cache)


def make_engine(cfg, params, *, batch_slots: int, prompt_pad_len: int,
                max_len: int, dtype=jnp.float32, kv_bits: int = 16,
                paged: bool = False, block_size: int = 16,
                ctx_factory: Optional[Callable] = None,
                chunked=None, dist=None, quant_telemetry: bool = False,
                telemetry_sink: Optional[Callable] = None,
                with_chunk_fn: bool = False) -> Engine:
    """Build a ready-to-serve :class:`Engine` for a model config: jitted
    admit/decode (and optionally chunk) steps with the cache donated, params
    bound, and — when ``dist`` is given — parameters and caches placed with
    the tensor-parallel sharding rules (parallel.sharding) so decode runs
    under ``jax.sharding`` across the mesh while admission stays host-local.

    Every step is wrapped with a trace-time counter
    (``engine.trace_counts``): the conformance suite's recompile guard
    asserts each of prefill/insert/generate traced exactly once. Paged
    engines use the identity-mapped drop-in dense layout (the decomposed
    insert's contract)."""
    from repro.models import transformer as tfm
    from repro.runtime.steps import (make_admit_step, make_chunk_prefill_step,
                                     make_decode_step)

    if dist is not None:
        from repro.parallel.sharding import (make_cache_shardings,
                                             make_param_shardings)
        params = jax.tree.map(jax.device_put, params,
                              make_param_shardings(params, dist))

    counts: Dict[str, int] = {}

    def counted(name, fn):
        counts.setdefault(name, 0)

        def wrapper(*args):
            counts[name] += 1
            return fn(*args)
        return wrapper

    admit = jax.jit(counted("prefill", make_admit_step(
        cfg, dist=dist, ctx_factory=ctx_factory, chunked=chunked,
        quant_telemetry=quant_telemetry)), donate_argnums=(4,))
    decode = jax.jit(counted("generate", make_decode_step(
        cfg, dist=dist, ctx_factory=ctx_factory,
        quant_telemetry=quant_telemetry)), donate_argnums=(3,))
    chunk = None
    if with_chunk_fn:
        chunk = jax.jit(counted("chunk", make_chunk_prefill_step(
            cfg, dist=dist, ctx_factory=ctx_factory, chunked=chunked,
            quant_telemetry=quant_telemetry)), donate_argnums=(4,))

    def init_cache_fn(batch):
        cache = tfm.init_cache(cfg, batch, max_len, dtype=dtype,
                               kv_bits=kv_bits, paged=paged,
                               block_size=block_size)
        if dist is not None:
            from repro.parallel.sharding import make_cache_shardings
            cache = jax.tree.map(jax.device_put, cache,
                                 make_cache_shardings(cache, dist))
        return cache

    engine = Engine(
        lambda t, pm, m, c: admit(params, t, pm, m, c),
        lambda t, p, c: decode(params, t, p, c),
        init_cache_fn, batch_slots=batch_slots,
        prompt_pad_len=prompt_pad_len, max_len=max_len,
        chunk_fn=(None if chunk is None else
                  lambda t, pm, m, c: chunk(params, t, pm, m, c)),
        dist=dist, telemetry_sink=telemetry_sink)
    engine.trace_counts = counts
    return engine


def serve_engine(engine: Engine, requests: List[Any],
                 state: Optional[DecodeState] = None) -> DecodeState:
    """Reference FIFO driver over the decomposed triad — the engine
    conformance suite's 'bare engine' side, and the simplest possible
    serving loop: fill free slots with prefill+insert, run generate until
    every request drained. Appends tokens to each request's ``tokens_out``
    (greedy, identical to the Scheduler's emissions for the same
    requests). Requests with ``max_new_tokens <= 0`` retire untouched."""
    B = engine.batch_slots
    if state is None:
        state = engine.init_state()
    queue = [r for r in requests if r.max_new_tokens > 0]
    for r in requests:
        if r.max_new_tokens <= 0:
            r.done = True
    lanes: List[Optional[Any]] = [None] * B
    while queue or any(r is not None for r in lanes):
        for slot in range(B):
            if lanes[slot] is not None or not queue:
                continue
            r = queue.pop(0)
            first, payload = engine.prefill(r)
            state = engine.insert(payload, slot, state)
            r.tokens_out.append(first)
            if len(r.tokens_out) >= r.max_new_tokens:
                r.done = True
                state = engine.release(slot, state)
            else:
                lanes[slot] = r
        if not any(r is not None for r in lanes):
            continue
        toks, cache = engine.generate(state)
        tokens, pos = state.tokens.copy(), state.pos.copy()
        for slot in range(B):
            r = lanes[slot]
            if r is None:
                continue
            tokens[slot, 0] = toks[slot, 0]
            pos[slot, 0] += 1
            r.tokens_out.append(int(toks[slot, 0]))
            if len(r.tokens_out) >= r.max_new_tokens:
                r.done = True
                lanes[slot] = None
                pos[slot, 0] = -1
        state = DecodeState(tokens, pos, cache)
    return state
