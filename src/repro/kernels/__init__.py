"""Pallas TPU kernels for the paper's quantization hot-spots:

  peg_quant      — fused per-embedding-group quantize(-dequantize)
  int8_matmul    — s8xs8->s32 MXU matmul; PEG variant fuses the per-group
                   accumulator re-scalings of paper eq. (4)->(5)
  fused_ln_quant — LayerNorm + quantize in one VPU pass (Fig.-4 hot path)

ops.py exposes jit'd wrappers (interpret mode on CPU, Mosaic on TPU);
ref.py holds the pure-jnp oracles used by tests/test_kernels.py."""
from repro.kernels import ops, ref
