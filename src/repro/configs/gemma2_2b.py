"""gemma2-2b [dense] — local+global alternating attention, logit softcap,
post-norm sandwich, scaled embeddings. [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    block_pattern=("local_attn", "attn"),   # alternating local/global
    local_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    rope_theta=10000.0,
    norm="rmsnorm",
    act="gelu",
    ffn_type="glu",
    post_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    # half the layers are bounded-window; global layers decode linearly with
    # an SP-sharded cache -> included in long_500k (DESIGN.md §5)
    sub_quadratic=True,
    source="arXiv:2408.00118; hf",
)
