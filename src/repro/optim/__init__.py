from repro.optim.adam import (AdamState, adam_init, adam_update, adamw_init,
                              apply_updates, clip_by_global_norm)
from repro.optim.schedule import (constant_schedule, cosine_schedule,
                                  linear_warmup_linear_decay)
