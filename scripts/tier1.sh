#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md): the full fast test suite from the repo
# root with src/ on the path. Extra args pass through to pytest verbatim,
# including combined marker selections (quote the expression):
#   scripts/tier1.sh -m deploy              # integer-deployment tests
#   scripts/tier1.sh -m serve               # serving-runtime schedulers
#   scripts/tier1.sh -m paged               # paged KV-cache subsystem
#   scripts/tier1.sh -m "deploy or serve"   # combined selection
#   scripts/tier1.sh -m "not slow"
# The marker set is documented in pytest.ini.
set -euo pipefail
cd "$(dirname "$0")/.."
# ${1+"$@"} (not bare "$@") keeps zero-arg invocations safe under set -u
# on pre-4.4 bash, so marker-less and marker-combined runs both work.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q ${1+"$@"}
