"""Engine API conformance suite (runtime/engine.py).

The contract under test: the decomposed prefill -> insert -> generate triad
driven by the reference FIFO loop (``serve_engine``) emits exactly the same
greedy tokens as the continuous Scheduler — whatever serving mode the
Scheduler runs in (dense/paged, prefix-cache, over-commit, f32 / deploy-int8
/ kv-bits 8/4). The triad reuses the Scheduler's ONE admit trace on a
private scratch cache, so the suite also pins the recompile guard (each of
prefill / insert / generate traces exactly once across arbitrary admission
patterns) and the insert bit-isolation invariant (landing a payload in one
lane leaves every other lane's cache bytes untouched).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.runtime import (BlockPool, RadixCache, Request, serve_continuous,
                           serve_engine)
from repro.runtime.engine import make_engine
from repro.runtime.steps import (make_admit_step, make_chunk_prefill_step,
                                 make_decode_step, make_swap_steps)

pytestmark = [pytest.mark.engine, pytest.mark.serve]

MAX_LEN = 32
PAD = 8
BLOCK = 4


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    return cfg, params


@pytest.fixture(scope="module")
def deployed():
    """Integer deployment path (packed int8 weights + Pallas kernels),
    mirroring tests/test_scheduler.py's setup."""
    from repro.core import Mode, QuantCtx, build_deploy, peg_policy
    from repro.core.pipeline import ptq
    cfg = get_config("gemma2-2b").reduced()
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key, stacked=True, dtype=jnp.float32)
    pol = peg_policy(4)
    flat = tfm.init_params(cfg, key, stacked=False, dtype=jnp.float32)
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10),
                                           (2, 8), 0, cfg.vocab_size)}]

    def fwd(p, b, ctx):
        logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
        return logits

    qm = ptq(fwd, flat, calib, pol, collect_inputs=True)
    shared = {}
    for site, qp in qm.act_state.items():
        base = ("layer/" + site.split("/", 1)[1]
                if site.startswith("layer") else site)
        shared.setdefault(base, qp)
    packed, acts = build_deploy(cfg, params, pol, shared)

    def ctx_factory():
        return QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=shared,
                        deploy_acts=acts)
    return cfg, packed, ctx_factory


def _mk_reqs(rng, cfg, lens_quotas):
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, size=n)
                    .astype(np.int32),
                    max_new_tokens=q)
            for i, (n, q) in enumerate(lens_quotas)]


SPEC = [(4, 2), (8, 6), (3, 1), (6, 4), (5, 3)]


def _engine(cfg, params, *, kv_bits=16, paged=False, ctx_factory=None,
            batch_slots=2):
    return make_engine(cfg, params, batch_slots=batch_slots,
                       prompt_pad_len=PAD, max_len=MAX_LEN,
                       dtype=jnp.float32, kv_bits=kv_bits, paged=paged,
                       block_size=BLOCK, ctx_factory=ctx_factory)


def _scheduler_tokens(cfg, params, reqs, *, kv_bits=16, ctx_factory=None,
                      batch_slots=2, paged=False, prefix=False,
                      over_commit=False, swap=False, num_blocks=None):
    """The Scheduler side of the conformance check: serve ``reqs`` through
    serve_continuous in the requested mode (the Scheduler itself routes
    every model call through its internal Engine)."""
    admit_j = jax.jit(make_admit_step(cfg, ctx_factory=ctx_factory))
    decode_j = jax.jit(make_decode_step(cfg, ctx_factory=ctx_factory))
    admit = lambda t, pm, m, c: admit_j(params, t, pm, m, c)
    decode = lambda t, p, c: decode_j(params, t, p, c)
    chunk = None
    if prefix or over_commit:
        chunk_j = jax.jit(make_chunk_prefill_step(cfg, ctx_factory=ctx_factory))
        chunk = lambda t, pm, m, c: chunk_j(params, t, pm, m, c)
    nb_lane = tfm.paged_lane_blocks(cfg, MAX_LEN, BLOCK)
    pool = (BlockPool(num_blocks or batch_slots * nb_lane, BLOCK,
                      batch_slots, nb_lane) if paged else None)
    swap_out = swap_in = None
    if swap:
        so, si = make_swap_steps()
        swap_out, swap_in = jax.jit(so), jax.jit(si, donate_argnums=(0,))

    def init(b):
        if not paged:
            return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                                  kv_bits=kv_bits)
        return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                              kv_bits=kv_bits, paged=True, block_size=BLOCK,
                              num_blocks=pool.num_blocks, mapped=False)

    serve_continuous(
        admit, decode, init, reqs, batch_slots=batch_slots,
        prompt_pad_len=PAD, max_len=MAX_LEN, block_pool=pool,
        chunk_fn=chunk, prefill_chunk=PAD if chunk is not None else None,
        radix_cache=RadixCache(BLOCK) if prefix else None,
        write_caps=(tfm.attn_write_caps(cfg, MAX_LEN, BLOCK)
                    if paged else None),
        ring_tokens=(tfm.paged_ring_tokens(cfg, MAX_LEN, BLOCK)
                     if paged else None),
        copy_block_fn=(jax.jit(tfm.cache_copy_block, donate_argnums=(0,))
                       if prefix else None),
        over_commit=over_commit, swap_out_fn=swap_out, swap_in_fn=swap_in)
    return [r.tokens_out for r in reqs]


def _assert_same_tokens(eng_reqs, sched_toks, kv_bits):
    if kv_bits == 4:
        # int4 per-slot dynamic grids round-trip prefill reads
        # approximately (house rule, launch/serve.py compare()): report a
        # strict match-rate floor instead of exact equality
        matched = sum(1 for r, s in zip(eng_reqs, sched_toks)
                      for x, y in zip(r.tokens_out, s) if x == y)
        total = sum(min(len(r.tokens_out), len(s))
                    for r, s in zip(eng_reqs, sched_toks))
        assert matched / max(total, 1) >= 0.9, (matched, total)
        return
    for r, s in zip(eng_reqs, sched_toks):
        assert r.tokens_out == s, f"rid {r.rid}: {r.tokens_out} != {s}"


class TestEngineSchedulerParity:
    @pytest.mark.parametrize("kv_bits", [16, 8, 4])
    def test_dense(self, tiny, kv_bits):
        cfg, params = tiny
        rng = np.random.RandomState(7)
        reqs = _mk_reqs(rng, cfg, SPEC)
        sched = _scheduler_tokens(
            cfg, params, _mk_reqs(np.random.RandomState(7), cfg, SPEC),
            kv_bits=kv_bits)
        serve_engine(_engine(cfg, params, kv_bits=kv_bits), reqs)
        _assert_same_tokens(reqs, sched, kv_bits)

    @pytest.mark.paged
    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_paged(self, tiny, kv_bits):
        """Identity-mapped paged engine == pool-managed paged Scheduler ==
        each other's greedy tokens (the decomposed insert's drop-in dense
        layout contract)."""
        cfg, params = tiny
        reqs = _mk_reqs(np.random.RandomState(8), cfg, SPEC)
        sched = _scheduler_tokens(
            cfg, params, _mk_reqs(np.random.RandomState(8), cfg, SPEC),
            kv_bits=kv_bits, paged=True)
        serve_engine(_engine(cfg, params, kv_bits=kv_bits, paged=True), reqs)
        _assert_same_tokens(reqs, sched, kv_bits)

    @pytest.mark.prefix
    def test_prefix_cache(self, tiny):
        """Prefix sharing is parity-preserving: the Scheduler WITH a radix
        cache (shared-prefix workload, real hits) matches the bare dense
        engine's FIFO tokens."""
        cfg, params = tiny
        rng = np.random.RandomState(9)
        shared = rng.randint(1, cfg.vocab_size, size=4).astype(np.int32)
        spec = [(8, 4)] * 4

        def mk():
            r = np.random.RandomState(9)
            r.randint(1, cfg.vocab_size, size=4)    # burn the shared draw
            return [Request(rid=i,
                            prompt=np.concatenate(
                                [shared, r.randint(1, cfg.vocab_size,
                                                   size=n - 4)])
                            .astype(np.int32),
                            max_new_tokens=q)
                    for i, (n, q) in enumerate(spec)]
        reqs = mk()
        sched = _scheduler_tokens(cfg, params, mk(), paged=True, prefix=True)
        serve_engine(_engine(cfg, params), reqs)
        _assert_same_tokens(reqs, sched, 16)

    @pytest.mark.preempt
    @pytest.mark.parametrize("swap", [False, True])
    def test_over_commit(self, tiny, swap):
        """Over-commit preemption (drop AND swap resume) is
        parity-preserving vs the bare dense engine. A starved pool forces
        real preemptions."""
        cfg, params = tiny
        nb_lane = tfm.paged_lane_blocks(cfg, MAX_LEN, BLOCK)
        reqs = _mk_reqs(np.random.RandomState(10), cfg, SPEC)
        sched = _scheduler_tokens(
            cfg, params, _mk_reqs(np.random.RandomState(10), cfg, SPEC),
            paged=True, over_commit=True, swap=swap,
            num_blocks=nb_lane + nb_lane // 2)
        serve_engine(_engine(cfg, params), reqs)
        _assert_same_tokens(reqs, sched, 16)

    @pytest.mark.deploy
    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_deploy_int8(self, deployed, kv_bits):
        cfg, packed, ctx_factory = deployed
        reqs = _mk_reqs(np.random.RandomState(11), cfg, SPEC[:4])
        sched = _scheduler_tokens(
            cfg, packed, _mk_reqs(np.random.RandomState(11), cfg, SPEC[:4]),
            kv_bits=kv_bits, ctx_factory=ctx_factory)
        serve_engine(_engine(cfg, packed, kv_bits=kv_bits,
                             ctx_factory=ctx_factory), reqs)
        _assert_same_tokens(reqs, sched, kv_bits)


class TestRecompileGuard:
    @pytest.mark.parametrize("paged", [False, True])
    def test_each_step_traces_once(self, tiny, paged):
        """Across arbitrary admission patterns — varying prompt lengths,
        quotas, lane compositions, a mid-stream second wave — each of
        prefill / insert (payload extract + lane insert) / generate traces
        exactly once. A recompile would show as a count > 1 (the counters
        bump inside the traced python body, once per trace)."""
        cfg, params = tiny
        eng = _engine(cfg, params, batch_slots=3, paged=paged)
        rng = np.random.RandomState(12)
        state = serve_engine(eng, _mk_reqs(rng, cfg, [(4, 2), (7, 5)]))
        # second wave reuses the same state object — new lane compositions
        serve_engine(eng, _mk_reqs(rng, cfg, [(3, 1), (8, 3), (5, 4)]),
                     state=state)
        assert eng.trace_counts == {"prefill": 1, "generate": 1,
                                    "extract": 1, "insert": 1}, \
            eng.trace_counts


def _lane_bytes(cache, lane):
    """Concatenated raw bytes of one batch lane across every cache leaf
    (scan leaves carry batch on axis 1, tail leaves on axis 0)."""
    parts = []
    for c in cache["scan"]:
        parts.extend(np.asarray(leaf[:, lane]).tobytes() for leaf in c)
    for c in cache["tail"]:
        parts.extend(np.asarray(leaf[lane]).tobytes() for leaf in c)
    return b"".join(parts)


class TestLaneBitIsolation:
    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_insert_touches_only_target_lane(self, tiny, kv_bits):
        """engine.insert is a FULL-lane overwrite: landing a payload in
        lane 1 leaves lanes 0 and 2 BIT-IDENTICAL across every cache leaf
        — including after those lanes already hold live requests."""
        cfg, params = tiny
        eng = _engine(cfg, params, kv_bits=kv_bits, batch_slots=3)
        rng = np.random.RandomState(13)
        state = eng.init_state()
        # occupy lanes 0 and 2 first so isolation is tested against live
        # bytes, not just zero-init
        for slot, n in ((0, 5), (2, 7)):
            _, payload = eng.prefill(
                rng.randint(1, cfg.vocab_size, size=n).astype(np.int32))
            state = eng.insert(payload, slot, state)
        before = {i: _lane_bytes(state.cache, i) for i in (0, 2)}
        _, payload = eng.prefill(
            rng.randint(1, cfg.vocab_size, size=6).astype(np.int32))
        state = eng.insert(payload, 1, state)
        for i in (0, 2):
            assert _lane_bytes(state.cache, i) == before[i], \
                f"insert into lane 1 perturbed lane {i}"
        # and the overwrite really replaced lane 1: a second insert of a
        # DIFFERENT prompt changes lane 1's bytes
        mid = _lane_bytes(state.cache, 1)
        _, payload = eng.prefill(
            rng.randint(1, cfg.vocab_size, size=4).astype(np.int32))
        state = eng.insert(payload, 1, state)
        assert _lane_bytes(state.cache, 1) != mid
        for i in (0, 2):
            assert _lane_bytes(state.cache, i) == before[i]


SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel import make_dist
    from repro.runtime import Request, serve_engine
    from repro.runtime.engine import make_engine

    assert len(jax.devices()) == 2, jax.devices()
    cfg = get_config("gemma2-2b").reduced()
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key, stacked=True, dtype=jnp.float32)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    dist = make_dist(mesh)
    SPEC = [(4, 2), (8, 6), (3, 1), (6, 4)]

    def mk_reqs(seed):
        rng = np.random.RandomState(seed)
        return [Request(rid=i,
                        prompt=rng.randint(1, cfg.vocab_size, size=n)
                        .astype(np.int32),
                        max_new_tokens=q)
                for i, (n, q) in enumerate(SPEC)]

    def run(p, d, ctx_factory=None):
        eng = make_engine(cfg, p, batch_slots=2, prompt_pad_len=8,
                          max_len=32, dtype=jnp.float32, dist=d,
                          ctx_factory=ctx_factory)
        reqs = mk_reqs(21)
        serve_engine(eng, reqs)
        return eng, [r.tokens_out for r in reqs]

    # 1) sharded == unsharded greedy tokens, f32
    eng_sh, toks_sh = run(params, dist)
    _, toks_un = run(params, None)
    assert toks_sh == toks_un, (toks_sh, toks_un)

    # 2) admit-mask broadcast: engine._put replicates host masks onto
    # EVERY mesh device (the insert/admit mask must be identical on all
    # shards or lanes diverge per-device)
    mask = np.array([True, False])
    put = eng_sh._put(mask)
    assert put.sharding.is_fully_replicated, put.sharding
    assert len(put.sharding.device_set) == 2, put.sharding
    np.testing.assert_array_equal(np.asarray(put), mask)

    # 3) deploy-int8 path under the same mesh (packed integer payloads
    # ride the replicate-by-default sharding rule)
    from repro.core import Mode, QuantCtx, build_deploy, peg_policy
    from repro.core.pipeline import ptq
    pol = peg_policy(4)
    flat = tfm.init_params(cfg, key, stacked=False, dtype=jnp.float32)
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10),
                                           (2, 8), 0, cfg.vocab_size)}]

    def fwd(p, b, ctx):
        logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
        return logits

    qm = ptq(fwd, flat, calib, pol, collect_inputs=True)
    shared = {}
    for site, qp in qm.act_state.items():
        base = ("layer/" + site.split("/", 1)[1]
                if site.startswith("layer") else site)
        shared.setdefault(base, qp)
    packed, acts = build_deploy(cfg, params, pol, shared)

    def ctx_factory():
        return QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=shared,
                        deploy_acts=acts)

    _, dep_sh = run(packed, dist, ctx_factory)
    _, dep_un = run(packed, None, ctx_factory)
    assert dep_sh == dep_un, (dep_sh, dep_un)
    print("SHARDED ENGINE OK")
""")


@pytest.mark.slow
def test_sharded_decode_parity(tmp_path):
    """Engine on 2 simulated CPU devices (tensor-parallel mesh (1, 2) over
    ("data", "model")): sharded == unsharded greedy tokens for f32 AND the
    deploy-int8 path, and the admit-mask broadcast lands fully replicated.
    Subprocess because XLA_FLAGS must be set before jax import (same idiom
    as tests/test_distribution.py)."""
    script = tmp_path / "sharded_engine.py"
    script.write_text(SHARDED_SCRIPT)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep +
               os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED ENGINE OK" in proc.stdout
