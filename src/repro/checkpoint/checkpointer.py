"""Sharded checkpointing with async save, step housekeeping, and
mesh-shape-agnostic layout (arrays are saved in logical form and resharded on
restore, so a 16x16 run can resume on an 8x16 mesh — elastic scaling,
DESIGN.md §4).

Format: one .npz per step (flattened pytree paths as keys) + a JSON metadata
sidecar (step, data-iterator state, mesh shape at save time). No external
checkpoint libraries are available offline, so this is self-contained.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

_SEP = "||"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_order(tree):
    return [
        _SEP.join(str(p) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, *, extra: Optional[dict] = None,
             block: bool = False):
        """Snapshot the pytree at ``step``. With async_save the host copy is
        taken synchronously (cheap) and the disk write happens in a
        background thread — training continues."""
        self.wait()                       # at most one outstanding write
        host_flat = {}
        dtypes = {}
        for k, v in _flatten(tree).items():
            arr = np.asarray(v)
            if arr.dtype.kind == "V":     # bfloat16 etc: store raw bits
                dtypes[k] = str(jax.numpy.asarray(v).dtype)
                arr = arr.view(np.uint16)
            host_flat[k] = arr
        meta = {"step": int(step), "time": time.time(), "dtypes": dtypes,
                **(extra or {})}

        def _write():
            tmp = os.path.join(self.directory, f".tmp-{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host_flat)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            final = os.path.join(self.directory, f"step_{step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)         # atomic publish
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``template``. ``shardings`` (same
        pytree structure, NamedSharding leaves) reshards on load — the saved
        mesh shape does not need to match the current one."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        arrays = np.load(os.path.join(d, "arrays.npz"))
        order = _path_order(template)
        leaves = []
        treedef = jax.tree_util.tree_structure(template)
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(order))
        meta = json.load(open(os.path.join(d, "meta.json")))
        dtypes = meta.get("dtypes", {})
        for key, shard in zip(order, shard_leaves):
            arr = arrays[key]
            if key in dtypes:             # restore raw-bit dtypes (bf16)
                import ml_dtypes
                arr = arr.view(np.dtype(dtypes[key]))
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), meta
