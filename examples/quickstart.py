"""Quickstart: the paper's pipeline end-to-end in ~a minute on CPU.

1. Build a small BERT with planted structured outliers (the paper's Fig.-2
   regime).
2. Calibrate activation ranges on a few batches (static range estimation).
3. Quantize W8A8 per-tensor -> see the degradation.
4. Re-quantize with per-embedding-group (PEG) K=4 + range-based permutation
   -> recover.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (fake_quant, peg_policy, w8a8_policy)
from repro.core.pipeline import ptq
from repro.models import bert


def main():
    cfg = bert.tiny()
    params = bert.init_params(cfg, jax.random.PRNGKey(0))
    # plant the paper's structured outliers: a few embedding dims of every
    # FFN output are consistently large
    for p in params["layers"]:
        for j, dim in enumerate((5, 40, 77, 100)):
            p["w_out"] = p["w_out"].at[:, dim].multiply(100.0 - 10 * j)

    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10 + i),
                                           (8, 32), 0, cfg.vocab_size)}
             for i in range(4)]

    def fwd(p, b, ctx):
        return bert.encode(cfg, p, b["tokens"], ctx=ctx)

    hidden_fp = fwd(params, calib[0], None)
    print(f"FP32 hidden-state std: {float(jnp.std(hidden_fp)):.3f}")

    def rel_err(policy, label):
        qm = ptq(fwd, params, calib, policy)
        hidden_q = fwd(params, calib[0], qm.ctx())
        rel = float(jnp.mean(jnp.square(hidden_fp - hidden_q)) /
                    jnp.mean(jnp.square(hidden_fp)))
        print(f"{label:<28s} relative hidden error: {rel:.5f}")
        return qm, rel

    _, e_pt = rel_err(w8a8_policy(), "W8A8 per-tensor PTQ")
    qm, e_peg = rel_err(peg_policy(4), "W8A8 PEG-PTQ (K=4 + perm)")
    print(f"\nPEG recovers {e_pt / max(e_peg, 1e-12):.1f}x of the per-tensor "
          "quantization error.")

    # inspect a PEG spec: the permutation isolates the outlier dims
    site = "layer0/residual_ffn"
    spec = qm.peg_specs[site]
    gi_nat = spec.group_index[spec.inverse_permutation]
    print(f"\n{site}: outlier dims -> groups "
          f"{[int(gi_nat[d]) for d in (5, 40, 77, 100)]} "
          f"(all isolated in group {spec.num_groups - 1})")


if __name__ == "__main__":
    main()
