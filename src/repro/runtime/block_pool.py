"""Host-side block-pool allocator for the paged KV cache.

The paged cache (models/attention.py: PagedKVCache / PagedQuantKVCache)
stores every attention layer's K/V as one shared HBM arena of
``num_blocks`` fixed-size blocks of ``block_size`` token cells. Which
physical block backs which token of which decode lane is pure *data*: a
``(batch_slots, max_blocks_per_lane)`` int32 block table (-1 = unmapped)
that the jitted admit / decode steps receive inside the cache pytree, so
allocation never changes traced shapes and the steps still trace exactly
once.

This module is the allocator behind that table. It is deliberately
host-side (numpy): the continuous scheduler (runtime/serve_loop.Scheduler)
allocates on admission, grows lanes incrementally as decode crosses block
boundaries, and releases a lane's blocks the moment its request retires —
all between jitted step calls.

Invariants the rest of the subsystem builds on:

* **Prefix mapping.** A lane's mapped blocks are always the contiguous
  logical prefix ``table[lane, 0:n]``. A lane that has written positions
  ``0..p`` has ``n >= p // block_size + 1``, so every logical cell a read
  path can derive as valid (see the derived-position rule in
  models/attention.py) is backed by a mapped block. Sliding-window layers
  write logical cell ``p % S_w`` whose block index never exceeds
  ``p // block_size`` — the same prefix covers them.

* **Reservation-backed growth (backpressure, no deadlock).** Admission
  reserves the request's WORST-CASE block count up front
  (``ceil((prompt + quota - 1) / block_size)``, clamped to the lane's ring
  capacity when every layer is windowed) and only admits when the
  reservation fits; decode-time growth then draws from that reservation
  and can never fail mid-flight. A request whose reservation does not fit
  stays at the head of the queue (FIFO backpressure) until a retirement
  frees blocks. Reservations are bookkeeping only — HBM-resident bytes
  are ``blocks_in_use * block_bytes``, which is what the paged
  ``ServeStats.cache_bytes`` reports.

* **Over-commit growth (preemption instead of worst-case sizing).** The
  over-commit scheduler skips the worst-case claim: admission reserves
  only what it actually maps, and ``try_grow`` extends the reservation on
  demand — returning False (instead of raising like ``grow``) when the
  pool cannot physically supply the extra blocks, at which point the
  scheduler preempts a victim lane and retries. ``available_blocks``
  (free list + evictable cached blocks) is the exact supply ``_pop_free``
  can produce, so a True from ``try_grow`` never underflows.

* **Refcounted sharing + copy-on-write (prefix cache).** Every physical
  block carries a refcount (how many lane tables map it) and a ``cached``
  flag (it backs a node of an attached
  :class:`~repro.runtime.radix_cache.RadixCache`). ``map_shared`` installs
  already-written blocks read-only into a lane's prefix; ``free_lane``
  decrements instead of freeing, returning a block to the free list only
  at refcount 0 when it is not cached. A lane about to *write* into a
  block it does not solely own first calls ``cow`` — the table entry is
  swapped for a fresh private copy (charged against the lane's novel
  reservation) so a shared block's payload is never mutated. Reservations
  therefore count only the lane's NOVEL blocks (suffix + decode growth +
  a COW allowance); shared blocks are capacity-accounted through
  ``blocks_pinned`` (cached blocks some lane still maps — unevictable),
  while cached refcount-0 blocks stay reclaimable: ``_map`` evicts them
  LRU through the attached radix cache when the free list runs dry.

All gauges are PHYSICAL (deduplicated): a block mapped by five lanes
counts once in ``blocks_in_use``; ``fragmentation`` is computed against
physically allocated cells.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to back token cells 0..n_tokens-1 (0 -> 0 blocks)."""
    return -(-max(n_tokens, 0) // block_size)


class BlockPool:
    """Free-list allocator over ``num_blocks`` physical KV-cache blocks.

    ``table`` is the (batch_slots, max_blocks_per_lane) int32 block table
    the jitted steps consume (-1 = unmapped). All mutation happens through
    ``reserve_and_alloc`` / ``map_shared`` / ``grow`` / ``cow`` /
    ``free_lane`` so the prefix-mapping and reservation invariants cannot
    be broken from outside.
    """

    def __init__(self, num_blocks: int, block_size: int, batch_slots: int,
                 max_blocks_per_lane: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError(
                f"need num_blocks >= 1 and block_size >= 1, got "
                f"{num_blocks}/{block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.batch_slots = batch_slots
        self.max_blocks_per_lane = max_blocks_per_lane
        self._cache = None          # attached RadixCache (eviction source)
        # observability hook: called with the list of evicted block ids
        # whenever radix LRU eviction reclaims cached blocks (set by the
        # scheduler when a Tracer is attached; None costs nothing)
        self.on_evict = None
        self.reset()

    def reset(self) -> None:
        self._free: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.table = np.full((self.batch_slots, self.max_blocks_per_lane),
                             -1, np.int32)
        self._n_mapped = np.zeros((self.batch_slots,), np.int64)
        # novel-only worst-case claims: shared (refcounted) blocks are NOT
        # part of a lane's reservation — they are already allocated
        self._reserved = np.zeros((self.batch_slots,), np.int64)
        # per-lane count of still-shared mapped blocks (decremented by cow)
        self._n_shared = np.zeros((self.batch_slots,), np.int64)
        self._ref = np.zeros((self.num_blocks,), np.int64)
        self._cached = np.zeros((self.num_blocks,), bool)
        if self._cache is not None:
            self._cache.reset()
        # set on every table mutation; the scheduler clears it after
        # re-uploading the table, skipping the per-step host->device
        # transfer on the (common) steps where no block was mapped or freed
        self.dirty = True

    def attach_cache(self, cache) -> None:
        """Attach a RadixCache as the LRU eviction source: when the free
        list runs dry, ``_map`` reclaims refcount-0 cached blocks from it."""
        self._cache = cache

    # -- gauges -------------------------------------------------------------

    @property
    def blocks_in_use(self) -> int:
        """Physically allocated blocks (each counted ONCE however many
        lanes map it; includes cached prefix blocks)."""
        return self.num_blocks - len(self._free)

    @property
    def blocks_free(self) -> int:
        return len(self._free)

    @property
    def blocks_reserved(self) -> int:
        """Outstanding worst-case NOVEL claims (shared blocks excluded)."""
        return int(self._reserved.sum())

    @property
    def blocks_cached(self) -> int:
        """Blocks backing radix-cache nodes (evictable iff refcount 0)."""
        return int(self._cached.sum())

    @property
    def blocks_pinned(self) -> int:
        """Cached blocks some lane still maps — not evictable, so they
        subtract from the capacity admission can claim."""
        return int((self._cached & (self._ref > 0)).sum())

    @property
    def shared_blocks(self) -> int:
        """Physical blocks currently serving a shared prefix (cached and
        mapped by at least one lane)."""
        return self.blocks_pinned

    @property
    def blocks_evictable(self) -> int:
        """Cached refcount-0 blocks — reclaimable through the attached
        radix cache's LRU eviction when the free list runs dry (each is
        itself an eviction candidate, so every one of them IS supplyable)."""
        return int((self._cached & (self._ref == 0)).sum())

    def available_blocks(self) -> int:
        """Blocks ``_pop_free`` could physically supply right now: the
        free list plus every evictable cached block. The over-commit
        scheduler's growth / admission / COW paths test against this
        before drawing, preempting a lane when it comes up short."""
        return len(self._free) + self.blocks_evictable

    def fragmentation(self, live_tokens: int) -> float:
        """Fraction of physically allocated token cells not holding a live
        token — the internal (within-block) waste of the current
        allocation. ``live_tokens`` must be deduplicated the same way
        (count a shared prefix once, see Scheduler._track)."""
        cells = self.blocks_in_use * self.block_size
        if cells == 0:
            return 0.0
        return 1.0 - min(live_tokens, cells) / cells

    def lane_blocks(self, lane: int) -> np.ndarray:
        return self.table[lane, :int(self._n_mapped[lane])].copy()

    def lane_mapped(self, lane: int) -> int:
        """Number of blocks currently mapped into ``lane``'s table row."""
        return int(self._n_mapped[lane])

    @property
    def refcount_total(self) -> int:
        """Sum of block refcounts (block sharing gauge for metrics
        snapshots: equals blocks_in_use when nothing is shared)."""
        return int(self._ref.sum())

    def lane_shared(self, lane: int) -> int:
        """Number of ``lane``'s mapped blocks still shared (not yet COWed)."""
        return int(self._n_shared[lane])

    def block_ref(self, block: int) -> int:
        return int(self._ref[block])

    def is_cached(self, block: int) -> bool:
        return bool(self._cached[block])

    # -- allocation ---------------------------------------------------------

    def _fits(self, n_novel: int, n_cols: int, new_pins: int) -> bool:
        """Core admission test: ``n_cols`` table columns must fit the lane
        width, and the NOVEL claim must fit next to every outstanding
        reservation and every pinned cached block. (A COW allowance
        inflates the claim but never the column count — a COW swaps a
        column in place.)"""
        return (n_cols <= self.max_blocks_per_lane
                and (self.blocks_reserved + n_novel
                     + self.blocks_pinned + new_pins) <= self.num_blocks)

    def can_reserve(self, n_blocks: int) -> bool:
        """True if a worst-case NOVEL claim of ``n_blocks`` fits (admission
        backpressure test, no shared prefix)."""
        return self._fits(n_blocks, n_blocks, 0)

    def can_map_shared(self, blocks: Sequence[int], n_reserve: int,
                       n_cols: int) -> bool:
        """Backpressure test for a prefix-hit admission: ``blocks`` mapped
        shared, ``n_reserve`` novel claim, ``n_cols`` total table columns
        the lane may ever occupy."""
        new_pins = sum(1 for b in blocks if self._ref[b] == 0)
        return self._fits(n_reserve, n_cols, new_pins)

    def reserve_and_alloc(self, lane: int, n_alloc: int,
                          n_reserve: int) -> bool:
        """Admission: claim ``n_reserve`` worst-case blocks for ``lane`` and
        map the first ``n_alloc`` (the prompt's blocks) now. Returns False —
        with no state change — when the reservation does not fit (the
        request stays queued)."""
        n_reserve = max(n_reserve, n_alloc)
        if self._reserved[lane] or self._n_mapped[lane]:
            raise RuntimeError(f"lane {lane} still holds blocks/reservation")
        if not self.can_reserve(n_reserve):
            return False
        self._reserved[lane] = n_reserve
        self._map(lane, n_alloc)
        return True

    def map_shared(self, lane: int, blocks: Sequence[int], n_alloc: int,
                   n_reserve: int, n_cols: int) -> bool:
        """Prefix-hit admission: install the already-written ``blocks``
        read-only at ``table[lane, 0:k]`` (refcount bump, no allocation),
        then map ``n_alloc`` fresh blocks for the first novel chunk and
        claim ``n_reserve`` NOVEL worst-case blocks (suffix + decode growth
        + COW allowance). ``n_cols`` is the total table columns the lane
        may ever occupy (shared + novel-growth; COW adds none). Returns
        False with no state change when the claim does not fit."""
        if self._reserved[lane] or self._n_mapped[lane]:
            raise RuntimeError(f"lane {lane} still holds blocks/reservation")
        k = len(blocks)
        if k == 0:
            return self.reserve_and_alloc(lane, n_alloc, n_reserve)
        n_reserve = max(n_reserve, n_alloc)
        if not self.can_map_shared(blocks, n_reserve, max(n_cols,
                                                          k + n_alloc)):
            return False
        for j, b in enumerate(blocks):
            if not self._cached[b]:
                raise RuntimeError(
                    f"map_shared: block {b} is not a cached prefix block")
            self.table[lane, j] = b
            self._ref[b] += 1
        self._n_mapped[lane] = k
        self._n_shared[lane] = k
        self._reserved[lane] = n_reserve
        self._map(lane, n_alloc)
        self.dirty = True
        return True

    def grow(self, lane: int, n_total: int) -> None:
        """Decode growth: extend ``lane``'s mapped prefix to ``n_total``
        blocks. Always succeeds within the lane's reservation (the
        scheduler reserves worst case at admission). Only the NOVEL part
        (beyond the lane's shared prefix + COW swaps) draws on the
        reservation."""
        novel = n_total - int(self._n_shared[lane])
        if novel > self._reserved[lane]:
            raise RuntimeError(
                f"lane {lane}: growth to {n_total} blocks ({novel} novel) "
                f"exceeds its reservation of {int(self._reserved[lane])}")
        if n_total > self._n_mapped[lane]:
            self._map(lane, n_total - int(self._n_mapped[lane]))

    def try_grow(self, lane: int, n_total: int) -> bool:
        """Over-commit growth: extend ``lane``'s mapped prefix to
        ``n_total`` blocks, EXTENDING its reservation on demand instead of
        drawing on a worst-case claim made at admission. Returns False —
        with no state change — when the pool cannot physically supply the
        extra blocks (or the lane's table row is too narrow); the
        over-commit scheduler then preempts a victim lane and retries.
        The prefix-mapping invariant is untouched: growth still appends
        to ``table[lane, 0:n]``."""
        if n_total > self.max_blocks_per_lane:
            return False
        n_new = n_total - int(self._n_mapped[lane])
        if n_new <= 0:
            return True
        if n_new > self.available_blocks():
            return False
        # under over-commit the reservation tracks the novel mapped count
        # (so the shared accounting in _fits stays physically exact)
        novel = n_total - int(self._n_shared[lane])
        self._reserved[lane] = max(int(self._reserved[lane]), novel)
        self._map(lane, n_new)
        return True

    def needs_cow(self, lane: int, col: int) -> bool:
        """True when ``lane`` does not solely own the (mapped) block at
        table column ``col`` — writing it would mutate a shared/cached
        block."""
        if col >= int(self._n_mapped[lane]):
            return False
        b = int(self.table[lane, col])
        return bool(self._cached[b]) or int(self._ref[b]) > 1

    def cow(self, lane: int, col: int,
            extend: bool = False) -> Optional[Tuple[int, int]]:
        """Copy-on-write: if ``lane`` is about to write into a block it
        does not solely own, swap ``table[lane, col]`` for a fresh private
        block (charged to the lane's novel reservation) and return
        ``(src, dst)`` physical ids for the device-side payload copy.
        Returns None when the lane already owns the block. ``extend``
        (over-commit mode, no up-front COW allowance) grows the
        reservation in place instead of raising — the scheduler checks
        ``available_blocks`` (preempting when dry) before calling."""
        if not self.needs_cow(lane, col):
            return None
        src = int(self.table[lane, col])
        novel = int(self._n_mapped[lane]) - int(self._n_shared[lane]) + 1
        if novel > self._reserved[lane]:
            if not extend:                    # pragma: no cover - see above
                raise RuntimeError(
                    f"lane {lane}: COW at col {col} exceeds its "
                    f"reservation of {int(self._reserved[lane])}")
            self._reserved[lane] = novel
        dst = self._pop_free(1)[0]
        self.table[lane, col] = dst
        self._ref[dst] = 1
        self._ref[src] -= 1
        self._n_shared[lane] -= 1
        self.dirty = True
        return src, dst

    def _pop_free(self, n: int) -> List[int]:
        """Take ``n`` blocks off the free list, reclaiming LRU refcount-0
        cached blocks through the attached radix cache when it runs dry."""
        while len(self._free) < n and self._cache is not None:
            evicted = self._cache.evict_lru(self.block_ref)
            if not evicted:
                break
            if self.on_evict is not None:
                self.on_evict(evicted)
            for b in evicted:
                self._cached[b] = False
                if self._ref[b] == 0:
                    self._free.append(b)
        if n > len(self._free):
            raise RuntimeError(
                f"free list underflow: need {n}, have {len(self._free)} "
                "(reservation invariant violated)")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._ref[b] = 0
        return out

    def _map(self, lane: int, n_new: int) -> None:
        if n_new <= 0:
            return
        start = int(self._n_mapped[lane])
        for j, b in enumerate(self._pop_free(n_new)):
            self.table[lane, start + j] = b
            self._ref[b] = 1
        self._n_mapped[lane] = start + n_new
        self.dirty = True

    def set_cached(self, block: int, cached: bool = True) -> None:
        """Mark ``block`` as backing a radix-cache node (called by the
        scheduler on donation / by the pool itself on eviction). An
        uncached refcount-0 block goes straight back to the free list."""
        self._cached[block] = cached
        if not cached and self._ref[block] == 0:
            self._free.append(int(block))

    def free_lane(self, lane: int) -> int:
        """Retirement: decrement every mapped block's refcount, returning
        blocks that reach refcount 0 (and are not cached) to the free
        list; clear the lane's reservation and table row. Returns the
        number of blocks actually released to the free list."""
        n = int(self._n_mapped[lane])
        released = 0
        for j in range(n - 1, -1, -1):
            b = int(self.table[lane, j])
            self._ref[b] -= 1
            if self._ref[b] == 0 and not self._cached[b]:
                self._free.append(b)
                released += 1
        self.table[lane, :n] = -1
        self._n_mapped[lane] = 0
        self._n_shared[lane] = 0
        self._reserved[lane] = 0
        if n:
            self.dirty = True
        return released
