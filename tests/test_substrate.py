"""Substrate tests: optimizer, schedules, data pipeline determinism,
checkpoint/restore, fault-tolerance primitives, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adam_init, adam_update, apply_updates,
                         clip_by_global_norm, cosine_schedule,
                         linear_warmup_linear_decay)


class TestAdam:
    def test_converges_on_quadratic(self):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        state = adam_init(params)

        def loss(p):
            return jnp.sum(jnp.square(p["w"] - target))

        for _ in range(500):
            g = jax.grad(loss)(params)
            upd, state = adam_update(g, state, params, lr=5e-2)
            params = apply_updates(params, upd)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_weight_decay_decoupled(self):
        params = {"w": jnp.ones(4)}
        state = adam_init(params)
        zero_g = {"w": jnp.zeros(4)}
        upd, state = adam_update(zero_g, state, params, lr=0.1,
                                 weight_decay=0.1)
        p2 = apply_updates(params, upd)
        assert float(p2["w"][0]) < 1.0            # decays without gradient

    def test_bf16_params_fp32_moments(self):
        params = {"w": jnp.ones(4, jnp.bfloat16)}
        state = adam_init(params)
        assert state.mu["w"].dtype == jnp.float32
        g = {"w": jnp.full(4, 0.5, jnp.bfloat16)}
        upd, state = adam_update(g, state, params, lr=1e-2)
        assert upd["w"].dtype == jnp.bfloat16

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full(4, 10.0), "b": jnp.full(9, 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        total = sum(float(jnp.sum(jnp.square(x)))
                    for x in jax.tree.leaves(clipped))
        assert abs(total - 1.0) < 1e-4
        assert float(norm) > 1.0


class TestSchedules:
    def test_linear_warmup_decay(self):
        s = linear_warmup_linear_decay(1e-3, 1000, warmup_frac=0.1)
        assert float(s(0)) == 0.0
        assert abs(float(s(100)) - 1e-3) < 1e-9   # peak at warmup end
        assert abs(float(s(1000))) < 1e-9         # decayed to zero
        assert float(s(50)) < float(s(100))

    def test_cosine(self):
        s = cosine_schedule(1e-3, 1000)
        assert float(s(100)) == pytest.approx(1e-3, rel=1e-3)
        assert float(s(1000)) == pytest.approx(0.0, abs=1e-6)


class TestData:
    def test_lm_deterministic(self):
        from repro.data import LMTaskConfig, SyntheticLM
        src = SyntheticLM(LMTaskConfig(vocab_size=256, seq_len=32), seed=7)
        b1 = src.batch(4, 11)
        b2 = src.batch(4, 11)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = src.batch(4, 12)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_glue_rules_learnable_signal(self):
        from repro.data import GLUE_SUITE, SyntheticGLUE
        for cfg in GLUE_SUITE:
            src = SyntheticGLUE(cfg, seed=0)
            b = src.batch(64, 0)
            assert b["tokens"].shape == (64, cfg.seq_len)
            if not cfg.regression:
                # both classes present
                assert len(np.unique(b["labels"])) >= 2

    def test_pipeline_checkpoint_resume(self):
        from repro.data import DataPipeline, LMTaskConfig, SyntheticLM
        src = SyntheticLM(LMTaskConfig(vocab_size=128, seq_len=16), seed=3)
        p1 = DataPipeline(src, batch_size=2, seed=3)
        batches = [next(p1) for _ in range(5)]
        state = p1.checkpoint_state()
        after = [next(p1) for _ in range(3)]
        # resume from the saved state: identical continuation
        p2 = DataPipeline(src, batch_size=2, seed=3)
        p2.restore_state(state)
        resumed = [next(p2) for _ in range(3)]
        for a, b in zip(after, resumed):
            np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_glue_metric(self):
        from repro.data import GLUETaskConfig, SyntheticGLUE
        src = SyntheticGLUE(GLUETaskConfig("t"))
        assert src.metric(np.asarray([1, 0, 1]), np.asarray([1, 0, 0])) == \
            pytest.approx(100 * 2 / 3)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        from repro.checkpoint import Checkpointer
        ck = Checkpointer(str(tmp_path), async_save=False)
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
        ck.save(5, tree, extra={"data_state": {"seed": 1, "step": 5}})
        restored, meta = ck.restore(tree)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["nested"]["b"].dtype == jnp.bfloat16
        assert meta["step"] == 5 and meta["data_state"]["step"] == 5

    def test_keeps_latest_n(self, tmp_path):
        from repro.checkpoint import Checkpointer
        ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            ck.save(s, {"x": jnp.zeros(2)})
        assert ck.all_steps() == [3, 4]

    def test_async_save_visible_after_wait(self, tmp_path):
        from repro.checkpoint import Checkpointer
        ck = Checkpointer(str(tmp_path), async_save=True)
        ck.save(1, {"x": jnp.arange(3)})
        ck.wait()
        assert ck.latest_step() == 1

    def test_atomicity_no_partial_checkpoints(self, tmp_path):
        """tmp dirs are not listed as valid steps."""
        from repro.checkpoint import Checkpointer
        ck = Checkpointer(str(tmp_path), async_save=False)
        os.makedirs(tmp_path / ".tmp-9")
        ck.save(1, {"x": jnp.zeros(1)})
        assert ck.all_steps() == [1]


class TestFaultTolerance:
    def test_straggler_watchdog(self):
        from repro.runtime import StragglerWatchdog
        wd = StragglerWatchdog(threshold=2.0, warmup_steps=3, trip_after=2)
        for _ in range(10):
            assert not wd.observe(1.0)
        assert wd.observe(5.0)          # flagged
        assert not wd.tripped
        assert wd.observe(5.0)
        assert wd.tripped               # consecutive -> tripped

    def test_watchdog_recovers(self):
        from repro.runtime import StragglerWatchdog
        wd = StragglerWatchdog(threshold=2.0, warmup_steps=2, trip_after=3)
        for _ in range(5):
            wd.observe(1.0)
        wd.observe(10.0)
        wd.observe(1.0)                 # back to normal
        assert wd.consecutive == 0 and not wd.tripped

    def test_restart_policy_window(self):
        from repro.runtime import RestartPolicy
        rp = RestartPolicy(max_restarts=2, window_s=100)
        assert rp.should_restart(now=0.0)
        assert rp.should_restart(now=1.0)
        assert not rp.should_restart(now=2.0)       # exhausted
        assert rp.should_restart(now=200.0)         # window expired


class TestTrainLoopIntegration:
    def test_resume_after_interrupt(self, tmp_path):
        """Train 6 steps with checkpoint_every=2, kill, resume, finish —
        the resumed run continues from the checkpoint (params + data)."""
        from repro.data import DataPipeline, LMTaskConfig, SyntheticLM
        from repro.runtime import TrainLoopConfig, run_train_loop
        from repro.optim import adam_init

        params = {"w": jnp.zeros(4)}

        def step_fn(params, opt, batch):
            tgt = jnp.asarray(batch["tokens"][:, :4], jnp.float32).mean(0)
            g = jax.grad(lambda p: jnp.sum(jnp.square(p["w"] - tgt)))(params)
            upd, opt = adam_update(g, opt, params, lr=1e-1)
            return apply_updates(params, upd), opt, \
                {"loss": jnp.sum(jnp.square(params["w"] - tgt))}

        src = SyntheticLM(LMTaskConfig(vocab_size=64, seq_len=8), seed=0)

        def fresh():
            return (dict(params), adam_init(params),
                    DataPipeline(src, batch_size=2, seed=0))

        p, o, pipe = fresh()
        cfg1 = TrainLoopConfig(total_steps=4, checkpoint_every=2,
                               checkpoint_dir=str(tmp_path), log_every=100)
        out1 = run_train_loop(step_fn, p, o, pipe, cfg1, log=lambda s: None)
        assert out1["step"] == 4

        # resume with a higher target; loop picks up from step 4
        p, o, pipe = fresh()
        cfg2 = TrainLoopConfig(total_steps=7, checkpoint_every=2,
                               checkpoint_dir=str(tmp_path), log_every=100)
        out2 = run_train_loop(step_fn, p, o, pipe, cfg2, log=lambda s: None)
        assert out2["step"] == 7
        assert pipe.state.step == 7     # data iterator resumed too


class TestGradCompression:
    def test_quant_dequant_roundtrip_bounded(self):
        from repro.core.grad_compression import (dequantize_grad,
                                                 quantize_grad)
        g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.01
        q, s = quantize_grad(g, group_size=128)
        g2 = dequantize_grad(q, s, g.shape, g.dtype)
        assert float(jnp.max(jnp.abs(g - g2))) <= float(jnp.max(s)) * 0.51

    def test_error_feedback_unbiased_over_steps(self):
        """With error feedback, the ACCUMULATED compressed signal tracks the
        accumulated true gradient (bias does not grow)."""
        from repro.core.grad_compression import (dequantize_grad,
                                                 quantize_grad)
        rng = np.random.RandomState(0)
        err = jnp.zeros(256)
        total_true = np.zeros(256)
        total_sent = np.zeros(256)
        for i in range(50):
            g = jnp.asarray(rng.randn(256) * 0.01)
            comp = g + err
            q, s = quantize_grad(comp, group_size=64)
            sent = dequantize_grad(q, s, g.shape, jnp.float32)
            err = comp - sent
            total_true += np.asarray(g)
            total_sent += np.asarray(sent)
        # residual bias is bounded by one quantization step, not 50 of them
        assert np.max(np.abs(total_true - total_sent)) < 0.01

    def test_compressed_psum_matches_mean(self):
        """shard_map over a 2-member axis: compressed all-reduce ~= mean."""
        if jax.device_count() < 2:
            pytest.skip("needs >=2 devices (run under dry-run env)")
