from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, all_configs,
                                get_config, input_specs, shape_cells)
