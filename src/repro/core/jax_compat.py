"""Version-compat shims for jax API drift (paired with
parallel.sharding.make_abstract_mesh).

``shard_map`` moved to the top-level namespace (with ``check_vma``) in
newer jax; older installs expose it under ``jax.experimental`` (with
``check_rep``). ``shard_map(...)`` here accepts the new-style call and
rewrites the kwarg for old installs.
"""
from __future__ import annotations

import jax

try:                                              # new API (jax >= 0.6)
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:                            # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: check_vma})
