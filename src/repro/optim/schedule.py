"""Learning-rate schedules. The paper (App. B.1/B.3) uses linear warmup for
the first 10% of steps followed by linear decay to zero."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_linear_decay(max_lr: float, total_steps: int,
                               warmup_frac: float = 0.1):
    warmup = max(int(total_steps * warmup_frac), 1)

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / warmup
        decay = jnp.maximum(0.0, (total_steps - step) /
                            jnp.maximum(total_steps - warmup, 1))
        return max_lr * jnp.where(step < warmup, warm, decay)
    return schedule


def cosine_schedule(max_lr: float, total_steps: int, warmup_frac: float = 0.1,
                    min_lr: float = 0.0):
    warmup = max(int(total_steps * warmup_frac), 1)

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / warmup
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
        cos = min_lr + 0.5 * (max_lr - min_lr) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, max_lr * warm, cos)
    return schedule


def constant_schedule(lr: float):
    def schedule(step):
        return jnp.full((), lr, jnp.float32)
    return schedule
