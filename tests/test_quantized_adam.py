"""8-bit Adam (int8 moments, the paper's grouped quantization applied to
optimizer state)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adam import adam_init, adam_update, apply_updates
from repro.optim.quantized_adam import (QUANT_MIN_ELEMS, qadam_init,
                                        qadam_update)


def test_small_leaves_stay_fp32():
    params = {"small": jnp.zeros((4, 4)), "big": jnp.zeros((2048, 1024))}
    st = qadam_init(params)
    assert isinstance(st.mu["small"], jnp.ndarray)
    assert isinstance(st.mu["big"], dict)
    assert st.mu["big"]["q"].dtype == jnp.int8
    assert st.mu["big"]["s"].shape == (2048,)


def test_matches_fp32_adam_closely():
    """On a quadratic, 8-bit Adam should track fp32 Adam and converge."""
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (2048, 1024)) * 0.1
    p32 = {"w": jnp.zeros((2048, 1024))}
    p8 = {"w": jnp.zeros((2048, 1024))}
    s32 = adam_init(p32)
    s8 = qadam_init(p8)

    def loss(p):
        return jnp.mean(jnp.square(p["w"] - target))

    @jax.jit
    def step32(p, s):
        g = jax.grad(loss)(p)
        u, s = adam_update(g, s, p, lr=1e-2)
        return apply_updates(p, u), s

    @jax.jit
    def step8(p, s):
        g = jax.grad(loss)(p)
        u, s = qadam_update(g, s, p, lr=1e-2)
        return apply_updates(p, u), s

    for _ in range(60):
        p32, s32 = step32(p32, s32)
        p8, s8 = step8(p8, s8)
    l32, l8 = float(loss(p32)), float(loss(p8))
    assert l8 < float(loss({"w": jnp.zeros_like(target)})) / 3   # converging
    assert l8 < l32 * 2.0 + 1e-4                                 # tracks fp32


def test_grad_scale_fused():
    p = {"w": jnp.ones((2048, 1024))}
    s = qadam_init(p)
    g = {"w": jnp.full((2048, 1024), 100.0)}     # huge grads
    u_noclip, _ = qadam_update(g, s, p, lr=1e-2)
    u_clip, _ = qadam_update(g, s, p, lr=1e-2, grad_scale=jnp.asarray(0.0))
    assert float(jnp.max(jnp.abs(u_clip["w"]))) < \
        float(jnp.max(jnp.abs(u_noclip["w"])))


def test_memory_footprint():
    """int8 moments cost ~2 bytes/param vs 8 for fp32 Adam."""
    p = {"w": jnp.zeros((4096, 1024), jnp.bfloat16)}
    st = qadam_init(p)
    n = p["w"].size
    bytes8 = (st.mu["w"]["q"].size * 1 + st.mu["w"]["s"].size * 4) * 2
    assert bytes8 < 0.27 * (n * 8)      # >3.7x smaller than fp32 moments
