"""BERT encoder — the paper's model, with every quantization site of the
paper's Fig. 1 threaded through QuantCtx (post-LN blocks, learned positions,
token-type embeddings, pooler + classification/regression head).

Sites (per layer i):
  layer{i}/attn/{q,k,v,softmax_in,softmax_out,ctx_out}
  layer{i}/residual_attn           — sum x + attn_out (input of LN_attn)
  layer{i}/ln_attn                 — LN output (= FFN input path)
  layer{i}/ffn_in                  — FFN input (paper "FFN's input")
  layer{i}/ffn/hidden              — GELU hidden
  layer{i}/ffn_out                 — FFN output (paper "FFN's output")
  layer{i}/residual_ffn            — THE bottleneck: sum after FFN
  layer{i}/ln_ffn                  — LN output feeding the next layer
Global: embed/sum, head/pooled, head/logits.
Weight sites: layer{i}/attn/{wq,wk,wv,wo}, layer{i}/ffn/{w_in,w_out},
embed/tokens, head/w_pool, head/w_cls.

For BERT-base (12 layers) this yields 8 + 12*13 = 161-ish activation
quantizers, matching the paper's "36 of 161" accounting granularity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import AttnConfig, _dense_attend
from repro.models.common import (cross_entropy, dense_init, embed_init, gelu,
                                 layer_norm, split_keys)


@dataclasses.dataclass(frozen=True)
class BertConfig:
    num_layers: int = 12
    d_model: int = 768
    num_heads: int = 12
    d_ff: int = 3072
    vocab_size: int = 30522
    type_vocab: int = 2
    max_positions: int = 512
    num_labels: int = 2
    regression: bool = False      # STS-B-style

    @property
    def hd(self):
        return self.d_model // self.num_heads


def tiny(num_labels=2, regression=False, **kw) -> BertConfig:
    """The reduced BERT used by the reproduction benchmarks."""
    defaults = dict(num_layers=4, d_model=128, num_heads=4, d_ff=512,
                    vocab_size=1024, max_positions=128,
                    num_labels=num_labels, regression=regression)
    defaults.update(kw)
    return BertConfig(**defaults)


def init_params(cfg: BertConfig, key, dtype=jnp.float32):
    ks = split_keys(key, cfg.num_layers + 6)
    params: Dict[str, Any] = {
        "tok_embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "pos_embed": embed_init(ks[1], cfg.max_positions, cfg.d_model, dtype),
        "type_embed": embed_init(ks[2], cfg.type_vocab, cfg.d_model, dtype),
        "embed_ln_g": jnp.ones((cfg.d_model,), dtype),
        "embed_ln_b": jnp.zeros((cfg.d_model,), dtype),
        "w_pool": dense_init(ks[3], cfg.d_model, cfg.d_model, dtype),
        "b_pool": jnp.zeros((cfg.d_model,), dtype),
        "w_cls": dense_init(ks[4], cfg.d_model, cfg.num_labels, dtype),
        "b_cls": jnp.zeros((cfg.num_labels,), dtype),
        "layers": [],
    }
    for i in range(cfg.num_layers):
        lk = split_keys(ks[5 + i], 6)
        params["layers"].append({
            "wq": dense_init(lk[0], cfg.d_model, cfg.d_model, dtype),
            "wk": dense_init(lk[1], cfg.d_model, cfg.d_model, dtype),
            "wv": dense_init(lk[2], cfg.d_model, cfg.d_model, dtype),
            "wo": dense_init(lk[3], cfg.d_model, cfg.d_model, dtype),
            "bq": jnp.zeros((cfg.d_model,), dtype),
            "bk": jnp.zeros((cfg.d_model,), dtype),
            "bv": jnp.zeros((cfg.d_model,), dtype),
            "bo": jnp.zeros((cfg.d_model,), dtype),
            "ln_attn_g": jnp.ones((cfg.d_model,), dtype),
            "ln_attn_b": jnp.zeros((cfg.d_model,), dtype),
            "w_in": dense_init(lk[4], cfg.d_model, cfg.d_ff, dtype),
            "b_in": jnp.zeros((cfg.d_ff,), dtype),
            "w_out": dense_init(lk[5], cfg.d_ff, cfg.d_model, dtype),
            "b_out": jnp.zeros((cfg.d_model,), dtype),
            "ln_ffn_g": jnp.ones((cfg.d_model,), dtype),
            "ln_ffn_b": jnp.zeros((cfg.d_model,), dtype),
        })
    return params


def _self_attention(cfg: BertConfig, p, x, pad_mask, ctx, prefix):
    B, T, D = x.shape
    H, hd = cfg.num_heads, cfg.hd

    def w(name):
        return ctx.weight(f"{prefix}/{name}", p[name]) if ctx is not None else p[name]

    q = (x @ w("wq") + p["bq"]).reshape(B, T, H, hd)
    k = (x @ w("wk") + p["bk"]).reshape(B, T, H, hd)
    v = (x @ w("wv") + p["bv"]).reshape(B, T, H, hd)
    if ctx is not None:
        q = ctx.act(f"{prefix}/q", q)
        k = ctx.act(f"{prefix}/k", k)
        v = ctx.act(f"{prefix}/v", v)
    acfg = AttnConfig(num_heads=H, num_kv_heads=H, head_dim=hd, causal=False,
                      rope_theta=None)
    # positions encode padding: valid tokens >= 0, padded -> -1 (masked out)
    pos = jnp.where(pad_mask, jnp.arange(T, dtype=jnp.int32)[None], -1)
    out = _dense_attend(q, k, v, jnp.zeros((B, T), jnp.int32), pos, acfg,
                        ctx=ctx, prefix=prefix)
    out = out.reshape(B, T, D) @ w("wo") + p["bo"]
    if ctx is not None:
        out = ctx.act(f"{prefix}/ctx_out", out)
    return out


def encode(cfg: BertConfig, params, tokens, *, type_ids=None, pad_mask=None,
           ctx=None):
    """tokens: (B, T) -> hidden states (B, T, D)."""
    B, T = tokens.shape
    if pad_mask is None:
        pad_mask = jnp.ones((B, T), bool)
    if type_ids is None:
        type_ids = jnp.zeros((B, T), jnp.int32)

    def wsite(site, w):
        return ctx.weight(site, w) if ctx is not None else w

    x = jnp.take(wsite("embed/tokens", params["tok_embed"]), tokens, axis=0)
    x = x + params["pos_embed"][None, :T]
    x = x + jnp.take(params["type_embed"], type_ids, axis=0)
    if ctx is not None:
        x = ctx.act("embed/sum", x)       # paper: "sum of embeddings"
    x = layer_norm(x, params["embed_ln_g"], params["embed_ln_b"])
    if ctx is not None:
        x = ctx.act("embed/ln", x)

    for i, p in enumerate(params["layers"]):
        pre = f"layer{i}"
        attn_out = _self_attention(cfg, p, x, pad_mask, ctx, f"{pre}/attn")
        s = x + attn_out
        if ctx is not None:
            s = ctx.act(f"{pre}/residual_attn", s)
        x = layer_norm(s, p["ln_attn_g"], p["ln_attn_b"])
        if ctx is not None:
            x = ctx.act(f"{pre}/ln_attn", x)

        f_in = x
        if ctx is not None:
            f_in = ctx.act(f"{pre}/ffn_in", f_in)
        h = f_in @ (ctx.weight(f"{pre}/ffn/w_in", p["w_in"])
                    if ctx is not None else p["w_in"]) + p["b_in"]
        h = gelu(h)
        if ctx is not None:
            h = ctx.act(f"{pre}/ffn/hidden", h)
        f_out = h @ (ctx.weight(f"{pre}/ffn/w_out", p["w_out"])
                     if ctx is not None else p["w_out"]) + p["b_out"]
        if ctx is not None:
            f_out = ctx.act(f"{pre}/ffn_out", f_out)
        s = x + f_out
        if ctx is not None:
            s = ctx.act(f"{pre}/residual_ffn", s)   # THE paper bottleneck
        x = layer_norm(s, p["ln_ffn_g"], p["ln_ffn_b"])
        if ctx is not None:
            x = ctx.act(f"{pre}/ln_ffn", x)
    return x


def classify(cfg: BertConfig, params, tokens, *, type_ids=None,
             pad_mask=None, ctx=None):
    """Sequence classification/regression head on [CLS] (position 0)."""
    h = encode(cfg, params, tokens, type_ids=type_ids, pad_mask=pad_mask,
               ctx=ctx)
    cls = h[:, 0]
    pooled = jnp.tanh(cls @ (ctx.weight("head/w_pool", params["w_pool"])
                             if ctx is not None else params["w_pool"])
                      + params["b_pool"])
    if ctx is not None:
        pooled = ctx.act("head/pooled", pooled)
    logits = pooled @ (ctx.weight("head/w_cls", params["w_cls"])
                       if ctx is not None else params["w_cls"]) + params["b_cls"]
    if ctx is not None:
        logits = ctx.act("head/logits", logits)
    return logits


def loss_fn(cfg: BertConfig, params, batch, ctx=None):
    logits = classify(cfg, params, batch["tokens"],
                      type_ids=batch.get("type_ids"),
                      pad_mask=batch.get("pad_mask"), ctx=ctx)
    if cfg.regression:
        return jnp.mean(jnp.square(logits[:, 0] - batch["labels"]))
    onehot_ce = cross_entropy(logits, batch["labels"])
    return onehot_ce


def predict(cfg: BertConfig, params, batch, ctx=None):
    logits = classify(cfg, params, batch["tokens"],
                      type_ids=batch.get("type_ids"),
                      pad_mask=batch.get("pad_mask"), ctx=ctx)
    if cfg.regression:
        return logits[:, 0]
    return jnp.argmax(logits, axis=-1)


def named_weight_sites(cfg: BertConfig, params) -> Dict[str, jnp.ndarray]:
    """site -> weight array, for PTQ weight-state building / AdaRound."""
    out = {"embed/tokens": params["tok_embed"],
           "head/w_pool": params["w_pool"], "head/w_cls": params["w_cls"]}
    for i, p in enumerate(params["layers"]):
        for nm in ("wq", "wk", "wv", "wo"):
            out[f"layer{i}/attn/{nm}"] = p[nm]
        out[f"layer{i}/ffn/w_in"] = p["w_in"]
        out[f"layer{i}/ffn/w_out"] = p["w_out"]
    return out


def activation_sites(cfg: BertConfig) -> list:
    """All activation site names (for the paper's '161 quantizers' census)."""
    sites = ["embed/sum", "embed/ln", "head/pooled", "head/logits"]
    for i in range(cfg.num_layers):
        pre = f"layer{i}"
        sites += [f"{pre}/attn/q", f"{pre}/attn/k", f"{pre}/attn/v",
                  f"{pre}/attn/softmax_in", f"{pre}/attn/softmax_out",
                  f"{pre}/attn/ctx_out", f"{pre}/residual_attn",
                  f"{pre}/ln_attn", f"{pre}/ffn_in", f"{pre}/ffn/hidden",
                  f"{pre}/ffn_out", f"{pre}/residual_ffn", f"{pre}/ln_ffn"]
    return sites
