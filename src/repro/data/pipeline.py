"""Sharded, checkpointable input pipeline.

The iterator state is just (seed, step) — generation is deterministic per
(seed, index), so restart-after-failure replays the exact token stream
(DESIGN.md §4 fault tolerance). ``shard_batch`` places the host batch onto
the mesh with the data-parallel sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class IteratorState:
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class DataPipeline:
    """Wraps a generator with .batch(batch_size, index) into a stateful,
    checkpointable iterator."""

    def __init__(self, source, batch_size: int, state: Optional[IteratorState]
                 = None, seed: int = 0):
        self.source = source
        self.batch_size = batch_size
        self.state = state or IteratorState(seed=seed, step=0)

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self.source.batch(self.batch_size, self.state.step)
        self.state.step += 1
        return batch

    def checkpoint_state(self) -> dict:
        return self.state.to_dict()

    def restore_state(self, d: dict):
        self.state = IteratorState.from_dict(d)


def shard_batch(batch: Dict[str, np.ndarray], mesh,
                dp_axes=("data",)) -> Dict[str, jax.Array]:
    """Device-put the host batch sharded over the data-parallel axes."""
    out = {}
    for k, v in batch.items():
        spec = P(dp_axes, *([None] * (v.ndim - 1))) if v.ndim else P()
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
