"""Property-based tests (hypothesis) for the quantization system's
invariants."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="install via requirements-dev.txt")
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import (Granularity, QuantizerConfig, fake_quant,
                        params_from_range, quantize, reduce_range)
from repro.core.peg import build_groups, group_index_natural_layout

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")

finite_arrays = hnp.arrays(
    np.float32, hnp.array_shapes(min_dims=1, max_dims=2, min_side=2,
                                 max_side=64),
    elements=st.floats(-1e4, 1e4, width=32))


@given(finite_arrays, st.integers(2, 8), st.booleans())
def test_fake_quant_idempotent(x, bits, symmetric):
    """Quantizing an already-quantized tensor is a no-op (projection)."""
    cfg = QuantizerConfig(bits=bits, symmetric=symmetric)
    qp = params_from_range(*reduce_range(jnp.asarray(x), cfg), cfg)
    once = fake_quant(jnp.asarray(x), qp, cfg)
    twice = fake_quant(once, qp, cfg)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               rtol=1e-5, atol=1e-6)


@given(finite_arrays, st.integers(2, 8))
def test_quantize_outputs_in_grid(x, bits):
    cfg = QuantizerConfig(bits=bits, symmetric=False)
    qp = params_from_range(*reduce_range(jnp.asarray(x), cfg), cfg)
    q = np.asarray(quantize(jnp.asarray(x), qp, cfg))
    assert q.min() >= cfg.qmin and q.max() <= cfg.qmax


@given(finite_arrays, st.integers(2, 8))
def test_error_bounded_by_half_step_inside_range(x, bits):
    """|x - q(x)| <= scale/2 for values inside the clipping range."""
    cfg = QuantizerConfig(bits=bits, symmetric=False)
    xj = jnp.asarray(x)
    qp = params_from_range(*reduce_range(xj, cfg), cfg)
    xq = fake_quant(xj, qp, cfg)
    err = np.abs(np.asarray(xj - xq))
    bound = float(qp.scale) * 0.5 + 1e-3 * max(1.0, float(qp.scale))
    assert err.max() <= bound


@given(finite_arrays)
def test_monotonicity(x):
    """fake_quant is monotone non-decreasing in its input."""
    cfg = QuantizerConfig(bits=4, symmetric=False)
    xj = jnp.sort(jnp.asarray(x).reshape(-1))
    qp = params_from_range(xj[0], xj[-1], cfg)
    out = np.asarray(fake_quant(xj, qp, cfg))
    assert np.all(np.diff(out) >= -1e-6)


@given(st.integers(2, 512), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_peg_groups_partition_dims(d, k, seed):
    """PEG group assignment is always a partition of the d dims."""
    hypothesis.assume(k <= d)
    r = np.random.RandomState(seed % 2**31).rand(d)
    spec = build_groups(r, k, lane_align=False)
    gi = group_index_natural_layout(spec)
    assert gi.shape == (d,)
    assert set(np.unique(spec.group_index)) == set(range(k))
    assert spec.group_sizes.sum() == d
    # permutation is a bijection
    assert sorted(spec.permutation.tolist()) == list(range(d))


@given(st.integers(4, 256), st.integers(2, 4), st.integers(0, 10 ** 6))
def test_peg_sorted_ranges_are_grouped_contiguously(d, k, seed):
    """After the range-based permutation, group ranges are non-overlapping
    in sorted order: max range of group j <= min range of group j+1."""
    hypothesis.assume(k <= d)
    r = np.random.RandomState(seed % 2**31).rand(d)
    spec = build_groups(r, k, use_permutation=True, lane_align=False)
    sorted_r = r[spec.permutation]
    bounds = np.cumsum(spec.group_sizes)
    prev_max = -np.inf
    for j in range(k):
        lo = 0 if j == 0 else bounds[j - 1]
        grp = sorted_r[lo:bounds[j]]
        assert grp.min() >= prev_max - 1e-12
        prev_max = grp.max()


@given(hnp.arrays(np.float32, st.tuples(st.integers(2, 16),
                                        st.integers(2, 32)),
                  elements=st.floats(-100, 100, width=32)),
       st.integers(2, 8))
def test_grad_compression_roundtrip_bound(g, group):
    from repro.core.grad_compression import dequantize_grad, quantize_grad
    gj = jnp.asarray(g)
    q, s = quantize_grad(gj, group_size=group * 32)
    g2 = dequantize_grad(q, s, gj.shape, gj.dtype)
    # error per element bounded by half its group's scale
    assert float(jnp.max(jnp.abs(gj - g2))) <= float(jnp.max(s)) * 0.51 + 1e-6
