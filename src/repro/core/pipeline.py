"""End-to-end post-training-quantization pipeline (paper §5 setup).

    fp32 model + calibration batches + policy
        -> collect activation ranges (static range estimation)
        -> build PEG groups (range-based permutation) where the policy asks
        -> finalize activation QuantParams
        -> estimate weight QuantParams (MSE for <8-bit per §5)
        -> optional AdaRound refinement of selected weights
        -> frozen QuantState ready for Mode.APPLY inference / QAT init.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

import jax.numpy as jnp

from repro.core.adaround import AdaRoundConfig, optimize_rounding
from repro.core.calibration import (Mode, QuantCtx, build_act_state,
                                    build_weight_state, collect_ranges)
from repro.core.quant_config import QuantizationPolicy


@dataclasses.dataclass
class QuantizedModel:
    """Frozen PTQ artifact: everything needed to run quantized inference."""
    policy: QuantizationPolicy
    act_state: dict
    weight_state: dict
    peg_specs: dict
    adarounded_weights: dict          # site -> hard-rounded weight tensor

    def ctx(self) -> QuantCtx:
        return QuantCtx(policy=self.policy, mode=Mode.APPLY,
                        act_state=self.act_state,
                        weight_state=self.weight_state)


def ptq(forward: Callable, params, calib_batches: Sequence,
        policy: QuantizationPolicy, *,
        named_weights: Optional[Dict[str, jnp.ndarray]] = None,
        tp_shards: int = 1,
        adaround_sites: Optional[Dict[str, tuple]] = None,
        adaround_cfg: AdaRoundConfig = AdaRoundConfig(),
        collect_inputs: bool = False) -> QuantizedModel:
    """Run the full PTQ pipeline.

    forward(params, batch, ctx) -> model output, calling ctx.act()/ctx.weight()
    named_weights: site -> weight array for weight-state precomputation.
    adaround_sites: site -> (weight, calib_inputs) for AdaRound refinement.
    collect_inputs: also calibrate the matmul-input sites (ctx.act_in) so the
    artifact can feed the integer deployment path (core.deploy).
    """
    range_states, calib_tensors = collect_ranges(
        forward, params, calib_batches, policy,
        collect_inputs=collect_inputs)
    act_state, peg_specs = build_act_state(
        range_states, calib_tensors, policy, tp_shards=tp_shards)
    weight_state = build_weight_state(named_weights or {}, policy)

    adarounded = {}
    if adaround_sites:
        for site, (w, x_in) in adaround_sites.items():
            cfg = policy.weight_config(site)
            qp = weight_state.get(site)
            if qp is None:
                from repro.core.range_estimation import estimate_weight_params
                qp = estimate_weight_params(w, cfg)
            w_hard, _ = optimize_rounding(w, x_in, qp, cfg, adaround_cfg)
            adarounded[site] = w_hard

    return QuantizedModel(policy=policy, act_state=act_state,
                          weight_state=weight_state, peg_specs=peg_specs,
                          adarounded_weights=adarounded)
