"""Modality frontend STUBS (per the assignment: the transformer backbone is
specified; the audio/vision frontend provides precomputed frame/patch
embeddings via input_specs()).

These helpers synthesize deterministic embeddings for smoke tests and
examples; production inputs arrive as (B, N, d_model) arrays."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def synth_patch_embeddings(key, batch: int, num_tokens: int, d_model: int,
                           dtype=jnp.bfloat16):
    """Stand-in for a CLIP vision tower output (phi-3-vision)."""
    return (jax.random.normal(key, (batch, num_tokens, d_model)) * 0.02
            ).astype(dtype)


def synth_frame_embeddings(key, batch: int, num_frames: int, d_model: int,
                           dtype=jnp.bfloat16):
    """Stand-in for a speech feature encoder output (seamless-m4t)."""
    return (jax.random.normal(key, (batch, num_frames, d_model)) * 0.02
            ).astype(dtype)
