"""Fault-tolerant training loop: checkpoint/restart (incl. data-iterator
state), preemption-safe exit, straggler watchdog, metrics logging."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.data.pipeline import DataPipeline
from repro.runtime.fault_tolerance import PreemptionGuard, StragglerWatchdog


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    checkpoint_every: int = 100
    log_every: int = 10
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    resume: bool = True


def run_train_loop(step_fn: Callable, params, opt_state,
                   pipeline: DataPipeline, loop_cfg: TrainLoopConfig, *,
                   put_batch: Optional[Callable] = None,
                   shardings=None,
                   log: Callable[[str], None] = print) -> Dict[str, Any]:
    """Drives ``step_fn(params, opt_state, batch)``.

    Resumes (params, opt, data-iterator) from the latest checkpoint when
    present; checkpoints asynchronously every N steps and once more on
    preemption or completion. Returns final state + history.
    """
    ckpt = None
    start_step = 0
    if loop_cfg.checkpoint_dir:
        ckpt = Checkpointer(loop_cfg.checkpoint_dir,
                            keep=loop_cfg.keep_checkpoints)
        if loop_cfg.resume and ckpt.latest_step() is not None:
            (params, opt_state), meta = ckpt.restore(
                (params, opt_state), shardings=shardings)
            start_step = int(meta["step"])
            if "data_state" in meta:
                pipeline.restore_state(meta["data_state"])
            log(f"[train] resumed from step {start_step}")

    guard = PreemptionGuard()
    watchdog = StragglerWatchdog()
    history = []

    def save(step):
        if ckpt is not None:
            ckpt.save(step, (params, opt_state),
                      extra={"data_state": pipeline.checkpoint_state()})

    step = start_step
    try:
        while step < loop_cfg.total_steps:
            batch = next(pipeline)
            if put_batch is not None:
                batch = put_batch(batch)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            step += 1
            flagged = watchdog.observe(dt)
            if step % loop_cfg.log_every == 0 or flagged:
                loss = float(metrics["loss"])
                msg = (f"[train] step {step} loss {loss:.4f} "
                       f"{dt*1e3:.1f} ms" + ("  STRAGGLER" if flagged else ""))
                log(msg)
                history.append({"step": step, "loss": loss, "time_s": dt})
            if loop_cfg.checkpoint_every and \
                    step % loop_cfg.checkpoint_every == 0:
                save(step)
            if guard.preempted:
                log(f"[train] preempted at step {step}; checkpointing")
                save(step)
                break
            if watchdog.tripped:
                log(f"[train] straggler watchdog tripped at step {step}; "
                    "checkpointing for elastic re-mesh")
                save(step)
                watchdog.tripped = False
                watchdog.consecutive = 0
    finally:
        guard.uninstall()
        if ckpt is not None:
            save(step)
            ckpt.wait()

    return {"params": params, "opt_state": opt_state, "step": step,
            "history": history, "straggler_events": watchdog.events}
