"""recurrentgemma-2b [hybrid] — Griffin: RG-LRU + local attention, 1:2 ratio
(pattern rec,rec,local_attn; 26 = 8x3 + 2 tail rec). [arXiv:2402.19427; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,               # MQA in the local-attention blocks
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rec", "rec", "local_attn"),
    tail_pattern=("rec", "rec"),
    local_window=2048,
    d_rnn=2560,
    rope_theta=10000.0,
    norm="rmsnorm",
    act="gelu",
    ffn_type="glu",
    embed_scale=True,
    tie_embeddings=True,
    sub_quadratic=True,           # O(1) recurrent state + bounded local attn
    source="arXiv:2402.19427; hf",
)
