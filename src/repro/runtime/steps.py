"""Jittable train / serve step builders (shared by launchers, dry-run and
benchmarks).

``make_train_step`` supports microbatch gradient accumulation (lax.scan over
microbatches — per-device activation memory scales 1/M), global-norm
clipping, Adam, and optional PEG-int8 cross-pod gradient compression.
``make_prefill_step`` / ``make_decode_step`` build serve steps with KV-cache
threading; ``make_admit_step`` is the continuous-batching slot-insert
prefill (reset admitted lanes + prefill, other lanes bit-preserved);
``make_chunk_prefill_step`` is its chunked-prefill sibling (append one
fixed-width chunk at each lane's current position).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as encdec_lib
from repro.models import transformer as tfm
from repro.optim import (adam_update, apply_updates, clip_by_global_norm)


def _loss_fn_for(cfg: ModelConfig):
    if cfg.encoder_layers:
        return encdec_lib.train_loss
    return tfm.train_loss


def make_train_step(cfg: ModelConfig, *, lr_schedule, microbatches: int = 1,
                    dist=None, clip_norm: float = 1.0,
                    ctx_factory: Optional[Callable] = None,
                    remat: bool = True, chunked=None,
                    optimizer: str = "adam", accum_dtype=jnp.float32):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ctx_factory: () -> QuantCtx for QAT (fake-quant in the train graph).
    optimizer: "adam" (f32 moments) or "adam8bit" (int8 moments with
    row-wise scales — repro.optim.quantized_adam).
    """
    loss_fn = _loss_fn_for(cfg)
    if optimizer == "adam8bit":
        from repro.optim.quantized_adam import qadam_update as _opt_update
    else:
        _opt_update = adam_update

    def loss_for(params, mb):
        ctx = ctx_factory() if ctx_factory is not None else None
        kw = {} if cfg.encoder_layers else {"chunked": chunked}
        return loss_fn(cfg, params, mb, ctx=ctx, dist=dist, remat=remat, **kw)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_for)(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            mbs = jax.tree.map(split, batch)
            gz = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def acc(carry, mb):
                lsum, gsum = carry
                l, g = jax.value_and_grad(loss_for)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), gsum, g)
                return (lsum + l, gsum), None

            (lsum, gsum), _ = jax.lax.scan(acc, (jnp.zeros(()), gz), mbs)
            loss = lsum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)

        # global-norm clip FUSED into the moment update (no scaled-grad copy)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-12))
        updates, opt_state = _opt_update(grads, opt_state, params,
                                         lr=lr_schedule, grad_scale=scale)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": lr_schedule(opt_state.step) if callable(lr_schedule)
                   else jnp.asarray(lr_schedule)}
        return params, opt_state, metrics

    return step


def _telem_ctx(ctx_factory):
    """Fresh ctx with quant-telemetry armed: every fake-quant / deploy site
    it hits appends a fixed-shape [clipped, total, amax, range] vector to
    ``ctx.telemetry`` — a dict of arrays, i.e. a pytree the step returns as
    an EXTRA jit output. Same traced computation otherwise, so enabling it
    builds a separate (3-output) jit entry while the plain 2-output step's
    signature — and its compiled executable — is untouched."""
    ctx = ctx_factory() if ctx_factory is not None else None
    if ctx is not None:
        ctx.telemetry = {}
        return ctx, ctx.telemetry
    return None, {}


def make_prefill_step(cfg: ModelConfig, *, dist=None,
                      ctx_factory: Optional[Callable] = None, chunked=None,
                      quant_telemetry: bool = False):
    """prefill(params, tokens, cache[, positions]) -> (last_logits, cache).

    ``positions`` (B, T) carries the dead-cell sentinel: pads in a
    left-packed ragged prompt are position -1 (masked from attention, cache
    write dropped) so packing never perturbs a request's own lane. None
    keeps the legacy arange positions (no pads).

    ``quant_telemetry=True`` returns (last_logits, cache, telemetry) — the
    extra output is the per-site quant-health dict (see _telem_ctx); the
    default path is byte-identical to before the flag existed.
    """
    def prefill(params, tokens, cache, positions=None, embeds=None):
        ctx = ctx_factory() if ctx_factory is not None else None
        return tfm.prefill(cfg, params, tokens, cache, positions=positions,
                           embeds=embeds, ctx=ctx, dist=dist, chunked=chunked)

    if not quant_telemetry:
        return prefill

    def prefill_t(params, tokens, cache, positions=None, embeds=None):
        ctx, tel = _telem_ctx(ctx_factory)
        logits, cache = tfm.prefill(cfg, params, tokens, cache,
                                    positions=positions, embeds=embeds,
                                    ctx=ctx, dist=dist, chunked=chunked)
        return logits, cache, tel
    return prefill_t


def make_admit_step(cfg: ModelConfig, *, dist=None,
                    ctx_factory: Optional[Callable] = None, chunked=None,
                    quant_telemetry: bool = False):
    """Slot-insert prefill for continuous batching (one jitted step, fixed
    shapes — admissions never recompile).

    admit(params, tokens (B, P), positions (B, P), admit_mask (B,), cache)
        -> (last_logits (B, 1, V), cache)

    Admitted lanes are first reset (pos -> -1 across every layer's cache,
    see transformer.cache_reset_slots) and then prefilled with their
    left-padded prompt (real positions 0..len-1, pads -1). Non-admitted
    lanes carry ALL -1 positions: they neither attend nor write, so their
    cache lanes pass through bit-identical while requests are admitted
    mid-flight.

    Paged caches need no extra plumbing here: the block table rides inside
    the cache pytree (``cache["block_table"]``, updated host-side by the
    scheduler's BlockPool between calls), cache_reset_slots empties the
    admitted lanes' mapped *blocks*, and the prompt scatter routes through
    the table — all data, so this step still traces exactly once.
    """
    def admit(params, tokens, positions, admit_mask, cache):
        ctx = ctx_factory() if ctx_factory is not None else None
        cache = tfm.cache_reset_slots(cache, admit_mask)
        return tfm.prefill(cfg, params, tokens, cache, positions=positions,
                           ctx=ctx, dist=dist, chunked=chunked)

    if not quant_telemetry:
        return admit

    def admit_t(params, tokens, positions, admit_mask, cache):
        ctx, tel = _telem_ctx(ctx_factory)
        cache = tfm.cache_reset_slots(cache, admit_mask)
        logits, cache = tfm.prefill(cfg, params, tokens, cache,
                                    positions=positions, ctx=ctx, dist=dist,
                                    chunked=chunked)
        return logits, cache, tel
    return admit_t


def make_chunk_prefill_step(cfg: ModelConfig, *, dist=None,
                            ctx_factory: Optional[Callable] = None,
                            chunked=None, quant_telemetry: bool = False):
    """Chunked-prefill step for continuous batching: append ONE fixed-width
    chunk of prompt tokens at each participating lane's current cache
    position (one jitted step, fixed (B, C) shapes — traced exactly once
    across arbitrarily many chunks and admissions).

    chunk(params, tokens (B, C), positions (B, C), reset_mask (B,), cache)
        -> (last_logits (B, 1, V), cache)

    ``reset_mask`` marks lanes starting their FIRST chunk — their cache
    lanes are emptied first (pos -> -1, exactly the admit-step reset).
    Every row is the lane's next chunk, left-padded into the fixed width C
    (real positions off..off+c-1, pads -1); lanes not prefilling this step
    carry ALL -1 positions and pass through bit-identical. Attention runs
    in append mode (models.attention): queries see the cache (the lane's
    earlier chunks) plus the fresh chunk, so after the last chunk the
    lane's cache and last-token logits match a monolithic slot-insert
    prefill — resident lanes keep decoding between chunks instead of
    stalling through one long prefill.

    The paged twin needs no extra plumbing (same reasoning as
    make_admit_step); the scheduler grows a lane's mapped block prefix
    by O(chunk / block_size) blocks before each chunk.
    """
    def chunk(params, tokens, positions, reset_mask, cache):
        ctx = ctx_factory() if ctx_factory is not None else None
        cache = tfm.cache_reset_slots(cache, reset_mask)
        return tfm.prefill(cfg, params, tokens, cache, positions=positions,
                           ctx=ctx, dist=dist, chunked=chunked, append=True)

    if not quant_telemetry:
        return chunk

    def chunk_t(params, tokens, positions, reset_mask, cache):
        ctx, tel = _telem_ctx(ctx_factory)
        cache = tfm.cache_reset_slots(cache, reset_mask)
        logits, cache = tfm.prefill(cfg, params, tokens, cache,
                                    positions=positions, ctx=ctx, dist=dist,
                                    chunked=chunked, append=True)
        return logits, cache, tel
    return chunk_t


def make_decode_step(cfg: ModelConfig, *, dist=None,
                     ctx_factory: Optional[Callable] = None,
                     quant_telemetry: bool = False):
    """serve_step: one new token against the KV cache/state."""
    if cfg.encoder_layers:
        def decode(params, tokens, pos, cache):
            ctx = ctx_factory() if ctx_factory is not None else None
            return encdec_lib.decode_step(cfg, params, tokens, pos, cache,
                                          ctx=ctx)
        return decode

    def decode(params, tokens, pos, cache):
        ctx = ctx_factory() if ctx_factory is not None else None
        return tfm.decode_step(cfg, params, tokens, pos, cache, ctx=ctx,
                               dist=dist)

    if not quant_telemetry:
        return decode

    def decode_t(params, tokens, pos, cache):
        ctx, tel = _telem_ctx(ctx_factory)
        logits, cache = tfm.decode_step(cfg, params, tokens, pos, cache,
                                        ctx=ctx, dist=dist)
        return logits, cache, tel
    return decode_t


def make_swap_steps():
    """Block swap-out / swap-in pair for over-commit preemption (paged
    caches only — thin wrappers over models.transformer's gather/scatter,
    shaped for jitting by launch/serve.py):

    swap_out(cache, ids (max_blocks_per_lane,)) -> payload pytree
    swap_in(cache, ids, payload) -> cache

    ``ids`` is a FIXED-length int32 vector — the lane's live physical
    block ids first, padded with ``num_blocks`` (an out-of-range POSITIVE
    id: the gather clips it to a garbage row, the scatter DROPS the
    write, and a negative pad would wrap around instead). One trace
    serves every preemption/resume since block ids are data. The
    scheduler device_gets the payload into a host spill buffer at
    preemption and device_puts it back at resume against the lane's NEW
    block ids — bit-exact, so the resumed lane emits identical greedy
    tokens. Jit swap_in with ``donate_argnums=(0,)`` (the cache arena is
    updated in place); swap_out must NOT donate (the cache lives on).
    """
    def swap_out(cache, ids):
        return tfm.cache_gather_blocks(cache, ids)

    def swap_in(cache, ids, payload):
        return tfm.cache_scatter_blocks(cache, ids, payload)
    return swap_out, swap_in


def make_encoder_forward(cfg: ModelConfig, *, dist=None):
    """Prefill-equivalent for encoder-decoder archs: encode the frames and
    project the decoder's cross-attention KV (the serving 'prefill')."""
    def fwd(params, frames, bos_tokens):
        return encdec_lib.prefill_from_encoder(
            cfg, params, frames, bos_tokens, max_decode_len=1024)
    return fwd
