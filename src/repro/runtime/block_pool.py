"""Host-side block-pool allocator for the paged KV cache.

The paged cache (models/attention.py: PagedKVCache / PagedQuantKVCache)
stores every attention layer's K/V as one shared HBM arena of
``num_blocks`` fixed-size blocks of ``block_size`` token cells. Which
physical block backs which token of which decode lane is pure *data*: a
``(batch_slots, max_blocks_per_lane)`` int32 block table (-1 = unmapped)
that the jitted admit / decode steps receive inside the cache pytree, so
allocation never changes traced shapes and the steps still trace exactly
once.

This module is the allocator behind that table. It is deliberately
host-side (numpy): the continuous scheduler (runtime/serve_loop.Scheduler)
allocates on admission, grows lanes incrementally as decode crosses block
boundaries, and releases a lane's blocks the moment its request retires —
all between jitted step calls.

Invariants the rest of the subsystem builds on:

* **Prefix mapping.** A lane's mapped blocks are always the contiguous
  logical prefix ``table[lane, 0:n]``. A lane that has written positions
  ``0..p`` has ``n >= p // block_size + 1``, so every logical cell a read
  path can derive as valid (see the derived-position rule in
  models/attention.py) is backed by a mapped block. Sliding-window layers
  write logical cell ``p % S_w`` whose block index never exceeds
  ``p // block_size`` — the same prefix covers them.

* **Reservation-backed growth (backpressure, no deadlock).** Admission
  reserves the request's WORST-CASE block count up front
  (``ceil((prompt + quota - 1) / block_size)``) and only admits when the
  reservation fits; decode-time growth then draws from that reservation
  and can never fail mid-flight. A request whose reservation does not fit
  stays at the head of the queue (FIFO backpressure) until a retirement
  frees blocks. Reservations are bookkeeping only — HBM-resident bytes
  are ``blocks_in_use * block_bytes``, which is what the paged
  ``ServeStats.cache_bytes`` reports.
"""
from __future__ import annotations

from typing import List

import numpy as np


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to back token cells 0..n_tokens-1 (0 -> 0 blocks)."""
    return -(-max(n_tokens, 0) // block_size)


class BlockPool:
    """Free-list allocator over ``num_blocks`` physical KV-cache blocks.

    ``table`` is the (batch_slots, max_blocks_per_lane) int32 block table
    the jitted steps consume (-1 = unmapped). All mutation happens through
    ``reserve_and_alloc`` / ``grow`` / ``free_lane`` so the prefix-mapping
    and reservation invariants cannot be broken from outside.
    """

    def __init__(self, num_blocks: int, block_size: int, batch_slots: int,
                 max_blocks_per_lane: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError(
                f"need num_blocks >= 1 and block_size >= 1, got "
                f"{num_blocks}/{block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.batch_slots = batch_slots
        self.max_blocks_per_lane = max_blocks_per_lane
        self.reset()

    def reset(self) -> None:
        self._free: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.table = np.full((self.batch_slots, self.max_blocks_per_lane),
                             -1, np.int32)
        self._n_mapped = np.zeros((self.batch_slots,), np.int64)
        self._reserved = np.zeros((self.batch_slots,), np.int64)
        # set on every table mutation; the scheduler clears it after
        # re-uploading the table, skipping the per-step host->device
        # transfer on the (common) steps where no block was mapped or freed
        self.dirty = True

    # -- gauges -------------------------------------------------------------

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def blocks_free(self) -> int:
        return len(self._free)

    @property
    def blocks_reserved(self) -> int:
        """Outstanding worst-case claims (>= blocks_in_use)."""
        return int(self._reserved.sum())

    def fragmentation(self, live_tokens: int) -> float:
        """Fraction of allocated token cells not holding a live token —
        the internal (within-block) waste of the current allocation."""
        cells = self.blocks_in_use * self.block_size
        if cells == 0:
            return 0.0
        return 1.0 - min(live_tokens, cells) / cells

    def lane_blocks(self, lane: int) -> np.ndarray:
        return self.table[lane, :int(self._n_mapped[lane])].copy()

    # -- allocation ---------------------------------------------------------

    def can_reserve(self, n_blocks: int) -> bool:
        """True if a worst-case claim of ``n_blocks`` fits next to every
        outstanding reservation (admission backpressure test)."""
        return (n_blocks <= self.max_blocks_per_lane
                and self.blocks_reserved + n_blocks <= self.num_blocks)

    def reserve_and_alloc(self, lane: int, n_alloc: int,
                          n_reserve: int) -> bool:
        """Admission: claim ``n_reserve`` worst-case blocks for ``lane`` and
        map the first ``n_alloc`` (the prompt's blocks) now. Returns False —
        with no state change — when the reservation does not fit (the
        request stays queued)."""
        n_reserve = max(n_reserve, n_alloc)
        if self._reserved[lane] or self._n_mapped[lane]:
            raise RuntimeError(f"lane {lane} still holds blocks/reservation")
        if not self.can_reserve(n_reserve):
            return False
        self._reserved[lane] = n_reserve
        self._map(lane, n_alloc)
        return True

    def grow(self, lane: int, n_total: int) -> None:
        """Decode growth: extend ``lane``'s mapped prefix to ``n_total``
        blocks. Always succeeds within the lane's reservation (the
        scheduler reserves worst case at admission)."""
        if n_total > self._reserved[lane]:
            raise RuntimeError(
                f"lane {lane}: growth to {n_total} blocks exceeds its "
                f"reservation of {int(self._reserved[lane])}")
        if n_total > self._n_mapped[lane]:
            self._map(lane, n_total - int(self._n_mapped[lane]))

    def _map(self, lane: int, n_new: int) -> None:
        if n_new > len(self._free):      # pragma: no cover - guarded above
            raise RuntimeError(
                f"free list underflow: need {n_new}, have {len(self._free)} "
                "(reservation invariant violated)")
        start = int(self._n_mapped[lane])
        for j in range(n_new):
            self.table[lane, start + j] = self._free.pop()
        self._n_mapped[lane] = start + n_new
        self.dirty = True

    def free_lane(self, lane: int) -> int:
        """Retirement: return every mapped block of ``lane`` to the free
        list, clear its reservation and table row. Returns the number of
        blocks released."""
        n = int(self._n_mapped[lane])
        for j in range(n - 1, -1, -1):
            self._free.append(int(self.table[lane, j]))
        self.table[lane, :n] = -1
        self._n_mapped[lane] = 0
        self._reserved[lane] = 0
        if n:
            self.dirty = True
        return n
