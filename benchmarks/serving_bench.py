"""Serving-scheduler benchmark: static group batching vs continuous
(slot-scheduled) batching on a skewed-quota workload.

The workload is the scheduling worst case the paper's deployment story runs
into in production: ``max_new_tokens`` drawn from {SHORT_QUOTA, LONG_QUOTA}
(interleaved), so under static batching every group decodes in lockstep at
the pace of its slowest request while the short requests' lanes idle.
Continuous batching retires those lanes immediately and admits queued
requests mid-flight, so the measured tokens/s ratio is (mostly) the
slot-utilization ratio.

Both schedulers serve the IDENTICAL request set through the same jitted
steps (warmed up before timing) on gemma2-2b-reduced, for the f32 KV cache
and the int8 QuantKVCache (``kv_bits=8``, dynamic per-slot scales +
``int8_attend_decode``). Greedy parity between the schedulers is asserted
as part of the bench — a speedup with diverging tokens would be a bug, not
a result.

``python -m benchmarks.serving_bench`` (or benchmarks/run.py --sections
serving) also writes machine-readable ``BENCH_serving.json``.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.runtime import Request, serve
from repro.runtime.steps import (make_admit_step, make_decode_step,
                                 make_prefill_step)

JSON_PATH = "BENCH_serving.json"

BATCH_SLOTS = 8
N_REQUESTS = 16
PROMPT_LEN = 8
SHORT_QUOTA = 4
LONG_QUOTA = 96
MAX_LEN = 128
REPEATS = 3          # timed repeats; best tokens/s wins (CPU wall jitter)


def _requests(cfg):
    rng = np.random.RandomState(0)
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size,
                                       size=PROMPT_LEN).astype(np.int32),
                    max_new_tokens=LONG_QUOTA if i % 2 else SHORT_QUOTA)
            for i in range(N_REQUESTS)]


def _serve(cfg, params, steps, reqs, scheduler, kv_bits):
    admit, decode, prefill = steps

    def init(b):
        return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                              kv_bits=kv_bits)

    return serve(prefill, admit, decode, init, params, reqs,
                 scheduler=scheduler, batch_slots=BATCH_SLOTS,
                 max_len=MAX_LEN)


def bench():
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    rows = []
    for kv_bits in (16, 8):
        # donate the cache operand exactly as launch/serve.py does, so the
        # bench measures the in-place-update configuration production runs
        steps = (jax.jit(make_admit_step(cfg), donate_argnums=(4,)),
                 jax.jit(make_decode_step(cfg), donate_argnums=(3,)),
                 jax.jit(make_prefill_step(cfg)))
        # warm-up: compile admit/prefill/decode outside the timed runs, at
        # the SAME shapes the timed runs use (a full group of batch_slots);
        # fresh Request objects per run — serving mutates done/tokens_out
        def warm():
            return [Request(rid=0, prompt=np.ones(PROMPT_LEN, np.int32),
                            max_new_tokens=2)
                    for _ in range(BATCH_SLOTS)]
        _serve(cfg, params, steps, warm(), "continuous", kv_bits)
        _serve(cfg, params, steps, warm(), "static", kv_bits)

        outs = {}
        for scheduler in ("static", "continuous"):
            stats = None
            for _ in range(REPEATS):
                reqs = _requests(cfg)
                s = _serve(cfg, params, steps, reqs, scheduler, kv_bits)
                if stats is None or s.tokens_per_s > stats.tokens_per_s:
                    stats = s
            outs[scheduler] = [r.tokens_out for r in reqs]
            rows.append({
                "name": f"serve_{scheduler}_kv{kv_bits}",
                "scheduler": scheduler,
                "kv_bits": kv_bits,
                "batch_slots": BATCH_SLOTS,
                "requests": N_REQUESTS,
                "quotas": [SHORT_QUOTA, LONG_QUOTA],
                "tokens": stats.tokens_generated,
                "prefill_calls": stats.prefill_calls,
                "decode_steps": stats.decode_steps,
                "wall_s": round(stats.wall_s, 3),
                "tokens_per_s": round(stats.tokens_per_s, 1),
                "slot_utilization": round(stats.slot_utilization, 3),
                "peak_cache_bytes": stats.cache_bytes,
            })
        assert outs["static"] == outs["continuous"], \
            "scheduler parity violated under benchmark workload"
        stat, cont = rows[-2], rows[-1]
        cont["speedup_vs_static"] = round(
            cont["tokens_per_s"] / max(stat["tokens_per_s"], 1e-9), 2)
    return rows


def report(rows) -> str:
    hdr = ("name,kv_bits,tokens,decode_steps,wall_s,tokens_per_s,"
           "slot_utilization,speedup_vs_static")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r['name']},{r['kv_bits']},{r['tokens']},{r['decode_steps']},"
            f"{r['wall_s']},{r['tokens_per_s']},{r['slot_utilization']},"
            f"{r.get('speedup_vs_static', '')}")
    return "\n".join(lines)


def write_json(rows, path=JSON_PATH):
    with open(path, "w") as f:
        json.dump({"workload": {
            "batch_slots": BATCH_SLOTS, "requests": N_REQUESTS,
            "prompt_len": PROMPT_LEN,
            "max_new_tokens": [SHORT_QUOTA, LONG_QUOTA],
            "arch": "gemma2-2b-reduced"}, "rows": rows}, f, indent=1)
        f.write("\n")
    return path


if __name__ == "__main__":
    rows = bench()
    print(report(rows))
    print(f"# wrote {write_json(rows)}")
