"""Paper Table 5: per-embedding-group activation quantization vs number of
groups K, with/without the range-based permutation.

Our bench BERT has d=64 (vs paper's 768); K values scale accordingly:
paper {768, 6, 3} -> ours {64 (= per-embedding), 4, 2}."""
from __future__ import annotations

from benchmarks.common import (BENCH_CFG, cached_table, eval_task,
                               quantize_and_eval, train_task)
from repro.core import peg_policy, w8a8_policy
from repro.data.synthetic import GLUE_SUITE

TASKS = [t for t in GLUE_SUITE if t.name in
         ("syn-sst2", "syn-mnli", "syn-qnli", "syn-qqp")]

D = BENCH_CFG["d_model"]

CONFIGS = {
    "K=1 (= per-tensor)": None,                    # plain W8A8
    f"K={D} (= per-embd, FFN only)": dict(num_groups=D,
                                          use_permutation=False),
    "K=4 (FFN only)": dict(num_groups=4, use_permutation=False),
    "K=2 (FFN only)": dict(num_groups=2, use_permutation=False),
    "K=2 + P (FFN only)": dict(num_groups=2, use_permutation=True),
    "K=4 + P (FFN only)": dict(num_groups=4, use_permutation=True),
}


def compute():
    rows = {"FP32": {}}
    for task in TASKS:
        params = train_task(task)
        rows["FP32"][task.name] = eval_task(task, params)
        for label, kw in CONFIGS.items():
            pol = w8a8_policy() if kw is None else peg_policy(**kw)
            rows.setdefault(label, {})[task.name] = \
                quantize_and_eval(task, params, pol)
    return rows


def run():
    return cached_table("table5_peg", compute)


def report(rows):
    tasks = [t.name for t in TASKS]
    lines = ["num_groups," + ",".join(tasks)]
    for label, scores in rows.items():
        lines.append(f"\"{label}\"," +
                     ",".join(f"{scores[t]:.2f}" for t in tasks))
    return "\n".join(lines)


if __name__ == "__main__":
    print(report(run()))
