"""Unified decoder-only transformer covering the assigned LM families:
dense (GQA/MQA, sliding-window, local+global alternating, soft-capping),
MoE, hybrid RG-LRU (Griffin), and attention-free RWKV6 — with the paper's
quantization sites threaded throughout.

Two execution layouts share the same block functions:
  * stacked + lax.scan over "super-blocks" (one repeat of cfg.block_pattern)
    — the production path; compiles O(1) HLO in depth.
  * unrolled Python loop — for smoke tests, calibration and per-layer
    quantization experiments (sites get per-layer names ``layer{i}/...``).

Quantization sites per block (paper Fig. 1 / Table 2 naming):
  {L}/residual_attn     — residual sum after self-attention
  {L}/ffn_in            — FFN input (LN output)
  {L}/ffn_out           — FFN output (before residual add)
  {L}/residual_ffn      — THE paper bottleneck: residual sum after FFN
plus the attention-internal sites from attention.py and:
  embed/sum, head/logits
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ffn as ffn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.attention import (AttnConfig, KVCache, PagedKVCache,
                                    PagedQuant4KVCache, PagedQuantKVCache,
                                    Quant4KVCache, QuantKVCache,
                                    attention_block, init_attention_params,
                                    init_kv_cache, init_paged_kv_cache,
                                    init_paged_quant4_kv_cache,
                                    init_paged_quant_kv_cache,
                                    init_quant4_kv_cache,
                                    init_quant_kv_cache, reset_kv_lanes,
                                    reset_paged_lanes)
from repro.models.common import (cross_entropy, embed_init, layer_norm,
                                 rms_norm, softcap, split_keys)


# ---------------------------------------------------------------------------
# Distribution context (kept minimal; rules live in repro/parallel)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Any
    tp_axis: str = "model"
    fsdp_axis: Any = "data"                  # str or tuple (pod FSDP)
    dp_axes: Tuple[str, ...] = ("data",)     # ("pod","data") multi-pod
    onehot_embed: bool = False               # perf: vocab-sharded einsum
    quantized_gathers: bool = False          # perf: int8 FSDP weight gathers

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def _norm(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["g"], p["b"])
    return rms_norm(x, p["g"])


def _init_norm(cfg: ModelConfig, dtype):
    if cfg.norm == "layernorm":
        return {"g": jnp.ones((cfg.d_model,), dtype),
                "b": jnp.zeros((cfg.d_model,), dtype)}
    return {"g": jnp.zeros((cfg.d_model,), dtype)}   # rms: 1 + g


# ---------------------------------------------------------------------------
# Attention config per block kind
# ---------------------------------------------------------------------------

def attn_cfg_for(cfg: ModelConfig, kind: str) -> AttnConfig:
    window = cfg.window
    if kind == "local_attn":
        window = cfg.local_window
    return AttnConfig(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                      head_dim=cfg.hd, causal=True, window=window,
                      logit_softcap=cfg.attn_logit_softcap,
                      rope_theta=cfg.rope_theta)


# ---------------------------------------------------------------------------
# FFN dispatch (dense / GLU / MoE, optionally expert-parallel)
# ---------------------------------------------------------------------------

def _ffn_apply(cfg: ModelConfig, p, x, *, ctx, prefix, dist: Optional[DistContext]):
    if cfg.moe is not None:
        B, T, D = x.shape
        if dist is not None and dist.tp_size > 1:
            return _moe_sharded(cfg, p, x, dist)
        out = moe_lib.moe_apply(p, x.reshape(B * T, D), cfg.moe, ctx=ctx,
                                prefix=prefix)
        return out.reshape(B, T, D)
    if cfg.ffn_type == "glu":
        return ffn_lib.glu_mlp(p, x, activation=cfg.act, ctx=ctx, prefix=prefix)
    return ffn_lib.mlp(p, x, activation=cfg.act, ctx=ctx, prefix=prefix)


def _moe_sharded(cfg: ModelConfig, p, x, dist: DistContext):
    """Expert-parallel MoE via shard_map (DESIGN.md §4): FLATTENED tokens
    data-sharded, experts model-sharded, FSDP re-gather of expert weights
    inside. Token count not divisible by the dp group -> tokens replicate
    (each shard computes its experts over all tokens)."""
    from jax.sharding import PartitionSpec as P
    import numpy as np
    mesh = dist.mesh
    tp, fsdp, dp = dist.tp_axis, dist.fsdp_axis, dist.dp_axes
    ep_size = mesh.shape[tp]
    mcfg = cfg.moe
    B, T, D = x.shape
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    tok_spec = P(dp, None) if (B * T) % dp_size == 0 else P(None, None)

    # E >= tp: expert parallelism (E/tp experts per shard). E < tp (grok-1:
    # 8 experts, 16 shards): hybrid — every shard holds ALL experts with a
    # d_ff slice (TP inside experts); the end psum reduces partial-F sums.
    expert_parallel = mcfg.num_experts % ep_size == 0

    def _gather(w, axis):
        if not dist.quantized_gathers:
            return jax.lax.all_gather(w, fsdp, axis=axis, tiled=True)
        # perf variant: int8 wire format for the per-layer FSDP weight
        # gathers (the paper's symmetric per-tensor weight quantization
        # applied to the collective payload) — 2x fewer ICI/DCN bytes.
        amax = jnp.max(jnp.abs(w))
        s_w = jnp.maximum(amax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / s_w),
                     -127, 127).astype(jnp.int8)
        q_full = jax.lax.all_gather(q, fsdp, axis=axis, tiled=True)
        s_full = jax.lax.all_gather(s_w[None], fsdp, axis=0)
        # every shard contributed its own scale; payload dequantizes with
        # the max (scales are near-identical for homogeneous shards; exact
        # per-shard dequant would segment the axis — done on real HW)
        return q_full.astype(w.dtype) * jnp.max(s_full).astype(w.dtype)

    def body(router, wg, wu, wo, xt):
        router = _gather(router, 0)
        wg = _gather(wg, 1)
        wu = _gather(wu, 1)
        wo = _gather(wo, 2)
        return moe_lib.moe_apply_sharded(
            {"router": router, "w_gate": wg, "w_up": wu, "w_out": wo},
            xt, mcfg, ep_axis=tp, ep_size=ep_size,
            expert_parallel=expert_parallel)

    if expert_parallel:
        w_specs = (P(tp, fsdp, None), P(tp, fsdp, None), P(tp, None, fsdp))
    else:
        w_specs = (P(None, fsdp, tp), P(None, fsdp, tp), P(None, tp, fsdp))

    from repro.core.jax_compat import shard_map
    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(fsdp, None),) + w_specs + (tok_spec,),
        out_specs=tok_spec,
        check_vma=False,
    )(p["router"], p["w_gate"], p["w_up"], p["w_out"], x.reshape(B * T, D))
    return out.reshape(B, T, D)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _ffn_packed(p) -> bool:
    from repro.core import deploy
    ffn = p.get("ffn")
    return isinstance(ffn, dict) and deploy.is_packed(
        ffn.get("w_in", ffn.get("w_gate")))


def _attn_packed(p) -> bool:
    from repro.core import deploy
    attn = p.get("attn")
    return isinstance(attn, dict) and deploy.is_packed(attn.get("wq"))


def _ffn_input(cfg: ModelConfig, p, x, ctx, prefix):
    """LN2 + the ffn_in quantizer. In DEPLOY mode with packed FFN weights the
    two fuse into one norm+int8-emit kernel pass returning a QTensor."""
    if ctx is not None:
        aq = ctx.deploy_act(f"{prefix}/ffn_in")
        if aq is not None and _ffn_packed(p):
            from repro.core import deploy
            if ctx.telemetry is not None:
                ctx.telem_site(f"{prefix}/ffn_in",
                               deploy.site_stats(_norm(cfg, p["ln2"], x), aq))
            return deploy.norm_quantize(cfg.norm, p["ln2"], x, aq)
    h = _norm(cfg, p["ln2"], x)
    if ctx is not None:
        h = ctx.act(f"{prefix}/ffn_in", h)
    return h


def _attn_input(cfg: ModelConfig, p, x, ctx, prefix):
    """LN1 + the attn_in input quantizer (fused in DEPLOY, see _ffn_input)."""
    if ctx is not None:
        aq = ctx.deploy_act(f"{prefix}/attn_in")
        if aq is not None and _attn_packed(p):
            from repro.core import deploy
            if ctx.telemetry is not None:
                ctx.telem_site(f"{prefix}/attn_in",
                               deploy.site_stats(_norm(cfg, p["ln1"], x), aq))
            return deploy.norm_quantize(cfg.norm, p["ln1"], x, aq)
    h = _norm(cfg, p["ln1"], x)
    if ctx is not None:
        h = ctx.act_in(f"{prefix}/attn_in", h)
    return h


def block_apply(cfg: ModelConfig, kind: str, p, x, positions, *, ctx=None,
                prefix="layer", cache=None, dist=None, chunked=None,
                block_table=None, append=False):
    """One transformer block of the given kind. Returns (x, new_cache)."""
    if append and kind not in ("attn", "local_attn"):
        raise ValueError(
            f"chunked (append) prefill supports attention blocks only, got "
            f"{kind!r} (recurrent state cannot replay earlier chunks)")
    if kind in ("attn", "local_attn"):
        acfg = attn_cfg_for(cfg, kind)
        h = _attn_input(cfg, p, x, ctx, prefix)
        attn_out, new_cache = attention_block(
            p["attn"], h, positions, acfg, ctx=ctx, prefix=f"{prefix}/attn",
            cache=cache, chunked=chunked, block_table=block_table,
            append=append)
        if cfg.post_norm:
            attn_out = _norm(cfg, p["post_ln1"], attn_out)
        x = x + attn_out
        if ctx is not None:
            x = ctx.act(f"{prefix}/residual_attn", x)
        h = _ffn_input(cfg, p, x, ctx, prefix)
        ffn_out = _ffn_apply(cfg, p.get("moe", p.get("ffn")), h, ctx=ctx,
                             prefix=f"{prefix}/ffn", dist=dist)
        if cfg.post_norm:
            ffn_out = _norm(cfg, p["post_ln2"], ffn_out)
        if ctx is not None:
            ffn_out = ctx.act(f"{prefix}/ffn_out", ffn_out)
        x = x + ffn_out
        if ctx is not None:
            x = ctx.act(f"{prefix}/residual_ffn", x)
        return x, new_cache

    if kind == "rec":
        h = _norm(cfg, p["ln1"], x)
        rec_out, new_state = rglru_lib.recurrent_block(
            p["rec"], h, state=cache, ctx=ctx, prefix=f"{prefix}/rec")
        x = x + rec_out
        if ctx is not None:
            x = ctx.act(f"{prefix}/residual_attn", x)
        h = _ffn_input(cfg, p, x, ctx, prefix)
        ffn_out = _ffn_apply(cfg, p["ffn"], h, ctx=ctx, prefix=f"{prefix}/ffn",
                             dist=dist)
        if ctx is not None:
            ffn_out = ctx.act(f"{prefix}/ffn_out", ffn_out)
        x = x + ffn_out
        if ctx is not None:
            x = ctx.act(f"{prefix}/residual_ffn", x)
        return x, new_state

    if kind == "rwkv":
        h = _norm(cfg, p["ln1"], x)
        tm_out, st = rwkv_lib.time_mix(p["tmix"], h, cfg.rwkv_head_size,
                                       state=cache, ctx=ctx,
                                       prefix=f"{prefix}/tmix")
        x = x + tm_out
        if ctx is not None:
            x = ctx.act(f"{prefix}/residual_attn", x)
        h = _norm(cfg, p["ln2"], x)
        cm_out, st = rwkv_lib.channel_mix(p["cmix"], h, state=st, ctx=ctx,
                                          prefix=f"{prefix}/cmix")
        x = x + cm_out
        if ctx is not None:
            x = ctx.act(f"{prefix}/residual_ffn", x)
        return x, st

    raise ValueError(f"unknown block kind {kind!r}")


def init_block_params(cfg: ModelConfig, kind: str, key, dtype):
    ks = split_keys(key, 4)
    p: Dict[str, Any] = {"ln1": _init_norm(cfg, dtype),
                         "ln2": _init_norm(cfg, dtype)}
    if kind in ("attn", "local_attn"):
        p["attn"] = init_attention_params(ks[0], cfg.d_model,
                                          attn_cfg_for(cfg, kind), dtype,
                                          qk_norm=cfg.qk_norm)
        if cfg.moe is not None:
            p["moe"] = moe_lib.init_moe_params(ks[1], cfg.d_model, cfg.moe,
                                               dtype)
        elif cfg.ffn_type == "glu":
            p["ffn"] = ffn_lib.init_glu_params(ks[1], cfg.d_model, cfg.d_ff,
                                               dtype)
        else:
            p["ffn"] = ffn_lib.init_mlp_params(ks[1], cfg.d_model, cfg.d_ff,
                                               dtype)
        if cfg.post_norm:
            p["post_ln1"] = _init_norm(cfg, dtype)
            p["post_ln2"] = _init_norm(cfg, dtype)
    elif kind == "rec":
        p["rec"] = rglru_lib.init_recurrent_params(
            ks[0], cfg.d_model, cfg.d_rnn or cfg.d_model, dtype)
        p["ffn"] = (ffn_lib.init_glu_params(ks[1], cfg.d_model, cfg.d_ff, dtype)
                    if cfg.ffn_type == "glu" else
                    ffn_lib.init_mlp_params(ks[1], cfg.d_model, cfg.d_ff, dtype))
    elif kind == "rwkv":
        tm = rwkv_lib.init_rwkv_params(ks[0], cfg.d_model, cfg.d_ff,
                                       cfg.rwkv_head_size, dtype)
        p["tmix"] = {k: v for k, v in tm.items()
                     if not k.startswith(("w_c", "mu_c"))}
        p["cmix"] = {k: v for k, v in tm.items()
                     if k.startswith(("w_c", "mu_c"))}
    else:
        raise ValueError(kind)
    return p


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16, kv_bits: int = 16,
                     paged_blocks: Optional[Tuple[int, int]] = None):
    if kind in ("attn", "local_attn"):
        acfg = attn_cfg_for(cfg, kind)
        if paged_blocks is not None:
            num_blocks, block_size = paged_blocks
            if kv_bits == 4:
                return init_paged_quant4_kv_cache(num_blocks, block_size,
                                                  acfg)
            if kv_bits == 8:
                return init_paged_quant_kv_cache(num_blocks, block_size,
                                                 acfg)
            return init_paged_kv_cache(num_blocks, block_size, acfg, dtype)
        if kv_bits == 4:
            return init_quant4_kv_cache(batch, max_len, acfg)
        if kv_bits == 8:
            return init_quant_kv_cache(batch, max_len, acfg)
        return init_kv_cache(batch, max_len, acfg, dtype)
    if paged_blocks is not None:
        raise ValueError(
            f"paged KV cache supports attention layers only, got {kind!r} "
            "(recurrent state has no block layout)")
    if kind == "rec":
        return rglru_lib.init_rglru_state(batch, cfg.d_rnn or cfg.d_model)
    if kind == "rwkv":
        return rwkv_lib.init_rwkv_state(batch, cfg.d_model, cfg.rwkv_head_size)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key, *, stacked: bool = True,
                dtype=jnp.bfloat16):
    """stacked=True: per-pattern-position params stacked over repeats (scan
    layout). stacked=False: params["layers"] is a flat per-layer list."""
    plan = cfg.layer_plan
    n_pat = len(cfg.block_pattern)
    n_tail = len(cfg.tail_pattern)
    n_super = (len(plan) - n_tail) // n_pat
    keys = split_keys(key, len(plan) + 3)

    params: Dict[str, Any] = {
        "embed": embed_init(keys[-1], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": _init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[-2], cfg.vocab_size, cfg.d_model,
                                       dtype).T

    if stacked:
        scan_groups = []
        for j, kind in enumerate(cfg.block_pattern):
            per = [init_block_params(cfg, kind, keys[s * n_pat + j], dtype)
                   for s in range(n_super)]
            scan_groups.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
        params["scan"] = scan_groups
        params["tail"] = [init_block_params(cfg, kind,
                                            keys[n_super * n_pat + i], dtype)
                          for i, kind in enumerate(cfg.tail_pattern)]
    else:
        params["layers"] = [init_block_params(cfg, kind, keys[i], dtype)
                            for i, kind in enumerate(plan)]
    return params


def attn_write_spans(cfg: ModelConfig, max_len: int) -> List[int]:
    """Per-attention-layer token write SPANS: how many distinct cache
    cells the layer can ever occupy per lane — ``min(max_len, window)``
    for ring (sliding-window) layers, ``max_len`` for global ones."""
    spans = []
    for kind in cfg.layer_plan:
        if kind not in ("attn", "local_attn"):
            continue
        w = attn_cfg_for(cfg, kind).window
        spans.append(min(max_len, w) if w else max_len)
    return spans


def paged_lane_blocks(cfg: ModelConfig, max_len: int,
                      block_size: int) -> int:
    """Per-lane worst-case block-table width for a paged cache of this
    arch: ``ceil(max(write spans) / block_size)``. For an all-window
    model this is ``ceil(S_w / block_size)`` — window layers stop
    inflating the table, the default pool size, and reservations. Mixed
    local/global models keep the global layers' ``ceil(max_len /
    block_size)`` (one shared table must cover every layer's span)."""
    spans = attn_write_spans(cfg, max_len)
    if not spans:
        raise ValueError(f"{cfg.name}: no attention layers to page")
    return -(-max(spans) // block_size)


def attn_write_caps(cfg: ModelConfig, max_len: int,
                    block_size: int) -> List[int]:
    """Distinct per-layer paged write capacities in TOKENS — exactly the
    ``s_cap`` each layer's write path wraps at
    (``min(table_width * block_size, window)``, see
    attention.paged_capacity). The scheduler uses these as its
    copy-on-write barrier: a write at position ``p`` lands in table
    column ``(p % cap) // block_size`` for some cap in this list, and any
    such column inside a lane's shared prefix must be COWed first. The
    MINIMUM cap is also the donation rule (a lane that ever wrote at or
    past it has wrapped a ring layer, so its prompt blocks are not
    generation-0 and must not be donated), and the MAXIMUM cap is the
    ring clamp for reservations (an all-window lane never needs more than
    ``ceil(max_cap / block_size)`` blocks however long it decodes)."""
    width = paged_lane_blocks(cfg, max_len, block_size)
    caps = set()
    for kind in cfg.layer_plan:
        if kind not in ("attn", "local_attn"):
            continue
        w = attn_cfg_for(cfg, kind).window
        caps.add(min(width * block_size, w) if w else width * block_size)
    return sorted(caps)


def paged_ring_tokens(cfg: ModelConfig, max_len: int,
                      block_size: int) -> Optional[int]:
    """Ring clamp for per-lane reservations: when EVERY attention layer
    is a sliding-window ring smaller than ``max_len``, a lane's paged
    writes all wrap in place past ``max(window)`` tokens, so reservations
    and growth never need more than ``ceil(max(window) / block_size)``
    blocks however long the request decodes. Returns None for models with
    any global (or window >= max_len) layer — there a long request
    genuinely needs ``max_len`` cells and clamping would silently drop
    context."""
    windows = []
    for kind in cfg.layer_plan:
        if kind not in ("attn", "local_attn"):
            continue
        w = attn_cfg_for(cfg, kind).window
        if not w or w >= max_len:
            return None
        windows.append(w)
    return max(windows) if windows else None


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               stacked: bool = True, dtype=jnp.bfloat16, kv_bits: int = 16,
               paged: bool = False, block_size: int = 16,
               num_blocks: Optional[int] = None,
               mapped: Optional[bool] = None):
    """kv_bits=8 stores attention caches as int8 QuantKVCache (deployment
    serving path); kv_bits=4 as nibble-packed Quant4KVCache (two int4 cells
    per byte — half the cache HBM of int8); 16 keeps the bf16/f32 KVCache.

    ``paged=True`` switches every attention layer to the block-paged
    layout: one shared arena of ``num_blocks`` blocks of ``block_size``
    token cells per layer (default: the worst case,
    ``batch * paged_lane_blocks(...)`` — ``ceil(max_len / block_size)``
    per lane unless EVERY attention layer is sliding-window, in which
    case the ring bound ``ceil(min(max_len, S_w) / block_size)`` sizes
    the table and the default pool instead) plus a single
    ``"block_table"`` (batch, max_blocks_per_lane) entry in the returned
    pytree. ``mapped`` (default: True iff ``num_blocks`` was left at the
    worst case) pre-maps the identity table — lane i owns blocks
    [i*nb, (i+1)*nb) — which makes the paged cache a drop-in dense
    equivalent (the static scheduler path); pool-managed serving starts
    unmapped and lets runtime.block_pool.BlockPool own the table.
    """
    plan = cfg.layer_plan
    n_pat = len(cfg.block_pattern)
    n_tail = len(cfg.tail_pattern)
    n_super = (len(plan) - n_tail) // n_pat
    paged_blocks = None
    table = None
    if paged:
        nb_lane = paged_lane_blocks(cfg, max_len, block_size)
        if mapped is None:
            mapped = num_blocks is None
        if num_blocks is None:
            num_blocks = batch * nb_lane
        paged_blocks = (num_blocks, block_size)
        if mapped:
            if num_blocks < batch * nb_lane:
                raise ValueError(
                    f"mapped paged cache needs num_blocks >= "
                    f"batch*{nb_lane} = {batch * nb_lane}, got {num_blocks}")
            table = jnp.arange(batch * nb_lane,
                               dtype=jnp.int32).reshape(batch, nb_lane)
        else:
            table = jnp.full((batch, nb_lane), -1, jnp.int32)

    def blk(kind):
        return init_block_cache(cfg, kind, batch, max_len, dtype, kv_bits,
                                paged_blocks)

    if stacked:
        groups = []
        for kind in cfg.block_pattern:
            per = [blk(kind) for _ in range(n_super)]
            groups.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
        tail = [blk(kind) for kind in cfg.tail_pattern]
        cache = {"scan": groups, "tail": tail}
    else:
        cache = {"layers": [blk(kind) for kind in plan]}
    if paged:
        cache["block_table"] = table
    return cache


def paged_block_bytes(cache) -> int:
    """HBM bytes per physical block, summed over every paged arena in the
    cache pytree (stacked leaves count all their layers) — multiply by the
    pool's blocks_in_use for the live paged footprint."""
    total = 0
    for node in _cache_nodes(cache):
        if isinstance(node, (PagedKVCache, PagedQuantKVCache)):
            n = node.pos.shape[-2]
            total += sum(leaf.size * leaf.dtype.itemsize for leaf in node) // n
    return total


def _cache_nodes(cache):
    return (cache.get("layers") or
            list(cache.get("scan", [])) + list(cache.get("tail", [])))


def cache_reset_slots(cache, lane_mask):
    """Empty the masked batch lanes of a whole-model cache pytree for slot
    reuse (continuous batching): every attention cache's ``pos`` becomes -1
    on those lanes, so the next occupant starts from an empty lane while the
    other lanes are untouched. Works for both cache layouts (stacked scan
    leaves carry batch on axis 1) and every cache type (KVCache /
    QuantKVCache — the int8 per-head per-slot scale layout is preserved;
    stale payload bytes are unreadable once pos == -1 — and the paged
    variants, where the masked lanes' *mapped blocks* are emptied through
    the cache's block table).

    Recurrent state (rglru / rwkv6) has no per-slot validity sentinel, so
    those caches are not supported by the continuous scheduler.
    """
    lane_mask = jnp.asarray(lane_mask, bool)
    table = cache.get("block_table")

    def _reset(c, axis):
        if isinstance(c, (PagedKVCache, PagedQuantKVCache)):
            return reset_paged_lanes(c, lane_mask, table)
        if isinstance(c, (KVCache, QuantKVCache)):
            return reset_kv_lanes(c, lane_mask, batch_axis=axis)
        raise ValueError(
            "cache_reset_slots: continuous batching supports attention "
            f"caches only, got {type(c).__name__} (recurrent state has no "
            "per-slot validity to reset)")

    if "layers" in cache:
        out = {"layers": [_reset(c, 0) for c in cache["layers"]]}
    else:
        out = {"scan": [_reset(c, 1) for c in cache["scan"]],
               "tail": [_reset(c, 0) for c in cache["tail"]]}
    if table is not None:
        out["block_table"] = table
    return out


def cache_copy_block(cache, src, dst):
    """Copy physical block ``src``'s payload (K/V, scales, positions) into
    block ``dst`` across EVERY paged arena of a whole-model cache pytree —
    the device half of the scheduler's copy-on-write: the pool swaps a
    shared table entry for a fresh private block, this clones the shared
    payload so the lane's subsequent writes land in its own copy.

    ``src`` / ``dst`` are traced int32 scalars (block ids are data, so one
    jitted trace serves every COW). Stacked scan leaves carry the block
    axis at position 1 (after n_super), tail/flat leaves at position 0.
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def _copy(c, axis):
        if not isinstance(c, (PagedKVCache, PagedQuantKVCache)):
            raise ValueError(
                "cache_copy_block: paged caches only, got "
                f"{type(c).__name__}")
        if axis == 1:
            return jax.tree.map(
                lambda x: jax.lax.dynamic_update_index_in_dim(
                    x, jax.lax.dynamic_index_in_dim(x, src, axis=1,
                                                    keepdims=False),
                    dst, axis=1), c)
        return jax.tree.map(
            lambda x: jax.lax.dynamic_update_index_in_dim(
                x, jax.lax.dynamic_index_in_dim(x, src, axis=0,
                                                keepdims=False),
                dst, axis=0), c)

    if "layers" in cache:
        out = {"layers": [_copy(c, 0) for c in cache["layers"]]}
    else:
        out = {"scan": [_copy(c, 1) for c in cache["scan"]],
               "tail": [_copy(c, 0) for c in cache["tail"]]}
    if "block_table" in cache:
        out["block_table"] = cache["block_table"]
    return out


def cache_gather_blocks(cache, ids):
    """Gather the payload rows of physical blocks ``ids`` from every paged
    arena of a whole-model cache pytree — the device half of the
    scheduler's swap-out: the result (block axis shrunk to ``len(ids)``,
    ``block_table`` omitted) is device_get into a host spill buffer while
    the pool frees the blocks for other lanes.

    ``ids`` is a traced int32 vector of FIXED length (max_blocks_per_lane;
    one jitted trace serves every preemption): live block ids first,
    padded with ``num_blocks`` — an out-of-range POSITIVE id. The gather
    clips it to the last block (garbage rows in the padded tail), and the
    matching scatter in :func:`cache_scatter_blocks` DROPS those writes,
    so the padding round-trips harmlessly. Stacked scan leaves carry the
    block axis at position 1 (after n_super), tail/flat leaves at 0.
    """
    ids = jnp.asarray(ids, jnp.int32)

    def _gather(c, axis):
        if not isinstance(c, (PagedKVCache, PagedQuantKVCache)):
            raise ValueError(
                "cache_gather_blocks: paged caches only, got "
                f"{type(c).__name__}")
        return jax.tree.map(
            lambda x: jnp.take(x, ids, axis=axis, mode="clip"), c)

    if "layers" in cache:
        return {"layers": [_gather(c, 0) for c in cache["layers"]]}
    return {"scan": [_gather(c, 1) for c in cache["scan"]],
            "tail": [_gather(c, 0) for c in cache["tail"]]}


def cache_scatter_blocks(cache, ids, payload):
    """Scatter a :func:`cache_gather_blocks` ``payload`` back into physical
    blocks ``ids`` across every paged arena — the device half of the
    scheduler's swap-in on resume. ``ids`` are the lane's NEWLY allocated
    block ids (same fixed length and live-prefix layout as the gather;
    the ``num_blocks`` padding is out of range, so those rows are
    scatter-dropped). The re-uploaded payload is bit-identical to what
    the preempted lane held, so resume emits the same greedy tokens."""
    ids = jnp.asarray(ids, jnp.int32)

    def _scatter(c, p, axis):
        if not isinstance(c, (PagedKVCache, PagedQuantKVCache)):
            raise ValueError(
                "cache_scatter_blocks: paged caches only, got "
                f"{type(c).__name__}")
        if axis == 1:
            return jax.tree.map(
                lambda x, v: x.at[:, ids].set(v, mode="drop"), c, p)
        return jax.tree.map(
            lambda x, v: x.at[ids].set(v, mode="drop"), c, p)

    if "layers" in cache:
        out = {"layers": [_scatter(c, p, 0) for c, p in
                          zip(cache["layers"], payload["layers"])]}
    else:
        out = {"scan": [_scatter(c, p, 1) for c, p in
                        zip(cache["scan"], payload["scan"])],
               "tail": [_scatter(c, p, 0) for c, p in
                        zip(cache["tail"], payload["tail"])]}
    if "block_table" in cache:
        out["block_table"] = cache["block_table"]
    return out


def cache_extract_lane(cache, lane):
    """Slice one batch lane out of a DENSE whole-model cache pytree — the
    device half of the engine's decomposed ``prefill``: a request is
    prefilled into a scratch cache and its lane (batch axis kept, size 1)
    becomes the transferable ``lane_payload`` that :func:`cache_insert_lane`
    lands in any decode slot. ``lane`` is a traced int32 scalar, so one
    jitted trace serves every prefill. Paged caches have no per-lane
    batch axis — extract their lane payloads with
    :func:`cache_gather_blocks` over the lane's mapped block ids instead."""
    lane = jnp.asarray(lane, jnp.int32)

    def _extract(c, axis):
        if not isinstance(c, (KVCache, QuantKVCache)):
            raise ValueError(
                "cache_extract_lane: dense attention caches only, got "
                f"{type(c).__name__}")
        return jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, lane, 1, axis=axis), c)

    if "block_table" in cache:
        raise ValueError("cache_extract_lane: paged caches carry no batch "
                         "axis — use cache_gather_blocks on the lane's "
                         "mapped block ids")
    if "layers" in cache:
        return {"layers": [_extract(c, 0) for c in cache["layers"]]}
    return {"scan": [_extract(c, 1) for c in cache["scan"]],
            "tail": [_extract(c, 0) for c in cache["tail"]]}


def cache_insert_lane(cache, lane, payload):
    """Write a :func:`cache_extract_lane` payload into batch lane ``lane``
    of a DENSE whole-model cache pytree — the device half of the engine's
    ``insert``. The payload covers the lane's every cell (prompt KV plus
    the -1 dead-cell padding), so the write is a full lane overwrite: the
    slot's previous occupant needs no separate reset, and every other
    lane's bytes pass through bit-identical (the lane bit-isolation
    contract the engine conformance suite asserts)."""
    lane = jnp.asarray(lane, jnp.int32)

    def _insert(c, p, axis):
        if not isinstance(c, (KVCache, QuantKVCache)):
            raise ValueError(
                "cache_insert_lane: dense attention caches only, got "
                f"{type(c).__name__}")
        return jax.tree.map(
            lambda x, v: jax.lax.dynamic_update_slice_in_dim(
                x, v, lane, axis=axis), c, p)

    if "block_table" in cache:
        raise ValueError("cache_insert_lane: paged caches carry no batch "
                         "axis — use cache_scatter_blocks on the lane's "
                         "mapped block ids")
    if "layers" in cache:
        return {"layers": [_insert(c, p, 0) for c, p in
                           zip(cache["layers"], payload["layers"])]}
    return {"scan": [_insert(c, p, 1) for c, p in
                     zip(cache["scan"], payload["scan"])],
            "tail": [_insert(c, p, 0) for c, p in
                     zip(cache["tail"], payload["tail"])]}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _constrain(x, dist: Optional[DistContext], spec):
    """Divisibility-aware sharding constraint: any dim that does not divide
    its assigned axis group is replicated instead."""
    if dist is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    fixed = []
    for dim, axis in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if axis is None:
            fixed.append(None)
            continue
        names = axis if isinstance(axis, tuple) else (axis,)
        size = 1
        for a in names:
            size *= dist.mesh.shape[a]
        fixed.append(axis if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(dist.mesh, PartitionSpec(*fixed)))


def _embed(cfg: ModelConfig, params, tokens, embeds, ctx, dist=None):
    from repro.models.common import resolve_weight
    table = resolve_weight(params["embed"])
    if dist is not None and dist.onehot_embed and tokens.size <= 4096:
        # decode-path perf variant: a one-hot einsum keeps the vocab axis
        # SHARDED through the lookup (partial rows + one tiny psum over tp)
        # instead of all-gathering the whole embedding table per step.
        oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=table.dtype)
        x = jnp.einsum("btv,vd->btd", oh, table)
    else:
        x = jnp.take(table, tokens, axis=0)
    if dist is not None:
        # keep the gathered activations batch-sharded (avoids the SPMD
        # "involuntary full rematerialization" reshard on the vocab gather)
        from jax.sharding import PartitionSpec as P
        x = _constrain(x, dist, P(dist.dp_axes, None, None))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if embeds is not None:
        # modality frontend stub: precomputed patch/frame embeddings are
        # prepended to the token embeddings (assignment: frontend is a stub).
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    if ctx is not None:
        x = ctx.act("embed/sum", x)
    return x


def _head(cfg: ModelConfig, params, x, ctx, dist=None):
    from repro.models.common import resolve_weight
    h = _norm(cfg, params["final_norm"], x)
    w = resolve_weight(params["embed"]).T if cfg.tie_embeddings \
        else resolve_weight(params["lm_head"])
    if ctx is not None:
        w = ctx.weight("head/w", w)
    logits = h @ w.astype(h.dtype)
    if dist is not None:
        # logits stay vocab-sharded on the TP axis end-to-end (the CE
        # logsumexp reduces with one small all-reduce instead of gathering
        # the (B, T, V) tensor)
        from jax.sharding import PartitionSpec as P
        logits = _constrain(logits, dist,
                            P(dist.dp_axes, None, dist.tp_axis))
    logits = softcap(logits, cfg.final_logit_softcap)
    if ctx is not None:
        logits = ctx.act("head/logits", logits)
    return logits


def forward(cfg: ModelConfig, params, tokens, *, embeds=None, ctx=None,
            dist: Optional[DistContext] = None, cache=None, positions=None,
            remat: bool = False, chunked=None, append: bool = False):
    """Returns (logits, new_cache). tokens: (B, T) int32.

    positions: (B, T) absolute positions (defaults to arange).
    cache: pytree from init_cache (stacked or unrolled layout must match
    params layout).
    append: chunked-prefill mode — the tokens are one chunk appended at
    each lane's current cache position; attention reads the cache (earlier
    chunks) in addition to the fresh tokens (see models.attention).
    """
    B, T = tokens.shape
    x = _embed(cfg, params, tokens, embeds, ctx, dist=dist)
    T_full = x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T_full, dtype=jnp.int32),
                                     (B, T_full))
    # the paged caches' (B, max_blocks) block table is shared by every
    # layer: thread it alongside the per-layer cache leaves and hand it
    # back unchanged (allocation is host-side, runtime.block_pool)
    block_table = cache.get("block_table") if cache is not None else None

    if "layers" in params:                      # unrolled path
        new_layer_caches = []
        for i, kind in enumerate(cfg.layer_plan):
            c = cache["layers"][i] if cache is not None else None

            def _blk(p, x, c, kind=kind, i=i):
                return block_apply(cfg, kind, p, x, positions, ctx=ctx,
                                   prefix=f"layer{i}", cache=c, dist=dist,
                                   chunked=chunked, block_table=block_table,
                                   append=append)
            if remat:
                _blk = jax.checkpoint(
                    _blk, policy=jax.checkpoint_policies.nothing_saveable)
            x, nc = _blk(params["layers"][i], x, c)
            new_layer_caches.append(nc)
        new_cache = None
        if cache is not None:
            new_cache = {"layers": new_layer_caches}
            if block_table is not None:
                new_cache["block_table"] = block_table
        logits = _head(cfg, params, x, ctx, dist=dist)
        return logits, new_cache

    # stacked scan path
    n_pat = len(cfg.block_pattern)

    def superblock(x, slices):
        p_slices, c_slices = slices
        new_cs = []
        for j, kind in enumerate(cfg.block_pattern):
            c = c_slices[j] if c_slices is not None else None
            x, nc = block_apply(cfg, kind, p_slices[j], x, positions,
                                ctx=ctx, prefix="layer", cache=c, dist=dist,
                                chunked=chunked, block_table=block_table,
                                append=append)
            new_cs.append(nc)
        return x, (new_cs if c_slices is not None else None)

    body = superblock
    if remat:
        body = jax.checkpoint(
            superblock,
            policy=jax.checkpoint_policies.nothing_saveable)

    scan_caches = cache["scan"] if cache is not None else None
    # Quant-health telemetry entries created INSIDE the scan body (prefix
    # "layer") would leak tracers through the ctx dict; pop them in the body
    # and return them as scan ys instead — they come back stacked (L, 4)
    # per site, which is exactly the per-layer resolution we want.
    telem = ctx.telemetry if ctx is not None else None

    def scan_fn(x, xs):
        p_slices = xs[0]
        c_slices = xs[1] if cache is not None else None
        before = set(telem) if telem is not None else None
        x, new_c = body(x, (p_slices, c_slices))
        tel_ys = {}
        if telem is not None:
            tel_ys = {k: telem.pop(k) for k in sorted(set(telem) - before)}
        return x, (new_c, tel_ys)

    # lax.scan needs xs leaves with a leading axis; pack params (+caches).
    if cache is not None:
        x, (new_scan_caches, tel_stacked) = jax.lax.scan(
            lambda carry, xs_: scan_fn(carry, xs_),
            x, (params["scan"], scan_caches))
    else:
        x, (_, tel_stacked) = jax.lax.scan(
            lambda carry, p: scan_fn(carry, (p,)), x, params["scan"])
        new_scan_caches = None
    if telem is not None:
        telem.update(tel_stacked)

    new_tail_caches = []
    for i, kind in enumerate(cfg.tail_pattern):
        c = cache["tail"][i] if cache is not None else None
        p_tail = params["tail"][i]
        x, nc = block_apply(cfg, kind, p_tail, x, positions, ctx=ctx,
                            prefix="tail", cache=c, dist=dist,
                            chunked=chunked, block_table=block_table,
                            append=append)
        new_tail_caches.append(nc)

    new_cache = None
    if cache is not None:
        new_cache = {"scan": new_scan_caches, "tail": new_tail_caches}
        if block_table is not None:
            new_cache["block_table"] = block_table
    logits = _head(cfg, params, x, ctx, dist=dist)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def train_loss(cfg: ModelConfig, params, batch, *, ctx=None, dist=None,
               remat: bool = True, chunked=None):
    """Next-token CE. batch: {tokens (B,T), labels (B,T) [, embeds]}."""
    logits, _ = forward(cfg, params, batch["tokens"],
                        embeds=batch.get("embeds"), ctx=ctx, dist=dist,
                        remat=remat, chunked=chunked)
    n_front = logits.shape[1] - batch["labels"].shape[1]
    if n_front > 0:
        logits = logits[:, n_front:]
    return cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


def prefill(cfg: ModelConfig, params, tokens, cache, *, positions=None,
            ctx=None, embeds=None, dist=None, chunked=None,
            append: bool = False):
    """Fill the cache from a prompt; returns (last_logits, cache).

    positions: optional (B, T) absolute positions. Left-packed ragged
    prompts pass their pads as position -1 (dead cells: masked out of
    attention, cache writes dropped) and real tokens as 0..len-1, so a
    padded request produces the same logits/cache lane as serving it alone.
    A lane whose positions are ALL -1 writes nothing — the slot-insert
    admission path of the continuous scheduler relies on this.

    append=True appends the tokens as ONE chunk at each lane's current
    cache position (chunked prefill): attention covers the cache contents
    plus the fresh chunk, so a prompt split into chunks fills the cache —
    and emits its last-token logits — exactly like a monolithic prefill.
    """
    logits, cache = forward(cfg, params, tokens, embeds=embeds, ctx=ctx,
                            dist=dist, cache=cache, positions=positions,
                            chunked=chunked, append=append)
    return logits[:, -1:], cache


def decode_step(cfg: ModelConfig, params, tokens, pos, cache, *, ctx=None,
                dist=None):
    """One decode step. tokens/pos: (B, 1). Returns (logits, cache)."""
    logits, cache = forward(cfg, params, tokens, positions=pos, cache=cache,
                            ctx=ctx, dist=dist)
    return logits, cache
