"""Model substrate: pure-JAX transformer families (dense/GQA/SWA, MoE,
RG-LRU hybrid, RWKV6, encoder-decoder, BERT) with the paper's quantization
sites threaded throughout.

Submodules are imported directly (``from repro.models import transformer``)
rather than re-exported here, to keep config <-> model imports acyclic."""
