"""Pallas TPU kernels for the paper's quantization hot-spots.

Module map (which kernel serves which paper equation):

  peg_quant      — fused per-embedding-group quantize(-dequantize): eq. (5).
                   ``peg_fake_quant`` simulates; ``peg_quantize`` emits the
                   int8 payload (deployment).
  int8_matmul    — s8xs8->s32 MXU matmuls. ``int8_matmul`` is the per-tensor
                   fixed-point product of eq. (3) (asymmetric activations via
                   the zero-point colsum correction); ``int8_matmul_peg``
                   fuses the per-group accumulator re-scalings of eq.
                   (4)->(5) into the K-loop. Both carry the fused deployment
                   EPILOGUE (bias + activation + optional re-quantize) so
                   integer FFN chains keep int8 in HBM end-to-end.
  fused_ln_quant — LayerNorm / RMSNorm + quantize in one VPU pass (the
                   Fig.-4 rewriting: quantizer directly after the norm).
                   ``*_fake_quant`` simulates; ``*_quantize`` emits int8 and
                   feeds ``int8_matmul[_peg]`` directly.
  int8_attend_decode — fused decode attention over the int8 KV cache
                   (serving hot path). Covers the paper's Fig.-1 attention
                   quantization sites in true fixed point: the q/k sites
                   become the int8 payloads themselves (q on the calibrated
                   site grid with an in-kernel zero-point correction, k/v as
                   the per-head per-slot symmetric cache), ``softmax_in``
                   fake-quants the soft-capped logits in-kernel, and
                   ``softmax_out`` the normalized probabilities (two-pass
                   schedule: the S grid is walked twice because the online-
                   softmax denominator only exists after the last chunk).
                   Halves decode-time cache HBM bytes vs bf16.
  paged_attend_decode — block-PAGED twins of the decode attention paths
                   (``paged_attend_decode`` bf16/f32,
                   ``paged_int8_attend_decode`` int8 with the same Fig.-1
                   site treatment / eq.-(3)-style zero-point corrections as
                   int8_attend_decode). The grid walks each lane's logical
                   blocks; the block table rides as a scalar-prefetch
                   operand so every K/V DMA targets the lane's *physical*
                   arena block, and cell validity is DERIVED from (logical
                   index, q_pos) — stale cells of reallocated blocks are
                   unreadable by construction. This is the deployment
                   payoff squared: int8 halves bytes per token, paging
                   makes bytes proportional to live tokens
                   (runtime/block_pool.py, BENCH_serving.json paged rows).

Simulate vs deploy: the ``*_fake_quant`` variants back ``Mode.APPLY`` / QAT
(f32 in, f32 out — quantization error only); the emitting variants back
``Mode.DEPLOY`` (repro.core.deploy), where activations travel between
kernels as int8 and scales are traced operands (no recompile per
calibration / per scanned layer).

ops.py exposes jit'd wrappers (interpret mode on CPU, Mosaic on TPU) that
also handle batched (B, T, D) inputs and ragged row counts via padding;
ref.py holds the pure-jnp oracles used by tests/test_kernels.py."""
from repro.kernels import ops, ref
