"""Prefix-sharing tests: runtime.radix_cache.RadixCache +
runtime.block_pool.BlockPool refcounted copy-on-write sharing + the
continuous scheduler's O(suffix) prefix-hit admission.

Coverage layers, mirroring tests/test_paged_kv.py / test_chunked_prefill.py:

* RadixCache unit tests: longest block-aligned match (with the
  (prompt-1)//bs logits-contract cap), dedup insert (existing nodes keep
  their original physical block), and LRU subtree eviction gated on the
  root block's refcount.
* BlockPool sharing unit tests: map_shared refcounts, decrementing
  free_lane, needs_cow/cow column swaps, cached-block pinning, LRU
  reclamation through an attached radix cache, and the ``dirty``
  table-upload flag transitions (the _sync_table fast path's contract).
* Golden stub-model tests: prefix-hit admissions emit exactly the greedy
  continuation, hit/saved/rate stats, O(suffix) block draws, donation and
  eviction lifecycles, config validation.
* Property sweeps (seeded + hypothesis when installed): refcounts
  conserved (all zero after drain), no free-list leak (free + cached
  partition the pool), COW never re-maps the shared source block.
* Real-model invariants on gemma2-2b-reduced: shared == unshared greedy
  parity across schedulers for f32/int8-KV and the deploy-int8 path,
  incl. prompts whose decode crosses the local_attn ring window (COW on
  the shared boundary block); prefix-hit admissions are BIT-identical for
  resident lanes; cached shared blocks are never mutated while mapped; a
  recompile guard (chunk / decode / copy-block steps trace exactly once
  across hit admissions and COWs).
* Window-sized arenas (h2o-danube3-4b-reduced, every layer windowed):
  paged_lane_blocks / paged_ring_tokens size lanes by the ring, serving
  clamps reservations to the ring, and long decodes that would overflow a
  max_len-sized table serve correctly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.runtime import (BlockPool, RadixCache, Request, blocks_for_tokens,
                           serve, serve_continuous)
from repro.runtime.serve_loop import _check_capacity
from repro.runtime.steps import (make_admit_step, make_chunk_prefill_step,
                                 make_decode_step, make_prefill_step)
from serve_testlib import golden as _golden
from serve_testlib import next_arr as _next_arr
from serve_testlib import onehot as _onehot

pytestmark = pytest.mark.prefix


# ---------------------------------------------------------------------------
# RadixCache unit tests
# ---------------------------------------------------------------------------


class TestRadixCache:
    def test_match_longest_block_aligned_prefix(self):
        rc = RadixCache(4)
        assert rc.insert(np.arange(12), [5, 7, 2]) == [5, 7, 2]
        assert rc.match(np.arange(12)) == ([5, 7, 2], 12)
        # shorter prompt walks a shorter path
        assert rc.match(np.arange(8)) == ([5, 7], 8)
        # a partial trailing block never matches
        assert rc.match(np.arange(10)) == ([5, 7], 8)
        # divergence stops the walk at the last shared block
        q = np.concatenate([np.arange(8), [99, 98, 97, 96]])
        assert rc.match(q) == ([5, 7], 8)
        # cold tree / unseen prefix
        assert rc.match(np.arange(50, 62)) == ([], 0)

    def test_match_cap_preserves_logits_contract(self):
        rc = RadixCache(4)
        rc.insert(np.arange(12), [5, 7, 2])
        # a fully cached prompt capped at (P-1)//bs keeps >= 1 novel token
        blocks, tok = rc.match(np.arange(12), max_blocks=(12 - 1) // 4)
        assert blocks == [5, 7] and tok == 8

    def test_insert_dedup_keeps_original_blocks(self):
        rc = RadixCache(4)
        rc.insert(np.arange(8), [5, 7])
        # same path donated again: duplicates NOT adopted, tail adopted
        adopted = rc.insert(np.arange(12), [9, 8, 2])
        assert adopted == [2]
        assert rc.match(np.arange(12)) == ([5, 7, 2], 12)
        assert rc.n_nodes == 3

    def test_insert_rejects_partial_blocks(self):
        rc = RadixCache(4)
        with pytest.raises(ValueError, match="full"):
            rc.insert(np.arange(6), [5, 7])     # only one full 4-chunk

    def test_evict_lru_picks_oldest_ref0_subtree(self):
        rc = RadixCache(4)
        rc.insert(np.arange(8), [0, 1])          # path A (older)
        rc.insert(np.arange(50, 54), [2])        # path B (newer)
        rc.match(np.arange(8))                   # bump A -> B is now LRU
        assert rc.evict_lru(lambda b: 0) == [2]
        # next eviction detaches A's WHOLE subtree (root + child)
        assert sorted(rc.evict_lru(lambda b: 0)) == [0, 1]
        assert rc.n_nodes == 0

    def test_evict_respects_refcounts(self):
        rc = RadixCache(4)
        rc.insert(np.arange(8), [0, 1])
        ref = {0: 1, 1: 0}                       # root still mapped somewhere
        # only the ref-0 CHILD is evictable; its mapped parent stays
        assert rc.evict_lru(lambda b: ref[b]) == [1]
        assert rc.match(np.arange(8)) == ([0], 4)
        # everything referenced -> nothing to evict
        assert rc.evict_lru(lambda b: 1) == []


# ---------------------------------------------------------------------------
# BlockPool sharing unit tests
# ---------------------------------------------------------------------------


class TestSharedBlockPool:
    def _donated(self, pool, lane=0, n=3, cached=2):
        """Allocate ``n`` blocks on ``lane``, cache the first ``cached``
        and retire the lane — the canonical donation sequence."""
        assert pool.reserve_and_alloc(lane, n, n)
        blocks = [int(b) for b in pool.lane_blocks(lane)]
        for b in blocks[:cached]:
            pool.set_cached(b)
        released = pool.free_lane(lane)
        assert released == n - cached            # cached blocks NOT freed
        return blocks

    def test_map_shared_refcounts_and_decrementing_free(self):
        pool = BlockPool(8, 4, 2, 6)
        blocks = self._donated(pool)
        shared = blocks[:2]
        assert pool.blocks_cached == 2 and pool.blocks_pinned == 0
        assert pool.map_shared(1, shared, n_alloc=1, n_reserve=2, n_cols=4)
        assert pool.lane_shared(1) == 2
        assert [pool.block_ref(b) for b in shared] == [1, 1]
        assert pool.shared_blocks == 2           # cached AND mapped
        # a second mapper only bumps refcounts — no allocation
        in_use = pool.blocks_in_use
        assert pool.map_shared(0, shared, n_alloc=0, n_reserve=1, n_cols=4)
        assert pool.blocks_in_use == in_use
        assert [pool.block_ref(b) for b in shared] == [2, 2]
        # free decrements; blocks leave the pool only at ref 0 + uncached
        pool.free_lane(0)
        assert [pool.block_ref(b) for b in shared] == [1, 1]
        pool.free_lane(1)
        assert [pool.block_ref(b) for b in shared] == [0, 0]
        assert pool.blocks_in_use == 2           # still cached, not freed
        assert pool.shared_blocks == 0
        # un-caching a ref-0 block returns it to the free list
        pool.set_cached(shared[0], False)
        assert pool.blocks_in_use == 1

    def test_map_shared_rejects_uncached_blocks(self):
        pool = BlockPool(8, 4, 2, 6)
        assert pool.reserve_and_alloc(0, 2, 2)
        b = int(pool.lane_blocks(0)[0])
        with pytest.raises(RuntimeError, match="cached"):
            pool.map_shared(1, [b], n_alloc=1, n_reserve=1, n_cols=2)

    def test_cow_swaps_column_and_preserves_source(self):
        pool = BlockPool(8, 4, 2, 6)
        blocks = self._donated(pool)
        shared = blocks[:2]
        # reserve includes a COW allowance of 2 (both shared cols)
        assert pool.map_shared(1, shared, n_alloc=1, n_reserve=3, n_cols=4)
        assert pool.needs_cow(1, 0) and pool.needs_cow(1, 1)
        assert not pool.needs_cow(1, 2)          # privately owned novel block
        pair = pool.cow(1, 0)
        assert pair is not None
        src, dst = pair
        assert src == shared[0] and dst not in shared
        assert int(pool.table[1, 0]) == dst
        assert pool.block_ref(src) == 0 and pool.block_ref(dst) == 1
        assert pool.is_cached(src)               # source stays cached
        assert pool.lane_shared(1) == 1
        # second write to the same column: lane now owns it
        assert pool.cow(1, 0) is None
        pool.cow(1, 1)
        assert pool.lane_shared(1) == 0
        pool.free_lane(1)
        assert all(pool.block_ref(b) == 0 for b in range(pool.num_blocks))
        assert pool.blocks_in_use == pool.blocks_cached == 2

    def test_pinned_blocks_gate_admission(self):
        pool = BlockPool(4, 4, 2, 4)
        blocks = self._donated(pool, n=2, cached=2)
        assert pool.map_shared(0, blocks, n_alloc=1, n_reserve=1, n_cols=3)
        # 2 pinned + 1 reserved: a 2-block novel claim no longer fits
        assert not pool.can_reserve(2)
        assert pool.can_reserve(1)
        # a hit on the SAME pinned blocks adds no pins — still admissible
        assert pool.can_map_shared(blocks, n_reserve=1, n_cols=3)

    def test_free_list_reclaims_lru_cached_via_radix(self):
        pool = BlockPool(4, 4, 1, 4)
        rc = RadixCache(4)
        pool.attach_cache(rc)
        assert pool.reserve_and_alloc(0, 3, 3)
        blocks = [int(b) for b in pool.lane_blocks(0)]
        rc.insert(np.arange(12), blocks)
        for b in blocks:
            pool.set_cached(b)
        pool.free_lane(0)
        assert pool.blocks_free == 1 and pool.blocks_cached == 3
        # a 3-block admission must evict the (sole) cached subtree
        assert pool.reserve_and_alloc(0, 3, 3)
        assert pool.blocks_cached == 0 and rc.n_nodes == 0
        assert rc.match(np.arange(12)) == ([], 0)

    def test_dirty_flag_transitions(self):
        """The _sync_table fast path's contract: ``dirty`` is set by every
        table mutation and ONLY by table mutations."""
        pool = BlockPool(8, 4, 2, 6)
        assert pool.dirty                        # fresh table needs upload
        pool.dirty = False
        assert pool.reserve_and_alloc(0, 1, 3)
        assert pool.dirty                        # map
        pool.dirty = False
        pool.grow(0, 1)                          # idempotent growth
        assert not pool.dirty
        pool.grow(0, 2)
        assert pool.dirty                        # real growth
        pool.dirty = False
        for b in pool.lane_blocks(0)[:1]:
            pool.set_cached(int(b))
        assert not pool.dirty                    # caching is not a table op
        pool.free_lane(0)
        assert pool.dirty                        # rows cleared
        pool.dirty = False
        shared = [b for b in range(pool.num_blocks) if pool.is_cached(b)]
        assert pool.map_shared(1, shared, n_alloc=0, n_reserve=2, n_cols=3)
        assert pool.dirty                        # shared install
        pool.dirty = False
        assert pool.cow(1, 0) is not None
        assert pool.dirty                        # COW column swap
        pool.free_lane(1)


# ---------------------------------------------------------------------------
# Golden stub-model tests (deterministic next_token = (2t+1) % VOCAB)
# ---------------------------------------------------------------------------


class PrefixStub:
    """StubChunkModel twin for radix-mode serving: prefix-hit admissions
    go through chunk_fn (append mode), residents through decode."""

    def __init__(self):
        self.calls = []

    def init_cache(self, batch):
        return {"kv": jnp.zeros((batch, 4), jnp.float32)}

    def admit(self, tokens, positions, admit_mask, cache):
        self.calls.append("admit")
        return _onehot(_next_arr(tokens)), cache

    def chunk(self, tokens, positions, reset_mask, cache):
        self.calls.append("chunk")
        return _onehot(_next_arr(tokens)), cache

    def decode(self, tokens, pos, cache):
        self.calls.append("decode")
        return _onehot(_next_arr(tokens)), cache


_PREFIX8 = np.arange(1, 9, dtype=np.int32)      # two 4-token blocks


def _prefix_reqs(specs, shared=_PREFIX8):
    """Requests sharing ``shared`` as their common prompt head; suffixes
    are distinct per request (value 10+i, inside the stub VOCAB)."""
    out = []
    for i, (n, q) in enumerate(specs):
        tail = np.full(n - len(shared), 10 + i, np.int32)
        out.append(Request(rid=i, prompt=np.concatenate([shared, tail]),
                           max_new_tokens=q))
    return out


def _serve_prefix(reqs, *, slots=2, bs=4, width=8, num_blocks=16,
                  radix=True):
    m = PrefixStub()
    pool = BlockPool(num_blocks, bs, slots, width)
    rc = RadixCache(bs) if radix else None
    stats = serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=slots, block_pool=pool,
                             chunk_fn=m.chunk, radix_cache=rc)
    return m, stats, pool, rc


def _check_drained(pool, rc):
    """Post-drain invariants: refcounts conserved, free + cached partition
    the pool, every cached block backs exactly one radix node."""
    assert pool.blocks_reserved == 0
    assert all(pool.block_ref(b) == 0 for b in range(pool.num_blocks))
    assert (pool.table == -1).all()
    free = list(pool._free)
    cached = [b for b in range(pool.num_blocks) if pool.is_cached(b)]
    assert len(free) == len(set(free))           # no double-free
    assert sorted(free + cached) == list(range(pool.num_blocks))
    assert pool.blocks_in_use == len(cached)
    if rc is not None:
        assert pool.blocks_cached == rc.n_nodes


class TestGoldenPrefix:
    def test_prefix_hits_golden_and_stats(self):
        reqs = _prefix_reqs([(10, 3), (10, 2), (12, 4)])
        m, stats, pool, rc = _serve_prefix(reqs, slots=1)
        for r in reqs:
            assert r.done
            assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
        # r0 misses and donates its 2 full prompt blocks; r1 and r2 each
        # hit the 8-token cached prefix
        assert stats.prefix_hit_tokens == 16
        assert stats.prefill_tokens_saved == 16
        assert stats.prefix_hit_rate == pytest.approx(16 / 32)
        assert stats.shared_blocks == 2
        assert "admit" not in m.calls            # radix mode always chunks
        _check_drained(pool, rc)

    def test_match_cap_and_deeper_prefix(self):
        # r0 donates 3 blocks; r1 (same 12-token prompt) is capped at
        # (12-1)//4 = 2 blocks so one novel token remains; r2 extends the
        # prompt by a block and matches all 3
        p0 = np.concatenate([_PREFIX8, np.full(4, 10, np.int32)])
        reqs = [Request(rid=0, prompt=p0, max_new_tokens=2),
                Request(rid=1, prompt=p0.copy(), max_new_tokens=3),
                Request(rid=2,
                        prompt=np.concatenate([p0, np.full(4, 20, np.int32)]),
                        max_new_tokens=2)]
        m, stats, pool, rc = _serve_prefix(reqs, slots=1)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
        assert stats.prefix_hit_tokens == 8 + 12
        _check_drained(pool, rc)

    def test_o_suffix_block_draws(self):
        """Prefix hits draw fresh blocks for the novel suffix ONLY: each
        hit lane skips its 2 cached prefix blocks."""

        class CountingPool(BlockPool):
            def reset(self):
                self.popped = 0
                super().reset()

            def _pop_free(self, n):
                self.popped += n
                return super()._pop_free(n)

        specs = [(12, 2)] * 4                    # 4 cols each unshared
        pops = []
        for radix in (False, True):
            m = PrefixStub()
            pool = CountingPool(16, 4, 1, 8)
            rc = RadixCache(4) if radix else None
            reqs = _prefix_reqs(specs)
            serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=1, block_pool=pool,
                             chunk_fn=m.chunk, radix_cache=rc)
            for r in reqs:
                assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
            pops.append(pool.popped)
        assert pops[0] == 4 * 4                  # every lane draws 4 blocks
        assert pops[1] == 4 + 3 * (4 - 2)        # hits draw the suffix only

    def test_eviction_under_pool_pressure(self):
        """Distinct prompts overflow a small pool: LRU subtrees are
        evicted to serve new admissions, and serving still drains."""
        reqs = [Request(rid=i, prompt=np.full(8, 3 + i, np.int32),
                        max_new_tokens=2) for i in range(4)]
        m, stats, pool, rc = _serve_prefix(reqs, slots=1, num_blocks=6)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
        assert stats.prefix_hit_tokens == 0      # all prompts distinct
        _check_drained(pool, rc)
        assert pool.blocks_cached <= pool.num_blocks

    def test_shared_equals_unshared_tokens(self):
        specs = [(9, 3), (10, 2), (12, 4), (9, 1), (11, 5)]
        shared_reqs = _prefix_reqs(specs)
        _, stats, pool, rc = _serve_prefix(shared_reqs, slots=2)
        plain_reqs = _prefix_reqs(specs)
        _, _, _, _ = _serve_prefix(plain_reqs, slots=2, radix=False)
        for s, p in zip(shared_reqs, plain_reqs):
            assert s.tokens_out == p.tokens_out
        assert stats.prefill_tokens_saved > 0
        _check_drained(pool, rc)

    def test_invalid_configs_raise(self):
        reqs = _prefix_reqs([(9, 1)])
        m = PrefixStub()
        with pytest.raises(ValueError, match="block_pool"):
            serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=1, chunk_fn=m.chunk,
                             radix_cache=RadixCache(4))
        with pytest.raises(ValueError, match="chunk_fn"):
            serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=1, block_pool=BlockPool(8, 4, 1, 8),
                             radix_cache=RadixCache(4))
        with pytest.raises(ValueError, match="block_size"):
            serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=1, block_pool=BlockPool(8, 4, 1, 8),
                             chunk_fn=m.chunk, radix_cache=RadixCache(8))
        with pytest.raises(ValueError, match="continuous-scheduler"):
            serve(None, None, m.decode, m.init_cache, None, reqs,
                  scheduler="static", batch_slots=1,
                  radix_cache=RadixCache(4))


class TestPrefixSweep:
    def test_conservation_sweep(self):
        """Seeded workloads x prefix depths x pool sizes: goldens hold,
        refcounts drain to zero, free list + cache partition the pool."""
        rng = np.random.RandomState(11)
        for _ in range(15):
            shared_len = int(rng.choice([0, 4, 8]))
            pre = rng.randint(1, 30, size=shared_len).astype(np.int32)
            n = rng.randint(1, 7)
            specs = [(shared_len + rng.randint(1, 6), rng.randint(0, 6))
                     for _ in range(n)]
            slots = rng.randint(1, 4)
            blocks = rng.randint(8, 17)
            shared_reqs = _prefix_reqs(specs, shared=pre)
            m, stats, pool, rc = _serve_prefix(
                shared_reqs, slots=slots, num_blocks=blocks)
            plain = _prefix_reqs(specs, shared=pre)
            _serve_prefix(plain, slots=slots, num_blocks=blocks,
                          radix=False)
            for s, p in zip(shared_reqs, plain):
                assert s.done
                assert s.tokens_out == p.tokens_out
                assert s.tokens_out == _golden(s.prompt,
                                               max(s.max_new_tokens, 0))
            _check_drained(pool, rc)


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                # pragma: no cover - dev-only dependency
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    class TestPrefixHypothesis:
        @settings(max_examples=40, deadline=None)
        @given(st.lists(st.tuples(st.integers(1, 5), st.integers(0, 6)),
                        min_size=1, max_size=8),
               st.integers(1, 3), st.integers(8, 16),
               st.sampled_from([0, 4, 8]))
        def test_refcounts_conserved_no_freelist_leak(self, specs, slots,
                                                      blocks, shared_len):
            pre = np.arange(1, shared_len + 1, dtype=np.int32)
            reqs = _prefix_reqs([(shared_len + n, q) for n, q in specs],
                                shared=pre)
            m, stats, pool, rc = _serve_prefix(reqs, slots=slots,
                                               num_blocks=blocks)
            for r in reqs:
                assert r.done
                assert r.tokens_out == _golden(r.prompt,
                                               max(r.max_new_tokens, 0))
            _check_drained(pool, rc)

        @settings(max_examples=40, deadline=None)
        @given(st.integers(1, 3), st.integers(0, 2), st.data())
        def test_cow_never_remaps_shared_source(self, k, extra, data):
            """Allocator-level COW property: the swapped-in block is always
            drawn fresh, the cached source never re-enters the lane's
            table, and refcounts stay conserved."""
            pool = BlockPool(2 * k + extra + 2, 4, 2, 2 * k + 2)
            assert pool.reserve_and_alloc(0, k, k)
            shared = [int(b) for b in pool.lane_blocks(0)]
            for b in shared:
                pool.set_cached(b)
            pool.free_lane(0)
            assert pool.map_shared(1, shared, n_alloc=extra,
                                   n_reserve=extra + k, n_cols=2 * k + 2)
            cols = data.draw(st.permutations(list(range(k))))
            swapped = 0
            for col in cols:
                pair = pool.cow(1, col)
                assert pair is not None
                src, dst = pair
                assert src == shared[col]
                assert dst not in shared
                swapped += 1
                assert pool.lane_shared(1) == k - swapped
                assert pool.cow(1, col) is None      # now privately owned
                table = [int(b) for b in pool.lane_blocks(1)]
                assert src not in table
                assert pool.is_cached(src)
            assert all(pool.block_ref(b) == 0 for b in shared)
            pool.free_lane(1)
            _check_drained(pool, None)
            assert pool.blocks_cached == k
else:                              # keep the skip visible in test reports
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(see requirements-dev.txt)")
    def test_refcounts_conserved_no_freelist_leak():
        pass


# ---------------------------------------------------------------------------
# _sync_table fast path (table uploaded only after pool mutations)
# ---------------------------------------------------------------------------


class TableSpyStub(PrefixStub):
    """Records the device block-table array flowing through each step
    (held by reference, so identity comparisons are GC-safe): a new object
    means the scheduler re-uploaded the table."""

    def __init__(self):
        super().__init__()
        self.admit_tables = []
        self.decode_tables = []

    def admit(self, tokens, positions, admit_mask, cache):
        self.admit_tables.append(cache.get("block_table"))
        return super().admit(tokens, positions, admit_mask, cache)

    def decode(self, tokens, pos, cache):
        self.decode_tables.append(cache.get("block_table"))
        return super().decode(tokens, pos, cache)


class TestSyncTableFastPath:
    def test_steady_decode_skips_table_upload(self):
        """Block-boundary growth re-uploads the table; the decode steps
        between boundaries reuse the SAME device array (no per-step
        host->device transfer)."""
        m = TableSpyStub()
        pool = BlockPool(4, 4, 1, 4)
        reqs = [Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                        max_new_tokens=4),
                Request(rid=1, prompt=np.arange(1, 5, dtype=np.int32),
                        max_new_tokens=2)]
        serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                         batch_slots=1, block_pool=pool)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
        # r0: decode at pos 4 grows into block 2 (upload), pos 5 and 6 are
        # steady state (same object); r1's sole decode re-uploads again
        # (its admission freed r0's row and mapped new blocks)
        a0, a1 = m.admit_tables
        d = m.decode_tables
        assert len(d) == 4
        assert d[0] is not a0                    # growth re-upload
        assert d[0] is d[1] is d[2]              # fast path: no re-upload
        assert d[3] is not d[0] and d[3] is not a0   # admission re-upload
        assert a1 is not d[2]


# ---------------------------------------------------------------------------
# Real-model invariants (gemma2-2b-reduced: GQA + local_attn ring window 16
# next to global layers, so caps {16, 32} and COW fires on window wrap)
# ---------------------------------------------------------------------------

MAX_LEN = 32
BS = 8


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    return cfg, params


_STEP_CACHE = {}


def _steps(cfg, ctx_factory=None):
    key = (cfg.name, ctx_factory)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = (
            jax.jit(make_admit_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_chunk_prefill_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_decode_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_prefill_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(tfm.cache_copy_block))
    return _STEP_CACHE[key]


def _serve_real(cfg, params, reqs, *, kv_bits=16, batch_slots=2,
                scheduler="continuous", prefix=True, num_blocks=None,
                ctx_factory=None, max_len=MAX_LEN):
    """Paged continuous serving (with or without the radix cache), or the
    dense static reference."""
    admit, chunkstep, decode, prefill, copyblock = _steps(cfg, ctx_factory)
    pool = radix = None
    if scheduler == "continuous":
        width = tfm.paged_lane_blocks(cfg, max_len, BS)
        num_blocks = num_blocks or batch_slots * width
        pool = BlockPool(num_blocks, BS, batch_slots, width)
        radix = RadixCache(BS) if prefix else None

    def init(b):
        if pool is None:
            return tfm.init_cache(cfg, b, max_len, dtype=jnp.float32,
                                  kv_bits=kv_bits)
        return tfm.init_cache(cfg, b, max_len, dtype=jnp.float32,
                              kv_bits=kv_bits, paged=True, block_size=BS,
                              num_blocks=num_blocks, mapped=False)

    stats = serve(prefill, admit, decode, init, params, reqs,
                  scheduler=scheduler, batch_slots=batch_slots,
                  max_len=max_len, block_pool=pool,
                  chunk_step=chunkstep if pool is not None else None,
                  radix_cache=radix,
                  write_caps=tfm.attn_write_caps(cfg, max_len, BS)
                  if pool is not None else None,
                  ring_tokens=tfm.paged_ring_tokens(cfg, max_len, BS)
                  if pool is not None else None,
                  copy_block_fn=copyblock if radix is not None else None)
    return stats, pool


def _mk_shared_reqs(seed, cfg, specs, shared=8):
    """Random prompts sharing a common ``shared``-token head."""
    rng = np.random.RandomState(seed)
    pre = rng.randint(1, cfg.vocab_size, size=shared).astype(np.int32)
    out = []
    for i, (n, q) in enumerate(specs):
        tail = rng.randint(1, cfg.vocab_size, size=n - shared) \
            .astype(np.int32)
        out.append(Request(rid=i, prompt=np.concatenate([pre, tail]),
                           max_new_tokens=q))
    return out


def _block_bytes(cache, blocks):
    """Raw bytes of the given physical blocks across every paged arena
    leaf (scan leaves carry a leading stacking axis)."""
    blocks = np.asarray(blocks, np.int64)
    parts = []
    for c in cache["scan"]:
        parts.extend(np.asarray(leaf[:, blocks]).tobytes() for leaf in c)
    for c in cache["tail"]:
        parts.extend(np.asarray(leaf[blocks]).tobytes() for leaf in c)
    return b"".join(parts)


# donors keep prompt+quota-2 < 16 (window ring) so their full prompt
# blocks are generation-0 and donate; later requests hit the cached head
SPEC = [(10, 2), (12, 3), (9, 4), (12, 2), (11, 3), (10, 4)]
# a donor, then prefix-hit recipients whose decode crosses the window
# ring (position 16): the wrap write lands in the SHARED boundary block
# and must copy-on-write.
#
# NOTE on kv_bits=8 with DYNAMIC per-slot scales: a prefix-hit lane reads
# its prefix K/V back through int8 storage while an unshared lane computes
# them fresh in f32 inside its own admission row, and the admit/chunk
# programs round scales differently at the last ULP — so exact greedy
# equality is workload-dependent (quant noise must not flip an argmax),
# exactly as in tests/test_chunked_prefill.py. The calibrated deploy path
# round-trips int8 storage exactly and restores bit parity — see
# TestPrefixDeployParity for the wrap workload under --deploy-int8.
SPEC_COW = [(12, 2), (12, 9), (12, 8)]


@pytest.mark.serve
class TestPrefixServingParity:
    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_shared_matches_unshared_and_static(self, tiny, kv_bits):
        cfg, params = tiny
        base = _mk_shared_reqs(3, cfg, SPEC)
        _serve_real(cfg, params, base, kv_bits=kv_bits, scheduler="static",
                    prefix=False)
        plain = _mk_shared_reqs(3, cfg, SPEC)
        _serve_real(cfg, params, plain, kv_bits=kv_bits, prefix=False)
        reqs = _mk_shared_reqs(3, cfg, SPEC)
        stats, pool = _serve_real(cfg, params, reqs, kv_bits=kv_bits)
        for b, p, r in zip(base, plain, reqs):
            assert b.tokens_out == p.tokens_out, (kv_bits, r.rid)
            assert p.tokens_out == r.tokens_out, (kv_bits, r.rid)
            assert r.done
        assert stats.prefill_tokens_saved > 0
        assert stats.prefix_hit_rate > 0
        assert pool.blocks_reserved == 0
        assert pool.blocks_in_use == pool.blocks_cached

    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_window_crossing_recipients_cow(self, tiny, kv_bits):
        """Prefix-hit lanes whose decode wraps the local_attn ring COW the
        shared boundary block — greedy parity must survive the copy."""
        cfg, params = tiny
        plain = _mk_shared_reqs(6, cfg, SPEC_COW)
        _serve_real(cfg, params, plain, kv_bits=kv_bits, batch_slots=1,
                    prefix=False, num_blocks=6)
        reqs = _mk_shared_reqs(6, cfg, SPEC_COW)
        stats, pool = _serve_real(cfg, params, reqs, kv_bits=kv_bits,
                                  batch_slots=1, num_blocks=6)
        for p, r in zip(plain, reqs):
            assert p.tokens_out == r.tokens_out, (kv_bits, r.rid)
        # r1 and r2 both hit r0's donated 8-token block
        assert stats.prefill_tokens_saved == 16

    def test_cow_never_mutates_cached_blocks(self, tiny):
        """Byte-level guarantee behind the parity above: cached blocks are
        never written while shared — every wrap write lands in a COW
        copy."""
        cfg, params = tiny
        admit, chunkstep, decode, prefill, copyblock = _steps(cfg)
        width = tfm.paged_lane_blocks(cfg, MAX_LEN, BS)
        pool = BlockPool(8, BS, 1, width)
        radix = RadixCache(BS)
        cows = []
        orig_cow = pool.cow

        def spy_cow(lane, col):
            pair = orig_cow(lane, col)
            if pair is not None:
                cows.append(pair)
            return pair
        pool.cow = spy_cow
        seen = {}

        def check(cache):
            for b in range(pool.num_blocks):
                if not pool.is_cached(b):
                    seen.pop(b, None)
                    continue
                cur = _block_bytes(cache, [b])
                if b in seen:
                    assert cur == seen[b], f"cached block {b} mutated"
                seen[b] = cur

        def chunk_fn(t, pm, m, c):
            logits, c2 = chunkstep(params, t, pm, m, c)
            check(c2)
            return logits, c2

        def decode_fn(t, p, c):
            logits, c2 = decode(params, t, p, c)
            check(c2)
            return logits, c2

        reqs = _mk_shared_reqs(6, cfg, SPEC_COW)
        stats = serve_continuous(
            None, decode_fn,
            lambda b: tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                                     paged=True, block_size=BS, num_blocks=8,
                                     mapped=False),
            reqs, batch_slots=1, max_len=MAX_LEN, block_pool=pool,
            chunk_fn=chunk_fn, radix_cache=radix,
            write_caps=tfm.attn_write_caps(cfg, MAX_LEN, BS),
            copy_block_fn=lambda c, s, d: copyblock(c, s, d))
        assert cows, "workload failed to trigger copy-on-write"
        assert stats.prefill_tokens_saved > 0

    def test_prefix_hit_admission_preserves_residents_bitwise(self, tiny):
        """A prefix-hit admission (append-mode chunk, reset=False, start
        position K) leaves every resident lane's blocks BIT-identical."""
        cfg, params = tiny
        admit, chunkstep, decode, prefill, copyblock = _steps(cfg)
        width = tfm.paged_lane_blocks(cfg, MAX_LEN, BS)
        pool = BlockPool(8, BS, 2, width)
        radix = RadixCache(BS)
        hit_chunks = []

        def chunk_fn(t, pm, m, c):
            pm_np, m_np = np.asarray(pm), np.asarray(m)
            resident = [i for i in range(pm_np.shape[0])
                        if (pm_np[i] < 0).all()]
            before = {i: _block_bytes(c, pool.lane_blocks(i))
                      for i in resident}
            logits, c2 = chunkstep(params, t, pm, m, c)
            for i in resident:
                assert _block_bytes(c2, pool.lane_blocks(i)) == before[i], \
                    f"resident lane {i} perturbed"
            hits = [i for i in range(pm_np.shape[0])
                    if (pm_np[i] >= 0).any() and not m_np[i]
                    and int(pm_np[i][pm_np[i] >= 0].min()) > 0]
            if hits and resident:
                hit_chunks.append(hits)
            return logits, c2

        # r1 retires early and donates; r2's hit admission lands while r0
        # is still decoding in the other lane
        reqs = _mk_shared_reqs(8, cfg, [(10, 8), (10, 2), (10, 6)])
        serve_continuous(
            None, lambda t, p, c: decode(params, t, p, c),
            lambda b: tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                                     paged=True, block_size=BS, num_blocks=8,
                                     mapped=False),
            reqs, batch_slots=2, max_len=MAX_LEN, block_pool=pool,
            chunk_fn=chunk_fn, radix_cache=radix,
            write_caps=tfm.attn_write_caps(cfg, MAX_LEN, BS),
            copy_block_fn=lambda c, s, d: copyblock(c, s, d))
        assert hit_chunks, "no prefix-hit admission landed beside residents"
        plain = _mk_shared_reqs(8, cfg, [(10, 8), (10, 2), (10, 6)])
        _serve_real(cfg, params, plain, prefix=False)
        for p, r in zip(plain, reqs):
            assert p.tokens_out == r.tokens_out

    def test_no_recompiles_across_hit_admissions_and_cow(self, tiny):
        """The jitted chunk / decode / copy-block steps trace exactly once
        across miss admissions, hit admissions and COW copies — shared
        block mapping is pure table data."""
        cfg, params = tiny
        traces = {"chunk": 0, "decode": 0, "copy": 0}
        base_chunk = make_chunk_prefill_step(cfg)
        base_decode = make_decode_step(cfg)

        def chunk_fn(params, t, pm, m, c):
            traces["chunk"] += 1
            return base_chunk(params, t, pm, m, c)

        def decode_fn(params, t, p, c):
            traces["decode"] += 1
            return base_decode(params, t, p, c)

        def copy_fn(c, s, d):
            traces["copy"] += 1
            return tfm.cache_copy_block(c, s, d)

        chunk_j, decode_j, copy_j = (jax.jit(chunk_fn), jax.jit(decode_fn),
                                     jax.jit(copy_fn))
        width = tfm.paged_lane_blocks(cfg, MAX_LEN, BS)
        pool = BlockPool(8, BS, 1, width)
        reqs = _mk_shared_reqs(6, cfg, SPEC_COW)
        stats = serve_continuous(
            None, lambda t, p, c: decode_j(params, t, p, c),
            lambda b: tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                                     paged=True, block_size=BS, num_blocks=8,
                                     mapped=False),
            reqs, batch_slots=1, max_len=MAX_LEN, block_pool=pool,
            chunk_fn=lambda t, pm, m, c: chunk_j(params, t, pm, m, c),
            radix_cache=RadixCache(BS),
            write_caps=tfm.attn_write_caps(cfg, MAX_LEN, BS),
            copy_block_fn=copy_j)
        assert stats.prefill_tokens_saved > 0
        assert traces == {"chunk": 1, "decode": 1, "copy": 1}


@pytest.mark.deploy
class TestPrefixDeployParity:
    """Prefix sharing on the integer deployment path: calibrated int8
    KV round-trips storage exactly, so shared-block reads match the
    unshared prefill bit for bit — including the window-crossing
    recipient ((12, 8) decodes past the ring at 16 and COWs the shared
    boundary block), where DYNAMIC kv8 scales would only give approximate
    parity."""

    @pytest.fixture(scope="class")
    def deployed(self):
        from repro.core import Mode, QuantCtx, build_deploy, peg_policy
        from repro.core.pipeline import ptq
        cfg = get_config("gemma2-2b").reduced()
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(cfg, key, stacked=True, dtype=jnp.float32)
        pol = peg_policy(4)
        flat = tfm.init_params(cfg, key, stacked=False, dtype=jnp.float32)
        calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10),
                                               (2, 8), 0, cfg.vocab_size)}]

        def fwd(p, b, ctx):
            logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
            return logits

        qm = ptq(fwd, flat, calib, pol, collect_inputs=True)
        shared = {}
        for site, qp in qm.act_state.items():
            base = ("layer/" + site.split("/", 1)[1]
                    if site.startswith("layer") else site)
            shared.setdefault(base, qp)
        packed, acts = build_deploy(cfg, params, pol, shared)

        def ctx_factory():
            return QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=shared,
                            deploy_acts=acts)
        return cfg, packed, ctx_factory

    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_shared_matches_unshared_int8(self, deployed, kv_bits):
        cfg, packed, ctx_factory = deployed
        spec = [(10, 2), (11, 2), (12, 8), (10, 3)]
        plain = _mk_shared_reqs(5, cfg, spec)
        _serve_real(cfg, packed, plain, kv_bits=kv_bits, prefix=False,
                    ctx_factory=ctx_factory)
        reqs = _mk_shared_reqs(5, cfg, spec)
        stats, _ = _serve_real(cfg, packed, reqs, kv_bits=kv_bits,
                               ctx_factory=ctx_factory)
        for p, r in zip(plain, reqs):
            assert p.tokens_out == r.tokens_out, (kv_bits, r.rid)
        assert stats.prefill_tokens_saved > 0


# ---------------------------------------------------------------------------
# Window-sized arenas (h2o-danube3-4b-reduced: EVERY layer windowed at 16,
# so paged lanes need only ceil(16/8) = 2 blocks however long the decode)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_h2o():
    cfg = get_config("h2o-danube3-4b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1), stacked=True,
                             dtype=jnp.float32)
    return cfg, params


@pytest.mark.serve
class TestWindowArenaSizing:
    def test_sizing_helpers(self):
        g = get_config("gemma2-2b").reduced()       # window 16 + global mix
        h = get_config("h2o-danube3-4b").reduced()  # all layers window 16
        assert tfm.paged_lane_blocks(g, MAX_LEN, BS) == 4
        assert tfm.paged_lane_blocks(h, MAX_LEN, BS) == 2
        assert tfm.attn_write_caps(g, MAX_LEN, BS) == [16, 32]
        assert tfm.attn_write_caps(h, MAX_LEN, BS) == [16]
        # the ring clamp only exists when NO layer needs full history
        assert tfm.paged_ring_tokens(g, MAX_LEN, BS) is None
        assert tfm.paged_ring_tokens(h, MAX_LEN, BS) == 16

    def test_init_cache_table_width_is_ring_bound(self, tiny_h2o):
        cfg, _ = tiny_h2o
        cache = tfm.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32,
                               paged=True, block_size=BS, num_blocks=4,
                               mapped=False)
        assert cache["block_table"].shape == (1, 2)

    def test_capacity_check_uses_ring_clamp(self):
        reqs = [Request(rid=0, prompt=np.arange(1, 6, dtype=np.int32),
                        max_new_tokens=20)]       # needs 24 cells unclamped
        pool = BlockPool(4, BS, 2, 2)
        with pytest.raises(ValueError, match="blocks"):
            _check_capacity(reqs, MAX_LEN, pool)
        _check_capacity(reqs, MAX_LEN, pool, ring_tokens=16)  # clamped: fits

    def test_ring_clamped_serving_matches_dense(self, tiny_h2o):
        """Decodes far past the window serve correctly from 2-block lanes
        (the unclamped worst case, 3 blocks, would not even admit)."""
        cfg, params = tiny_h2o
        specs = [(5, 20), (3, 18), (7, 12)]
        base = _mk_shared_reqs(2, cfg, specs, shared=2)
        _serve_real(cfg, params, base, scheduler="static", prefix=False)
        reqs = _mk_shared_reqs(2, cfg, specs, shared=2)
        stats, pool = _serve_real(cfg, params, reqs, prefix=False,
                                  num_blocks=4)
        assert pool.max_blocks_per_lane == 2
        for b, r in zip(base, reqs):
            assert b.tokens_out == r.tokens_out, r.rid
            assert r.done
        assert pool.blocks_in_use == 0

    def test_prefix_sharing_with_ring_clamped_reservations(self, tiny_h2o):
        """Radix hits on the all-window model: reservations and COW
        allowances are ring-clamped, parity vs the unshared run holds."""
        cfg, params = tiny_h2o
        specs = [(10, 2), (12, 3), (11, 4), (12, 2)]
        plain = _mk_shared_reqs(4, cfg, specs)
        _serve_real(cfg, params, plain, prefix=False, num_blocks=6)
        reqs = _mk_shared_reqs(4, cfg, specs)
        stats, pool = _serve_real(cfg, params, reqs, num_blocks=6)
        for p, r in zip(plain, reqs):
            assert p.tokens_out == r.tokens_out, r.rid
        assert stats.prefill_tokens_saved > 0
        assert pool.blocks_reserved == 0
