"""Int4 <-> packed-int8 nibble layouts for the 4-bit deploy path.

Two int4 cells share one int8 byte, halving HBM bytes for the quantized KV
cache and for packed weight payloads. Everything here is pure jnp bit
arithmetic (int32 compare/shift/mask on the VPU — no gathers, no lane
shuffles in the pack direction), so the same helpers run host-side at pack
time and inside the Pallas kernel bodies at unpack time.

Two layouts, chosen for how each consumer blocks the packed axis:

* **split-half** (:func:`pack_nibbles` / :func:`unpack_nibbles`) — along
  ``axis`` of length ``n``, byte ``j`` holds cell ``j`` in its low nibble
  and cell ``j + ceil(n/2)`` in its high nibble. Unpack is a sign-extend +
  one concatenate — no element interleave. Used for the KV cache head_dim
  axis, which the decode kernels always load whole (one (C, hd/2) block
  unpacks to (C, hd) in VMEM). Odd ``n`` pads the tail nibble with 0.

* **pairwise rows** (:func:`pack_rows` / :func:`unpack_rows`) — along axis
  0 of a (K, N) weight, packed row ``r`` holds original row ``2r`` (low
  nibble) and ``2r + 1`` (high nibble). This layout COMPOSES with K-axis
  blocking: a block of packed rows [a, b) is exactly original rows
  [2a, 2b), so the matmul kernels' k-grid (and the PEG group boundaries,
  which stay row-aligned for even group sizes) never straddle a byte.
  Requires even K — pack-time gating falls back to 8-bit otherwise.

Sign convention: nibbles store two's-complement int4 in [-8, 7]
(``_sext4`` re-extends the sign), so both the symmetric [-7, 7] weight
grid and the shifted asymmetric cache grid (uint4 - 8) fit.
"""
from __future__ import annotations

import jax.numpy as jnp


def _sext4(v):
    """Sign-extend the low nibble of an int32 array to int4 values [-8, 7]."""
    return (jnp.bitwise_and(v, 15) ^ 8) - 8


def _pack_pair(lo, hi):
    """Two int arrays of int4-range values -> one int8 byte array."""
    b = jnp.bitwise_or(jnp.bitwise_and(lo.astype(jnp.int32), 15),
                       jnp.left_shift(jnp.bitwise_and(hi.astype(jnp.int32),
                                                      15), 4))
    return jnp.where(b >= 128, b - 256, b).astype(jnp.int8)


def packed_len(n: int) -> int:
    """Packed length of an ``n``-cell int4 axis."""
    return -(-n // 2)


def pack_nibbles(x, axis: int = -1):
    """Split-half pack: int4-range values (..., n, ...) -> packed int8 with
    ``ceil(n/2)`` along ``axis``. Odd ``n`` pads the spare high nibble
    with 0 (dropped again by :func:`unpack_nibbles`)."""
    x = jnp.asarray(x)
    axis = axis % x.ndim
    n = x.shape[axis]
    half = packed_len(n)
    if 2 * half != n:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, 2 * half - n)
        x = jnp.pad(x, pad)
    lo = jnp.take(x, jnp.arange(half), axis=axis)
    hi = jnp.take(x, jnp.arange(half, 2 * half), axis=axis)
    return _pack_pair(lo, hi)


def unpack_nibbles(packed, n: int, axis: int = -1):
    """Inverse of :func:`pack_nibbles`: packed int8 -> int8 array of int4
    values with the original length ``n`` along ``axis``."""
    b = jnp.asarray(packed).astype(jnp.int32)
    lo = _sext4(b)
    hi = _sext4(jnp.right_shift(b, 4))
    out = jnp.concatenate([lo, hi], axis=axis).astype(jnp.int8)
    axis = axis % out.ndim
    if out.shape[axis] != n:
        out = jnp.take(out, jnp.arange(n), axis=axis)
    return out


def pack_rows(w):
    """Pairwise-row pack for (K, N) int4-range weights: packed row ``r`` =
    original rows (2r | 2r+1). K must be even (gate at pack time)."""
    k = w.shape[0]
    if k % 2:
        raise ValueError(f"pack_rows needs even K, got {k}")
    return _pack_pair(w[0::2], w[1::2])


def unpack_rows(packed):
    """Inverse of :func:`pack_rows`: (K/2, N) packed int8 -> (K, N) int8.
    The stack-then-reshape interleave restores exact row order, so int8
    activations in original K order dot against the unpacked block
    unchanged (and PEG group boundaries stay where pack time put them)."""
    b = jnp.asarray(packed).astype(jnp.int32)
    lo = _sext4(b)
    hi = _sext4(jnp.right_shift(b, 4))
    k2, n = b.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * k2, n).astype(jnp.int8)
