"""Multi-head attention: MHA / GQA / MQA, sliding-window, local+global,
logit soft-capping, RoPE, KV cache (full + ring-buffer windowed), and a
flash-style chunked path (online softmax over KV chunks via lax.scan) so long
contexts never materialize the (T, S) score matrix.

Absolute positions drive both masking and cache writes, and position -1
marks a DEAD cell — a pad token inside a left-packed prompt or an idle
decode lane: dead cells are masked out of attention (every path checks
k_pos >= 0) and their KV-cache writes are dropped (_write_slots). That
sentinel is the lane-safety contract the continuous-batching scheduler
builds on (runtime/serve_loop.py): a slot-insert prefill or a masked decode
step can never perturb co-resident lanes' caches.

Quantization sites (paper Fig. 1 naming) are threaded via QuantCtx:
  {prefix}/q, {prefix}/k, {prefix}/v       — linear outputs
  {prefix}/softmax_in, {prefix}/softmax_out
  {prefix}/ctx_out                          — self-attention output (after Wo)
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, softcap

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    causal: bool = True
    window: Optional[int] = None          # sliding-window size (None = global)
    logit_softcap: Optional[float] = None # gemma-2 style
    rope_theta: Optional[float] = 10000.0 # None = no RoPE (e.g. BERT)
    query_scale: Optional[float] = None   # default 1/sqrt(head_dim)

    @property
    def q_groups(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def scale(self) -> float:
        return (self.query_scale if self.query_scale is not None
                else 1.0 / math.sqrt(self.head_dim))


class KVCache(NamedTuple):
    """k/v: (B, S, KV, hd); pos: (B, S) absolute positions (-1 = empty).
    S = max_len for global attention, window size for sliding-window."""
    k: jnp.ndarray
    v: jnp.ndarray
    pos: jnp.ndarray


class QuantKVCache(NamedTuple):
    """Int8 KV cache (deployment serving path): k_q/v_q (B, S, KV, hd) int8
    payloads with zero-point-free symmetric per-head, per-slot scales k_s/v_s
    (B, S, KV) f32; pos as in :class:`KVCache`. Symmetry keeps the zero-point
    colsum correction out of the decode kernel's S-loop; per-slot scales make
    the write a pure in-place quantize (ring-buffer slots included)."""
    k_q: jnp.ndarray
    v_q: jnp.ndarray
    k_s: jnp.ndarray
    v_s: jnp.ndarray
    pos: jnp.ndarray


class PagedKVCache(NamedTuple):
    """Block-paged bf16/f32 KV cache: k/v (N, bs, KV, hd) — one shared
    arena of N physical blocks of bs token cells, NO batch axis. Which
    blocks back which decode lane is data: the (B, max_blocks) int32 block
    table (-1 = unmapped) that travels inside the whole-model cache pytree
    (runtime.block_pool.BlockPool allocates it host-side), so lanes own
    bytes proportional to their LIVE tokens, not to max_len. ``pos``
    (N, bs) keeps the per-cell dead-cell sentinel (-1) of :class:`KVCache`;
    the read paths additionally derive validity from (logical index,
    q_pos) alone — see paged_key_positions — so a freshly grown block's
    stale cells are unreadable even before any write touches them."""
    k: jnp.ndarray
    v: jnp.ndarray
    pos: jnp.ndarray


class PagedQuantKVCache(NamedTuple):
    """Paged int8 KV cache: :class:`QuantKVCache` payloads/scales laid out
    over the shared block arena of :class:`PagedKVCache` — k_q/v_q
    (N, bs, KV, hd) int8, k_s/v_s (N, bs, KV) f32, pos (N, bs)."""
    k_q: jnp.ndarray
    v_q: jnp.ndarray
    k_s: jnp.ndarray
    v_s: jnp.ndarray
    pos: jnp.ndarray


class Quant4KVCache(QuantKVCache):
    """Packed int4 KV cache: same fields and scale layout as
    :class:`QuantKVCache` but k_q/v_q hold two int4 cells per byte —
    (B, S, KV, hd/2) split-half nibble payloads (repro.kernels.nibble).
    The TYPE is the bit-width marker: every isinstance check on the int8
    base class still applies (write/reset/reads), and the decode paths
    select ``kv_bits=4`` kernels plus the int4 quantizer by this subclass.
    JAX tree ops rebuild namedtuples as ``type(x)(*children)``, so the
    marker survives jit/scan/donation."""


class PagedQuant4KVCache(PagedQuantKVCache):
    """Paged packed int4 KV cache: :class:`Quant4KVCache` payloads over the
    shared block arena — k_q/v_q (N, bs, KV, hd/2) nibble-packed int8,
    k_s/v_s (N, bs, KV) f32, pos (N, bs). Halves arena HBM per block, so a
    pool of the same byte budget holds ~2x the resident decode lanes."""


def init_kv_cache(batch: int, max_len: int, cfg: AttnConfig,
                  dtype=jnp.bfloat16) -> KVCache:
    size = min(max_len, cfg.window) if cfg.window else max_len
    return KVCache(
        k=jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dtype),
        v=jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim), dtype),
        pos=jnp.full((batch, size), -1, jnp.int32))


def init_quant_kv_cache(batch: int, max_len: int,
                        cfg: AttnConfig) -> QuantKVCache:
    size = min(max_len, cfg.window) if cfg.window else max_len
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return QuantKVCache(
        k_q=jnp.zeros((batch, size, kv, hd), jnp.int8),
        v_q=jnp.zeros((batch, size, kv, hd), jnp.int8),
        k_s=jnp.zeros((batch, size, kv), jnp.float32),
        v_s=jnp.zeros((batch, size, kv), jnp.float32),
        pos=jnp.full((batch, size), -1, jnp.int32))


def init_paged_kv_cache(num_blocks: int, block_size: int, cfg: AttnConfig,
                        dtype=jnp.bfloat16) -> PagedKVCache:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return PagedKVCache(
        k=jnp.zeros((num_blocks, block_size, kv, hd), dtype),
        v=jnp.zeros((num_blocks, block_size, kv, hd), dtype),
        pos=jnp.full((num_blocks, block_size), -1, jnp.int32))


def init_paged_quant_kv_cache(num_blocks: int, block_size: int,
                              cfg: AttnConfig) -> PagedQuantKVCache:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return PagedQuantKVCache(
        k_q=jnp.zeros((num_blocks, block_size, kv, hd), jnp.int8),
        v_q=jnp.zeros((num_blocks, block_size, kv, hd), jnp.int8),
        k_s=jnp.zeros((num_blocks, block_size, kv), jnp.float32),
        v_s=jnp.zeros((num_blocks, block_size, kv), jnp.float32),
        pos=jnp.full((num_blocks, block_size), -1, jnp.int32))


def init_quant4_kv_cache(batch: int, max_len: int,
                         cfg: AttnConfig) -> Quant4KVCache:
    size = min(max_len, cfg.window) if cfg.window else max_len
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    assert hd % 2 == 0, f"int4 KV cache needs even head_dim, got {hd}"
    return Quant4KVCache(
        k_q=jnp.zeros((batch, size, kv, hd // 2), jnp.int8),
        v_q=jnp.zeros((batch, size, kv, hd // 2), jnp.int8),
        k_s=jnp.zeros((batch, size, kv), jnp.float32),
        v_s=jnp.zeros((batch, size, kv), jnp.float32),
        pos=jnp.full((batch, size), -1, jnp.int32))


def init_paged_quant4_kv_cache(num_blocks: int, block_size: int,
                               cfg: AttnConfig) -> PagedQuant4KVCache:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    assert hd % 2 == 0, f"int4 KV cache needs even head_dim, got {hd}"
    return PagedQuant4KVCache(
        k_q=jnp.zeros((num_blocks, block_size, kv, hd // 2), jnp.int8),
        v_q=jnp.zeros((num_blocks, block_size, kv, hd // 2), jnp.int8),
        k_s=jnp.zeros((num_blocks, block_size, kv), jnp.float32),
        v_s=jnp.zeros((num_blocks, block_size, kv), jnp.float32),
        pos=jnp.full((num_blocks, block_size), -1, jnp.int32))


def paged_capacity(block_table, block_size: int,
                   window: Optional[int]) -> int:
    """A layer's logical capacity S over a paged cache: the block table
    covers max_blocks*bs cells; ring (sliding-window) layers wrap at the
    window exactly like the dense sized-to-window cache."""
    cap = block_table.shape[-1] * block_size
    return min(cap, window) if window else cap


def quantize_kv(x, grid_scale=None, zero_point=None):
    """Per-head (last-two-axes: ..., KV, hd) int8 quantization.

    Without calibration each (token, kv-head) vector gets its own symmetric
    scale amax/127. With a calibrated site grid (``grid_scale`` +
    ``zero_point`` from deploy.kv_quant_for, both broadcastable over (KV,))
    the write re-uses the site's affine grid shifted onto int8 — values the
    simulate path already fake-quantized then store EXACTLY, so the int8
    cache adds no storage error on the deploy path. The zero-point is NOT
    stored per slot; it is static per head and corrected inside the decode
    kernel. Returns (q int8, scale f32 x.shape[:-1]).
    """
    xf = x.astype(jnp.float32)
    if zero_point is not None:
        s = jnp.broadcast_to(jnp.asarray(grid_scale, jnp.float32),
                             xf.shape[:-1])
        z = jnp.asarray(zero_point, jnp.float32)
        q = jnp.clip(jnp.round(xf / s[..., None]) + z[..., None],
                     -128, 127).astype(jnp.int8)
        return q, s
    amax = jnp.max(jnp.abs(xf), axis=-1)
    s = amax / 127.0
    if grid_scale is not None:
        s = jnp.maximum(s, jnp.asarray(grid_scale, jnp.float32))
    s = jnp.maximum(s, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(xf / s[..., None]), -127, 127).astype(jnp.int8)
    return q, s


def quantize_kv4(x, grid_scale=None, zero_point=None):
    """Per-head int4 quantization + split-half nibble pack: the 4-bit twin
    of :func:`quantize_kv`. Calibrated grids (from deploy.kv_quant_for with
    bits=4, zero-point already shifted onto the int4 grid) clip to [-8, 7];
    dynamic symmetric uses amax/7 on [-7, 7]. Returns
    (packed int8 (..., hd/2), scale f32 x.shape[:-1])."""
    from repro.kernels.nibble import pack_nibbles
    xf = x.astype(jnp.float32)
    if zero_point is not None:
        s = jnp.broadcast_to(jnp.asarray(grid_scale, jnp.float32),
                             xf.shape[:-1])
        z = jnp.asarray(zero_point, jnp.float32)
        q = jnp.clip(jnp.round(xf / s[..., None]) + z[..., None],
                     -8, 7).astype(jnp.int8)
        return pack_nibbles(q), s
    amax = jnp.max(jnp.abs(xf), axis=-1)
    s = amax / 7.0
    if grid_scale is not None:
        s = jnp.maximum(s, jnp.asarray(grid_scale, jnp.float32))
    s = jnp.maximum(s, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(xf / s[..., None]), -7, 7).astype(jnp.int8)
    return pack_nibbles(q), s


def dequantize_kv(cache: QuantKVCache, kvq=None):
    """(k, v) f32 views of a quantized cache (the fallback read path).
    ``kvq``: the deploy.KVQuant whose static zero-points the cache was
    written with (None = symmetric dynamic writes). Packed int4 caches
    unpack their nibbles first (hd = 2 * stored payload width)."""
    kq, vq = cache.k_q, cache.v_q
    if isinstance(cache, Quant4KVCache):
        from repro.kernels.nibble import unpack_nibbles
        hd = 2 * kq.shape[-1]
        kq = unpack_nibbles(kq, hd)
        vq = unpack_nibbles(vq, hd)
    kq = kq.astype(jnp.float32)
    vq = vq.astype(jnp.float32)
    if kvq is not None:
        kq = kq - jnp.asarray(kvq.k_zp, jnp.float32)[..., None]
        vq = vq - jnp.asarray(kvq.v_zp, jnp.float32)[..., None]
    return kq * cache.k_s[..., None], vq * cache.v_s[..., None]


def _mask(q_pos, k_pos, cfg: AttnConfig):
    """Boolean validity mask (..., T, S) from absolute positions."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    valid = kp >= 0
    if cfg.causal:
        valid &= kp <= qp
    if cfg.window is not None:
        valid &= kp > qp - cfg.window
    return valid


def _dense_attend(q, k, v, q_pos, k_pos, cfg: AttnConfig, ctx=None, prefix=""):
    """q: (B,T,H,hd), k/v: (B,S,KV,hd). Returns (B,T,H,hd)."""
    B, T, H, hd = q.shape
    S = k.shape[1]
    KV, G = cfg.num_kv_heads, cfg.q_groups
    qg = q.reshape(B, T, KV, G, hd)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * cfg.scale
    logits = softcap(logits, cfg.logit_softcap)
    if ctx is not None:
        logits = ctx.act(f"{prefix}/softmax_in", logits)
    valid = _mask(q_pos, k_pos, cfg)[:, None, None]     # (B,1,1,T,S)
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    if ctx is not None:
        probs = ctx.act(f"{prefix}/softmax_out", probs)
    out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(jnp.float32),
                     v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)


def _chunked_attend(q, k, v, q_pos, k_pos, cfg: AttnConfig,
                    kv_chunk: int = 1024):
    """Flash-style online-softmax scan over KV chunks; never materializes
    the full (T, S) score matrix. Numerically matches _dense_attend."""
    B, T, H, hd = q.shape
    S = k.shape[1]
    KV, G = cfg.num_kv_heads, cfg.q_groups
    qg = q.reshape(B, T, KV, G, hd).astype(jnp.float32) * cfg.scale

    n_chunks = -(-S // kv_chunk)
    pad = n_chunks * kv_chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)

    ks = k.reshape(B, n_chunks, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n_chunks, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    ps = k_pos.reshape(B, n_chunks, kv_chunk).transpose(1, 0, 2)

    def step(carry, chunk):
        m, l, acc = carry                       # running max / denom / numer
        kc, vc, pc = chunk                      # (B,C,KV,hd), (B,C)
        s = jnp.einsum("btkgd,bckd->bkgtc", qg, kc.astype(jnp.float32))
        s = softcap(s, cfg.logit_softcap)
        valid = _mask(q_pos, pc, cfg)[:, None, None]
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows: keep m finite
        m_new = jnp.maximum(m_new, -1e30)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgtc,bckd->bkgtd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, T), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, T), jnp.float32)
    acc0 = jnp.zeros((B, KV, G, T, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (ks, vs, ps))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, hd).astype(q.dtype)


def _banded_attend(q, k, v, q_pos, k_pos, cfg: AttnConfig,
                   block: int = 1024):
    """Sliding-window attention that COMPUTES only the band (perf variant):
    queries are processed in blocks of ``block``; each block attends only to
    the kv blocks that can intersect its window — O(T·W) flops/bytes instead
    of O(T²). Requires aligned q/k (self-attention layout, q_pos == k_pos ==
    arange) and cfg.window set.
    """
    B, T, H, hd = q.shape
    W = cfg.window
    assert W is not None
    nq = -(-T // block)
    pad = nq * block - T
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-10**9)
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    Tp = nq * block
    nband = -(-W // block) + 1            # kv blocks a q block can reach
    KV = cfg.num_kv_heads

    qb = q.reshape(B, nq, block, H, hd)
    kb = k.reshape(B, nq, block, KV, hd)
    vb = v.reshape(B, nq, block, KV, hd)
    qp = q_pos.reshape(B, nq, block)
    kp = k_pos.reshape(B, nq, block)

    # band gather: for q block i, kv blocks [i-nband+1 .. i] (causal window)
    idx = jnp.arange(nq)[:, None] - (nband - 1) + jnp.arange(nband)[None, :]
    valid_blk = idx >= 0
    idx_c = jnp.clip(idx, 0, nq - 1)
    k_band = kb[:, idx_c].reshape(B, nq, nband * block, KV, hd)
    v_band = vb[:, idx_c].reshape(B, nq, nband * block, KV, hd)
    kp_band = jnp.where(valid_blk[None, :, :, None], kp[:, idx_c], -1)
    kp_band = kp_band.reshape(B, nq, nband * block)

    # fold (B, nq) into the batch dim and reuse the dense kernel per band
    q2 = qb.reshape(B * nq, block, H, hd)
    k2 = k_band.reshape(B * nq, nband * block, KV, hd)
    v2 = v_band.reshape(B * nq, nband * block, KV, hd)
    qp2 = qp.reshape(B * nq, block)
    kp2 = kp_band.reshape(B * nq, nband * block)
    out = _dense_attend(q2, k2, v2, qp2, kp2, cfg)
    return out.reshape(B, Tp, H, hd)[:, :T]


def attend(q, k, v, q_pos, k_pos, cfg: AttnConfig, *, ctx=None, prefix="",
           chunked: Optional[bool] = None, kv_chunk: int = 1024,
           banded: bool = False):
    """Dispatch dense vs chunked vs banded. Dense supports quant sites;
    chunked is the long-context path (online softmax, no (T,S)
    materialization); banded computes only the sliding-window band
    (perf variant, requires cfg.window and self-attention layout)."""
    T, S = q.shape[1], k.shape[1]
    if (banded or chunked == "banded") and cfg.window is not None \
            and T == S and T > cfg.window:
        return _banded_attend(q, k, v, q_pos, k_pos, cfg)
    if chunked is None or chunked == "banded":
        chunked = (T * S > 4096 * 4096)
    if chunked:
        return _chunked_attend(q, k, v, q_pos, k_pos, cfg, kv_chunk)
    return _dense_attend(q, k, v, q_pos, k_pos, cfg, ctx, prefix)


# ---------------------------------------------------------------------------
# Quantized-cache write / decode paths
# ---------------------------------------------------------------------------

def _write_slots(pw, S, window):
    """Cache slot index per new token from its absolute position. Dead cells
    (position < 0: prompt pads and idle decode lanes) are routed out of
    bounds so the scatter DROPS them — the lane-safety contract behind the
    slot-insert prefill and the masked decode step (a cell with pw == -1
    neither attends nor writes, so co-resident lanes pass through
    bit-identical)."""
    base = pw % S if window else pw
    return jnp.where(pw >= 0, base, S)


def _quantize_kv_writes(cache, k_new, v_new, kvq):
    """(kq, ks, vq, vs) on the cache's own grid: packed int4 for the
    Quant4 subclasses, int8 otherwise (``kvq``: calibrated clip ranges)."""
    qfn = quantize_kv4 \
        if isinstance(cache, (Quant4KVCache, PagedQuant4KVCache)) \
        else quantize_kv
    if kvq is None:
        kq, ks = qfn(k_new)
        vq, vs = qfn(v_new)
    else:
        kq, ks = qfn(k_new, kvq.k_grid, kvq.k_zp)
        vq, vs = qfn(v_new, kvq.v_grid, kvq.v_zp)
    return kq, ks, vq, vs


def _write_kv(cache, k_new, v_new, pw, slots, bidx, kvq):
    """Scatter new K/V tokens into the cache slots. Quantized caches write
    quantize in place (per-head per-slot scales, ring-buffer slots included;
    int4 subclasses nibble-pack); ``kvq`` optionally carries the calibrated
    per-head clip ranges. The result is rebuilt as ``type(cache)`` so the
    bit-width-marker subclass survives the write.
    Out-of-bounds slots (dead cells, see _write_slots) are dropped."""
    if isinstance(cache, QuantKVCache):
        kq, ks, vq, vs = _quantize_kv_writes(cache, k_new, v_new, kvq)
        return type(cache)(
            k_q=cache.k_q.at[bidx, slots].set(kq, mode="drop"),
            v_q=cache.v_q.at[bidx, slots].set(vq, mode="drop"),
            k_s=cache.k_s.at[bidx, slots].set(ks, mode="drop"),
            v_s=cache.v_s.at[bidx, slots].set(vs, mode="drop"),
            pos=cache.pos.at[bidx, slots].set(pw, mode="drop"))
    return KVCache(
        k=cache.k.at[bidx, slots].set(k_new.astype(cache.k.dtype),
                                      mode="drop"),
        v=cache.v.at[bidx, slots].set(v_new.astype(cache.v.dtype),
                                      mode="drop"),
        pos=cache.pos.at[bidx, slots].set(pw, mode="drop"))


def _write_paged_kv(cache, k_new, v_new, pw, block_table, window, kvq):
    """Scatter new K/V tokens into the paged arena via the lane's block
    table. The logical cell is ``pw % S`` (the dense _write_slots wrap
    rule — global layers never wrap in a capacity-checked workload); its
    physical block comes from the lane's table. Dead cells (pw < 0) and
    unmapped blocks route to ``num_blocks`` so the scatter DROPS them —
    the same lane-safety contract as the dense path. Quantized arenas
    quantize in place exactly like _write_kv."""
    num_blocks, bs = cache.pos.shape
    s_cap = paged_capacity(block_table, bs, window)
    L = jnp.mod(jnp.maximum(pw, 0), s_cap)
    phys = jnp.take_along_axis(block_table, L // bs, axis=1)      # (B, T)
    dead = (pw < 0) | (phys < 0)
    phys = jnp.where(dead, num_blocks, phys)
    cell = L % bs
    if isinstance(cache, PagedQuantKVCache):
        kq, ks, vq, vs = _quantize_kv_writes(cache, k_new, v_new, kvq)
        return type(cache)(
            k_q=cache.k_q.at[phys, cell].set(kq, mode="drop"),
            v_q=cache.v_q.at[phys, cell].set(vq, mode="drop"),
            k_s=cache.k_s.at[phys, cell].set(ks, mode="drop"),
            v_s=cache.v_s.at[phys, cell].set(vs, mode="drop"),
            pos=cache.pos.at[phys, cell].set(pw, mode="drop"))
    return PagedKVCache(
        k=cache.k.at[phys, cell].set(k_new.astype(cache.k.dtype),
                                     mode="drop"),
        v=cache.v.at[phys, cell].set(v_new.astype(cache.v.dtype),
                                     mode="drop"),
        pos=cache.pos.at[phys, cell].set(pw, mode="drop"))


def paged_key_positions(block_table, q_pos, s_cap: int, block_size: int):
    """Derived key positions (B, nb*bs) of each lane's dense block view.

    A lane writes positions 0..q_pos contiguously (left-pad dead cells are
    dropped, not stored), so logical cell L holds position
    ``p = q_pos - ((q_pos - L) mod S)`` — reconstructed validity that can
    never read a reallocated block's stale cells, because stale cells
    derive p < 0 / L >= S. Idle lanes (q_pos = -1) derive all -1.
    """
    nb = -(-s_cap // block_size)
    L = jnp.arange(nb * block_size, dtype=jnp.int32)[None, :]
    qp = jnp.asarray(q_pos, jnp.int32).reshape(-1, 1)
    p = qp - jnp.mod(qp - L, s_cap)
    mapped = jnp.repeat(block_table[:, :nb] >= 0, block_size, axis=1)
    valid = (L < s_cap) & (p >= 0) & mapped
    return jnp.where(valid, p, -1)


def paged_gather_kv(cache, block_table, window, kvq=None):
    """Dense (B, nb*bs, KV, hd) f32 view of each lane's mapped blocks (the
    fallback read path when the paged kernels cannot express a site) —
    quantized arenas dequantize on gather. Pair with paged_key_positions
    to mask unwritten/stale cells."""
    num_blocks, bs = cache.pos.shape
    s_cap = paged_capacity(block_table, bs, window)
    nb = -(-s_cap // bs)
    phys = jnp.clip(block_table[:, :nb], 0, num_blocks - 1)

    def g(arena):
        x = arena[phys]                                # (B, nb, bs, ...)
        return x.reshape(x.shape[0], nb * bs, *arena.shape[2:])

    if isinstance(cache, PagedQuantKVCache):
        kq, vq = g(cache.k_q), g(cache.v_q)
        if isinstance(cache, PagedQuant4KVCache):
            from repro.kernels.nibble import unpack_nibbles
            hd = 2 * kq.shape[-1]
            kq = unpack_nibbles(kq, hd)
            vq = unpack_nibbles(vq, hd)
        kq = kq.astype(jnp.float32)
        vq = vq.astype(jnp.float32)
        if kvq is not None:
            kq = kq - jnp.asarray(kvq.k_zp, jnp.float32)[..., None]
            vq = vq - jnp.asarray(kvq.v_zp, jnp.float32)[..., None]
        return kq * g(cache.k_s)[..., None], vq * g(cache.v_s)[..., None]
    return g(cache.k).astype(jnp.float32), g(cache.v).astype(jnp.float32)


def reset_paged_lanes(cache, lane_mask, block_table):
    """Empty every block mapped by the masked lanes: ``pos`` -> -1 on those
    blocks' cells (payload bytes stay, as in reset_kv_lanes — an empty
    position masks the cell out of every read path). Works for unstacked
    (N, bs) and stacked (n_super, N, bs) arena layouts; the block table
    itself is host-owned (runtime.block_pool) and not touched here."""
    num_blocks = cache.pos.shape[-2]
    mask = jnp.asarray(lane_mask, bool)[:, None]
    blocks = jnp.where(mask & (block_table >= 0), block_table,
                       num_blocks).reshape(-1)
    if cache.pos.ndim == 3:           # stacked scan leaf (n_super, N, bs)
        pos = cache.pos.at[:, blocks].set(-1, mode="drop")
    else:
        pos = cache.pos.at[blocks].set(-1, mode="drop")
    return cache._replace(pos=pos)


def reset_kv_lanes(cache, lane_mask, batch_axis: int = 0):
    """Empty the masked batch lanes of a (Quant)KVCache for slot reuse:
    ``pos`` -> -1 on those lanes. Payload bytes (and int8 scales) are left in
    place — an empty position masks the slot out of every read path (dense /
    chunked / fused int8 kernel), so stale K/V from a retired request can
    never leak into the next occupant. ``lane_mask``: (B,) bool;
    ``batch_axis``: where B sits in ``pos`` (1 for stacked scan leaves)."""
    shape = [1] * cache.pos.ndim
    shape[batch_axis] = lane_mask.shape[0]
    m = jnp.reshape(lane_mask, shape)
    return cache._replace(pos=jnp.where(m, -1, cache.pos))


def _sites_active(ctx):
    if ctx is None or not ctx.act_state:
        return False
    from repro.core.calibration import Mode
    return ctx.mode in (Mode.APPLY, Mode.DEPLOY)


def _site_quant(ctx, site):
    """((scale, zp) (2,), qmin, qmax) for an in-kernel fake-quant site;
    (None, 0, 0) when the site is inactive; ``False`` when calibrated but not
    expressible by the kernel (per-channel / PEG) — the caller then falls
    back to dequantize-then-attend so the site still applies."""
    qp = ctx.act_state.get(site)
    acfg = ctx.policy.act_config(site)
    if qp is None or not acfg.enabled:
        return None, 0, 0
    if jnp.size(qp.scale) != 1 or qp.group_index is not None:
        return False
    sm = jnp.stack([jnp.reshape(jnp.asarray(qp.scale, jnp.float32), ()),
                    jnp.reshape(jnp.asarray(qp.zero_point, jnp.float32), ())])
    return sm, acfg.qmin, acfg.qmax


def _q_site_quant(ctx, prefix):
    """(scale, shifted zero-point, qmin, qmax, shift) of the calibrated
    per-tensor ``{prefix}/q`` site, or None. Re-using the site's own affine
    grid (shifted onto int8, zero-point corrected in-kernel) makes already
    fake-quantized queries enter the kernel EXACTLY — no second rounding."""
    qp = ctx.act_state.get(f"{prefix}/q")
    acfg = ctx.policy.act_config(f"{prefix}/q")
    if qp is None or not acfg.enabled or acfg.bits != 8 \
            or jnp.size(qp.scale) != 1:
        return None
    shift = 128 if acfg.qmin == 0 else 0
    return (jnp.reshape(jnp.asarray(qp.scale, jnp.float32), ()),
            jnp.reshape(jnp.asarray(qp.zero_point, jnp.float32), ()),
            acfg.qmin, acfg.qmax, shift)


def _decode_site_params(ctx, prefix):
    """The in-kernel softmax site operands shared by the dense and paged
    decode kernels: (sm_kwargs dict, q_site) — or None when a calibrated
    site is not per-tensor expressible (caller falls back)."""
    sm_quant = smo_quant = None
    sm_qmin = sm_qmax = smo_qmin = smo_qmax = 0
    q_site = None
    if _sites_active(ctx):
        sm = _site_quant(ctx, f"{prefix}/softmax_in")
        smo = _site_quant(ctx, f"{prefix}/softmax_out")
        if sm is False or smo is False:
            return None
        sm_quant, sm_qmin, sm_qmax = sm
        smo_quant, smo_qmin, smo_qmax = smo
        q_site = _q_site_quant(ctx, prefix)
    return (dict(sm_quant=sm_quant, sm_qmin=sm_qmin, sm_qmax=sm_qmax,
                 smo_quant=smo_quant, smo_qmin=smo_qmin,
                 smo_qmax=smo_qmax), q_site)


def _quantize_decode_q(qg, q_site):
    """(q_q int8, scales (B, KV, G), zero-points | None) for the decode
    kernels: the calibrated ``{prefix}/q`` site grid when available
    (already fake-quantized queries enter EXACTLY), else dynamic symmetric
    per-head quantization."""
    B, KV, G, _ = qg.shape
    if q_site is not None:
        # re-use the site's affine grid (shifted to int8): already
        # fake-quantized queries enter the kernel exactly
        s_q, z_q, qmin, qmax, shift = q_site
        q_q = (jnp.clip(jnp.round(qg / s_q) + z_q, qmin, qmax)
               - shift).astype(jnp.int8)
        return q_q, jnp.full((B, KV, G), s_q), jnp.full((B, KV, G),
                                                        z_q - shift)
    amax = jnp.max(jnp.abs(qg), axis=-1)
    qs = jnp.maximum(amax / 127.0, jnp.finfo(jnp.float32).tiny)
    q_q = jnp.clip(jnp.round(qg / qs[..., None]), -127, 127).astype(jnp.int8)
    return q_q, qs, None


def _kv_zero_points(kvq, B, KV):
    if kvq is None:
        return None, None
    return (jnp.broadcast_to(jnp.asarray(kvq.k_zp, jnp.float32), (B, KV)),
            jnp.broadcast_to(jnp.asarray(kvq.v_zp, jnp.float32), (B, KV)))


def _quant_decode_attend(q, cache: QuantKVCache, q_pos, cfg: AttnConfig,
                         ctx, prefix, kvq=None):
    """Decode step through the fused int8 attention kernel.

    q: (B, 1, H, hd) (already RoPE'd / site-quantized); queries enter on
    the calibrated site grid when available (exact), else dynamically
    quantized per head; the attention scale is folded into the q scales.
    ``kvq``: the deploy.KVQuant the cache was written with (its static
    per-head zero-points are corrected in-kernel). Returns (B, 1, H, hd) in
    q.dtype, or None when the kernel cannot express the site (the caller
    dequantizes and takes the flash path — the simulate-path fallback rule).
    """
    if not cfg.causal:
        return None           # kernel masks causally; _mask handles the rest
    site = _decode_site_params(ctx, prefix)
    if site is None:
        return None
    sm_kwargs, q_site = site
    from repro.kernels import ops as kops
    B, T, H, hd = q.shape
    KV, G = cfg.num_kv_heads, cfg.q_groups
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    q_q, qs, qz = _quantize_decode_q(qg, q_site)
    kz, vz = _kv_zero_points(kvq, B, KV)
    out = kops.int8_attend_decode(
        q_q, qs * cfg.scale, cache.k_q, cache.k_s, cache.v_q, cache.v_s,
        cache.pos, q_pos[:, 0], q_zp=qz, k_zp=kz, v_zp=vz,
        window=cfg.window,
        logit_softcap=cfg.logit_softcap,
        kv_bits=4 if isinstance(cache, Quant4KVCache) else 8, **sm_kwargs)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def _paged_quant_decode_attend(q, cache: PagedQuantKVCache, block_table,
                               q_pos, cfg: AttnConfig, ctx, prefix,
                               kvq=None):
    """Decode step through the paged int8 attention kernel — the
    :func:`_quant_decode_attend` twin over a block-paged arena (same site
    grids, zero-point corrections and fallback rule; block gather + the
    derived-position mask happen in-kernel)."""
    if not cfg.causal:
        return None
    site = _decode_site_params(ctx, prefix)
    if site is None:
        return None
    sm_kwargs, q_site = site
    from repro.kernels import ops as kops
    B, T, H, hd = q.shape
    KV, G = cfg.num_kv_heads, cfg.q_groups
    bs = cache.pos.shape[1]
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    q_q, qs, qz = _quantize_decode_q(qg, q_site)
    kz, vz = _kv_zero_points(kvq, B, KV)
    out = kops.paged_int8_attend_decode(
        q_q, qs * cfg.scale, cache.k_q, cache.k_s, cache.v_q, cache.v_s,
        block_table, q_pos[:, 0],
        s_cap=paged_capacity(block_table, bs, cfg.window),
        q_zp=qz, k_zp=kz, v_zp=vz, window=cfg.window,
        logit_softcap=cfg.logit_softcap,
        kv_bits=4 if isinstance(cache, PagedQuant4KVCache) else 8,
        **sm_kwargs)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def _paged_decode_attend(q, cache: PagedKVCache, block_table, q_pos,
                         cfg: AttnConfig, ctx, prefix):
    """Decode step through the paged bf16/f32 attention kernel. Applies
    the softmax_in/softmax_out sites in-kernel when they are per-tensor
    (matching _dense_attend's placement); returns None when a site is
    calibrated per-channel/PEG — the caller gathers the lane's blocks and
    takes the dense path so the site still applies exactly."""
    if not cfg.causal:
        return None
    site = _decode_site_params(ctx, prefix)
    if site is None:
        return None
    sm_kwargs, _ = site
    from repro.kernels import ops as kops
    B, T, H, hd = q.shape
    KV, G = cfg.num_kv_heads, cfg.q_groups
    bs = cache.pos.shape[1]
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) * cfg.scale
    out = kops.paged_attend_decode(
        qg, cache.k, cache.v, block_table, q_pos[:, 0],
        s_cap=paged_capacity(block_table, bs, cfg.window),
        window=cfg.window, logit_softcap=cfg.logit_softcap, **sm_kwargs)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block with projections + cache handling
# ---------------------------------------------------------------------------

def _prev_positions(positions):
    """Per-lane position of the last token BEFORE this chunk (chunked
    prefill): one less than the lane's first live position, or -1 for lanes
    whose rows are all dead (idle lanes, and lanes starting chunk 1)."""
    live = positions >= 0
    big = jnp.where(live, positions, jnp.iinfo(jnp.int32).max)
    start = jnp.min(big, axis=1)
    return jnp.where(jnp.any(live, axis=1), start - 1, -1)


def attention_block(p, x, positions, cfg: AttnConfig, *, ctx=None,
                    prefix="attn", cache: Optional[KVCache] = None,
                    chunked: Optional[bool] = None, block_table=None,
                    append: bool = False
                    ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """x: (B, T, D). p: dict with wq (D,H*hd), wk/wv (D,KV*hd), wo (H*hd,D).

    Training/prefill: cache=None or empty cache to fill.
    Decode: T == 1 (or small), cache holds past KV; returns updated cache.

    Paged caches (PagedKVCache / PagedQuantKVCache) additionally need
    ``block_table`` (B, max_blocks) int32 — writes scatter through it and
    decode runs the paged kernels (gather + derived-position mask
    in-kernel).

    ``append=True`` is the chunked-prefill contract: the T tokens are ONE
    chunk appended at each lane's current position, so queries attend over
    the pre-write cache contents (the lane's earlier chunks) PLUS the fresh
    chunk, instead of over the fresh tokens alone. Earlier chunks are read
    back exactly as decode would read them (quantized caches dequantize on
    the calibrated grid), and the chunk's own writes keep the dead-cell
    scatter contract, so co-resident lanes pass through bit-identical per
    chunk.

    DEPLOY: ``x`` may arrive as a QTensor (int8 LN output) with packed
    projection weights — QKV and Wo then run on the int8 matmul kernel.
    """
    from repro.core import deploy as deploy_lib
    x_int8 = isinstance(x, deploy_lib.QTensor)
    B, T, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def w(name):
        from repro.models.common import resolve_weight
        wmat = resolve_weight(p[name])
        return ctx.weight(f"{prefix}/{name}", wmat) if ctx is not None else wmat

    if x_int8:
        q = deploy_lib.matmul(x, p["wq"]).reshape(B, T, H, hd)
        k = deploy_lib.matmul(x, p["wk"]).reshape(B, T, KV, hd)
        v = deploy_lib.matmul(x, p["wv"]).reshape(B, T, KV, hd)
    else:
        q = (x @ w("wq")).reshape(B, T, H, hd)
        k = (x @ w("wk")).reshape(B, T, KV, hd)
        v = (x @ w("wv")).reshape(B, T, KV, hd)
    if "q_norm" in p:   # qwen3-style per-head QK norm
        from repro.models.common import rms_norm
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.rope_theta is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if ctx is not None:
        q = ctx.act(f"{prefix}/q", q)
        k = ctx.act(f"{prefix}/k", k)
        v = ctx.act(f"{prefix}/v", v)

    new_cache = None
    out = None
    positions = jnp.broadcast_to(positions, (B, T))
    if cache is not None:
        paged = isinstance(cache, (PagedKVCache, PagedQuantKVCache))
        quantized = isinstance(cache, (QuantKVCache, PagedQuantKVCache))
        # int4 caches read their clip ranges from the separate kv4 site
        # (present only when k/v were calibrated at 4 bits) — falling back
        # to dynamic per-slot int4 grids when it is absent.
        kv_site = f"{prefix}/kv4" \
            if isinstance(cache, (Quant4KVCache, PagedQuant4KVCache)) \
            else f"{prefix}/kv"
        kvq = ctx.deploy_act(kv_site) \
            if (quantized and ctx is not None) else None
        if paged:
            if block_table is None:
                raise ValueError("paged KV cache needs the block_table "
                                 "threaded from the whole-model cache")
            S = paged_capacity(block_table, cache.pos.shape[1], cfg.window)
        else:
            S = cache.pos.shape[1]
        bidx = jnp.arange(B)[:, None]
        if T > 1:
            # Prefill: attend over the fresh K/V (window enforced by mask),
            # then write the last min(T, S) tokens into the cache. In
            # append mode (chunked prefill) the pre-write cache view is
            # snapshotted first: earlier chunks join the attended keys, and
            # ring slots the chunk overwrites still show their OLD occupant
            # (position p - S), which is exactly what earlier queries in
            # the chunk may still attend within their window.
            if append:
                if paged:
                    prev = _prev_positions(positions)
                    k_past, v_past = paged_gather_kv(cache, block_table,
                                                     cfg.window, kvq)
                    kpos_past = paged_key_positions(block_table, prev, S,
                                                    cache.pos.shape[1])
                elif quantized:
                    k_past, v_past = dequantize_kv(cache, kvq)
                    kpos_past = cache.pos
                else:
                    k_past, v_past, kpos_past = cache.k, cache.v, cache.pos
            keep = min(T, S)
            kw, vw, pw = k[:, -keep:], v[:, -keep:], positions[:, -keep:]
            if paged:
                new_cache = _write_paged_kv(cache, kw, vw, pw, block_table,
                                            cfg.window, kvq)
            else:
                slots = _write_slots(pw, S, cfg.window)
                new_cache = _write_kv(cache, kw, vw, pw, slots, bidx, kvq)
            if append:
                k_att = jnp.concatenate([k_past.astype(k.dtype), k], axis=1)
                v_att = jnp.concatenate([v_past.astype(v.dtype), v], axis=1)
                kpos_att = jnp.concatenate([kpos_past, positions], axis=1)
            else:
                k_att, v_att, kpos_att = k, v, positions
        elif paged:
            # Paged decode: write the new token through the block table,
            # attend through the paged kernel (site fallback: gather the
            # lane's blocks into a dense view + derived positions).
            new_cache = _write_paged_kv(cache, k, v, positions, block_table,
                                        cfg.window, kvq)
            if quantized:
                out = _paged_quant_decode_attend(q, new_cache, block_table,
                                                 positions, cfg, ctx,
                                                 prefix, kvq)
            else:
                out = _paged_decode_attend(q, new_cache, block_table,
                                           positions, cfg, ctx, prefix)
            if out is None:
                k_att, v_att = paged_gather_kv(new_cache, block_table,
                                               cfg.window, kvq)
                kpos_att = paged_key_positions(block_table, positions[:, 0],
                                               S, cache.pos.shape[1])
        else:
            # Decode: write the new token, attend over the cache.
            slots = _write_slots(positions, S, cfg.window)
            new_cache = _write_kv(cache, k, v, positions, slots, bidx, kvq)
            if quantized:
                out = _quant_decode_attend(q, new_cache, positions, cfg,
                                           ctx, prefix, kvq)
                if out is None:       # kernel can't express: dequant + flash
                    k_att, v_att = dequantize_kv(new_cache, kvq)
                    kpos_att = new_cache.pos
            else:
                k_att, v_att, kpos_att = (new_cache.k, new_cache.v,
                                          new_cache.pos)
    else:
        k_att, v_att = k, v
        kpos_att = positions

    if out is None:
        out = attend(q, k_att.astype(q.dtype), v_att.astype(q.dtype),
                     jnp.broadcast_to(positions, (B, T)), kpos_att, cfg,
                     ctx=ctx, prefix=prefix, chunked=chunked)
    out2d = out.reshape(B, T, H * hd)
    if x_int8:
        wo_aq = ctx.deploy_act(f"{prefix}/wo_in")
        if ctx.telemetry is not None:
            ctx.telem_site(f"{prefix}/wo_in",
                           deploy_lib.site_stats(out2d, wo_aq))
        out = deploy_lib.matmul(deploy_lib.quantize_act(out2d, wo_aq),
                                p["wo"])
    else:
        if ctx is not None:
            out2d = ctx.act_in(f"{prefix}/wo_in", out2d)
        out = out2d @ w("wo")
    if ctx is not None:
        out = ctx.act(f"{prefix}/ctx_out", out)
    return out, new_cache


def init_attention_params(key, d_model: int, cfg: AttnConfig,
                          dtype=jnp.float32, qk_norm: bool = False):
    from repro.models.common import dense_init, split_keys
    k1, k2, k3, k4 = split_keys(key, 4)
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {"wq": dense_init(k1, d_model, H * hd, dtype),
         "wk": dense_init(k2, d_model, KV * hd, dtype),
         "wv": dense_init(k3, d_model, KV * hd, dtype),
         "wo": dense_init(k4, H * hd, d_model, dtype)}
    if qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p
