"""Pallas TPU kernel: LayerNorm / RMSNorm fused with quantization.

The paper's Fig.-4 rewriting puts a quantizer directly after each LayerNorm
(the FFN-input path). On TPU this is a single VPU pass per token row: compute
the row statistics, normalize+affine, quantize — the normalized f32
intermediate never leaves VMEM.

Variants (x2 norms, x2 emit modes):
  * ln_fake_quant / ln_quantize    — LayerNorm (mean/var, gamma/beta)
  * rms_fake_quant / rms_quantize  — RMSNorm (no mean subtraction; the
    affine is (1 + gamma) matching repro.models.common.rms_norm)

``*_fake_quant`` returns quant->dequant f32 (simulation / QAT forward);
``*_quantize`` emits the int8 payload (deployment; feeds int8_matmul[_peg]).

Scales / zero-points are traced (G,) vectors: G == 1 is the per-tensor case,
G > 1 quantizes per contiguous embedding group (the paper's PEG scheme with
the range-based permutation already folded into gamma/beta and the adjacent
weights, so groups are contiguous lane-aligned spans).

Grid: (T / block_t,). Block: (block_t, d) — a full embedding row per token so
the reduction stays in-block (d up to ~8k fits VMEM easily:
256 x 8192 x 4B = 8 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _norm_quant_kernel(g_ref, b_ref, s_ref, z_ref, x_ref, o_ref, *,
                       kind, emit, qmin, qmax, eps):
    x = x_ref[...].astype(jnp.float32)
    if kind == "ln":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]
    else:                                   # rms: x * rsqrt(E[x^2]) * (1 + g)
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + eps) * (1.0 + g_ref[...])
    d = x.shape[-1]
    g = s_ref.shape[0]
    s = jnp.repeat(s_ref[...], d // g)[None, :]
    z = jnp.repeat(z_ref[...], d // g)[None, :]
    q = jnp.clip(jnp.round(y / s) + z, qmin, qmax)
    if emit:
        o_ref[...] = q.astype(o_ref.dtype)
    else:
        o_ref[...] = ((q - z) * s).astype(o_ref.dtype)


def _call(x, gamma, beta, scale, zp, *, kind, emit, qmin, qmax, eps,
          out_dtype, block_t, interpret):
    t, d = x.shape
    bt = min(block_t, t)
    assert t % bt == 0
    scale = jnp.atleast_1d(jnp.asarray(scale, jnp.float32))
    zp = jnp.atleast_1d(jnp.asarray(zp, jnp.float32))
    g = scale.shape[0]
    assert d % g == 0, "group count must divide the embedding dim"
    if beta is None:
        beta = jnp.zeros((d,), jnp.float32)
    kernel = functools.partial(_norm_quant_kernel, kind=kind, emit=emit,
                               qmin=qmin, qmax=qmax, eps=eps)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((t, d), out_dtype),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((g,), lambda i: (0,)),
            pl.BlockSpec((g,), lambda i: (0,)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        interpret=interpret,
    )(gamma.astype(jnp.float32), beta.astype(jnp.float32), scale, zp, x)


def ln_fake_quant(x, gamma, beta, scale, zp, *, qmin: int, qmax: int,
                  eps: float = 1e-6, block_t: int = 256,
                  interpret: bool = False):
    """x: (T, d) -> LN + fake-quant, same dtype."""
    return _call(x, gamma, beta, scale, zp, kind="ln", emit=False, qmin=qmin,
                 qmax=qmax, eps=eps, out_dtype=x.dtype, block_t=block_t,
                 interpret=interpret)


def ln_quantize(x, gamma, beta, scale, zp, *, qmin: int, qmax: int,
                eps: float = 1e-6, out_dtype=jnp.int8, block_t: int = 256,
                interpret: bool = False):
    """x: (T, d) -> LN + int8 emit."""
    return _call(x, gamma, beta, scale, zp, kind="ln", emit=True, qmin=qmin,
                 qmax=qmax, eps=eps, out_dtype=out_dtype, block_t=block_t,
                 interpret=interpret)


def rms_fake_quant(x, gamma, scale, zp, *, qmin: int, qmax: int,
                   eps: float = 1e-6, block_t: int = 256,
                   interpret: bool = False):
    """x: (T, d) -> RMSNorm + fake-quant, same dtype."""
    return _call(x, gamma, None, scale, zp, kind="rms", emit=False, qmin=qmin,
                 qmax=qmax, eps=eps, out_dtype=x.dtype, block_t=block_t,
                 interpret=interpret)


def rms_quantize(x, gamma, scale, zp, *, qmin: int, qmax: int,
                 eps: float = 1e-6, out_dtype=jnp.int8, block_t: int = 256,
                 interpret: bool = False):
    """x: (T, d) -> RMSNorm + int8 emit."""
    return _call(x, gamma, None, scale, zp, kind="rms", emit=True, qmin=qmin,
                 qmax=qmax, eps=eps, out_dtype=out_dtype, block_t=block_t,
                 interpret=interpret)
