"""8-bit Adam — the paper's grouped-quantization machinery applied to
optimizer state (beyond-paper extension; cf. Dettmers et al. block-wise
8-bit optimizers).

Large parameter leaves store their Adam moments as int8 with one f32 scale
per row of the last dim (a shard-alignment-friendly analogue of block-wise
scaling: the scale tree has the SAME sharding as the parameter minus its
last axis, so FSDP/TP layouts carry over unchanged and no resharding
collectives appear in the update). First moment: symmetric int8; second
moment (non-negative): [0,127] grid. Small leaves (norms, biases) keep
plain f32 moments — their memory is negligible and their dynamics matter.

Memory per big-leaf parameter: 2 x (1 + 4/last_dim) bytes instead of 8 —
the difference between 235B/314B training fitting 16 GB/chip or not
(EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

QUANT_MIN_ELEMS = 1 << 20       # leaves smaller than this keep f32 moments
QUANT_MIN_LASTDIM = 256


class QAdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any     # per leaf: {"q": int8 param-shaped, "s": f32 rows} or f32
    nu: Any


def _is_mdict(x):
    return isinstance(x, dict) and "q" in x


def _quantizable(p) -> bool:
    return p.ndim >= 2 and p.size >= QUANT_MIN_ELEMS and \
        p.shape[-1] >= QUANT_MIN_LASTDIM


def _quant(x, *, symmetric: bool):
    amax = jnp.max(jnp.abs(x) if symmetric else x, axis=-1)
    s = jnp.maximum(amax / 127.0, 1e-20)
    q = jnp.round(x / s[..., None])
    q = jnp.clip(q, -127 if symmetric else 0, 127).astype(jnp.int8)
    return {"q": q, "s": s}


def _dequant(m):
    return m["q"].astype(jnp.float32) * m["s"][..., None]


def qadam_init(params) -> QAdamState:
    def z(p):
        if _quantizable(p):
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "s": jnp.full(p.shape[:-1], 1e-20, jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)
    return QAdamState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(z, params),
                      nu=jax.tree.map(z, params))


def qadam_update(grads, state: QAdamState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                 grad_scale=None):
    """Same contract as adam_update, int8 moment storage for big leaves."""
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    b1t = 1.0 - b1 ** step.astype(jnp.float32)
    b2t = 1.0 - b2 ** step.astype(jnp.float32)

    def leaf(g, m, v, p):
        g = g.astype(jnp.float32)
        if grad_scale is not None:
            g = g * grad_scale
        m_f = _dequant(m) if _is_mdict(m) else m
        v_f = _dequant(v) if _is_mdict(v) else v
        m2 = b1 * m_f + (1 - b1) * g
        v2 = b2 * v_f + (1 - b2) * jnp.square(g)
        u = (m2 / b1t) / (jnp.sqrt(v2 / b2t) + eps)
        m_out = _quant(m2, symmetric=True) if _is_mdict(m) else m2
        v_out = _quant(v2, symmetric=False) if _is_mdict(v) else v2
        return m_out, v_out, (-lr_t * u).astype(p.dtype)

    out = jax.tree.map(leaf, grads, state.mu, state.nu, params,
                       is_leaf=_is_mdict)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(2), QAdamState(step=step, mu=pick(0), nu=pick(1))


def qadam_shardings(param_shardings):
    """Moment shardings mirror the parameters; row-scales drop the last
    axis of the spec."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def for_leaf(sh):
        spec = sh.spec
        # scale spec: param spec without its last entry
        entries = tuple(spec) if len(spec) else ()
        s_spec = P(*entries[:-1]) if entries else P()
        return {"q": sh, "s": NamedSharding(sh.mesh, s_spec)}
    return for_leaf
