from repro.parallel.sharding import (batch_spec, cache_spec_for, constrain,
                                     make_batch_shardings,
                                     make_cache_shardings, make_dist,
                                     make_opt_shardings, make_param_shardings,
                                     make_param_specs, param_spec_for)
