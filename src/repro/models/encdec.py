"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder consumes precomputed frame embeddings (the audio frontend is a stub
per the assignment); decoder is a standard causal transformer with
cross-attention into the encoder memory. Learned absolute positions,
LayerNorm, pre-norm blocks. Layers are scanned (stacked params).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ffn as ffn_lib
from repro.models.attention import (AttnConfig, KVCache, attend,
                                    attention_block, init_attention_params,
                                    init_kv_cache)
from repro.models.common import (cross_entropy, embed_init, layer_norm,
                                 split_keys)


def _acfg(cfg: ModelConfig, causal: bool) -> AttnConfig:
    return AttnConfig(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                      head_dim=cfg.hd, causal=causal, rope_theta=None)


def _ln(p, x):
    return layer_norm(x, p["g"], p["b"])


def _init_ln(d, dtype):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _init_layer(cfg: ModelConfig, key, dtype, cross: bool):
    ks = split_keys(key, 3)
    p = {"ln1": _init_ln(cfg.d_model, dtype),
         "attn": init_attention_params(ks[0], cfg.d_model, _acfg(cfg, True),
                                       dtype),
         "ln2": _init_ln(cfg.d_model, dtype),
         "ffn": ffn_lib.init_mlp_params(ks[1], cfg.d_model, cfg.d_ff, dtype)}
    if cross:
        p["ln_x"] = _init_ln(cfg.d_model, dtype)
        p["xattn"] = init_attention_params(ks[2], cfg.d_model,
                                           _acfg(cfg, False), dtype)
    return p


def init_params(cfg: ModelConfig, key, *, dtype=jnp.bfloat16):
    ks = split_keys(key, cfg.encoder_layers + cfg.num_layers + 4)
    enc = [_init_layer(cfg, ks[i], dtype, cross=False)
           for i in range(cfg.encoder_layers)]
    dec = [_init_layer(cfg, ks[cfg.encoder_layers + i], dtype, cross=True)
           for i in range(cfg.num_layers)]
    return {
        "embed": embed_init(ks[-1], cfg.vocab_size, cfg.d_model, dtype),
        "enc_pos": embed_init(ks[-2], cfg.max_seq_len, cfg.d_model, dtype),
        "dec_pos": embed_init(ks[-3], cfg.max_seq_len, cfg.d_model, dtype),
        "enc_scan": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_scan": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": _init_ln(cfg.d_model, dtype),
        "final_norm": _init_ln(cfg.d_model, dtype),
    }


def _cross_attention(p, x, memory, mem_pos, cfg: ModelConfig, ctx=None,
                     prefix="xattn", cached_kv: Optional[Tuple] = None):
    """x: (B,T,D) queries; memory: (B,S,D) encoder output (or None if
    cached_kv given)."""
    B, T, D = x.shape
    acfg = _acfg(cfg, causal=False)
    H, KV, hd = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim

    def w(name):
        return ctx.weight(f"{prefix}/{name}", p[name]) if ctx is not None else p[name]

    q = (x @ w("wq")).reshape(B, T, H, hd)
    if cached_kv is not None:
        k, v = cached_kv
    else:
        k = (memory @ w("wk")).reshape(B, -1, KV, hd)
        v = (memory @ w("wv")).reshape(B, -1, KV, hd)
    q_pos = jnp.zeros((B, T), jnp.int32)       # non-causal: positions unused
    out = attend(q, k.astype(q.dtype), v.astype(q.dtype), q_pos, mem_pos,
                 acfg, ctx=ctx, prefix=prefix)
    return out.reshape(B, T, H * hd) @ w("wo"), (k, v)


def encode(cfg: ModelConfig, params, frames, *, ctx=None):
    """frames: (B, S, D) stub frontend embeddings -> encoder memory."""
    B, S, D = frames.shape
    pos = jnp.arange(S, dtype=jnp.int32)
    x = frames.astype(params["enc_pos"].dtype) + params["enc_pos"][pos][None]
    if ctx is not None:
        x = ctx.act("embed/sum", x)
    positions = jnp.broadcast_to(pos, (B, S))
    acfg = _acfg(cfg, causal=False)

    def layer(x, p):
        h = _ln(p["ln1"], x)
        a, _ = attention_block(p["attn"], h, positions, acfg, ctx=ctx,
                               prefix="enc/attn")
        x = x + a
        h = _ln(p["ln2"], x)
        if ctx is not None:
            h = ctx.act("enc/ffn_in", h)
        f = ffn_lib.mlp(p["ffn"], h, activation=cfg.act, ctx=ctx,
                        prefix="enc/ffn")
        if ctx is not None:
            f = ctx.act("enc/ffn_out", f)
        x = x + f
        if ctx is not None:
            x = ctx.act("enc/residual_ffn", x)
        return x, None

    x, _ = jax.lax.scan(layer, x, params["enc_scan"])
    return _ln(params["enc_norm"], x)


class DecoderCache(NamedTuple):
    self_kv: Any                  # stacked KVCache (L leading)
    cross_k: jnp.ndarray          # (L, B, S, KV, hd)
    cross_v: jnp.ndarray
    mem_pos: jnp.ndarray          # (B, S)


def init_decoder_cache(cfg: ModelConfig, batch: int, max_len: int,
                       mem_len: int, dtype=jnp.bfloat16) -> DecoderCache:
    L = cfg.num_layers
    kv = [init_kv_cache(batch, max_len, _acfg(cfg, True), dtype)
          for _ in range(L)]
    return DecoderCache(
        self_kv=jax.tree.map(lambda *xs: jnp.stack(xs), *kv),
        cross_k=jnp.zeros((L, batch, mem_len, cfg.num_kv_heads, cfg.hd), dtype),
        cross_v=jnp.zeros((L, batch, mem_len, cfg.num_kv_heads, cfg.hd), dtype),
        mem_pos=jnp.zeros((batch, mem_len), jnp.int32))


def decode(cfg: ModelConfig, params, tokens, memory=None, *, positions=None,
           cache: Optional[DecoderCache] = None, ctx=None):
    """Decoder forward. Training: memory given, cache None, full teacher
    forcing. Serving: cache carries self-KV + projected cross-KV."""
    B, T = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = jnp.take(params["embed"], tokens, axis=0) + \
        jnp.take(params["dec_pos"], positions, axis=0)
    if ctx is not None:
        x = ctx.act("dec/embed_sum", x)
    acfg = _acfg(cfg, causal=True)
    if memory is not None:
        S = memory.shape[1]
        mem_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    else:
        mem_pos = cache.mem_pos

    def layer(x, slices):
        p, self_c, xk, xv = slices
        h = _ln(p["ln1"], x)
        a, new_self = attention_block(p["attn"], h, positions, acfg, ctx=ctx,
                                      prefix="dec/attn", cache=self_c)
        x = x + a
        if ctx is not None:
            x = ctx.act("dec/residual_attn", x)
        h = _ln(p["ln_x"], x)
        if memory is not None:
            a, (xk, xv) = _cross_attention(p["xattn"], h, memory, mem_pos,
                                           cfg, ctx=ctx)
        else:
            a, _ = _cross_attention(p["xattn"], h, None, mem_pos, cfg,
                                    ctx=ctx, cached_kv=(xk, xv))
        x = x + a
        h = _ln(p["ln2"], x)
        if ctx is not None:
            h = ctx.act("dec/ffn_in", h)
        f = ffn_lib.mlp(p["ffn"], h, activation=cfg.act, ctx=ctx,
                        prefix="dec/ffn")
        if ctx is not None:
            f = ctx.act("dec/ffn_out", f)
        x = x + f
        if ctx is not None:
            x = ctx.act("dec/residual_ffn", x)
        return x, (new_self, xk, xv)

    L = cfg.num_layers
    if cache is not None:
        xs = (params["dec_scan"], cache.self_kv, cache.cross_k, cache.cross_v)
    else:
        dummy_k = jnp.zeros((L, B, 1, cfg.num_kv_heads, cfg.hd), x.dtype)
        xs = (params["dec_scan"],
              jax.tree.map(lambda t: t, _none_cache(cfg, L, B, x.dtype)),
              dummy_k, dummy_k)

    def scan_fn(x, sl):
        p, self_c, xk, xv = sl
        self_c = self_c if cache is not None else None
        x, (new_self, nxk, nxv) = layer(x, (p, self_c, xk, xv))
        if cache is None:
            new_self = _dummy_kv(cfg, B, x.dtype)
        return x, (new_self, nxk, nxv)

    x, (new_self, new_xk, new_xv) = jax.lax.scan(scan_fn, x, xs)
    logits = _ln(params["final_norm"], x) @ params["embed"].T.astype(x.dtype)
    if ctx is not None:
        logits = ctx.act("head/logits", logits)
    new_cache = None
    if cache is not None:
        new_cache = DecoderCache(self_kv=new_self, cross_k=new_xk,
                                 cross_v=new_xv, mem_pos=mem_pos)
    return logits, new_cache


def _dummy_kv(cfg, B, dtype):
    return init_kv_cache(B, 1, _acfg(cfg, True), dtype)


def _none_cache(cfg, L, B, dtype):
    kv = [_dummy_kv(cfg, B, dtype) for _ in range(L)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *kv)


def train_loss(cfg: ModelConfig, params, batch, *, ctx=None, dist=None,
               remat: bool = True):
    """batch: {frames (B,S,D), tokens (B,T), labels (B,T)}."""
    memory = encode(cfg, params, batch["frames"], ctx=ctx)
    logits, _ = decode(cfg, params, batch["tokens"], memory, ctx=ctx)
    return cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


def prefill_from_encoder(cfg: ModelConfig, params, frames, bos_tokens,
                         max_decode_len: int, *, ctx=None):
    """Encode + project cross-KV + first decoder step."""
    memory = encode(cfg, params, frames, ctx=ctx)
    B, S, _ = memory.shape
    cache = init_decoder_cache(cfg, B, max_decode_len, S, memory.dtype)
    cache = cache._replace(
        mem_pos=jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)))
    pos0 = jnp.zeros((B, 1), jnp.int32)
    logits, cache = decode(cfg, params, bos_tokens, memory=memory,
                           positions=pos0, cache=cache, ctx=ctx)
    return logits, cache


def decode_step(cfg: ModelConfig, params, tokens, pos,
                cache: DecoderCache, *, ctx=None, dist=None):
    return decode(cfg, params, tokens, positions=pos, cache=cache, ctx=ctx)
