"""Quantization context + static-range calibration (paper §2 "static range
estimation", §5 experimental setup).

Models thread a ``QuantCtx`` through their forward pass and call
``ctx.act(site, x)`` at every activation-quantization site and
``ctx.weight(site, w)`` on every weight read. The ctx has four modes:

  OFF      — passthrough (FP32 baseline);
  COLLECT  — record range statistics (and, for MSE/PEG, the calibration
             tensors) per site; returns x unchanged;
  APPLY    — simulated quantization with the frozen ``QuantState``;
  QAT      — simulated quantization with *learnable* scale/offset taken from a
             trainable pytree (see qat.py);
  DEPLOY   — true fixed-point execution: models route deployable matmuls
             through the Pallas int8 kernels (repro.core.deploy) using
             ``ctx.deploy_acts``; every other site falls back to APPLY
             fake-quant so deployed and simulated runs stay comparable.

Matmul-INPUT sites (``{L}/attn_in``, ``{L}/attn/wo_in``) are tapped through
``ctx.act_in``: they are only observed during COLLECT when
``collect_inputs=True`` (the deploy calibration sets it) and only quantize in
APPLY/DEPLOY when calibrated params exist — legacy simulate-only flows are
byte-for-byte unchanged.

This is a functional design: COLLECT mutates only the Python-side dict of the
ctx object created inside the calling function, whose values are returned as
jit outputs — safe under tracing.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import peg as peg_lib
from repro.core.quant_config import (Granularity, QuantizationPolicy,
                                     QuantizerConfig, RangeEstimator)
from repro.core.quantizer import QuantParams, fake_quant, telemetry_stats
from repro.core.range_estimation import (RangeState, estimate_weight_params,
                                         finalize, init_range_state, observe)


class Mode(enum.Enum):
    OFF = "off"
    COLLECT = "collect"
    APPLY = "apply"
    QAT = "qat"
    DEPLOY = "deploy"


# QuantState: site name -> QuantParams (a pytree usable inside jit).
QuantState = Dict[str, QuantParams]


@dataclasses.dataclass
class QuantCtx:
    policy: QuantizationPolicy
    mode: Mode = Mode.OFF
    act_state: Optional[QuantState] = None       # APPLY/QAT
    weight_state: Optional[QuantState] = None    # APPLY (PTQ-frozen weights)
    qat_params: Optional[dict] = None            # QAT learnable (see qat.py)
    # COLLECT outputs:
    range_states: Dict[str, RangeState] = dataclasses.field(default_factory=dict)
    calib_tensors: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)
    keep_tensors: bool = True                    # needed for MSE / PEG finalize
    # PEG group assignment per site (natural layout), set by the pipeline:
    group_indices: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    # DEPLOY: site -> repro.core.deploy.ActQuant for matmul-input sites.
    deploy_acts: Optional[dict] = None
    # COLLECT: also observe the matmul-input sites (deploy calibration).
    collect_inputs: bool = False
    # Quant-health telemetry (runtime/telemetry.py): when a dict, every
    # APPLY/DEPLOY fake-quant site accumulates a fixed-shape
    # [n_clipped, n_total, amax, cal_range] vector keyed by site — the step
    # builders return it as an extra jit output. None (the default) is the
    # byte-identical disabled path.
    telemetry: Optional[Dict[str, jnp.ndarray]] = None

    # -- model-facing API ---------------------------------------------------

    def telem_site(self, site: str, vec: jnp.ndarray) -> None:
        """Accumulate one site's [clipped, total, amax, range] vector
        (counts add; amax/range take the max — a site hit repeatedly in one
        trace, e.g. per superblock, folds correctly)."""
        if self.telemetry is None:
            return
        prev = self.telemetry.get(site)
        if prev is not None:
            vec = jnp.stack([prev[0] + vec[0], prev[1] + vec[1],
                             jnp.maximum(prev[2], vec[2]),
                             jnp.maximum(prev[3], vec[3])])
        self.telemetry[site] = vec

    def act(self, site: str, x: jnp.ndarray) -> jnp.ndarray:
        cfg = self.policy.act_config(site)
        if self.mode == Mode.OFF or not cfg.enabled:
            return x
        if self.mode == Mode.COLLECT:
            prev = self.range_states.get(site, init_range_state())
            self.range_states[site] = observe(prev, x, cfg)
            if self.keep_tensors:
                self.calib_tensors[site] = x
            return x
        if self.mode in (Mode.APPLY, Mode.DEPLOY):
            qp = self.act_state.get(site) if self.act_state else None
            if qp is None:
                return x
            if self.telemetry is not None:
                self.telem_site(site, telemetry_stats(x, qp, cfg))
            return fake_quant(x, qp, cfg)
        if self.mode == Mode.QAT:
            from repro.core import qat as qat_lib
            return qat_lib.apply_act(self, site, x, cfg)
        raise ValueError(self.mode)

    def act_in(self, site: str, x: jnp.ndarray) -> jnp.ndarray:
        """Matmul-input quantizer sites (attn_in / wo_in): no-op unless the
        deploy calibration collected them (see the module docstring)."""
        cfg = self.policy.act_config(site)
        if not cfg.enabled:
            return x
        if self.mode == Mode.COLLECT:
            if not self.collect_inputs:
                return x
            prev = self.range_states.get(site, init_range_state())
            self.range_states[site] = observe(prev, x, cfg)
            if self.keep_tensors:
                self.calib_tensors[site] = x
            return x
        if self.mode in (Mode.APPLY, Mode.DEPLOY):
            qp = self.act_state.get(site) if self.act_state else None
            if qp is None:
                return x
            if self.telemetry is not None:
                self.telem_site(site, telemetry_stats(x, qp, cfg))
            return fake_quant(x, qp, cfg)
        return x                                   # OFF / QAT

    def deploy_act(self, site: str):
        """ActQuant for a deployable matmul-input site (DEPLOY mode only)."""
        if self.mode != Mode.DEPLOY or not self.deploy_acts:
            return None
        return self.deploy_acts.get(site)

    def weight(self, site: str, w: jnp.ndarray) -> jnp.ndarray:
        cfg = self.policy.weight_config(site)
        if self.mode in (Mode.OFF, Mode.COLLECT) or not cfg.enabled:
            return w
        if self.mode in (Mode.APPLY, Mode.DEPLOY):
            qp = (self.weight_state or {}).get(site)
            if qp is None:
                # Estimate on the fly from the (static) weight values. Cheap
                # under jit: constant-folded per compilation.
                qp = estimate_weight_params(w, cfg)
            return fake_quant(w, qp, cfg)
        if self.mode == Mode.QAT:
            from repro.core import qat as qat_lib
            return qat_lib.apply_weight(self, site, w, cfg)
        raise ValueError(self.mode)


def fp32_ctx() -> QuantCtx:
    from repro.core.quant_config import fp32_policy
    return QuantCtx(policy=fp32_policy(), mode=Mode.OFF)


# ---------------------------------------------------------------------------
# Calibration driver
# ---------------------------------------------------------------------------

def collect_ranges(forward: Callable, params, batches, policy: QuantizationPolicy,
                   *, keep_tensors: bool = True, collect_inputs: bool = False):
    """Run ``forward(params, batch, ctx)`` over calibration batches, return
    (range_states, calib_tensors). ``forward`` must call ctx.act at its sites.

    ``collect_inputs=True`` additionally observes the matmul-input sites
    (ctx.act_in) needed by the integer deployment path.

    Runs un-jitted so the EMA threading across batches stays simple; batches
    are small calibration samples (paper: 1-16 batches).
    """
    range_states: Dict[str, RangeState] = {}
    calib_tensors: Dict[str, jnp.ndarray] = {}
    for batch in batches:
        ctx = QuantCtx(policy=policy, mode=Mode.COLLECT,
                       range_states=dict(range_states),
                       keep_tensors=keep_tensors,
                       collect_inputs=collect_inputs)
        forward(params, batch, ctx)
        range_states = ctx.range_states
        calib_tensors.update(ctx.calib_tensors)   # keep the last batch's tensor
    return range_states, calib_tensors


def build_act_state(range_states, calib_tensors, policy: QuantizationPolicy,
                    *, tp_shards: int = 1):
    """Finalize collected statistics into a frozen activation QuantState.

    For PEG sites this also builds the group spec (range-based permutation)
    from the per-dim ranges — the "sorting and grouping happens only once
    before the range estimation phase" step of the paper.
    Returns (act_state, peg_specs).
    """
    act_state: QuantState = {}
    peg_specs: Dict[str, peg_lib.PEGSpec] = {}
    for site, state in range_states.items():
        cfg = policy.act_config(site)
        if not cfg.enabled:
            continue
        if cfg.granularity == Granularity.PER_EMBEDDING_GROUP:
            ranges = np.asarray(state.x_max - state.x_min)
            spec = peg_lib.build_groups(ranges, cfg.num_groups,
                                        use_permutation=cfg.use_permutation,
                                        tp_shards=tp_shards)
            peg_specs[site] = spec
            gi = jnp.asarray(peg_lib.group_index_natural_layout(spec))
            qp = finalize(state, cfg, calib_tensors.get(site), group_index=gi)
        else:
            qp = finalize(state, cfg, calib_tensors.get(site))
        act_state[site] = qp
    return act_state, peg_specs


def build_weight_state(params_named, policy: QuantizationPolicy,
                       rounding_offsets: Optional[dict] = None) -> QuantState:
    """Quantization params for every named weight. ``params_named`` is a dict
    site -> array (use models.quantized.named_weight_sites to build it).
    ``rounding_offsets`` come from AdaRound (adaround.py)."""
    state: QuantState = {}
    for site, w in params_named.items():
        cfg = policy.weight_config(site)
        if not cfg.enabled or cfg.bits >= 32:
            continue
        state[site] = estimate_weight_params(jnp.asarray(w), cfg)
    return state
