"""Batched serving loops over jitted prefill / decode / admit steps.

Policy / mechanism split: every jitted model call the continuous
``Scheduler`` makes — fused admit, chunk prefill, batched decode, block
swap in/out, copy-on-write block copy — goes through a
:class:`repro.runtime.engine.Engine` it builds internally. The Scheduler
is a pure POLICY layer: it decides which requests to admit, preempt or
retire and bookkeeps lanes, block tables and stats; the Engine owns the
MECHANISM (device dispatch, greedy readback, telemetry unwrapping,
mesh-aware input placement). The Engine is also usable standalone through
its decomposed prefill/insert/generate triad — see runtime/engine.py.

Two schedulers share the Request / ServeStats bookkeeping:

* ``serve_batch`` — STATIC group batching. Requests are packed into groups
  of up to ``batch_slots`` (prompts left-padded to the group max), each
  group is prefilled once and then decoded in lockstep until every request
  in the group hits its quota. A lane whose request finished early idles
  (still pays for decode steps) until the group's slowest request is done;
  the next group only starts after that. Simple, but measured tokens/s
  collapses when ``max_new_tokens`` is skewed across requests.

* ``Scheduler`` / ``serve_continuous`` — CONTINUOUS batching. A fixed pool
  of ``batch_slots`` decode lanes, each carrying its own request, position
  and KV-cache lane. Finished requests retire immediately and queued
  requests are admitted into the freed lanes mid-flight via a slot-insert
  prefill (runtime.steps.make_admit_step) that writes one request's cache
  lane while every other lane passes through bit-identical. All shapes are
  fixed (prompts pad to ``prompt_pad_len``, decode is always (B, 1)), so
  the jitted steps never recompile across admissions.

  With ``prefill_chunk=N`` (chunked prefill) admission becomes host-side
  bookkeeping only: an admitted lane enters a PREFILLING state and its
  prompt is appended chunk by chunk — at most N tokens per model call
  (runtime.steps.make_chunk_prefill_step) — interleaved 1:1 with the
  resident lanes' decode steps, so one long prompt never stalls resident
  decoding for a whole monolithic prefill. A lane becomes decodable only
  after its last chunk, whose final-position logits emit its first token
  (the admit-path contract), and the emitted tokens are identical to the
  unchunked schedulers'.

  With ``over_commit=True`` (paged + chunked only) the worst-case block
  reservations are dropped: admission claims only the actual prefix +
  first-chunk need, the queue becomes priority-aware ((-priority, seq) —
  FIFO within a tier, no head-of-line blocking), and when growth runs the
  pool dry a victim lane (lowest priority, then youngest) is PREEMPTED —
  its blocks either swap to a host-memory spill buffer (re-uploaded on
  resume) or are dropped and recomputed through chunked re-admission
  (radix hits make the recompute O(novel suffix)). Emitted tokens are
  identical either way: a preempted lane's cache holds exactly the first
  ``written`` tokens of prompt + generated-so-far, so re-prefilling that
  sequence reproduces the greedy continuation.

Position sentinel contract (models/attention.py): position -1 marks a dead
cell — a pad token inside a left-packed prompt or an idle decode lane. Dead
cells are masked out of attention and their KV-cache writes are dropped,
which is what makes the slot-insert prefill and the masked decode step
lane-safe. Both schedulers therefore pack prompts with per-request real
positions 0..len-1 (pads -1), so a short prompt packed next to longer ones
decodes exactly as if it were served alone.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.block_pool import BlockPool, blocks_for_tokens
from repro.runtime.engine import DecodeState, Engine
from repro.runtime.radix_cache import RadixCache
from repro.runtime.telemetry import ServeTelemetry


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 16
    # admission tier: larger = more important. The over-commit scheduler
    # admits in (-priority, arrival) order and preempts lowest-tier lanes
    # first; the FIFO schedulers ignore it.
    priority: int = 0
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class RequestLatency:
    """Per-request latency in model-call steps (every prefill/admit or
    decode call increments the global step counter by one — a wall-clock-
    free proxy). ``enqueue_step`` is recorded when the request enters the
    scheduler's queue, so first-token latency measured from it INCLUDES
    queueing delay; ``queue_wait_steps`` isolates the queued portion
    (summed across re-queues when the request was preempted)."""
    enqueue_step: int = 0       # step count when the request was queued
    admit_step: int = -1        # step count at (last) admission (-1: never)
    first_token_step: int = -1  # step whose output produced token 1
    finish_step: int = -1       # step whose output produced the last token
    queue_wait_steps: int = 0   # total steps spent queued before admission


@dataclasses.dataclass
class TierLatency:
    """Per-priority-tier latency percentiles, in model-call steps.

    First-token latency is measured from ``enqueue_step`` (queueing delay
    included — the whole point of the tier split); inter-token latency is
    the mean step gap between a request's consecutive tokens, defined only
    for requests that emitted >= 2 tokens."""
    requests: int = 0
    first_token_p50: float = 0.0
    first_token_p99: float = 0.0
    inter_token_p50: float = 0.0
    inter_token_p99: float = 0.0


@dataclasses.dataclass
class ServeStats:
    prefill_calls: int = 0
    # chunked prefill only: number of chunk-step model calls (each also
    # counts as a prefill_call); 0 when serving unchunked
    chunk_steps: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    wall_s: float = 0.0
    # PEAK live KV-cache bytes across the run. Dense lanes: the whole cache
    # pytree (every lane owns max_len slots). Paged serving: ALLOCATED
    # block bytes only (blocks_in_use x per-block bytes across layers) —
    # bytes scale with live tokens, which is the paged win this stat makes
    # visible.
    cache_bytes: int = 0
    tokens_per_s: float = 0.0
    # fraction of (decode step x slot) cells occupied by a live request;
    # denominator uses batch_slots so half-empty tail groups count as idle
    slot_utilization: float = 0.0
    # paged-pool gauges (0 for dense serving): peak mapped blocks, and the
    # fraction of allocated token cells not holding a live token at that
    # peak (internal fragmentation of the block_size granularity)
    blocks_in_use: int = 0
    block_fragmentation: float = 0.0
    # prefix-sharing gauges (0 unless a RadixCache is attached): total
    # prompt tokens found in the radix cache at admission (longest cached
    # match, before the >=1-token-suffix cap), prompt tokens whose prefill
    # was actually skipped (block-aligned, capped), peak count of physical
    # blocks mapped by a lane AND retained in the radix cache, and
    # hit-tokens / admitted prompt tokens
    prefix_hit_tokens: int = 0
    prefill_tokens_saved: int = 0
    shared_blocks: int = 0
    prefix_hit_rate: float = 0.0
    # over-commit gauges (0 unless over_commit=True): lane preemptions,
    # blocks spilled to the host swap buffer, and tokens re-prefilled by
    # drop-mode resume (already-computed positions recomputed)
    preemptions: int = 0
    swapped_blocks: int = 0
    recomputed_tokens: int = 0
    # total steps requests spent queued before admission, summed over all
    # requests (per-request values live in request_latency)
    queue_wait_steps: int = 0
    request_latency: Dict[int, RequestLatency] = \
        dataclasses.field(default_factory=dict)
    # priority tier -> latency percentiles (always at least tier 0 when any
    # request produced a token)
    tier_latency: Dict[int, TierLatency] = \
        dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        """JSON-serializable dict of every field (nested RequestLatency /
        TierLatency dataclasses included) — the machine-readable form
        behind ``serve.py --stats-json`` and the serving bench rows."""
        return dataclasses.asdict(self)


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))


def _paged_block_bytes(cache) -> int:
    """Per-physical-block bytes of a paged model cache (0 for anything
    else, e.g. the stub caches the scheduler tests drive)."""
    if not isinstance(cache, dict):
        return 0
    from repro.models.transformer import paged_block_bytes
    return paged_block_bytes(cache)


def _check_capacity(requests: List[Request], max_len: Optional[int],
                    pool: Optional[BlockPool] = None,
                    ring_tokens: Optional[int] = None) -> None:
    """Reject requests whose decode would write past a ``max_len``-slot
    cache segment (the final token is emitted without a write, so the last
    write lands at position len(prompt) + quota - 2). Writes past the
    segment are scatter-dropped by design (dead-cell contract), which would
    silently truncate the attended context — an error beats degraded
    output. ``max_len`` None (capacity unknown to the caller) skips the
    check; sliding-window ring caches wrap and never overflow.

    With a paged ``pool``, the same up-front rule extends to pool capacity:
    a request whose worst case exceeds ``num_blocks`` (or the per-lane
    block-table width) could never be admitted — backpressure would queue
    it forever — so it raises here instead. ``ring_tokens`` (models whose
    EVERY attention layer is a sliding-window ring — see
    models.transformer.paged_ring_tokens) caps the pool-side need: a ring
    lane never holds more than ``ceil(ring_tokens / block_size)`` blocks
    however long it decodes, so window layers stop inflating reservations.
    """
    if max_len is None and pool is None:
        return
    for r in requests:
        if r.max_new_tokens <= 0:
            continue                # zero-quota: never occupies a lane
        need = len(r.prompt) + r.max_new_tokens - 1
        if max_len is not None and need > max_len:
            raise ValueError(
                f"request {r.rid}: prompt ({len(r.prompt)}) + "
                f"max_new_tokens ({r.max_new_tokens}) needs {need} cache "
                f"slots but the cache holds max_len={max_len}; later KV "
                "writes would be silently dropped")
        if pool is not None:
            if ring_tokens is not None:
                need = min(need, ring_tokens)
            nb = blocks_for_tokens(need, pool.block_size)
            lane_cap = pool.max_blocks_per_lane * pool.block_size
            if nb > pool.num_blocks or need > lane_cap:
                raise ValueError(
                    f"request {r.rid}: prompt ({len(r.prompt)}) + "
                    f"max_new_tokens ({r.max_new_tokens}) needs {nb} cache "
                    f"blocks but the pool holds num_blocks="
                    f"{pool.num_blocks} (lane capacity {lane_cap} cells); "
                    "later KV writes would be silently dropped")


def _require_nonempty_prompt(r: Request) -> None:
    """Shared by the monolithic and chunked admission paths so the
    dead-lane/logits contract cannot drift between them."""
    if len(r.prompt) == 0:
        raise ValueError(f"request {r.rid}: empty prompt (an all-dead "
                         f"lane has no last-token logits to decode from)")


def _pack_prompts(group: List[Request], T: int
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Left-pad prompts to length T. Returns (tokens (B,T), positions (B,T))
    with real positions 0..len-1 and the -1 dead-cell sentinel on pads."""
    toks = np.zeros((len(group), T), np.int32)
    posm = np.full((len(group), T), -1, np.int32)
    for i, r in enumerate(group):
        n = len(r.prompt)
        _require_nonempty_prompt(r)
        if n > T:
            raise ValueError(f"request {r.rid}: prompt length {n} exceeds "
                             f"the packing length {T}")
        toks[i, T - n:] = r.prompt
        posm[i, T - n:] = np.arange(n)
    return toks, posm


class _Book:
    """Shared emission / latency / utilization bookkeeping."""

    def __init__(self, stats: ServeStats, batch_slots: int):
        self.stats = stats
        self.slots = batch_slots
        self.step = 0               # global model-call counter
        self.cells = 0
        self.active_cells = 0
        self.prompt_tokens = 0      # admitted prompt tokens (hit-rate denom)
        self.priority: Dict[int, int] = {}   # rid -> tier, for finalize
        self.emitted: Dict[int, int] = {}    # rid -> tokens emitted
        self._enq_step: Dict[int, int] = {}  # rid -> last (re)enqueue step

    def enqueue(self, r: Request) -> None:
        """Record queue entry: creates the request's latency record at the
        CURRENT step so first-token latency includes queueing delay."""
        self.stats.request_latency[r.rid] = RequestLatency(
            enqueue_step=self.step)
        self.priority[r.rid] = r.priority
        self._enq_step[r.rid] = self.step

    def requeue(self, r: Request) -> None:
        """A preempted request re-enters the queue: its renewed wait counts
        toward queue_wait_steps, but enqueue_step keeps the original entry
        step (first-token latency is measured from FIRST arrival)."""
        self._enq_step[r.rid] = self.step

    def admit(self, r: Request) -> None:
        lat = self.stats.request_latency.get(r.rid)
        if lat is None:             # defensive: enqueue() not seen
            lat = RequestLatency(enqueue_step=self.step)
            self.stats.request_latency[r.rid] = lat
            self.priority[r.rid] = r.priority
            self._enq_step[r.rid] = self.step
        wait = self.step - self._enq_step[r.rid]
        lat.queue_wait_steps += wait
        self.stats.queue_wait_steps += wait
        lat.admit_step = self.step

    def emit(self, r: Request, tok: int) -> None:
        r.tokens_out.append(int(tok))
        self.stats.tokens_generated += 1
        self.emitted[r.rid] = self.emitted.get(r.rid, 0) + 1
        lat = self.stats.request_latency.get(r.rid)
        if lat is None:             # defensive: caller skipped enqueue/admit
            lat = RequestLatency(enqueue_step=self.step)
            self.stats.request_latency[r.rid] = lat
            self.priority[r.rid] = r.priority
        if lat.first_token_step < 0:
            lat.first_token_step = self.step
        lat.finish_step = self.step
        if len(r.tokens_out) >= r.max_new_tokens:
            r.done = True

    def track_cache(self, cache) -> None:
        self.stats.cache_bytes = max(self.stats.cache_bytes,
                                     _tree_bytes(cache))

    def track_pool(self, pool: BlockPool, live_tokens: int,
                   block_bytes: int) -> None:
        """Paged serving: peak ALLOCATED bytes + pool gauges (fragmentation
        is sampled at the FIRST blocks_in_use peak — a strict > comparison,
        so a later equal-height peak cannot silently overwrite the first
        sample's fragmentation)."""
        s = self.stats
        s.cache_bytes = max(s.cache_bytes, pool.blocks_in_use * block_bytes)
        if pool.blocks_in_use > s.blocks_in_use:
            s.blocks_in_use = pool.blocks_in_use
            s.block_fragmentation = pool.fragmentation(live_tokens)
        s.shared_blocks = max(s.shared_blocks, pool.shared_blocks)

    def count_decode(self, n_active: int) -> None:
        self.stats.decode_steps += 1
        self.cells += self.slots
        self.active_cells += n_active

    def finalize(self, t_start: float) -> ServeStats:
        s = self.stats
        s.wall_s = time.perf_counter() - t_start
        s.tokens_per_s = s.tokens_generated / max(s.wall_s, 1e-9)
        s.slot_utilization = (self.active_cells / self.cells
                              if self.cells else 0.0)
        s.prefix_hit_rate = (s.prefix_hit_tokens / self.prompt_tokens
                             if self.prompt_tokens else 0.0)
        # per-tier percentiles over requests that produced a first token
        # (zero-quota requests keep their latency entry but are skipped)
        by_tier: Dict[int, List[Tuple[int, RequestLatency]]] = {}
        for rid, lat in s.request_latency.items():
            if lat.first_token_step < 0:
                continue
            by_tier.setdefault(self.priority.get(rid, 0), []).append(
                (rid, lat))
        for tier, entries in sorted(by_tier.items()):
            first = [lat.first_token_step - lat.enqueue_step
                     for _, lat in entries]
            inter = [(lat.finish_step - lat.first_token_step)
                     / (self.emitted[rid] - 1)
                     for rid, lat in entries if self.emitted.get(rid, 0) >= 2]
            s.tier_latency[tier] = TierLatency(
                requests=len(entries),
                first_token_p50=float(np.percentile(first, 50)),
                first_token_p99=float(np.percentile(first, 99)),
                inter_token_p50=(float(np.percentile(inter, 50))
                                 if inter else 0.0),
                inter_token_p99=(float(np.percentile(inter, 99))
                                 if inter else 0.0))
        return s


# ---------------------------------------------------------------------------
# Static group batching (legacy mode, kept for comparison + compatibility)
# ---------------------------------------------------------------------------

def serve_batch(prefill_fn: Callable, decode_fn: Callable, init_cache_fn,
                requests: List[Request], *, batch_slots: int,
                max_len: Optional[int] = None) -> ServeStats:
    """Static-batch serving: pack up to ``batch_slots`` requests (prompts
    left-padded to the group max, pads carrying the -1 position sentinel),
    prefill once, then decode the group in lockstep until every request has
    produced its max_new_tokens. Freed lanes idle until the group drains.
    Decoding is greedy (argmax), as in :class:`Scheduler`.

    prefill_fn: (tokens (B,T), positions (B,T), cache) -> (logits, cache)
    decode_fn:  (tokens (B,1), pos (B,1), cache) -> (logits, cache)
    """
    _check_capacity(requests, max_len)
    stats = ServeStats()
    book = _Book(stats, batch_slots)
    t_start = time.perf_counter()
    # zero-quota requests retire without consuming a group slot (as in the
    # continuous scheduler) — filtered before slicing AND before packing,
    # so an empty prompt on a zero-quota request is not an error either
    for r in requests:
        if r.max_new_tokens <= 0:
            r.done = True
    live = [r for r in requests if r.max_new_tokens > 0]
    for r in live:
        book.enqueue(r)
    for lo in range(0, len(live), batch_slots):
        group = live[lo:lo + batch_slots]
        T = max(len(r.prompt) for r in group)
        toks, posm = _pack_prompts(group, T)
        cache = init_cache_fn(len(group))
        book.track_cache(cache)
        for r in group:
            book.admit(r)
        logits, cache = prefill_fn(jnp.asarray(toks), jnp.asarray(posm),
                                   cache)
        stats.prefill_calls += 1
        book.step += 1
        book.track_cache(cache)
        # each lane decodes at ITS next position (prompt length), not the
        # padded group length — pads are dead cells, not context
        pos = np.array([[len(r.prompt)] for r in group], np.int32)
        cur = np.asarray(jnp.argmax(logits[:, -1:], axis=-1), np.int32)
        steps = max((r.max_new_tokens for r in group), default=0)
        for _ in range(steps):
            for i, r in enumerate(group):
                if not r.done:
                    book.emit(r, cur[i, 0])
            # check BEFORE decoding: once every request hit its quota the
            # group must not pay for (or emit tokens from) another step
            if all(r.done for r in group):
                break
            n_active = sum(not r.done for r in group)
            logits, cache = decode_fn(jnp.asarray(cur), jnp.asarray(pos),
                                      cache)
            book.count_decode(n_active)
            book.step += 1
            book.track_cache(cache)
            cur = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            pos = pos + 1
    return book.finalize(t_start)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Swapped:
    """Swap-mode preemption residue: the lane's block payload lives in a
    host-memory spill buffer until re-admission re-uploads it. Bit-exact
    resume — no token is ever recomputed."""
    payload: Any                # host pytree from swap_out_fn (n_blocks live)
    n_blocks: int               # live blocks at preemption (prefix of ids)
    prompt: np.ndarray          # the lane's working prompt at preemption
    pref_off: Optional[int]     # PREFILLING offset, or None if decodable
    token: int                  # pending decode token (decodable lanes)
    pos: int                    # its write position (decodable lanes)


@dataclasses.dataclass
class _Dropped:
    """Drop-mode preemption residue: the blocks were freed (prompt blocks
    donated to the radix cache when attached) and resume re-prefills
    prompt + tokens-emitted-so-far through the chunk path. Radix hits make
    the recompute O(novel suffix); the re-prefill reproduces the identical
    greedy continuation because the cache held exactly those tokens."""
    written: int                # cache positions held at preemption


@dataclasses.dataclass(eq=False)      # identity compare: queue.remove(entry)
class _QEntry:
    """Admission-queue entry. ``seq`` is the arrival number — the FIFO key
    within a priority tier, kept across preemptions so a re-queued request
    does not lose its place to later arrivals of the same tier."""
    req: Request
    seq: int
    resume: Optional[Any] = None    # _Swapped | _Dropped | None (fresh)


class Scheduler:
    """Slot-scheduled continuous batching over a fixed pool of decode lanes.

    Admission policy: FIFO and greedy — before every decode step, if at
    least one lane is free and the queue is non-empty, ALL free lanes are
    (re)filled in one slot-insert prefill call. Prompts are left-padded to
    the fixed ``prompt_pad_len`` and non-admitted lanes carry all -1
    positions, so one jitted admit step serves every admission without
    recompiling and without perturbing the resident lanes' caches.

    admit_fn: (tokens (B,P), positions (B,P), admit_mask (B,), cache)
              -> (last_logits (B,1,V) | (B,P,V), cache)
    decode_fn: (tokens (B,1), pos (B,1), cache) -> (logits (B,1,V), cache)
    chunk_fn:  (tokens (B,C), positions (B,C), reset_mask (B,), cache)
              -> (last_logits (B,1,V), cache)       [chunked prefill only]
    init_cache_fn: (batch,) -> model cache pytree

    Only greedy (argmax) decoding is implemented — the parity property
    "continuous == static == served alone, token for token" is only
    well-defined for deterministic sampling.

    **Chunked prefill** (``prefill_chunk=N`` + ``chunk_fn``): a lane's
    lifecycle gains a PREFILLING state between admission and decode.
    Admission marks the lane PREFILLING at prompt offset 0 (FIFO, greedy,
    and — when paged — with the same worst-case reservation, but mapping
    only the first chunk's blocks); every loop iteration then issues ONE
    chunk step advancing ALL prefilling lanes by up to N prompt tokens,
    followed by one decode step for the decodable lanes — a 1:1
    interleave, so resident lanes keep emitting between chunks. The lane
    becomes decodable after its last chunk (first token emitted from that
    chunk's logits). Prefilling lanes are dead (pos -1) in the decode
    step and count as idle in slot_utilization.

    **Paged mode** (``block_pool`` given): the scheduler owns a
    :class:`~repro.runtime.block_pool.BlockPool` whose block table rides
    inside the cache pytree (``cache["block_table"]``). Admission reserves
    a request's worst-case block count and maps its prompt blocks (a
    request whose reservation does not fit WAITS at the head of the queue
    — FIFO backpressure the dense path never needed); decode grows a
    lane's mapped prefix as its position crosses block boundaries (growth
    draws from the reservation, so it cannot fail mid-flight); retirement
    returns every block to the free list. All of it is host-side table
    bookkeeping between jitted calls — shapes never change, the steps
    still trace once.

    **Prefix sharing** (``radix_cache`` given; needs paged mode AND a
    ``chunk_fn``): admission matches the prompt against a
    :class:`~repro.runtime.radix_cache.RadixCache`, maps the longest
    block-aligned cached prefix read-only into the lane's table
    (``BlockPool.map_shared``) and prefills ONLY the novel suffix through
    the append-mode chunk path — the lane enters PREFILLING at offset
    K_aligned instead of 0, so the chunk step's reset_mask stays False and
    the shared blocks are never clobbered. Reservations count the novel
    suffix + decode growth only (plus a copy-on-write allowance when the
    request can wrap a ring-window layer back into its shared prefix);
    retirement donates the lane's full prompt blocks into the tree instead
    of freeing them — unless the lane ever wrapped a ring layer, which
    would leave stale generation data in prompt cells. ``write_caps``
    (models.transformer.attn_write_caps) lists the distinct token
    capacities at which the model's attention layers wrap their paged
    write index; ``copy_block_fn(cache, src, dst) -> cache`` (a jitted
    models.transformer.cache_copy_block) services copy-on-write when a
    wrapping write would land in a shared block. ``ring_tokens``
    (models.transformer.paged_ring_tokens, all-window models only) caps
    per-lane reservations and growth at the ring size.

    **Over-commit + preemption** (``over_commit=True``; needs paged mode
    AND a ``chunk_fn``): admission stops reserving the worst case and
    claims only the actual prefix + first-chunk blocks; growth extends the
    reservation on demand (``BlockPool.try_grow``). The queue becomes
    priority-aware — snapshot-sorted by ``(-priority, seq)``, so a starved
    head no longer blocks lower-demand requests behind it — and when the
    pool runs dry a victim lane (lowest priority, then youngest; admission
    only ever preempts a STRICTLY lower tier) is PREEMPTED: with
    ``swap_out_fn``/``swap_in_fn`` (runtime.steps.make_swap_steps) its
    blocks spill to a host buffer and re-upload bit-exact on resume,
    otherwise its blocks are dropped (prompt blocks donated to the radix
    cache when attached) and resume re-prefills prompt + emitted tokens
    through the chunk path — token-for-token identical either way.
    ``decode_ratio=N`` holds decode cadence under prefill pressure: N
    decode steps run per chunk step once lanes are decodable (1 = the
    classic 1:1 interleave).
    """

    def __init__(self, admit_fn: Callable, decode_fn: Callable,
                 init_cache_fn: Callable, *, batch_slots: int,
                 prompt_pad_len: Optional[int] = None,
                 max_len: Optional[int] = None,
                 block_pool: Optional[BlockPool] = None,
                 chunk_fn: Optional[Callable] = None,
                 prefill_chunk: Optional[int] = None,
                 radix_cache: Optional[RadixCache] = None,
                 write_caps: Optional[List[int]] = None,
                 ring_tokens: Optional[int] = None,
                 copy_block_fn: Optional[Callable] = None,
                 over_commit: bool = False,
                 swap_out_fn: Optional[Callable] = None,
                 swap_in_fn: Optional[Callable] = None,
                 decode_ratio: int = 1,
                 telemetry: Optional[ServeTelemetry] = None):
        if batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
        if block_pool is not None and block_pool.batch_slots != batch_slots:
            raise ValueError(
                f"block_pool is sized for {block_pool.batch_slots} lanes, "
                f"scheduler has batch_slots={batch_slots}")
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(
                    f"prefill_chunk must be >= 1, got {prefill_chunk}")
            if chunk_fn is None:
                raise ValueError("prefill_chunk requires a chunk_fn "
                                 "(runtime.steps.make_chunk_prefill_step)")
        if (write_caps is not None or ring_tokens is not None) \
                and block_pool is None:
            raise ValueError("write_caps / ring_tokens only apply to "
                             "paged serving (block_pool)")
        if radix_cache is not None:
            if block_pool is None:
                raise ValueError("radix_cache requires a block_pool "
                                 "(prefix sharing is a paged feature)")
            if chunk_fn is None:
                raise ValueError(
                    "radix_cache requires a chunk_fn: prefix-hit lanes "
                    "prefill their novel suffix through the append-mode "
                    "chunk path (the monolithic admit step would reset "
                    "the shared blocks)")
            if radix_cache.block_size != block_pool.block_size:
                raise ValueError(
                    f"radix_cache block_size {radix_cache.block_size} != "
                    f"pool block_size {block_pool.block_size}")
            block_pool.attach_cache(radix_cache)
        if over_commit:
            if block_pool is None:
                raise ValueError("over_commit requires a block_pool "
                                 "(preemption is a paged feature)")
            if chunk_fn is None:
                raise ValueError(
                    "over_commit requires a chunk_fn: optimistic admission "
                    "maps only the first chunk's blocks and drop-mode "
                    "resume re-prefills through the chunk path")
        if (swap_out_fn is None) != (swap_in_fn is None):
            raise ValueError("swap_out_fn and swap_in_fn come as a pair")
        if swap_out_fn is not None and not over_commit:
            raise ValueError("swap functions only apply to over_commit "
                             "preemption")
        if decode_ratio < 1:
            raise ValueError(f"decode_ratio must be >= 1, got {decode_ratio}")
        if decode_ratio > 1 and chunk_fn is None:
            raise ValueError("decode_ratio > 1 requires a chunk_fn (it "
                             "paces decode steps against chunk steps)")
        self.admit_fn = admit_fn
        self.decode_fn = decode_fn
        self.chunk_fn = chunk_fn
        self.init_cache_fn = init_cache_fn
        self.batch_slots = batch_slots
        self.prompt_pad_len = prompt_pad_len
        self.prefill_chunk = prefill_chunk
        self.max_len = max_len          # per-lane cache slots (None: unchecked)
        self.pool = block_pool
        self.radix = radix_cache
        self.copy_block_fn = copy_block_fn
        self.over_commit = over_commit
        self.swap_out_fn = swap_out_fn
        self.swap_in_fn = swap_in_fn
        self.decode_ratio = decode_ratio
        # observability (runtime/telemetry.py): None = fully disabled — the
        # hot loop then never touches a tracer, timer or metrics object
        self.tel = telemetry
        self._tracer = telemetry.tracer if telemetry is not None else None
        self._book: Optional[_Book] = None
        if block_pool is not None:
            lane_cap = block_pool.max_blocks_per_lane * block_pool.block_size
            caps = sorted(set(write_caps)) if write_caps else [lane_cap]
            if caps[0] < 1 or caps[-1] > lane_cap:
                raise ValueError(f"write_caps {caps} outside the lane "
                                 f"capacity 1..{lane_cap}")
            self._write_caps = caps
            self._min_cap = caps[0]
            if radix_cache is not None and copy_block_fn is None \
                    and caps[0] < lane_cap:
                raise ValueError(
                    "prefix sharing with a sliding-window layer (write cap "
                    f"{caps[0]} < lane capacity {lane_cap}) requires a "
                    "copy_block_fn for copy-on-write")
        else:
            self._write_caps = None
            self._min_cap = None
        self._ring_tokens = ring_tokens
        self._ring_blocks = (None if ring_tokens is None else
                             blocks_for_tokens(ring_tokens,
                                               block_pool.block_size))
        self._block_bytes = 0
        # per-lane PREFILLING state: next prompt offset to append, or None
        # when the lane is idle / decodable (chunked prefill only). With a
        # radix cache a prefix-hit lane STARTS at its matched offset.
        self._pref: List[Optional[int]] = [None] * batch_slots
        # per-lane count of shared (radix-mapped) tokens, for deduplicated
        # live-token accounting in _track
        self._shared_tok: List[int] = [0] * batch_slots
        # fixed chunk width: prefill_chunk when chunking, else the prompt
        # pad (radix mode routes ALL admissions through _chunk); set in run
        self._chunk_width: Optional[int] = prefill_chunk
        # over-commit per-lane state: the WORKING prompt (original prompt,
        # or prompt + emitted tokens for a drop-resumed lane — _chunk and
        # _decode read token sources / end positions from it, never from
        # r.prompt directly), the lane's queue entry (carries resume
        # residue across preemptions), and an admission age for
        # youngest-first victim selection
        self._lane_prompt: List[Optional[np.ndarray]] = [None] * batch_slots
        self._lane_entry: List[Optional[_QEntry]] = [None] * batch_slots
        self._lane_age: List[int] = [0] * batch_slots
        self._age = 0
        self._queue: collections.deque = collections.deque()
        # decode:chunk pacing credit — decremented per decode step, topped
        # back to decode_ratio after each chunk step; a chunk runs only
        # when the credit is spent (or nothing is decodable)
        self._decode_credit = 0
        # mechanism layer: every jitted model call (fused admit, chunk,
        # decode, swap in/out, block copy) goes through the Engine — the
        # Scheduler only decides WHICH lanes take part and bookkeeps the
        # results (runtime.engine for the interface contract)
        self.engine = Engine(
            admit_fn, decode_fn, init_cache_fn, batch_slots=batch_slots,
            prompt_pad_len=prompt_pad_len, max_len=max_len,
            chunk_fn=chunk_fn, swap_out_fn=swap_out_fn,
            swap_in_fn=swap_in_fn, copy_block_fn=copy_block_fn,
            telemetry_sink=(telemetry.quant.update
                            if telemetry is not None
                            and telemetry.quant is not None else None))

    def run(self, requests: List[Request]) -> ServeStats:
        _check_capacity(requests, self.max_len, self.pool, self._ring_tokens)
        stats = ServeStats()
        book = self._book = _Book(stats, self.batch_slots)
        if self.pool is not None and self._tracer is not None:
            self.pool.on_evict = lambda blocks: self._ev(
                "radix_evict", blocks=len(blocks))
        t_start = time.perf_counter()
        queue = self._queue = collections.deque()
        for seq, r in enumerate(requests):
            if r.max_new_tokens <= 0:
                r.done = True                # never occupies a lane
            else:
                book.enqueue(r)
                queue.append(_QEntry(r, seq))
                self._ev("enqueue", rid=r.rid, prompt_len=len(r.prompt),
                         max_new=r.max_new_tokens)
        pad = self.prompt_pad_len or max(
            (len(e.req.prompt) for e in queue), default=1)
        # radix mode prefills every admission (hit or miss) through _chunk;
        # without an explicit prefill_chunk the chunk width is the pad, so
        # a miss still completes in one chunk step exactly like _admit
        self._chunk_width = self.prefill_chunk or pad
        B = self.batch_slots
        lanes: List[Optional[Request]] = [None] * B
        self._pref = [None] * B
        self._shared_tok = [0] * B
        self._lane_prompt = [None] * B
        self._lane_entry = [None] * B
        self._lane_age = [0] * B
        self._age = 0
        self._decode_credit = 0
        state = self.engine.init_state()
        if self.pool is not None:
            self.pool.reset()
            self._block_bytes = _paged_block_bytes(state.cache)
            self._sync_table(state.cache)
        self._track(state.cache, lanes, state, book)

        while queue or any(r is not None for r in lanes):
            # progress snapshot for the deadlock guard: a preemption frees
            # blocks without issuing a model call, so it counts as progress
            before = (book.step, stats.preemptions)
            free = [i for i in range(B) if lanes[i] is None]
            if queue and self.over_commit:
                state = self._admit_over_commit(lanes, state, book)
            elif free and queue and self._head_fits(queue[0].req):
                if self.prefill_chunk is None and self.radix is None:
                    state = self._admit(free, queue, pad, lanes, state, book)
                    continue    # immediate retirees may have freed lanes
                self._admit_chunked(free, queue, lanes, book)
            prefilling = any(off is not None for off in self._pref)
            has_decodable = any(lanes[i] is not None and self._pref[i] is None
                                for i in range(B))
            # decode:chunk pacing: chunk only once the decode credit is
            # spent (ratio=1 reproduces the classic 1:1 interleave) or when
            # nothing is decodable anyway
            if prefilling and (self._decode_credit <= 0 or not has_decodable):
                state = self._chunk(lanes, state, book)
                self._decode_credit = self.decode_ratio
            decodable = [i for i in range(B) if lanes[i] is not None
                         and self._pref[i] is None]
            if decodable:
                state = self._decode(lanes, state, book)
                self._decode_credit -= 1
            elif (book.step, stats.preemptions) == before \
                    and not any(r is not None for r in lanes):
                # no model call, no preemption, no resident lane while the
                # queue is non-empty: nothing can ever make progress.
                # _check_capacity guarantees an empty pool fits any single
                # request, so reaching this means the pool violated that
                # contract (e.g. a leaked allocation).
                raise RuntimeError(
                    "scheduler deadlock: no queued request fits an empty "
                    f"pool (queue head rid {queue[0].req.rid})")
            self._snapshot(queue, lanes, book)
        if self.tel is not None and self.tel.quant is not None:
            self.tel.quant.update_kv_scales(state.cache)
        return book.finalize(t_start)

    # -- paged-pool plumbing (no-ops in dense mode) -------------------------

    def _need_blocks(self, r: Request) -> int:
        """Worst-case per-lane block count for ``r``, ring-clamped: an
        all-window model's lane never maps more than ``_ring_blocks``
        blocks no matter how long it decodes (writes wrap in place)."""
        need = len(r.prompt) + r.max_new_tokens - 1
        if self._ring_tokens is not None:
            need = min(need, self._ring_tokens)
        return blocks_for_tokens(need, self.pool.block_size)

    def _head_fits(self, r: Request) -> bool:
        """Admission backpressure: the queue head's worst-case reservation
        must fit or the whole admission waits (FIFO — later requests do not
        overtake a starved head)."""
        if self.pool is None:
            return True
        if self.radix is not None:
            blocks, _, _, n_alloc, n_reserve, total = self._plan_prefix(r)
            if blocks:
                return self.pool.can_map_shared(blocks, n_reserve, total)
            return self.pool.can_reserve(n_reserve)
        return self.pool.can_reserve(self._need_blocks(r))

    def _plan_prefix(self, r: Request):
        """Radix admission plan: match the prompt, then size the lane.

        Returns (shared_blocks, raw_hit_tokens, K_aligned, n_alloc,
        n_reserve, n_cols) where n_reserve counts the NOVEL blocks only
        (suffix + decode growth, ring-clamped) plus a copy-on-write
        allowance of one fresh block per shared block whenever the request
        can wrap a ring-window layer (its last write position reaches
        min(write_caps)) — COW replaces a shared block with a private one,
        drawing from the reservation like any growth. The match is capped
        at (P-1)//block_size blocks so the novel suffix keeps >= 1 token
        (the chunk step's final-position logits emit the first token)."""
        P = len(r.prompt)
        bs = self.pool.block_size
        blocks, raw = self.radix.match(r.prompt, max_blocks=(P - 1) // bs)
        k = len(blocks)
        total = self._need_blocks(r)        # ring-clamped table columns
        wraps = P + r.max_new_tokens - 2 >= self._min_cap
        cow_allow = k if wraps else 0
        first = min(self._chunk_width, P - k * bs)
        cols_first = blocks_for_tokens(k * bs + first, bs)
        if self._ring_blocks is not None:
            cols_first = min(cols_first, self._ring_blocks)
        n_alloc = max(cols_first - k, 0)
        n_reserve = (total - k) + cow_allow
        return blocks, raw, k * bs, n_alloc, n_reserve, total

    def _reserve(self, lane: int, r: Request) -> bool:
        """Worst-case reservation + prompt-block mapping at admission. In
        chunked mode only the FIRST chunk's blocks are mapped now; _chunk
        grows the prefix by O(chunk / block_size) blocks per chunk."""
        if self.pool is None:
            return True
        bs = self.pool.block_size
        first = len(r.prompt) if self.prefill_chunk is None \
            else min(len(r.prompt), self.prefill_chunk)
        n_alloc = blocks_for_tokens(first, bs)
        if self._ring_blocks is not None:
            n_alloc = min(n_alloc, self._ring_blocks)
        return self.pool.reserve_and_alloc(
            lane, n_alloc, self._need_blocks(r))

    def _reserve_prefix(self, lane: int, r: Request,
                        book: _Book) -> Optional[int]:
        """Radix admission: map the matched prefix read-only (refcounted)
        plus the first chunk's novel blocks, reserving novel growth only.
        Returns the prompt offset the lane starts prefilling at (K_aligned;
        0 on a miss), or None when the plan does not fit (backpressure)."""
        blocks, raw, k_tok, n_alloc, n_reserve, total = self._plan_prefix(r)
        if blocks:
            ok = self.pool.map_shared(lane, blocks, n_alloc, n_reserve,
                                      n_cols=total)
        else:
            ok = self.pool.reserve_and_alloc(lane, n_alloc, n_reserve)
        if not ok:
            return None
        self._shared_tok[lane] = k_tok
        book.stats.prefix_hit_tokens += raw
        book.stats.prefill_tokens_saved += k_tok
        return k_tok

    def _release(self, lane: int, r: Optional[Request] = None) -> None:
        if r is not None:
            self._ev("retire", rid=r.rid, lane=lane,
                     tokens=len(r.tokens_out))
        if self.pool is not None:
            if self.radix is not None and r is not None:
                self._donate(lane, r)
            self.pool.free_lane(lane)
            self._shared_tok[lane] = 0
        self._lane_prompt[lane] = None
        self._lane_entry[lane] = None

    def _donate(self, lane: int, r: Request) -> None:
        """Retirement donation: insert the lane's full WORKING-prompt
        blocks into the radix tree instead of freeing them (the working
        prompt is the original prompt, or prompt + pre-preemption tokens
        for a drop-resumed lane — either way exactly what those blocks
        hold). Skipped when the lane ever wrapped a ring-window layer
        (last write position >= min cap): a wrapping write lands
        generation data inside prompt cells, so those blocks no longer
        hold a clean prefix. The skip also guarantees any cached path is
        window-read-valid for every future recipient."""
        seq = self._lane_prompt[lane]
        if seq is None:
            seq = r.prompt
        n_full = len(seq) // self.pool.block_size
        if n_full == 0:
            return
        if len(r.prompt) + r.max_new_tokens - 2 >= self._min_cap:
            return
        blocks = [int(b) for b in self.pool.table[lane, :n_full]]
        adopted = self.radix.insert(
            np.asarray(seq[:n_full * self.pool.block_size]), blocks)
        for b in adopted:
            self.pool.set_cached(b, True)

    def _cow_barrier(self, lane: int, positions, cache,
                     lanes=None, state=None, book=None):
        """Copy-on-write barrier, called before any step that writes
        ``positions`` for ``lane``: for every attention write cap, find
        the table column each write wraps into; if that column still maps
        a shared (refcounted/cached) block, redirect it to a private copy
        first. Device copy via copy_block_fn (traced once — src/dst are
        data); the pool swap marks the table dirty for the next sync.

        Under over-commit the COW allowance was never reserved, so the
        fresh block may not physically exist: victims are preempted until
        it does (the lane itself as last resort — the caller then sees
        ``lanes[lane] is None`` and skips the step for it)."""
        if self.pool.lane_shared(lane) == 0:
            return cache
        bs = self.pool.block_size
        cols = sorted({(p % cap) // bs
                       for p in positions for cap in self._write_caps})
        for col in cols:
            if self.over_commit and self.pool.needs_cow(lane, col):
                while self.pool.available_blocks() < 1:
                    victim = self._pick_victim(lanes)
                    self._preempt(victim, lanes, state, book)
                    if victim == lane:
                        return cache
            pair = (self.pool.cow(lane, col, extend=True)
                    if self.over_commit else self.pool.cow(lane, col))
            if pair is not None:
                cache = self.engine.copy_block(cache, pair[0], pair[1])
                self._ev("cow", lane=lane, src=int(pair[0]),
                         dst=int(pair[1]))
        return cache

    def _sync_table(self, cache) -> None:
        """Re-upload the block table only when the pool mutated it since
        the last sync — steady-state decode steps (no admission, no growth,
        no retirement) reuse the device table flowing through the jitted
        step's outputs."""
        if self.pool is not None and self.pool.dirty \
                and isinstance(cache, dict):
            cache["block_table"] = jnp.asarray(self.pool.table)
            self.pool.dirty = False

    def _track(self, cache, lanes, state: DecodeState, book: _Book) -> None:
        if self.pool is None:
            book.track_cache(cache)
        else:
            # live tokens are DEDUPLICATED: each lane counts only the
            # tokens it privately wrote (position minus its shared-prefix
            # tokens); every cached block's tokens count once, however
            # many lanes map it
            live = sum(int(state.pos[i, 0]) - self._shared_tok[i]
                       for i, r in enumerate(lanes)
                       if r is not None and state.pos[i, 0] > 0)
            # PREFILLING lanes carry pos -1 but already hold their written
            # chunk tokens (offset counts from 0 — shared tokens excluded)
            live += sum(off - self._shared_tok[i]
                        for i, off in enumerate(self._pref) if off)
            live += self.pool.blocks_cached * self.pool.block_size
            book.track_pool(self.pool, live, self._block_bytes)

    # -- observability hooks (all no-ops when telemetry is None) ------------

    def _ev(self, name: str, rid: Optional[int] = None,
            lane: Optional[int] = None, **args) -> None:
        if self._tracer is not None:
            self._tracer.event(name, self._book.step, rid=rid, lane=lane,
                               **args)

    def _step_call(self, phase: str, op: Callable, args,
                   n_lanes: Optional[int] = None):
        """One engine op (a jitted model call plus greedy readback). Under
        tracing it becomes a phase duration event — the op's host-side
        token conversion already blocks on device execution, so the
        duration covers the computation, not just dispatch. Telemetry
        unwrapping happens inside the engine (telemetry_sink)."""
        if self._tracer is None:
            return op(*args)
        with self._tracer.phase(phase, self._book.step) as ph:
            toks, cache = op(*args)
            if n_lanes is not None:
                ph.args["lanes"] = n_lanes
        return toks, cache

    def _timed(self, phase: str, thunk: Callable, **args):
        """Time a host-side phase (block swap in/out) as a duration event."""
        if self._tracer is None:
            return thunk()
        with self._tracer.phase(phase, self._book.step) as ph:
            out = thunk()
            ph.args.update(args)
        return out

    def _snapshot(self, queue, lanes, book: _Book) -> None:
        """Periodic metrics snapshot (queue/lane/pool gauges), emitted at
        most once per global step when a MetricsLogger is attached."""
        m = self.tel.metrics if self.tel is not None else None
        if m is None or not m.due(book.step):
            return
        s = book.stats
        gauges: Dict[str, Any] = {
            "queue_depth": len(queue),
            "resident_lanes": sum(r is not None for r in lanes),
            "prefilling_lanes": sum(o is not None for o in self._pref),
            "tokens_generated": s.tokens_generated,
            "decode_steps": s.decode_steps,
            "prefill_calls": s.prefill_calls,
            "preemptions": s.preemptions,
            "swapped_blocks": s.swapped_blocks,
            "prefix_hit_rate": (s.prefix_hit_tokens / book.prompt_tokens
                                if book.prompt_tokens else 0.0),
        }
        if self.pool is not None:
            gauges.update(
                blocks_in_use=self.pool.blocks_in_use,
                blocks_free=self.pool.blocks_free,
                blocks_evictable=self.pool.blocks_evictable,
                blocks_cached=self.pool.blocks_cached,
                shared_blocks=self.pool.shared_blocks,
                refcount_total=self.pool.refcount_total)
        m.emit(book.step, gauges)

    # -----------------------------------------------------------------------

    def _admit(self, free, queue, pad, lanes, state: DecodeState,
               book: _Book) -> DecodeState:
        B = self.batch_slots
        group, entries, slots = [], [], []
        for i in free:
            if not queue:
                break
            if not self._reserve(i, queue[0].req):
                break           # head-of-line backpressure: keep FIFO order
            entries.append(queue.popleft())
            group.append(entries[-1].req)
            slots.append(i)
            book.prompt_tokens += len(group[-1].prompt)
        toks = np.zeros((B, pad), np.int32)
        posm = np.full((B, pad), -1, np.int32)
        g_toks, g_posm = _pack_prompts(group, pad)
        admit_mask = np.zeros((B,), bool)
        for j, i in enumerate(slots):
            toks[i], posm[i] = g_toks[j], g_posm[j]
            admit_mask[i] = True
            lanes[i] = group[j]
            self._register_lane(i, entries[j], group[j].prompt, book)
            self._ev("admit", rid=group[j].rid, lane=i)
        self._sync_table(state.cache)
        first, cache = self._step_call(
            "admit", self.engine.admit,
            (toks, posm, admit_mask, state.cache), n_lanes=len(slots))
        book.stats.prefill_calls += 1
        book.step += 1
        tokens, pos = state.tokens.copy(), state.pos.copy()
        for i in slots:
            r = lanes[i]
            tokens[i, 0] = first[i, 0]
            pos[i, 0] = len(r.prompt)
            book.emit(r, tokens[i, 0])
        # sample gauges BEFORE releasing quota-1 retirees: their blocks
        # were mapped during this prefill, so the peak must include them
        self._track(cache, lanes, DecodeState(tokens, pos, cache), book)
        for i in slots:
            if lanes[i].done:                # quota 1: retire before decoding
                r = lanes[i]
                lanes[i] = None
                pos[i, 0] = -1
                self._release(i, r)
        return DecodeState(tokens, pos, cache)

    def _admit_chunked(self, free, queue, lanes, book: _Book) -> None:
        """Chunked-prefill admission is pure host bookkeeping: mark each
        admitted lane PREFILLING at prompt offset 0 (FIFO, head-of-line
        backpressure as in _admit); the model work happens chunk by chunk
        in _chunk, interleaved with resident decode steps. With a radix
        cache a prefix hit starts the lane at offset K_aligned instead —
        the matched blocks are already mapped (read-only) and the chunk
        step's append-mode positions make them the lane's attended past."""
        for i in free:
            if not queue:
                break
            r = queue[0].req
            _require_nonempty_prompt(r)
            if self.radix is not None:
                off = self._reserve_prefix(i, r, book)
                if off is None:
                    break       # head-of-line backpressure: keep FIFO order
            else:
                if not self._reserve(i, r):
                    break       # head-of-line backpressure: keep FIFO order
                off = 0
            entry = queue.popleft()
            lanes[i] = r
            self._pref[i] = off
            self._register_lane(i, entry, r.prompt, book)
            book.prompt_tokens += len(r.prompt)
            self._ev("admit", rid=r.rid, lane=i)
            if off:
                self._ev("prefix_hit", rid=r.rid, lane=i, tokens=off)

    # -- over-commit: preemption + priority admission -----------------------

    def _register_lane(self, lane: int, entry: _QEntry,
                       prompt: np.ndarray, book: _Book) -> None:
        """Admission bookkeeping shared by every path: record the lane's
        working prompt (token source for _chunk/_decode), its queue entry
        (resume residue carrier), an age stamp for youngest-first victim
        selection, and the queue-wait/admit latency sample."""
        self._lane_prompt[lane] = prompt
        self._lane_entry[lane] = entry
        self._age += 1
        self._lane_age[lane] = self._age
        book.admit(entry.req)

    def _pick_victim(self, lanes,
                     *, below: Optional[int] = None) -> Optional[int]:
        """Victim lane for preemption: lowest priority first, youngest
        (largest age stamp) within a tier. ``below`` restricts candidates
        to strictly lower priority than the given tier (admission-driven
        preemption must never evict a peer to seat an equal); growth-driven
        callers pass no bound — the demander itself is then a candidate,
        guaranteeing a victim always exists."""
        cand = [i for i in range(self.batch_slots) if lanes[i] is not None]
        if below is not None:
            cand = [i for i in cand if lanes[i].priority < below]
        if not cand:
            return None
        return min(cand, key=lambda i: (lanes[i].priority,
                                        -self._lane_age[i]))

    def _pad_block_ids(self, ids: np.ndarray) -> np.ndarray:
        """Pad a lane's live block ids to the fixed swap-step width with
        ``num_blocks`` — an out-of-range POSITIVE id, so the gather clips
        to a garbage row and the scatter drops the write (a negative pad
        would wrap around under jnp indexing)."""
        pad = np.full((self.pool.max_blocks_per_lane,),
                      self.pool.num_blocks, np.int32)
        pad[:len(ids)] = ids
        return pad

    def _preempt(self, lane: int, lanes, state: DecodeState,
                 book: _Book) -> None:
        """Preempt ``lane``: spill its blocks to the host swap buffer
        (swap mode — bit-exact resume) or free them after donating the
        fully written prefix to the radix cache (drop mode — resume
        re-prefills prompt + emitted tokens, O(novel suffix) on a radix
        hit), then re-queue its request with the resume residue attached.
        The request keeps its original arrival seq, so it does not lose
        its FIFO place within its tier."""
        r = lanes[lane]
        entry = self._lane_entry[lane]
        off = self._pref[lane]
        written = off if off is not None else int(state.pos[lane, 0])
        stats = book.stats
        if self.swap_out_fn is not None:
            ids = self.pool.lane_blocks(lane)
            payload = self._timed(
                "swap_out",
                lambda: self.engine.swap_out(state.cache,
                                             self._pad_block_ids(ids)),
                blocks=len(ids))
            entry.resume = _Swapped(
                payload=payload, n_blocks=len(ids),
                prompt=self._lane_prompt[lane], pref_off=off,
                token=int(state.tokens[lane, 0]),
                pos=int(state.pos[lane, 0]))
            stats.swapped_blocks += len(ids)
        else:
            self._donate_written(lane, r, written)
            entry.resume = _Dropped(written=written)
        self.pool.free_lane(lane)
        self._shared_tok[lane] = 0
        self._lane_prompt[lane] = None
        self._lane_entry[lane] = None
        lanes[lane] = None
        self._pref[lane] = None
        state.pos[lane, 0] = -1        # idle: decode treats it as dead
        stats.preemptions += 1
        self._ev("preempt", rid=r.rid, lane=lane, written=written,
                 mode="swap" if self.swap_out_fn is not None else "drop")
        book.requeue(r)
        self._queue.append(entry)

    def _donate_written(self, lane: int, r: Request, written: int) -> None:
        """Drop-mode preemption donation: the lane's blocks hold positions
        0..written-1 of prompt + emitted tokens, so donate the fully
        covered blocks — the radix cache then turns the resume re-prefill
        into O(novel suffix). Skipped without a radix cache, and when a
        ring-window layer may already have wrapped (highest written
        position >= min cap would mean generation data landed inside
        earlier cells)."""
        if self.radix is None:
            return
        bs = self.pool.block_size
        n_full = written // bs
        if n_full == 0 or written - 1 >= self._min_cap:
            return
        full = np.concatenate([np.asarray(r.prompt, np.int32),
                               np.asarray(r.tokens_out, np.int32)])
        blocks = [int(b) for b in self.pool.table[lane, :n_full]]
        adopted = self.radix.insert(full[:n_full * bs], blocks)
        for b in adopted:
            self.pool.set_cached(b, True)

    def _ensure_blocks(self, lane: int, n_total: int, lanes,
                       state: DecodeState, book: _Book) -> bool:
        """Over-commit growth: grow ``lane`` to ``n_total`` mapped blocks,
        preempting victims (lowest priority, youngest) until the pool can
        supply them. The demander itself is the last-resort victim —
        False means it was preempted and the caller must skip it this
        step (it resumes through the queue)."""
        while not self.pool.try_grow(lane, n_total):
            victim = self._pick_victim(lanes)
            # the demander is always a candidate, so victim is never None
            self._preempt(victim, lanes, state, book)
            if victim == lane:
                return False
        return True

    def _admit_over_commit(self, lanes, state: DecodeState,
                           book: _Book) -> DecodeState:
        """Priority-aware over-commit admission: try queued entries in
        (-priority, seq) order — FIFO within a tier, but a starved head no
        longer blocks other tiers. An entry with no free lane may preempt
        a STRICTLY lower-tier victim to take its slot; an entry whose
        first chunk does not fit the pool may do the same. Entries that
        still cannot be placed stay queued (skipped, not blocking)."""
        B = self.batch_slots
        for entry in sorted(self._queue,
                            key=lambda e: (-e.req.priority, e.seq)):
            _require_nonempty_prompt(entry.req)
            free = [i for i in range(B) if lanes[i] is None]
            if not free:
                victim = self._pick_victim(lanes, below=entry.req.priority)
                if victim is None:
                    break       # every resident lane is >= this tier: wait
                self._preempt(victim, lanes, state, book)
                free = [victim]
            lane = free[0]
            placed, state = self._try_place(lane, entry, state, book)
            while not placed:
                victim = self._pick_victim(lanes, below=entry.req.priority)
                if victim is None:
                    break
                self._preempt(victim, lanes, state, book)
                placed, state = self._try_place(lane, entry, state, book)
            if not placed:
                continue        # pool too full even after preemption
            self._queue.remove(entry)
            lanes[lane] = entry.req
        return state

    def _try_place(self, lane: int, entry: _QEntry, state: DecodeState,
                   book: _Book) -> Tuple[bool, DecodeState]:
        """Seat ``entry`` in the free ``lane``. Swap residue re-allocates
        the same block count and re-uploads the host payload (bit-exact);
        anything else (fresh or drop residue) goes through optimistic
        chunked placement. Returns (placed, state) — False leaves the
        pool untouched."""
        r = entry.req
        res = entry.resume
        pool = self.pool
        if isinstance(res, _Swapped):
            n = res.n_blocks
            if n > pool.available_blocks() \
                    or not pool.reserve_and_alloc(lane, n, n):
                return False, state
            ids = pool.lane_blocks(lane)
            cache = self._timed(
                "swap_in",
                lambda: self.engine.swap_in(state.cache,
                                            self._pad_block_ids(ids),
                                            res.payload),
                blocks=len(ids))
            tokens, pos = state.tokens.copy(), state.pos.copy()
            self._pref[lane] = res.pref_off
            if res.pref_off is None:    # decodable: restore pending token
                tokens[lane, 0] = res.token
                pos[lane, 0] = res.pos
            self._register_lane(lane, entry, res.prompt, book)
            self._shared_tok[lane] = 0  # every re-uploaded block is private
            entry.resume = None
            self._ev("resume", rid=r.rid, lane=lane, mode="swap")
            return True, DecodeState(tokens, pos, cache)
        if isinstance(res, _Dropped):
            prompt = np.concatenate([np.asarray(r.prompt, np.int32),
                                     np.asarray(r.tokens_out, np.int32)])
        else:
            prompt = r.prompt
        off = self._place_chunked(lane, prompt, book)
        if off is None:
            return False, state
        if isinstance(res, _Dropped):
            book.stats.recomputed_tokens += max(res.written - off, 0)
            entry.resume = None
            self._ev("resume", rid=r.rid, lane=lane, mode="drop")
        else:
            self._ev("admit", rid=r.rid, lane=lane)
        if off:
            self._ev("prefix_hit", rid=r.rid, lane=lane, tokens=off)
        self._pref[lane] = off
        self._register_lane(lane, entry, prompt, book)
        book.prompt_tokens += len(prompt)
        return True, state

    def _place_chunked(self, lane: int, prompt: np.ndarray,
                       book: _Book) -> Optional[int]:
        """Optimistic admission sizing: map the radix-matched prefix (if
        any) plus ONLY the blocks the first chunk's writes land in — no
        worst-case reservation (try_grow extends it later). Returns the
        starting prefill offset, or None when even the first chunk does
        not physically fit."""
        pool = self.pool
        bs = pool.block_size
        P = len(prompt)
        blocks, raw = [], 0
        if self.radix is not None:
            blocks, raw = self.radix.match(np.asarray(prompt),
                                           max_blocks=(P - 1) // bs)
        k = len(blocks)
        first = min(self._chunk_width, P - k * bs)
        cols_first = blocks_for_tokens(k * bs + first, bs)
        if self._ring_blocks is not None:
            cols_first = min(cols_first, self._ring_blocks)
        n_alloc = max(cols_first - k, 0)
        if n_alloc > pool.available_blocks():
            return None
        if blocks:
            ok = pool.map_shared(lane, blocks, n_alloc, n_alloc,
                                 n_cols=cols_first)
        else:
            ok = pool.reserve_and_alloc(lane, n_alloc, n_alloc)
        if not ok:
            return None
        self._shared_tok[lane] = k * bs
        if k:
            book.stats.prefix_hit_tokens += raw
            book.stats.prefill_tokens_saved += k * bs
        return k * bs

    def _chunk(self, lanes, state: DecodeState, book: _Book) -> DecodeState:
        """One fixed-shape chunk step: append up to ``prefill_chunk`` prompt
        tokens to every PREFILLING lane (left-padded into the fixed chunk
        width; lanes starting chunk 1 are reset first via the step's
        reset_mask). Lanes finishing their last chunk emit their first
        token from the chunk's final-position logits and become decodable
        (quota-1 requests retire immediately, as in _admit).

        Token sources and end positions come from the lane's WORKING
        prompt (prompt + pre-preemption tokens for a drop-resumed lane),
        so a resumed lane re-prefills exactly what its cache held plus the
        pending token — the final-position logits then emit the NEXT
        (never-emitted) token, preserving greedy parity."""
        C = self._chunk_width
        B = self.batch_slots
        cache = state.cache
        if self.pool is not None:
            # pool pre-pass BEFORE building the step inputs: under
            # over-commit a COW or growth may PREEMPT a lane (possibly one
            # already visited, or the demander itself), changing who
            # chunks this step
            bs = self.pool.block_size
            for i in range(B):
                if self._pref[i] is None or lanes[i] is None:
                    continue
                off = self._pref[i]
                seq = self._lane_prompt[i]
                c = min(C, len(seq) - off)
                # copy-on-write BEFORE growth/sync: a ring-window write in
                # this chunk may wrap into a shared prefix column
                if self.radix is not None:
                    cache = self._cow_barrier(i, range(off, off + c), cache,
                                              lanes, state, book)
                    if lanes[i] is None:
                        continue    # preempted inside the COW barrier
                # map the blocks this chunk's writes land in (reservation-
                # backed, cannot fail mid-flight — unless over-commit,
                # which grows on demand and preempts when the pool is dry)
                n_total = (off + c - 1) // bs + 1
                if self._ring_blocks is not None:
                    n_total = min(n_total, self._ring_blocks)
                n_before = (self.pool.lane_mapped(i)
                            if self._tracer is not None else 0)
                if self.over_commit:
                    self._ensure_blocks(i, n_total, lanes, state, book)
                else:
                    self.pool.grow(i, n_total)
                if self._tracer is not None and lanes[i] is not None \
                        and self.pool.lane_mapped(i) > n_before:
                    self._ev("block_grow", rid=lanes[i].rid, lane=i,
                             blocks=self.pool.lane_mapped(i) - n_before)
        prefilling = [i for i in range(B) if self._pref[i] is not None]
        if not prefilling:          # every prefilling lane was preempted
            return DecodeState(state.tokens, state.pos, cache)
        toks = np.zeros((B, C), np.int32)
        posm = np.full((B, C), -1, np.int32)
        reset = np.zeros((B,), bool)
        ends = {}
        for i in prefilling:
            off = self._pref[i]
            seq = self._lane_prompt[i] if self._lane_prompt[i] is not None \
                else lanes[i].prompt
            c = min(C, len(seq) - off)
            toks[i, C - c:] = seq[off:off + c]
            posm[i, C - c:] = np.arange(off, off + c, dtype=np.int32)
            reset[i] = off == 0
            ends[i] = off + c
        self._sync_table(cache)
        last, cache = self._step_call(
            "chunk", self.engine.chunk,
            (toks, posm, reset, cache), n_lanes=len(prefilling))
        book.stats.prefill_calls += 1
        book.stats.chunk_steps += 1
        book.step += 1
        tokens, pos = state.tokens.copy(), state.pos.copy()
        for i in prefilling:
            r = lanes[i]
            seq = self._lane_prompt[i] if self._lane_prompt[i] is not None \
                else r.prompt
            if ends[i] < len(seq):
                self._pref[i] = ends[i]     # more chunks to go
                continue
            self._pref[i] = None            # last chunk: lane is decodable
            tokens[i, 0] = last[i, 0]
            pos[i, 0] = len(seq)
            book.emit(r, tokens[i, 0])
        # sample gauges BEFORE releasing quota-1 retirees (as in _admit)
        self._track(cache, lanes, DecodeState(tokens, pos, cache), book)
        for i in prefilling:
            if self._pref[i] is None and lanes[i].done:
                r = lanes[i]
                lanes[i] = None             # quota 1: retire immediately
                pos[i, 0] = -1
                self._release(i, r)
        return DecodeState(tokens, pos, cache)

    def _decode(self, lanes, state: DecodeState, book: _Book) -> DecodeState:
        cache = state.cache
        if self.pool is not None:
            # incremental growth: map the block the coming write lands in.
            # Reservation-backed growth cannot fail mid-flight; over-commit
            # growth may PREEMPT a lane instead (possibly the demander),
            # so the active set is recomputed after this pre-pass.
            bs = self.pool.block_size
            for i in range(self.batch_slots):
                if lanes[i] is None or self._pref[i] is not None:
                    continue
                p = int(state.pos[i, 0])
                if self.radix is not None:
                    # a ring-window write may wrap into a shared column
                    cache = self._cow_barrier(i, (p,), cache,
                                              lanes, state, book)
                    if lanes[i] is None:
                        continue    # preempted inside the COW barrier
                n_total = p // bs + 1
                if self._ring_blocks is not None:
                    n_total = min(n_total, self._ring_blocks)
                n_before = (self.pool.lane_mapped(i)
                            if self._tracer is not None else 0)
                if self.over_commit:
                    self._ensure_blocks(i, n_total, lanes, state, book)
                else:
                    self.pool.grow(i, n_total)
                if self._tracer is not None and lanes[i] is not None \
                        and self.pool.lane_mapped(i) > n_before:
                    self._ev("block_grow", rid=lanes[i].rid, lane=i,
                             blocks=self.pool.lane_mapped(i) - n_before)
            self._sync_table(cache)
        active = [i for i, r in enumerate(lanes)
                  if r is not None and self._pref[i] is None]
        if not active:              # every decodable lane was preempted
            return DecodeState(state.tokens, state.pos, cache)
        nxt, cache = self._step_call(
            "decode_batch", self.engine.generate,
            (DecodeState(state.tokens, state.pos, cache),),
            n_lanes=len(active))
        book.count_decode(len(active))
        book.step += 1
        tokens, pos = state.tokens.copy(), state.pos.copy()
        for i in active:
            r = lanes[i]
            tokens[i, 0] = nxt[i, 0]
            pos[i, 0] += 1
            book.emit(r, tokens[i, 0])
        # sample gauges BEFORE releasing retirees: a lane whose final write
        # just grew a block still holds it during this step, and the peak
        # must include it
        self._track(cache, lanes, DecodeState(tokens, pos, cache), book)
        for i in active:
            if lanes[i].done:
                r = lanes[i]
                lanes[i] = None
                pos[i, 0] = -1
                self._release(i, r)
        return DecodeState(tokens, pos, cache)


def serve_continuous(admit_fn: Callable, decode_fn: Callable, init_cache_fn,
                     requests: List[Request], *, batch_slots: int,
                     prompt_pad_len: Optional[int] = None,
                     max_len: Optional[int] = None,
                     block_pool: Optional[BlockPool] = None,
                     chunk_fn: Optional[Callable] = None,
                     prefill_chunk: Optional[int] = None,
                     radix_cache: Optional[RadixCache] = None,
                     write_caps: Optional[List[int]] = None,
                     ring_tokens: Optional[int] = None,
                     copy_block_fn: Optional[Callable] = None,
                     over_commit: bool = False,
                     swap_out_fn: Optional[Callable] = None,
                     swap_in_fn: Optional[Callable] = None,
                     decode_ratio: int = 1,
                     telemetry: Optional[ServeTelemetry] = None) -> ServeStats:
    """Continuous-batching counterpart of :func:`serve_batch` (see
    :class:`Scheduler` for the step-function contracts)."""
    return Scheduler(admit_fn, decode_fn, init_cache_fn,
                     batch_slots=batch_slots, prompt_pad_len=prompt_pad_len,
                     max_len=max_len, block_pool=block_pool,
                     chunk_fn=chunk_fn, prefill_chunk=prefill_chunk,
                     radix_cache=radix_cache, write_caps=write_caps,
                     ring_tokens=ring_tokens,
                     copy_block_fn=copy_block_fn, over_commit=over_commit,
                     swap_out_fn=swap_out_fn, swap_in_fn=swap_in_fn,
                     decode_ratio=decode_ratio,
                     telemetry=telemetry).run(requests)


def serve(prefill_step: Callable, admit_step: Callable,
          decode_step: Callable, init_cache_fn, params,
          requests: List[Request], *, scheduler: str = "static",
          batch_slots: int, prompt_pad_len: Optional[int] = None,
          max_len: Optional[int] = None,
          block_pool: Optional[BlockPool] = None,
          chunk_step: Optional[Callable] = None,
          prefill_chunk: Optional[int] = None,
          radix_cache: Optional[RadixCache] = None,
          write_caps: Optional[List[int]] = None,
          ring_tokens: Optional[int] = None,
          copy_block_fn: Optional[Callable] = None,
          over_commit: bool = False,
          swap_out_fn: Optional[Callable] = None,
          swap_in_fn: Optional[Callable] = None,
          decode_ratio: int = 1,
          telemetry: Optional[ServeTelemetry] = None) -> ServeStats:
    """Dispatch to a scheduler, binding ``params`` into step functions with
    the ``runtime.steps.make_*_step`` signatures (params first):

      prefill_step(params, tokens, cache, positions) — static mode
      admit_step(params, tokens, positions, admit_mask, cache) — continuous
      chunk_step(params, tokens, positions, reset_mask, cache) — chunked
      decode_step(params, tokens, pos, cache)

    The unused step for the chosen scheduler may be None. ``block_pool``
    (continuous only) switches the Scheduler to pool-managed paged
    admission; the static scheduler serves paged caches through a fully
    mapped identity table instead (init_cache(paged=True) default).
    ``prefill_chunk`` (continuous only, needs ``chunk_step``) admits
    prompts in chunks of at most that many tokens, interleaved with
    resident decode steps. ``radix_cache`` (+ ``write_caps`` /
    ``ring_tokens`` / ``copy_block_fn``, continuous paged only) enables
    prefix sharing — see :class:`Scheduler`. ``copy_block_fn`` takes
    (cache, src, dst) with no params (models.transformer.cache_copy_block).
    ``over_commit`` (+ optional ``swap_out_fn``/``swap_in_fn`` from
    runtime.steps.make_swap_steps, continuous paged chunked only) drops
    worst-case reservations in favor of preemption; ``decode_ratio``
    paces decode steps against chunk steps — see :class:`Scheduler`.
    Swap fns take (cache, ids) / (cache, ids, payload) with no params.
    """
    if scheduler == "continuous":
        return serve_continuous(
            lambda t, pm, m, c: admit_step(params, t, pm, m, c),
            lambda t, p, c: decode_step(params, t, p, c),
            init_cache_fn, requests, batch_slots=batch_slots,
            prompt_pad_len=prompt_pad_len, max_len=max_len,
            block_pool=block_pool,
            chunk_fn=(None if chunk_step is None else
                      lambda t, pm, m, c: chunk_step(params, t, pm, m, c)),
            prefill_chunk=prefill_chunk, radix_cache=radix_cache,
            write_caps=write_caps, ring_tokens=ring_tokens,
            copy_block_fn=copy_block_fn, over_commit=over_commit,
            swap_out_fn=swap_out_fn, swap_in_fn=swap_in_fn,
            decode_ratio=decode_ratio, telemetry=telemetry)
    if scheduler != "static":
        raise ValueError(f"unknown scheduler {scheduler!r}")
    if telemetry is not None:
        raise ValueError("telemetry is a continuous-scheduler feature; "
                         "the static scheduler has no request lifecycle")
    if block_pool is not None:
        raise ValueError("block_pool is a continuous-scheduler feature; "
                         "static paged serving uses a fully mapped table")
    if prefill_chunk is not None:
        raise ValueError("prefill_chunk is a continuous-scheduler feature; "
                         "static groups prefill each group monolithically")
    if radix_cache is not None:
        raise ValueError("radix_cache is a continuous-scheduler feature; "
                         "prefix sharing needs the paged block pool")
    if over_commit:
        raise ValueError("over_commit is a continuous-scheduler feature; "
                         "preemption needs the paged block pool")
    if decode_ratio != 1:
        raise ValueError("decode_ratio is a continuous-scheduler feature; "
                         "static groups have no chunk/decode interleave")
    return serve_batch(lambda t, pm, c: prefill_step(params, t, c, pm),
                       lambda t, p, c: decode_step(params, t, p, c),
                       init_cache_fn, requests, batch_slots=batch_slots,
                       max_len=max_len)
