"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run artifacts (benchmarks/results/dryrun/*.json).

  PYTHONPATH=src python -m benchmarks.make_experiments_md > /tmp/sections.md
"""
from __future__ import annotations

import json
import sys

from benchmarks.roofline import analyze, load_all


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_section(recs):
    lines = [
        "## §Dry-run\n",
        "Every (architecture x input-shape) cell lowered AND compiled with "
        "pjit shardings on the single-pod 16x16 (256 chips) and multi-pod "
        "2x16x16 (512 chips) meshes. Columns: per-device peak HBM estimate "
        "(argument+output+temp−aliased), exec-raw collective mix from the "
        "post-SPMD HLO, grad-accumulation factor (train cells), compile "
        "time on this container's single CPU core.\n",
        "| arch | shape | mesh | kind | HBM GiB | fits 16G | M | "
        "collectives (exec, MiB: AR/AG/A2A/CP) | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("variant", "baseline") != "baseline":
            continue
        mem = r["memory"]["peak_hbm_estimate"]
        coll = r.get("exec_raw", {}).get("collective_bytes_per_device", {})
        mix = "/".join(
            f"{coll.get(k, 0) / 2**20:.0f}"
            for k in ("all-reduce", "all-gather", "all-to-all",
                      "collective-permute"))
        fits = "yes" if mem < 16 * 2**30 else "**NO**"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | "
            f"{fmt_bytes(mem)} | {fits} | {r.get('microbatches', '-')} | "
            f"{mix} | {r['compile_s']} |")
    return "\n".join(lines)


def roofline_section(recs):
    lines = [
        "\n## §Roofline\n",
        "Terms from the per-device compiled module (TPU v5e: 197 bf16 "
        "TFLOP/s, 819 GB/s HBM, 50 GB/s/link ICI). HLO FLOPs/bytes come "
        "from the two-point cost-extrapolation lowerings (scan bodies are "
        "counted once by XLA cost analysis; we lower unrolled at 1 and 2 "
        "pattern repeats and extrapolate linearly — DESIGN.md §7). "
        "useful = MODEL_FLOPS / HLO_FLOPs (6·N_active·D train, 2·N_active·D "
        "serve); roofline fraction = ideal model-flops time / dominant "
        "term.\n",
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    for r in recs:
        if r.get("variant", "baseline") != "baseline" or \
                "flops_per_device" not in r:
            continue
        a = analyze(r)
        rows.append(a)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
            f"{a['compute_s']:.4f} | {a['memory_s']:.4f} | "
            f"{a['collective_s']:.4f} | {a['dominant']} | "
            f"{a['useful_ratio']:.3f} | {a['roofline_fraction']:.3f} |")
    # summary of dominant bottlenecks
    from collections import Counter
    doms = Counter(a["dominant"] for a in rows)
    lines.append(f"\nDominant-term census: {dict(doms)}")
    return "\n".join(lines)


def main():
    recs = load_all()
    print(dryrun_section(recs))
    print(roofline_section(recs))


if __name__ == "__main__":
    main()
