"""Golden-output tests for runtime.serve_loop.serve_batch using a tiny
deterministic stub model: next_token = (2 * token + 1) % VOCAB. Covers
left-pad packing (pads carry the -1 position sentinel), per-request
max_new_tokens (straggler off-by-one), the done-flag/decode accounting, and
ServeStats bookkeeping (peak cache bytes, slot utilization, per-request
latency). The continuous scheduler's counterpart lives in
tests/test_scheduler.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import Request, ServeStats, serve_batch
from serve_testlib import golden as _golden
from serve_testlib import next_arr as _next_arr
from serve_testlib import onehot as _onehot

pytestmark = pytest.mark.serve


class StubModel:
    """prefill predicts next(last prompt token); decode predicts next(cur).
    The 'cache' counts decode calls so scheduling is observable."""

    def __init__(self):
        self.prefill_tokens = []          # packed (B, T) matrices seen
        self.prefill_positions = []       # packed (B, T) position maps seen

    def init_cache(self, batch):
        return {"steps": jnp.zeros((), jnp.int32),
                "kv": jnp.zeros((batch, 4), jnp.float32)}

    def prefill(self, tokens, positions, cache):
        self.prefill_tokens.append(np.asarray(tokens))
        self.prefill_positions.append(np.asarray(positions))
        logits = _onehot(_next_arr(np.asarray(tokens)))    # (B, T, V)
        return logits, cache

    def decode(self, tokens, pos, cache):
        logits = _onehot(_next_arr(np.asarray(tokens)))    # (B, 1, V)
        cache = dict(cache, steps=cache["steps"] + 1)
        return logits, cache


def _serve(requests, batch_slots=4):
    m = StubModel()
    stats = serve_batch(m.prefill, m.decode, m.init_cache, requests,
                        batch_slots=batch_slots)
    return m, stats


class TestGoldenOutputs:
    def test_greedy_continuation_matches_golden(self):
        reqs = [Request(rid=i, prompt=np.asarray([3 + i, 5 + i]),
                        max_new_tokens=6) for i in range(3)]
        _, stats = _serve(reqs)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 6)
            assert r.done
        assert stats.tokens_generated == 18

    def test_left_pad_packing(self):
        reqs = [Request(rid=0, prompt=np.asarray([7]), max_new_tokens=2),
                Request(rid=1, prompt=np.asarray([1, 2, 3]),
                        max_new_tokens=2)]
        m, _ = _serve(reqs)
        toks = m.prefill_tokens[0]
        assert toks.shape == (2, 3)
        np.testing.assert_array_equal(toks[0], [0, 0, 7])       # left-pad
        np.testing.assert_array_equal(toks[1], [1, 2, 3])
        # padded request still decodes from ITS last prompt token
        assert reqs[0].tokens_out == _golden([7], 2)

    def test_pad_positions_are_dead_cells(self):
        """Pads carry the -1 position sentinel; real tokens get 0..len-1
        regardless of padding (so attention/RoPE see the un-padded
        request — the serve-alone-equivalence contract)."""
        reqs = [Request(rid=0, prompt=np.asarray([7]), max_new_tokens=1),
                Request(rid=1, prompt=np.asarray([1, 2, 3]),
                        max_new_tokens=1)]
        m, _ = _serve(reqs)
        posm = m.prefill_positions[0]
        np.testing.assert_array_equal(posm[0], [-1, -1, 0])
        np.testing.assert_array_equal(posm[1], [0, 1, 2])

    def test_groups_split_by_batch_slots(self):
        reqs = [Request(rid=i, prompt=np.asarray([i + 1]), max_new_tokens=3)
                for i in range(5)]
        m, stats = _serve(reqs, batch_slots=2)
        assert stats.prefill_calls == 3                         # 2+2+1
        assert len(m.prefill_tokens) == 3
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 3)


class TestStragglerHandling:
    def test_per_request_max_new_tokens_exact(self):
        """A request with a smaller quota than the group max stops exactly
        at its quota (the pre-fix loop appended while others decoded)."""
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=1),
                Request(rid=1, prompt=np.asarray([4]), max_new_tokens=5),
                Request(rid=2, prompt=np.asarray([5]), max_new_tokens=3)]
        _, stats = _serve(reqs)
        assert [len(r.tokens_out) for r in reqs] == [1, 5, 3]
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
            assert r.done
        assert stats.tokens_generated == 9

    def test_zero_quota_request_generates_nothing(self):
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=0),
                Request(rid=1, prompt=np.asarray([4]), max_new_tokens=2)]
        _, stats = _serve(reqs)
        assert reqs[0].tokens_out == []
        assert reqs[0].done
        assert reqs[1].tokens_out == _golden([4], 2)
        assert stats.tokens_generated == 2

    def test_no_decode_after_all_done(self):
        """The done check runs BEFORE paying for another decode step:
        generating N tokens costs exactly N-1 decode calls (the first token
        comes from prefill logits)."""
        n = 4
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=n)]
        m, stats = _serve(reqs)
        assert stats.decode_steps == n - 1
        # the stub cache counted the same number of decode invocations
        assert stats.tokens_generated == n

    def test_all_zero_quota_never_decodes(self):
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=0)]
        _, stats = _serve(reqs)
        assert stats.decode_steps == 0
        assert stats.tokens_generated == 0


class TestStatsAccounting:
    def test_stats_fields(self):
        reqs = [Request(rid=i, prompt=np.asarray([i + 2]), max_new_tokens=3)
                for i in range(4)]
        _, stats = _serve(reqs, batch_slots=4)
        assert isinstance(stats, ServeStats)
        assert stats.prefill_calls == 1
        assert stats.decode_steps == 2
        assert stats.tokens_generated == 12
        assert stats.wall_s > 0
        assert stats.tokens_per_s > 0
        # the stub cache: one int32 scalar + (4, 4) f32 = 4 + 64 bytes
        assert stats.cache_bytes == 4 + 4 * 4 * 4
        # uniform quotas, full group: every decode cell is occupied
        assert stats.slot_utilization == 1.0

    def test_cache_bytes_tracks_peak_group(self):
        reqs = [Request(rid=0, prompt=np.asarray([1]), max_new_tokens=1),
                Request(rid=1, prompt=np.asarray([2]), max_new_tokens=1),
                Request(rid=2, prompt=np.asarray([3]), max_new_tokens=1)]
        _, stats = _serve(reqs, batch_slots=2)    # groups of 2 then 1
        assert stats.cache_bytes == 4 + 2 * 4 * 4  # the B=2 group dominates

    def test_cache_bytes_tracks_peak_live_cache(self):
        """cache_bytes reflects the largest LIVE cache at any point in the
        run, not just the init_cache_fn snapshot (a model whose cache grows
        while serving is measured at its peak)."""
        class GrowingStub(StubModel):
            def decode(self, tokens, pos, cache):
                logits, cache = super().decode(tokens, pos, cache)
                n = int(cache["steps"])
                cache = dict(cache,
                             kv=jnp.zeros((tokens.shape[0], 4 + 4 * n),
                                          jnp.float32))
                return logits, cache

        m = GrowingStub()
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=4)]
        stats = serve_batch(m.prefill, m.decode, m.init_cache, reqs,
                            batch_slots=1)
        # 3 decode steps -> final kv is (1, 16) f32 = 64 bytes + 4 scalar
        assert stats.cache_bytes == 4 + 16 * 4

    def test_slot_utilization_drops_on_skewed_quotas(self):
        """Static lockstep: in a group of {1, 5} quotas the 1-quota lane is
        already retired (its token came from prefill) for all 4 decode
        steps -> 4 of 8 cells occupied."""
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=1),
                Request(rid=1, prompt=np.asarray([4]), max_new_tokens=5)]
        _, stats = _serve(reqs, batch_slots=2)
        assert stats.decode_steps == 4
        assert stats.slot_utilization == pytest.approx(4 / 8)

    def test_request_latency_records_first_and_finish(self):
        """Model-call steps: prefill is step 1, decode d is step 1 + d.
        Group 2's requests see their queueing delay in first_token_step."""
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=3),
                Request(rid=1, prompt=np.asarray([4]), max_new_tokens=1)]
        _, stats = _serve(reqs, batch_slots=1)
        lat0 = stats.request_latency[0]
        lat1 = stats.request_latency[1]
        assert (lat0.first_token_step, lat0.finish_step) == (1, 3)
        # request 1 waits for group 1: its prefill is model-call 4
        assert (lat1.first_token_step, lat1.finish_step) == (4, 4)
        # zero-quota requests never enter the latency map
        zq = [Request(rid=9, prompt=np.asarray([3]), max_new_tokens=0)]
        _, stats = _serve(zq)
        assert 9 not in stats.request_latency
