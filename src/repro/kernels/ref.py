"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul import EPILOGUE_ACTS as _ACTS
from repro.kernels.nibble import unpack_nibbles as _unpack_nibbles


def _expand_groups(v, d):
    """(G,) per-group vector -> (1, d) per-column row (uniform groups)."""
    v = jnp.atleast_1d(jnp.asarray(v, jnp.float32))
    return jnp.repeat(v, d // v.shape[0])[None, :]


def epilogue_ref(f, *, bias=None, activation="none", mul=None,
                 out_scale=None, out_zp=None, qmin=-128, qmax=127):
    """Reference for the fused matmul epilogue: bias -> act -> mul -> requant."""
    f = f.astype(jnp.float32)
    if bias is not None:
        f = f + bias.astype(jnp.float32)[None, :]
    f = _ACTS[activation](f)
    if mul is not None:
        f = f * mul.astype(jnp.float32)
    if out_scale is not None:
        zp = 0.0 if out_zp is None else out_zp
        return jnp.clip(jnp.round(f / out_scale) + zp, qmin,
                        qmax).astype(jnp.int8)
    return f


def peg_fake_quant_ref(x, scales, zps, *, qmin, qmax):
    """x: (T, d) group-sorted; scales/zps: (K,), uniform groups."""
    d = x.shape[-1]
    s = _expand_groups(scales, d)
    z = _expand_groups(zps, d)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s) + z, qmin, qmax)
    return ((q - z) * s).astype(x.dtype)


def peg_quantize_ref(x, scales, zps, *, qmin, qmax, out_dtype=jnp.int8):
    d = x.shape[-1]
    s = _expand_groups(scales, d)
    z = _expand_groups(zps, d)
    return jnp.clip(jnp.round(x.astype(jnp.float32) / s) + z, qmin,
                    qmax).astype(out_dtype)


def int8_matmul_ref(a_q, w_q, s_a, s_w, out_dtype=jnp.float32):
    acc = jnp.einsum("mk,kn->mn", a_q.astype(jnp.int32),
                     w_q.astype(jnp.int32))
    return (acc.astype(jnp.float32) * (s_a * s_w)).astype(out_dtype)


def int8_matmul_fused_ref(a_q, w_q, s_a, s_w, *, z_a=None, bias=None,
                          activation="none", mul=None, out_scale=None,
                          out_zp=None, qmin=-128, qmax=127):
    """Per-tensor asymmetric dequant-matmul + epilogue oracle."""
    a = a_q.astype(jnp.float32)
    if z_a is not None:
        a = a - jnp.asarray(z_a, jnp.float32)
    f = (a * jnp.asarray(s_a, jnp.float32)) @ \
        (w_q.astype(jnp.float32) * jnp.asarray(s_w, jnp.float32))
    return epilogue_ref(f, bias=bias, activation=activation, mul=mul,
                        out_scale=out_scale, out_zp=out_zp, qmin=qmin,
                        qmax=qmax)


def int8_matmul_peg_ref(a_q, w_q, act_scales, act_zps, w_scale,
                        out_dtype=jnp.float32):
    """Dequantize-then-matmul oracle for the PEG fixed-point path."""
    k = a_q.shape[-1]
    s = _expand_groups(act_scales, k)
    z = _expand_groups(act_zps, k)
    a_hat = (a_q.astype(jnp.float32) - z) * s
    w_hat = w_q.astype(jnp.float32) * w_scale
    return (a_hat @ w_hat).astype(out_dtype)


def int8_matmul_peg_fused_ref(a_q, w_q, act_scales, act_zps, w_scale, *,
                              bias=None, activation="none", mul=None,
                              out_scale=None, out_zp=None, qmin=-128,
                              qmax=127):
    """PEG dequant-matmul + epilogue oracle."""
    f = int8_matmul_peg_ref(a_q, w_q, act_scales, act_zps, w_scale)
    return epilogue_ref(f, bias=bias, activation=activation, mul=mul,
                        out_scale=out_scale, out_zp=out_zp, qmin=qmin,
                        qmax=qmax)


def w_colsum_groups(w_q, num_groups):
    """(G, N) per-group column sums of int8 weights (zero-point correction)."""
    k, n = w_q.shape
    gs = k // num_groups
    return jnp.sum(w_q.reshape(num_groups, gs, n).astype(jnp.int32), axis=1)


def int8_attend_decode_ref(q_q, q_scale, k_q, k_scale, v_q, v_scale, k_pos,
                           q_pos, *, q_zp=None, k_zp=None, v_zp=None,
                           window=None, logit_softcap=None,
                           sm_quant=None, sm_qmin=0, sm_qmax=255,
                           smo_quant=None, smo_qmin=0, smo_qmax=255,
                           kv_bits=8):
    """Dequantize-then-attend oracle for the int8 KV decode kernel.

    Shapes as in :func:`repro.kernels.int8_attend_decode.int8_attend_decode`:
    q_q (B, KV, G, hd), k_q/v_q (B, S, KV, hd), scales per head(-slot),
    q_zp optional (B, KV, G), k_zp/v_zp optional (B, KV), k_pos (B, S),
    q_pos (B,). ``kv_bits=4``: k_q/v_q are split-half nibble-packed
    (B, S, KV, hd/2) payloads, unpacked here before the math.
    Returns (B, KV, G, hd) f32.
    """
    if kv_bits == 4:
        hd = q_q.shape[-1]
        k_q = _unpack_nibbles(k_q, hd)
        v_q = _unpack_nibbles(v_q, hd)
    qh = q_q.astype(jnp.float32)
    if q_zp is not None:
        qh = qh - q_zp.astype(jnp.float32)[..., None]
    qh = qh * q_scale.astype(jnp.float32)[..., None]
    kh = k_q.astype(jnp.float32)
    vh = v_q.astype(jnp.float32)
    if k_zp is not None:
        kh = kh - k_zp.astype(jnp.float32)[:, None, :, None]
    if v_zp is not None:
        vh = vh - v_zp.astype(jnp.float32)[:, None, :, None]
    kh = kh * k_scale.astype(jnp.float32)[..., None]
    vh = vh * v_scale.astype(jnp.float32)[..., None]
    s = jnp.einsum("bkgd,bskd->bkgs", qh, kh)
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    if sm_quant is not None:
        sm_s, sm_z = sm_quant[0], sm_quant[1]
        sq = jnp.clip(jnp.round(s / sm_s) + sm_z, sm_qmin, sm_qmax)
        s = (sq - sm_z) * sm_s
    kp = k_pos[:, None, None, :]
    qp = q_pos[:, None, None, None]
    valid = (kp >= 0) & (kp <= qp)
    if window is not None:
        valid &= kp > qp - window
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if smo_quant is not None:        # fake-quant probs, NOT renormalized
        so_s, so_z = smo_quant[0], smo_quant[1]
        pq = jnp.clip(jnp.round(p / so_s) + so_z, smo_qmin, smo_qmax)
        p = (pq - so_z) * so_s
    return jnp.einsum("bkgs,bskd->bkgd", p, vh)


def paged_positions_ref(block_table, q_pos, *, s_cap, block_size):
    """Derived key positions (B, nb*bs) of a block-paged lane.

    A lane writes positions 0..q_pos contiguously, so logical cell ``L``
    holds position ``p = q_pos - ((q_pos - L) mod S)`` when that is >= 0
    (and L < S); everything else — unwritten cells, stale cells of freshly
    grown blocks, unmapped blocks, idle lanes (q_pos = -1) — derives -1.
    This is the validity rule both paged kernels implement.
    """
    nb = block_table.shape[1]
    L = jnp.arange(nb * block_size, dtype=jnp.int32)[None, :]
    qp = jnp.asarray(q_pos, jnp.int32)[:, None]
    p = qp - jnp.mod(qp - L, s_cap)
    mapped = jnp.repeat(block_table >= 0, block_size, axis=1)
    valid = (L < s_cap) & (p >= 0) & mapped
    return jnp.where(valid, p, -1)


def paged_gather_ref(arena, block_table):
    """(N, bs, ...) arena + (B, nb) block table -> (B, nb*bs, ...) dense
    per-lane view (unmapped blocks gather block 0's payload — callers mask
    with :func:`paged_positions_ref`)."""
    phys = jnp.clip(block_table, 0, arena.shape[0] - 1)
    g = arena[phys]                                    # (B, nb, bs, ...)
    return g.reshape(g.shape[0], -1, *arena.shape[2:])


def paged_attend_decode_ref(q, k_arena, v_arena, block_table, q_pos, *,
                            s_cap, window=None, logit_softcap=None,
                            sm_quant=None, sm_qmin=0, sm_qmax=255,
                            smo_quant=None, smo_qmin=0, smo_qmax=255):
    """Gather-then-attend oracle for the paged bf16/f32 decode kernel.

    q: (B, KV, G, hd) with the attention scale folded in; arenas
    (N, bs, KV, hd); block_table (B, nb); q_pos (B,). Returns
    (B, KV, G, hd) f32.
    """
    bs = k_arena.shape[1]
    k = paged_gather_ref(k_arena, block_table).astype(jnp.float32)
    v = paged_gather_ref(v_arena, block_table).astype(jnp.float32)
    kp = paged_positions_ref(block_table, q_pos, s_cap=s_cap,
                             block_size=bs)
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32), k)
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    if sm_quant is not None:
        sm_s, sm_z = sm_quant[0], sm_quant[1]
        sq = jnp.clip(jnp.round(s / sm_s) + sm_z, sm_qmin, sm_qmax)
        s = (sq - sm_z) * sm_s
    kpb = kp[:, None, None, :]
    qpb = jnp.asarray(q_pos)[:, None, None, None]
    valid = (kpb >= 0) & (kpb <= qpb)
    if window is not None:
        valid &= kpb > qpb - window
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if smo_quant is not None:        # fake-quant probs, NOT renormalized
        so_s, so_z = smo_quant[0], smo_quant[1]
        pq = jnp.clip(jnp.round(p / so_s) + so_z, smo_qmin, smo_qmax)
        p = (pq - so_z) * so_s
    return jnp.einsum("bkgs,bskd->bkgd", p, v)


def paged_int8_attend_decode_ref(q_q, q_scale, k_arena, k_scale, v_arena,
                                 v_scale, block_table, q_pos, *, s_cap,
                                 q_zp=None, k_zp=None, v_zp=None,
                                 window=None, logit_softcap=None,
                                 sm_quant=None, sm_qmin=0, sm_qmax=255,
                                 smo_quant=None, smo_qmin=0, smo_qmax=255,
                                 kv_bits=8):
    """Gather-then-dequantize oracle for the paged int8 decode kernel:
    delegates the attention math to :func:`int8_attend_decode_ref` over the
    dense per-lane view + derived positions (the block gather is
    layout-agnostic, so packed nibble arenas gather unchanged and the dense
    oracle unpacks them)."""
    bs = k_arena.shape[1]
    kp = paged_positions_ref(block_table, q_pos, s_cap=s_cap,
                             block_size=bs)
    return int8_attend_decode_ref(
        q_q, q_scale,
        paged_gather_ref(k_arena, block_table),
        paged_gather_ref(k_scale, block_table),
        paged_gather_ref(v_arena, block_table),
        paged_gather_ref(v_scale, block_table),
        kp, q_pos, q_zp=q_zp, k_zp=k_zp, v_zp=v_zp, window=window,
        logit_softcap=logit_softcap, sm_quant=sm_quant, sm_qmin=sm_qmin,
        sm_qmax=sm_qmax, smo_quant=smo_quant, smo_qmin=smo_qmin,
        smo_qmax=smo_qmax, kv_bits=kv_bits)


def ln_fake_quant_ref(x, gamma, beta, scale, zp, *, qmin, qmax, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    s = _expand_groups(scale, x.shape[-1])
    z = _expand_groups(zp, x.shape[-1])
    q = jnp.clip(jnp.round(y / s) + z, qmin, qmax)
    return ((q - z) * s).astype(x.dtype)


def ln_quantize_ref(x, gamma, beta, scale, zp, *, qmin, qmax, eps=1e-6,
                    out_dtype=jnp.int8):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    s = _expand_groups(scale, x.shape[-1])
    z = _expand_groups(zp, x.shape[-1])
    return jnp.clip(jnp.round(y / s) + z, qmin, qmax).astype(out_dtype)


def rms_fake_quant_ref(x, gamma, scale, zp, *, qmin, qmax, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), -1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    s = _expand_groups(scale, x.shape[-1])
    z = _expand_groups(zp, x.shape[-1])
    q = jnp.clip(jnp.round(y / s) + z, qmin, qmax)
    return ((q - z) * s).astype(x.dtype)


def rms_quantize_ref(x, gamma, scale, zp, *, qmin, qmax, eps=1e-6,
                     out_dtype=jnp.int8):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), -1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    s = _expand_groups(scale, x.shape[-1])
    z = _expand_groups(zp, x.shape[-1])
    return jnp.clip(jnp.round(y / s) + z, qmin, qmax).astype(out_dtype)
