"""Perf-variant correctness: banded attention == dense; int8 weight storage
keeps the forward close to bf16."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.models.attention import (AttnConfig, _banded_attend, _dense_attend)
from repro.models.common import quantize_weight_int8, resolve_weight


class TestBandedAttention:
    @pytest.mark.parametrize("T,W,block", [(96, 24, 16), (128, 32, 32),
                                           (90, 17, 16)])
    def test_matches_dense(self, T, W, block):
        B, H, KV, hd = 2, 4, 2, 16
        cfg = AttnConfig(num_heads=H, num_kv_heads=KV, head_dim=hd, window=W)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, T, H, hd))
        k = jax.random.normal(ks[1], (B, T, KV, hd))
        v = jax.random.normal(ks[2], (B, T, KV, hd))
        pos = jnp.broadcast_to(jnp.arange(T), (B, T)).astype(jnp.int32)
        ref = _dense_attend(q, k, v, pos, pos, cfg)
        out = _banded_attend(q, k, v, pos, pos, cfg, block=block)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-5, atol=1e-5)

    def test_model_level_banded_matches(self):
        cfg = get_config("h2o-danube3-4b").reduced()   # window 16
        params = tfm.init_params(cfg, jax.random.PRNGKey(0),
                                 dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0,
                                  cfg.vocab_size)
        l_ref, _ = tfm.forward(cfg, params, toks)
        l_band, _ = tfm.forward(cfg, params, toks, chunked="banded")
        np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_band),
                                   rtol=1e-4, atol=1e-4)


class TestInt8WeightStorage:
    def test_resolve_weight_roundtrip(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (128, 64)) / 8
        qw = quantize_weight_int8(w)
        w2 = resolve_weight(qw)
        assert qw["q"].dtype == jnp.int8
        # per-out-channel int8: error <= scale/2 + bf16 dequant rounding
        # (resolve_weight dequantizes in bf16 for the matmul: 2^-8 relative)
        err = np.asarray(jnp.abs(w - w2))
        amax = np.abs(np.asarray(w)).max(axis=0)
        bound = np.asarray(qw["s"])[0] * 0.51 + amax * 2.0 ** -8 + 1e-4
        assert np.all(err <= bound[None, :])

    def test_forward_with_int8_weights_close(self):
        cfg = get_config("internlm2-20b").reduced()
        params = tfm.init_params(cfg, jax.random.PRNGKey(0),
                                 dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                  cfg.vocab_size)
        l_ref, _ = tfm.forward(cfg, params, toks)

        def quantize_tree(p):
            out = jax.tree.map(lambda x: x, p)   # copy structure
            for g in out["scan"]:
                for blk in ("attn", "ffn"):
                    if blk not in g:
                        continue
                    for name, w in list(g[blk].items()):
                        if w.ndim == 3 and w.shape[-1] >= 64:  # (L, in, out)
                            g[blk][name] = jax.vmap(quantize_weight_int8)(w)
            return out

        pq = quantize_tree(params)
        l_q, _ = tfm.forward(cfg, pq, toks)
        # int8 weights perturb logits but keep them correlated
        ref = np.asarray(l_ref).reshape(-1)
        got = np.asarray(l_q).reshape(-1)
        corr = np.corrcoef(ref, got)[0, 1]
        assert corr > 0.99
