"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def peg_fake_quant_ref(x, scales, zps, *, qmin, qmax):
    """x: (T, d) group-sorted; scales/zps: (K,), uniform groups."""
    t, d = x.shape
    k = scales.shape[0]
    gs = d // k
    s = jnp.repeat(scales.astype(jnp.float32), gs)[None, :]
    z = jnp.repeat(zps.astype(jnp.float32), gs)[None, :]
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s) + z, qmin, qmax)
    return ((q - z) * s).astype(x.dtype)


def peg_quantize_ref(x, scales, zps, *, qmin, qmax, out_dtype=jnp.int8):
    t, d = x.shape
    k = scales.shape[0]
    gs = d // k
    s = jnp.repeat(scales.astype(jnp.float32), gs)[None, :]
    z = jnp.repeat(zps.astype(jnp.float32), gs)[None, :]
    return jnp.clip(jnp.round(x.astype(jnp.float32) / s) + z, qmin,
                    qmax).astype(out_dtype)


def int8_matmul_ref(a_q, w_q, s_a, s_w, out_dtype=jnp.float32):
    acc = jnp.einsum("mk,kn->mn", a_q.astype(jnp.int32),
                     w_q.astype(jnp.int32))
    return (acc.astype(jnp.float32) * (s_a * s_w)).astype(out_dtype)


def int8_matmul_peg_ref(a_q, w_q, act_scales, act_zps, w_scale,
                        out_dtype=jnp.float32):
    """Dequantize-then-matmul oracle for the PEG fixed-point path."""
    m, k = a_q.shape
    g = act_scales.shape[0]
    gs = k // g
    s = jnp.repeat(act_scales.astype(jnp.float32), gs)[None, :]
    z = jnp.repeat(act_zps.astype(jnp.float32), gs)[None, :]
    a_hat = (a_q.astype(jnp.float32) - z) * s
    w_hat = w_q.astype(jnp.float32) * w_scale
    return (a_hat @ w_hat).astype(out_dtype)


def w_colsum_groups(w_q, num_groups):
    """(G, N) per-group column sums of int8 weights (zero-point correction)."""
    k, n = w_q.shape
    gs = k // num_groups
    return jnp.sum(w_q.reshape(num_groups, gs, n).astype(jnp.int32), axis=1)


def ln_fake_quant_ref(x, gamma, beta, scale, zp, *, qmin, qmax, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    q = jnp.clip(jnp.round(y / scale) + zp, qmin, qmax)
    return ((q - zp) * scale).astype(x.dtype)


def ln_quantize_ref(x, gamma, beta, scale, zp, *, qmin, qmax, eps=1e-6,
                    out_dtype=jnp.int8):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return jnp.clip(jnp.round(y / scale) + zp, qmin, qmax).astype(out_dtype)
