"""Paper Table 6: comparison of all proposed methods on the full suite —
W8A8 PTQ baseline vs MP-PTQ vs PEG-PTQ (K + permutation) vs per-tensor QAT."""
from __future__ import annotations

from benchmarks.common import (cached_table, eval_qat, eval_task,
                               glue_average, qat_finetune, quantize_and_eval,
                               train_task)
from repro.core import (mixed_precision_policy, peg_policy, w8a8_policy)
from repro.data.synthetic import GLUE_SUITE


def compute():
    rows = {"FP32": {}, "W8A8 PTQ": {}, "W8A{8,16} MP-PTQ": {},
            "W8A8 PEG-PTQ (K=4+P)": {}, "W8A8 QAT": {}}
    for task in GLUE_SUITE:
        params = train_task(task)
        rows["FP32"][task.name] = eval_task(task, params)
        rows["W8A8 PTQ"][task.name] = \
            quantize_and_eval(task, params, w8a8_policy())
        rows["W8A{8,16} MP-PTQ"][task.name] = \
            quantize_and_eval(task, params, mixed_precision_policy())
        rows["W8A8 PEG-PTQ (K=4+P)"][task.name] = \
            quantize_and_eval(task, params, peg_policy(4))
        qat_params, ctx_factory = qat_finetune(task, params, w8a8_policy())
        rows["W8A8 QAT"][task.name] = eval_qat(task, qat_params, ctx_factory)
    for name in rows:
        rows[name]["GLUE"] = glue_average(
            {k: v for k, v in rows[name].items() if k != "GLUE"})
    return rows


def run():
    return cached_table("table6_methods", compute)


def report(rows):
    tasks = [t.name for t in GLUE_SUITE] + ["GLUE"]
    lines = ["method," + ",".join(tasks)]
    for label, scores in rows.items():
        lines.append(f"\"{label}\"," +
                     ",".join(f"{scores[t]:.2f}" for t in tasks))
    return "\n".join(lines)


if __name__ == "__main__":
    print(report(run()))
