"""Uniform affine quantization (paper eq. 1-2) with straight-through gradients.

The simulated-quantization forward is written so that plain ``jax.grad`` yields
the STE gradient for the input *and* the LSQ-style gradients for learnable
scale / zero-point (Esser et al. 2019; Jain et al. 2019) — no custom_vjp
needed: ``round`` is wrapped with a stop-gradient identity, while the
surrounding ``clip`` and de-quantization stay differentiable.

``QuantParams`` is a pytree so parameter sets can live inside jitted train
steps, be sharded with the model, and be learned during QAT.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.quant_config import Granularity, QuantizerConfig


class QuantParams(NamedTuple):
    """Pytree of quantization parameters for one tensor site.

    scale / zero_point shapes by granularity:
      PER_TENSOR           -> scalar ()
      PER_CHANNEL          -> (C,) along ``channel_axis``
      PER_EMBEDDING        -> (d,) along ``channel_axis``
      PER_EMBEDDING_GROUP  -> (K,), expanded through ``group_index`` (d,)

    ``group_index[j]`` = group id of embedding dim j (identity layout after the
    range-based permutation has been folded into the weights — see peg.py).
    """
    scale: jnp.ndarray
    zero_point: jnp.ndarray
    group_index: Optional[jnp.ndarray] = None


def _round_ste(x: jnp.ndarray) -> jnp.ndarray:
    """round-to-nearest with identity gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def _expand(qp: QuantParams, ndim: int, channel_axis: int):
    """Broadcast scale/zp to the tensor rank along channel_axis."""
    s, z = qp.scale, qp.zero_point
    if qp.group_index is not None:        # PEG: (K,) -> (d,)
        s = s[qp.group_index]
        z = z[qp.group_index]
    if s.ndim == 0:
        return s, z
    axis = channel_axis % ndim
    shape = [1] * ndim
    shape[axis] = s.shape[0]
    return s.reshape(shape), z.reshape(shape)


def fake_quant(x: jnp.ndarray, qp: QuantParams, cfg: QuantizerConfig) -> jnp.ndarray:
    """Simulated quantization: eq. (1) then eq. (2) of the paper.

    Differentiable in ``x`` (STE through round, zero outside the clip range)
    and in ``qp.scale`` / ``qp.zero_point`` (LSQ gradients).
    """
    if not cfg.enabled:
        return x
    s, z = _expand(qp, x.ndim, cfg.channel_axis)
    s = jnp.maximum(s, jnp.finfo(jnp.float32).tiny).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    q = _round_ste(xf / s) + z                       # eq. (1) before clipping
    q = jnp.clip(q, cfg.qmin, cfg.qmax)
    out = (q - z) * s                                # eq. (2)
    return out.astype(x.dtype)


def quantize(x: jnp.ndarray, qp: QuantParams, cfg: QuantizerConfig) -> jnp.ndarray:
    """To the integer grid (eq. 1). Returns int32 in [qmin, qmax]."""
    s, z = _expand(qp, x.ndim, cfg.channel_axis)
    s = jnp.maximum(s, jnp.finfo(jnp.float32).tiny)
    q = jnp.round(x.astype(jnp.float32) / s) + z
    return jnp.clip(q, cfg.qmin, cfg.qmax).astype(jnp.int32)


def dequantize(q: jnp.ndarray, qp: QuantParams, cfg: QuantizerConfig) -> jnp.ndarray:
    """eq. (2)."""
    s, z = _expand(qp, q.ndim, cfg.channel_axis)
    return ((q.astype(jnp.float32) - z) * s)


def params_from_range(x_min: jnp.ndarray, x_max: jnp.ndarray,
                      cfg: QuantizerConfig,
                      group_index: Optional[jnp.ndarray] = None) -> QuantParams:
    """Scale / zero-point from an estimated real-valued range.

    Symmetric: grid symmetric around 0 (paper uses this for weights).
    Asymmetric: affine grid covering [min, max] with an integer zero-point.
    """
    x_min = jnp.minimum(x_min.astype(jnp.float32), 0.0)   # grid must contain 0
    x_max = jnp.maximum(x_max.astype(jnp.float32), 0.0)
    if cfg.symmetric:
        amax = jnp.maximum(jnp.abs(x_min), jnp.abs(x_max))
        scale = jnp.maximum(amax / cfg.qmax, jnp.finfo(jnp.float32).tiny)
        zp = jnp.zeros_like(scale)
    else:
        scale = jnp.maximum((x_max - x_min) / cfg.num_levels,
                            jnp.finfo(jnp.float32).tiny)
        zp = jnp.clip(jnp.round(-x_min / scale), cfg.qmin, cfg.qmax)
    return QuantParams(scale=scale, zero_point=zp, group_index=group_index)


def reduce_range(x: jnp.ndarray, cfg: QuantizerConfig):
    """(min, max) reduced over all axes except the channel axis (if any)."""
    if cfg.granularity == Granularity.PER_TENSOR:
        return jnp.min(x), jnp.max(x)
    axis = cfg.channel_axis % x.ndim
    red = tuple(a for a in range(x.ndim) if a != axis)
    return jnp.min(x, axis=red), jnp.max(x, axis=red)


def quant_error(x: jnp.ndarray, qp: QuantParams, cfg: QuantizerConfig) -> jnp.ndarray:
    """Mean squared quantization error — the MSE-estimator objective."""
    return jnp.mean(jnp.square(x - fake_quant(x, qp, cfg)))


def telemetry_stats(x: jnp.ndarray, qp: QuantParams,
                    cfg: QuantizerConfig) -> jnp.ndarray:
    """Quant-health vector ``[n_clipped, n_total, amax, cal_range]`` (4,) f32.

    Mirrors :func:`fake_quant`'s grid exactly: a value counts as clipped when
    its pre-clip integer image lands outside [qmin, qmax]. ``cal_range`` is
    the largest real magnitude the calibrated grid can represent (max over
    channels/groups of ``max(|s*(qmin-z)|, |s*(qmax-z)|)``) so
    ``amax / cal_range > 1`` means traffic exceeded calibration.
    """
    s, z = _expand(qp, x.ndim, cfg.channel_axis)
    s = jnp.maximum(s, jnp.finfo(jnp.float32).tiny).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    t = jnp.round(xf / s) + z
    clipped = jnp.sum((t < cfg.qmin) | (t > cfg.qmax))
    lo = jnp.abs(s * (cfg.qmin - z))
    hi = jnp.abs(s * (cfg.qmax - z))
    cal_range = jnp.max(jnp.maximum(lo, hi))
    return jnp.stack([clipped.astype(jnp.float32),
                      jnp.float32(x.size),
                      jnp.max(jnp.abs(xf)),
                      cal_range.astype(jnp.float32)])
