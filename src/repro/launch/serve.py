"""Serving launcher: batched requests against a (optionally W8A8-quantized)
model — prefill + decode with KV cache.

``--quantize`` serves with *simulated* quantization (fake-quant, f32
matmuls). ``--quantize --deploy-int8`` serves the true fixed-point path:
weights are pre-packed to int8 in the param pytree and the FFN / attention
projections run on the Pallas kernels (``ln/rms_quantize ->
int8_matmul_peg(+fused epilogue) -> int8_matmul``); a parity check against
the fake-quant reference is printed at startup.

``--kv-bits 8`` additionally stores the KV cache int8 (per-head per-slot
scales) and decodes through the fused ``int8_attend_decode`` kernel; a
multi-step decode parity check against the bf16-cache path is printed at
startup. ``--kv-bits 4`` packs two int4 cells per cache byte (half the
int8 cache HBM — ~2x resident decode lanes per pool byte) and decodes
through the same kernels' in-VMEM nibble-unpack path; startup additionally
quantifies int4-vs-int8 drift (max-abs logit delta + greedy-token match
rate over teacher-forced decode steps).

``--weight-bits 4`` packs the projection/FFN weights at 4 bits (paper
Tables 5-7 sub-8-bit regime, MSE ranges): two int4 rows per byte in the
packed payload; the matmul kernels unpack to int8 in VMEM, halving HBM
weight reads. Sites the packing cannot express (odd K / odd PEG group)
fall back to 8-bit-style fake-quant exactly as today.

``--scheduler continuous`` replaces the static group batching with the
slot-scheduled continuous-batching runtime (in-flight admission into freed
decode lanes, see repro.runtime.serve_loop); ``--parity`` serves the same
requests under both schedulers and verifies identical greedy tokens.

``--paged-kv`` switches every attention layer's cache to the block-paged
layout (``--block-size`` cells per block): the continuous scheduler owns a
block pool (``--num-blocks``, default = the dense worst case) that
allocates on admission, grows lanes at decode and frees on retirement —
HBM cache bytes then scale with LIVE tokens instead of
batch_slots x max_len; the static scheduler serves through a fully mapped
identity table (dense-equivalent paging). With ``--parity`` the same
requests are additionally served on the dense cache and greedy tokens are
verified identical (paged == dense), on top of the scheduler parity check.

``--prefill-chunk N`` (continuous scheduler only) admits prompts in chunks
of at most N tokens interleaved with resident decode steps (chunked
prefill), so one long prompt never stalls the resident lanes for a whole
monolithic prefill. ``--parity`` then additionally serves the requests
unchunked and verifies chunked == unchunked greedy tokens.

``--over-commit`` (continuous + ``--paged-kv``) drops worst-case block
reservations: admission claims only the actual prefix + first-chunk need,
the queue becomes priority-aware (``--priority`` gives every other request
a higher tier) and a pool running dry preempts a victim lane — spilling
its blocks to a host buffer with ``--swap-blocks`` (bit-exact resume) or
dropping + re-prefilling them through chunked admission. ``--decode-ratio``
holds decode cadence under prefill pressure. ``--parity`` then additionally
serves the same requests with worst-case reservations (no preemption) and
verifies preempted == unpreempted greedy tokens — including under
``--deploy-int8 --kv-bits 8``.

``--prefix-cache`` (continuous + ``--paged-kv``) enables prefix sharing: a
radix tree caches retired lanes' prompt blocks, admission maps the longest
block-aligned cached prefix read-only (refcounted, copy-on-write under
ring-window wrap) and prefills only the novel suffix. The launcher then
synthesizes a shared-prefix workload (every request opens with the same
``--prompt-len``/2-token system prefix) so the cache actually hits;
``--parity`` additionally serves the same requests with sharing disabled
and verifies shared == unshared greedy tokens — in particular under
``--quantize --deploy-int8 [--kv-bits 8]``, where the int8 KV blocks carry
their per-head per-slot scales inside the block and sharing stays
bit-exact.

CPU smoke:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
      --requests 8 --new-tokens 8 [--quantize [--deploy-int8 [--kv-bits 8]]] \
      [--scheduler continuous [--parity] [--prefill-chunk 16]] \
      [--paged-kv [--block-size 16] [--prefix-cache]]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Mode, QuantCtx, w8a8_policy
from repro.core.pipeline import ptq
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.parallel import make_dist, make_param_shardings
from repro.runtime import Request, serve
from repro.runtime.steps import (make_admit_step, make_chunk_prefill_step,
                                 make_decode_step, make_prefill_step)


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI. Exposed as a function so tests (tests/test_docs.py)
    can introspect the flag set and keep the docs from drifting."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--scheduler", choices=("static", "continuous"),
                    default="static",
                    help="static: group batching, lockstep decode per "
                         "group; continuous: slot-scheduled decode with "
                         "in-flight admission into freed lanes")
    ap.add_argument("--parity", action="store_true",
                    help="serve the same requests under BOTH schedulers "
                         "and verify identical per-request greedy tokens")
    ap.add_argument("--skew", type=int, default=0, metavar="N",
                    help="give every other request max_new_tokens=N "
                         "(skewed-quota workload; shows the continuous "
                         "scheduler's utilization win)")
    ap.add_argument("--quantize", action="store_true",
                    help="W8A8 PTQ (PEG on the FFN path) before serving")
    ap.add_argument("--deploy-int8", action="store_true",
                    help="serve the integer path: packed int8 weights + "
                         "Pallas kernels (requires --quantize)")
    ap.add_argument("--kv-bits", type=int, default=16, choices=(4, 8, 16),
                    help="8: int8 KV cache + fused int8 decode attention; "
                         "4: nibble-packed int4 cache (half the int8 HBM), "
                         "decoded through the kernels' in-VMEM unpack path "
                         "(both require --deploy-int8); 16: bf16/f32 cache")
    ap.add_argument("--weight-bits", type=int, default=8, choices=(4, 8),
                    help="4: pack deployable weights as int4 (two rows per "
                         "byte, MSE ranges; kernels unpack in VMEM — "
                         "halves HBM weight reads; requires --quantize); "
                         "8: standard W8A8 packing")
    ap.add_argument("--paged-kv", action="store_true",
                    help="block-paged KV cache: continuous scheduling "
                         "allocates blocks per LIVE token (block pool + "
                         "per-lane block tables); static serves through a "
                         "fully mapped identity table")
    ap.add_argument("--block-size", type=int, default=16, metavar="N",
                    help="token cells per KV block (with --paged-kv)")
    ap.add_argument("--num-blocks", type=int, default=0, metavar="N",
                    help="physical blocks in the paged pool (0 = dense "
                         "worst case batch_slots x ceil(max_len/bs); "
                         "smaller values exercise admission backpressure; "
                         "continuous scheduler only)")
    ap.add_argument("--prefill-chunk", type=int, default=0, metavar="N",
                    help="admit prompts in chunks of at most N tokens "
                         "interleaved with resident decode steps (chunked "
                         "prefill; 0 = monolithic slot-insert prefill; "
                         "continuous scheduler only)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over retired prompt blocks: "
                         "admission maps the longest block-aligned cached "
                         "prefix read-only (refcounted, copy-on-write) and "
                         "prefills only the novel suffix; synthesizes a "
                         "shared-prefix workload (continuous + --paged-kv)")
    ap.add_argument("--over-commit", action="store_true",
                    help="drop worst-case block reservations: admit "
                         "against actual prefix + first-chunk need, grow "
                         "on demand, and preempt a victim lane (lowest "
                         "priority, then youngest) when the pool runs dry "
                         "(continuous + --paged-kv)")
    ap.add_argument("--swap-blocks", action="store_true",
                    help="preempt by spilling the victim's blocks to a "
                         "host-memory buffer and re-uploading on resume "
                         "(bit-exact) instead of dropping + re-prefilling "
                         "them (requires --over-commit)")
    ap.add_argument("--priority", type=int, default=0, metavar="N",
                    help="give every other request priority tier N "
                         "(mirrors --skew; the over-commit scheduler "
                         "admits high tiers first and preempts low tiers "
                         "first; 0 = all requests tier 0)")
    ap.add_argument("--decode-ratio", type=int, default=1, metavar="N",
                    help="decode steps per chunk-prefill step once lanes "
                         "are decodable (>1 holds decode cadence under "
                         "prefill pressure; needs a chunked path: "
                         "--prefill-chunk or --over-commit)")
    ap.add_argument("--trace", metavar="FILE", default="",
                    help="record request-lifecycle events and write a "
                         "Chrome-trace-event JSON (load in "
                         "https://ui.perfetto.dev) to FILE; also prints "
                         "per-phase step-latency p50/p95/p99 (continuous "
                         "scheduler only)")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="N",
                    help="snapshot scheduler gauges (queue depth, resident "
                         "lanes, pool blocks, prefix hit rate, preemptions) "
                         "every N steps; written as JSON-lines next to "
                         "--trace (FILE.metrics.jsonl) and printed as "
                         "Prometheus text at exit (continuous only)")
    ap.add_argument("--quant-telemetry", action="store_true",
                    help="thread fixed-shape clip/saturation reductions out "
                         "of the jitted steps and report per-site clip "
                         "fractions + observed-amax/calibrated-range ratios "
                         "(and kv-cache scale stats at --kv-bits 8/4); "
                         "requires --quantize, continuous scheduler only")
    ap.add_argument("--stats-json", metavar="FILE", default="",
                    help="write the primary run's ServeStats as JSON to "
                         "FILE (ServeStats.to_json)")
    ap.add_argument("--async", dest="async_serve", action="store_true",
                    help="serve through the async front-end: requests "
                         "submit into a thread-safe queue and stream "
                         "tokens back per request while ONE scheduler "
                         "thread drives the engine's decomposed "
                         "prefill/insert/generate triad "
                         "(runtime.async_serve; dense cache only — "
                         "incompatible with --paged-kv/--prefill-chunk/"
                         "--prefix-cache/--over-commit and the telemetry "
                         "flags)")
    ap.add_argument("--tp", type=int, default=1, metavar="N",
                    help="shard the engine tensor-parallel over N devices "
                         "(jax.sharding mesh (1, N) over (data, model); "
                         "admission stays host-local, the admit mask "
                         "broadcasts replicated). On CPU, simulate "
                         "devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N "
                         "(requires --reduced; 1 = unsharded)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.deploy_int8 and not args.quantize:
        ap.error("--deploy-int8 requires --quantize")
    if args.kv_bits < 16 and not args.deploy_int8:
        ap.error(f"--kv-bits {args.kv_bits} requires --deploy-int8 "
                 "(the quantized KV cache is a deploy-path feature; "
                 "without it the cache stays bf16/f32)")
    if args.weight_bits != 8 and not args.quantize:
        ap.error(f"--weight-bits {args.weight_bits} requires --quantize")
    if args.block_size < 1:
        ap.error("--block-size must be >= 1")
    if args.prefill_chunk < 0:
        ap.error("--prefill-chunk must be >= 0")
    if args.prefill_chunk and args.scheduler != "continuous":
        ap.error("--prefill-chunk requires --scheduler continuous "
                 "(static groups prefill monolithically)")
    from repro.runtime import BlockPool, RadixCache, blocks_for_tokens
    from repro.runtime.serve_loop import _check_capacity
    if args.num_blocks and not args.paged_kv:
        ap.error("--num-blocks requires --paged-kv")
    if args.prefix_cache and not args.paged_kv:
        ap.error("--prefix-cache requires --paged-kv (prefix sharing maps "
                 "cached blocks through the block pool)")
    if args.prefix_cache and args.scheduler != "continuous":
        ap.error("--prefix-cache requires --scheduler continuous (the "
                 "static scheduler has no pool to share blocks from)")
    if args.over_commit and not (args.paged_kv
                                 and args.scheduler == "continuous"):
        ap.error("--over-commit requires --paged-kv and --scheduler "
                 "continuous (preemption is a paged feature)")
    if args.swap_blocks and not args.over_commit:
        ap.error("--swap-blocks requires --over-commit")
    if args.decode_ratio < 1:
        ap.error("--decode-ratio must be >= 1")
    if args.decode_ratio > 1 and not (args.prefill_chunk
                                      or args.over_commit):
        ap.error("--decode-ratio > 1 requires a chunked path "
                 "(--prefill-chunk or --over-commit)")
    if args.metrics_every < 0:
        ap.error("--metrics-every must be >= 0")
    if (args.trace or args.metrics_every or args.quant_telemetry) \
            and args.scheduler != "continuous":
        ap.error("--trace/--metrics-every/--quant-telemetry require "
                 "--scheduler continuous (telemetry instruments the "
                 "continuous scheduler's request lifecycle)")
    if args.quant_telemetry and not args.quantize:
        ap.error("--quant-telemetry requires --quantize (clip fractions "
                 "are measured against the calibrated quantization grids)")
    if args.async_serve and (args.paged_kv or args.prefill_chunk
                             or args.prefix_cache or args.over_commit
                             or args.trace or args.metrics_every
                             or args.quant_telemetry or args.stats_json):
        ap.error("--async serves through the bare engine triad (dense "
                 "cache, FIFO admission) — incompatible with --paged-kv/"
                 "--prefill-chunk/--prefix-cache/--over-commit and the "
                 "telemetry/--stats-json flags")
    if args.tp < 1:
        ap.error("--tp must be >= 1")
    if args.tp > 1 and not args.reduced:
        ap.error("--tp is the host-simulated tensor-parallel mode "
                 "(--reduced); the full-size path builds its own "
                 "production mesh")

    cfg = get_config(args.arch)
    dist = None
    if args.reduced:
        cfg = cfg.reduced()
        dtype = jnp.float32
        if args.tp > 1:
            ndev = len(jax.devices())
            if ndev < args.tp:
                ap.error(
                    f"--tp {args.tp}: only {ndev} device(s) visible; "
                    "simulate CPU devices with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={args.tp} "
                    "(set BEFORE the process imports jax)")
            mesh = jax.make_mesh((1, args.tp), ("data", "model"))
            dist = make_dist(mesh)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        dist = make_dist(mesh)
        dtype = jnp.bfloat16

    # per-lane table width: ring-window bounded for all-window archs
    # (ceil(S_w / block_size) instead of ceil(max_len / block_size))
    nb_lane = (tfm.paged_lane_blocks(cfg, args.max_len, args.block_size)
               if args.paged_kv
               else blocks_for_tokens(args.max_len, args.block_size))
    ring_tokens = (tfm.paged_ring_tokens(cfg, args.max_len, args.block_size)
                   if args.paged_kv else None)
    full_blocks = args.batch_slots * nb_lane
    num_blocks = args.num_blocks or full_blocks
    if args.paged_kv and args.scheduler == "static" \
            and num_blocks < full_blocks:
        ap.error("static paged serving needs the dense worst case "
                 f"(--num-blocks >= {full_blocks}); pool-constrained "
                 "admission is a continuous-scheduler feature")
    # fail before model build on workloads the serve loop would reject
    # (same shared check serve() re-runs on the real requests)
    probe_pool = BlockPool(num_blocks, args.block_size, args.batch_slots,
                           nb_lane) if args.paged_kv else None
    try:
        _check_capacity([Request(rid=-1,
                                 prompt=np.zeros(args.prompt_len, np.int32),
                                 max_new_tokens=max(args.new_tokens,
                                                    args.skew))],
                        args.max_len, probe_pool, ring_tokens)
    except ValueError as e:
        ap.error(f"--max-len / --num-blocks too small: {e}")

    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(cfg, key, stacked=True, dtype=dtype)
    if dist is not None:
        params = jax.tree.map(jax.device_put, params,
                              make_param_shardings(params, dist))

    ctx_factory = None
    if args.quantize:
        # calibrate on a few synthetic prompts using the unrolled layout,
        # then serve with layer-shared quant params (DESIGN.md §4)
        from repro.core import peg_policy
        import dataclasses
        pol = peg_policy(4)
        if args.weight_bits == 4:
            # sub-8-bit weights (paper Tables 5-7): symmetric int4 grid,
            # MSE-fit ranges; activations stay on the W8A8/PEG policy
            from repro.core import QuantizerConfig, RangeEstimator
            pol = dataclasses.replace(
                pol, weight_default=QuantizerConfig(
                    bits=4, symmetric=True,
                    estimator=RangeEstimator.MSE))
        flat_params = tfm.init_params(cfg, key, stacked=False, dtype=dtype)
        calib = [{"tokens": jax.random.randint(
            jax.random.PRNGKey(10 + i), (2, args.prompt_len), 0,
            cfg.vocab_size)} for i in range(2)]

        def fwd(p, b, ctx):
            logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
            return logits
        qm = ptq(fwd, flat_params, calib, pol,
                 collect_inputs=args.deploy_int8)
        # collapse per-layer sites to shared "layer/..." names (median scale)
        shared = {}
        for site, qp in qm.act_state.items():
            base = "layer/" + site.split("/", 1)[1] if site.startswith("layer") \
                else site
            shared.setdefault(base, qp)
        state = dict(shared)

        if args.deploy_int8:
            from repro.core import build_deploy
            fp_params = params
            params, deploy_acts = build_deploy(cfg, params, pol, state)

            def ctx_factory():
                return QuantCtx(policy=pol, mode=Mode.DEPLOY,
                                act_state=state, deploy_acts=deploy_acts)

            # parity: integer path vs the fake-quant reference it replaces
            toks = jax.random.randint(jax.random.PRNGKey(99),
                                      (2, args.prompt_len), 0, cfg.vocab_size)
            ref_ctx = QuantCtx(policy=pol, mode=Mode.APPLY, act_state=state)
            logits_ref, _ = tfm.forward(cfg, fp_params, toks, ctx=ref_ctx)
            logits_int, _ = tfm.forward(cfg, params, toks, ctx=ctx_factory())
            diff = float(jnp.max(jnp.abs(logits_ref - logits_int)))
            scale = float(jnp.max(jnp.abs(logits_ref)) + 1e-9)
            print(f"[deploy-int8] max |fake-quant - int8| logits diff "
                  f"{diff:.5f} (rel {diff / scale:.4%})")

            if args.kv_bits in (4, 8):
                # multi-step decode parity: quantized KV cache (fused
                # decode kernel) vs the bf16/f32-cache integer path it
                # replaces, teacher-forced on the bf16 path's argmax
                B, steps = 2, 4
                c16 = tfm.init_cache(cfg, B, args.max_len, dtype=dtype)
                cq = tfm.init_cache(cfg, B, args.max_len, dtype=dtype,
                                    kv_bits=args.kv_bits)
                l16, c16 = tfm.prefill(cfg, params, toks, c16,
                                       ctx=ctx_factory())
                lq, cq = tfm.prefill(cfg, params, toks, cq,
                                     ctx=ctx_factory())
                worst = float(jnp.max(jnp.abs(l16 - lq)) /
                              (jnp.max(jnp.abs(l16)) + 1e-9))
                cur = jnp.argmax(l16, axis=-1).astype(jnp.int32)
                pos = jnp.full((B, 1), toks.shape[1], jnp.int32)
                for _ in range(steps):
                    l16, c16 = tfm.decode_step(cfg, params, cur, pos, c16,
                                               ctx=ctx_factory())
                    lq, cq = tfm.decode_step(cfg, params, cur, pos, cq,
                                             ctx=ctx_factory())
                    rel = float(jnp.max(jnp.abs(l16 - lq)) /
                                (jnp.max(jnp.abs(l16)) + 1e-9))
                    worst = max(worst, rel)
                    cur = jnp.argmax(l16, axis=-1).astype(jnp.int32)
                    pos = pos + 1
                print(f"[kv-int{args.kv_bits}] max rel logits diff over "
                      f"prefill + {steps} decode steps vs bf16 cache: "
                      f"{worst:.4%}")

            if args.kv_bits == 4:
                # drift quantification (int4 vs int8 cache): max-abs
                # logit delta and greedy-token match rate, teacher-forced
                # on the int8 path's argmax so both see identical inputs
                B, steps = 2, 4
                c8 = tfm.init_cache(cfg, B, args.max_len, dtype=dtype,
                                    kv_bits=8)
                c4 = tfm.init_cache(cfg, B, args.max_len, dtype=dtype,
                                    kv_bits=4)
                l8, c8 = tfm.prefill(cfg, params, toks, c8,
                                     ctx=ctx_factory())
                l4, c4 = tfm.prefill(cfg, params, toks, c4,
                                     ctx=ctx_factory())
                delta = float(jnp.max(jnp.abs(l8 - l4)))
                matched = int(jnp.sum(jnp.argmax(l4, axis=-1) ==
                                      jnp.argmax(l8, axis=-1)))
                total = B
                cur = jnp.argmax(l8, axis=-1).astype(jnp.int32)
                pos = jnp.full((B, 1), toks.shape[1], jnp.int32)
                for _ in range(steps):
                    l8, c8 = tfm.decode_step(cfg, params, cur, pos, c8,
                                             ctx=ctx_factory())
                    l4, c4 = tfm.decode_step(cfg, params, cur, pos, c4,
                                             ctx=ctx_factory())
                    delta = max(delta, float(jnp.max(jnp.abs(l8 - l4))))
                    matched += int(jnp.sum(jnp.argmax(l4, axis=-1) ==
                                           jnp.argmax(l8, axis=-1)))
                    total += B
                    cur = jnp.argmax(l8, axis=-1).astype(jnp.int32)
                    pos = pos + 1
                print(f"[kv-int4] int4 vs int8 cache drift over prefill + "
                      f"{steps} decode steps: max |logit delta| "
                      f"{delta:.5f}, greedy-token match {matched}/{total} "
                      f"({matched / total:.1%})")
        else:
            def ctx_factory():
                return QuantCtx(policy=pol, mode=Mode.APPLY, act_state=state)

    prefill = jax.jit(make_prefill_step(cfg, dist=dist,
                                        ctx_factory=ctx_factory))
    admit = jax.jit(make_admit_step(cfg, dist=dist,
                                    ctx_factory=ctx_factory),
                    donate_argnums=(4,))
    decode = jax.jit(make_decode_step(cfg, dist=dist,
                                      ctx_factory=ctx_factory),
                     donate_argnums=(3,))
    chunk_step = jax.jit(make_chunk_prefill_step(cfg, dist=dist,
                                                 ctx_factory=ctx_factory),
                         donate_argnums=(4,))

    telemetry = None
    if args.trace or args.metrics_every or args.quant_telemetry:
        from repro.runtime import ServeTelemetry
        telemetry = ServeTelemetry.create(trace=bool(args.trace),
                                          metrics_every=args.metrics_every,
                                          quant=args.quant_telemetry)
    # quant telemetry uses SEPARATE jitted closures (the plain steps keep
    # their 2-output signature — parity runs reuse them untraced and the
    # tracer-off path never recompiles)
    admit_t = decode_t = chunk_t = None
    if args.quant_telemetry:
        admit_t = jax.jit(make_admit_step(cfg, dist=dist,
                                          ctx_factory=ctx_factory,
                                          quant_telemetry=True),
                          donate_argnums=(4,))
        decode_t = jax.jit(make_decode_step(cfg, dist=dist,
                                            ctx_factory=ctx_factory,
                                            quant_telemetry=True),
                           donate_argnums=(3,))
        chunk_t = jax.jit(make_chunk_prefill_step(cfg, dist=dist,
                                                  ctx_factory=ctx_factory,
                                                  quant_telemetry=True),
                          donate_argnums=(4,))

    def make_requests():
        rng = np.random.RandomState(args.seed)
        shared = (rng.randint(10, cfg.vocab_size, size=args.prompt_len // 2)
                  if args.prefix_cache else np.zeros(0, np.int64))
        return [Request(rid=i,
                        prompt=np.concatenate(
                            [shared,
                             rng.randint(10, cfg.vocab_size,
                                         size=args.prompt_len - len(shared))]
                        ).astype(np.int64),
                        max_new_tokens=(args.skew if args.skew and i % 2
                                        else args.new_tokens),
                        priority=(args.priority if args.priority and i % 2
                                  else 0))
                for i in range(args.requests)]

    def init_cache(batch, paged, scheduler, kv_bits=None):
        kvb = args.kv_bits if kv_bits is None else kv_bits
        if not paged:
            return tfm.init_cache(cfg, batch, args.max_len, dtype=dtype,
                                  kv_bits=kvb)
        if scheduler == "static":
            # fully mapped identity table (dense-equivalent paging; the
            # static loop has no pool to grow from)
            return tfm.init_cache(cfg, batch, args.max_len, dtype=dtype,
                                  kv_bits=kvb, paged=True,
                                  block_size=args.block_size)
        return tfm.init_cache(cfg, batch, args.max_len, dtype=dtype,
                              kv_bits=kvb, paged=True,
                              block_size=args.block_size,
                              num_blocks=num_blocks, mapped=False)

    copy_block = jax.jit(tfm.cache_copy_block, donate_argnums=(0,))
    if args.swap_blocks:
        from repro.runtime.steps import make_swap_steps
        _swap_out, _swap_in = make_swap_steps()
        # swap_out keeps the cache alive (no donation); swap_in updates the
        # arena in place
        swap_out = jax.jit(_swap_out)
        swap_in = jax.jit(_swap_in, donate_argnums=(0,))
    else:
        swap_out = swap_in = None

    def run(scheduler, requests, paged=None, chunk=0, prefix=None,
            over_commit=None, kv_bits=None, tel=None):
        paged = args.paged_kv if paged is None else paged
        prefix = ((args.prefix_cache if prefix is None else prefix)
                  and paged and scheduler == "continuous")
        oc = ((args.over_commit if over_commit is None else over_commit)
              and paged and scheduler == "continuous")
        pool = None
        if paged and scheduler == "continuous":
            pool = BlockPool(num_blocks, args.block_size, args.batch_slots,
                             nb_lane)
        armed = tel is not None and tel.quant is not None
        a_step, d_step = (admit_t, decode_t) if armed else (admit, decode)
        c_step = chunk_t if armed else chunk_step
        return serve(prefill, a_step, d_step,
                     lambda b: init_cache(b, paged, scheduler,
                                          kv_bits=kv_bits), params,
                     requests, scheduler=scheduler,
                     batch_slots=args.batch_slots,
                     max_len=args.max_len, block_pool=pool,
                     chunk_step=c_step if (chunk or prefix or oc)
                     else None,
                     prefill_chunk=chunk or None,
                     radix_cache=RadixCache(args.block_size) if prefix
                     else None,
                     write_caps=tfm.attn_write_caps(
                         cfg, args.max_len, args.block_size) if pool
                     else None,
                     ring_tokens=ring_tokens if pool else None,
                     copy_block_fn=copy_block if prefix else None,
                     over_commit=oc,
                     swap_out_fn=swap_out if oc else None,
                     swap_in_fn=swap_in if oc else None,
                     decode_ratio=args.decode_ratio
                     if (chunk or prefix or oc) else 1,
                     telemetry=tel)

    requests = make_requests()
    if args.async_serve:
        import time
        from repro.runtime import AsyncServer
        from repro.runtime.engine import make_engine
        eng = make_engine(cfg, params, batch_slots=args.batch_slots,
                          prompt_pad_len=args.prompt_len,
                          max_len=args.max_len, dtype=dtype,
                          kv_bits=args.kv_bits, ctx_factory=ctx_factory,
                          dist=dist)
        t0 = time.perf_counter()
        with AsyncServer(eng) as srv:
            streams = [srv.submit(r.prompt, r.max_new_tokens, rid=r.rid)
                       for r in requests]
            for r, s in zip(requests, streams):
                r.tokens_out = s.result(timeout=600)
                r.done = True
        wall = time.perf_counter() - t0
        total = sum(len(r.tokens_out) for r in requests)
        tp_note = (f", tp={args.tp} over {len(jax.devices())} devices"
                   if args.tp > 1 else "")
        print(f"[serve:async] {total} tokens from {len(requests)} streamed "
              f"requests, {wall:.2f}s ({total / max(wall, 1e-9):.1f} tok/s), "
              f"engine traces {eng.trace_counts}{tp_note}")
        if args.parity:
            for sched in ("static", "continuous"):
                sync_reqs = make_requests()
                run(sched, sync_reqs)
                pairs = list(zip(requests, sync_reqs))
                if args.kv_bits == 4:
                    matched = sum(1 for r, b in pairs
                                  for x, y in zip(r.tokens_out, b.tokens_out)
                                  if x == y)
                    tot = sum(min(len(r.tokens_out), len(b.tokens_out))
                              for r, b in pairs)
                    print(f"[parity] async engine vs {sched} scheduler: "
                          f"{matched}/{tot} greedy tokens match "
                          f"({matched / max(tot, 1):.1%}) — int4 drift "
                          f"reported, not asserted")
                    continue
                bad = [r.rid for r, b in pairs
                       if list(r.tokens_out) != list(b.tokens_out)]
                if bad:
                    raise SystemExit(
                        f"[parity] FAIL: request ids {bad} diverge between "
                        f"the async engine and the {sched} scheduler")
                print(f"[parity] OK: async engine and {sched} scheduler "
                      f"emit identical greedy tokens for all "
                      f"{len(requests)} requests")
        return None
    stats = run(args.scheduler, requests, chunk=args.prefill_chunk,
                tel=telemetry)
    if args.paged_kv and args.scheduler == "continuous":
        paged_note = (f", blocks {stats.blocks_in_use}/{num_blocks} "
                      f"(frag {stats.block_fragmentation:.0%}, "
                      f"block-size {args.block_size})")
    elif args.paged_kv:
        paged_note = f", paged identity-mapped (block-size {args.block_size})"
    else:
        paged_note = ""
    chunk_note = (f", chunked prefill ({stats.chunk_steps} chunk steps @ "
                  f"<= {args.prefill_chunk} tokens)"
                  if args.prefill_chunk else "")
    prefix_note = (f", prefix-cache hits {stats.prefix_hit_tokens} tokens "
                   f"(rate {stats.prefix_hit_rate:.0%}, "
                   f"{stats.prefill_tokens_saved} prefill tokens saved, "
                   f"peak {stats.shared_blocks} shared blocks)"
                   if args.prefix_cache else "")
    oc_note = (f", over-commit: {stats.preemptions} preemptions "
               f"({stats.swapped_blocks} blocks swapped, "
               f"{stats.recomputed_tokens} tokens recomputed), "
               f"queue-wait {stats.queue_wait_steps} steps"
               if args.over_commit else "")
    print(f"[serve:{args.scheduler}] {stats.tokens_generated} tokens, "
          f"{stats.decode_steps} decode steps, "
          f"{stats.prefill_calls} prefills, {stats.wall_s:.2f}s "
          f"({stats.tokens_per_s:.1f} tok/s), "
          f"slot-utilization {stats.slot_utilization:.0%}, "
          f"peak kv-cache {stats.cache_bytes / 1024:.0f} KiB "
          f"(kv-bits {args.kv_bits}{paged_note}{chunk_note}{prefix_note}"
          f"{oc_note})")
    if args.over_commit:
        for tier in sorted(stats.tier_latency, reverse=True):
            t = stats.tier_latency[tier]
            print(f"[tier {tier}] {t.requests} requests, first-token "
                  f"p50/p99 {t.first_token_p50:.0f}/{t.first_token_p99:.0f} "
                  f"steps, inter-token p50/p99 {t.inter_token_p50:.1f}/"
                  f"{t.inter_token_p99:.1f} steps")

    if telemetry is not None:
        if telemetry.tracer is not None:
            telemetry.tracer.dump(args.trace)
            spans = telemetry.tracer.request_spans()
            retired = sum(1 for s in spans.values() if s["retired"])
            print(f"[trace] {len(telemetry.tracer.events)} events, "
                  f"{retired}/{len(spans)} requests retired -> {args.trace}")
            for ph, h in sorted(
                    telemetry.tracer.latency_histograms().items()):
                print(f"[trace] {ph}: n={h['n']} p50 {h['p50']:.2f}ms "
                      f"p95 {h['p95']:.2f}ms p99 {h['p99']:.2f}ms")
        if telemetry.metrics is not None:
            if args.trace:
                mpath = args.trace + ".metrics.jsonl"
                with open(mpath, "w") as f:
                    f.write(telemetry.metrics.jsonl())
                print(f"[metrics] {len(telemetry.metrics.snapshots)} "
                      f"snapshots -> {mpath}")
            print(telemetry.metrics.prometheus_text(), end="")
        if telemetry.quant is not None:
            rep = telemetry.quant.report()
            sites = rep["sites"]
            print(f"[quant-health] {len(sites)} sites over "
                  f"{rep['steps_observed']} telemetry steps")
            ranked = sorted(sites.items(),
                            key=lambda kv: -kv[1]["clip_fraction"])
            for s, d in ranked[:10]:
                print(f"[quant-health] {s}: clip {d['clip_fraction']:.4%} "
                      f"({d['clipped']}/{d['total']}), amax "
                      f"{d['observed_amax']:.4f} / range "
                      f"{d['calibrated_range']:.4f} "
                      f"(ratio {d['amax_ratio']:.2f})")
            for name, st in sorted(rep["kv_scales"].items()):
                print(f"[quant-health] {name}: n={st['n']} "
                      f"min {st['min']:.3e} p50 {st['p50']:.3e} "
                      f"p99 {st['p99']:.3e} max {st['max']:.3e}")
    if args.stats_json:
        import json
        with open(args.stats_json, "w") as f:
            json.dump(stats.to_json(), f, indent=2, default=str)
        print(f"[stats] ServeStats -> {args.stats_json}")

    if args.parity:
        def compare(tag, b_reqs, ok_msg):
            # At kv-bits 4 the dynamic per-slot int4 grids round-trip
            # prefill cache reads approximately (no exact bit-exactness
            # guarantee across serving configurations), so drift is
            # quantified instead of asserted; kv 8/16 stay exact.
            mismatch = [r.rid for r, b in zip(requests, b_reqs)
                        if r.tokens_out != b.tokens_out]
            if args.kv_bits == 4:
                matched = sum(
                    1 for r, b in zip(requests, b_reqs)
                    for x, y in zip(r.tokens_out, b.tokens_out) if x == y)
                total = sum(min(len(r.tokens_out), len(b.tokens_out))
                            for r, b in zip(requests, b_reqs))
                ok = len(requests) - len(mismatch)
                print(f"[parity] {tag}: {matched}/{total} greedy tokens "
                      f"match ({matched / max(total, 1):.1%}), "
                      f"{ok}/{len(requests)} requests identical — int4 "
                      f"dynamic per-slot grids round-trip prefill reads "
                      f"approximately, so drift is reported, not asserted")
                return
            if mismatch:
                raise SystemExit(f"[parity] FAIL: request ids {mismatch} "
                                 f"diverge between {tag}")
            print(f"[parity] OK: {ok_msg}")

        other = ("static" if args.scheduler == "continuous"
                 else "continuous")
        other_reqs = make_requests()
        run(other, other_reqs)
        compare(f"{args.scheduler} vs {other} schedulers", other_reqs,
                f"{args.scheduler} and {other} schedulers emit identical "
                f"greedy tokens for all {len(requests)} requests")
        if args.prefill_chunk:
            unchunked_reqs = make_requests()
            run(args.scheduler, unchunked_reqs)
            compare("chunked vs unchunked prefill", unchunked_reqs,
                    f"chunked (<= {args.prefill_chunk} tokens) and "
                    f"unchunked prefill emit identical greedy tokens "
                    f"for all {len(requests)} requests")
        if args.paged_kv:
            dense_reqs = make_requests()
            run(args.scheduler, dense_reqs, paged=False,
                chunk=args.prefill_chunk)
            compare("paged vs dense caches", dense_reqs,
                    f"paged and dense caches emit identical greedy "
                    f"tokens for all {len(requests)} requests "
                    f"(kv-bits {args.kv_bits})")
        if args.prefix_cache:
            unshared_reqs = make_requests()
            run(args.scheduler, unshared_reqs, chunk=args.prefill_chunk,
                prefix=False)
            compare("prefix-shared vs unshared serving", unshared_reqs,
                    f"prefix-shared and unshared serving emit identical "
                    f"greedy tokens for all {len(requests)} requests "
                    f"(kv-bits {args.kv_bits})")
        if args.over_commit:
            # preempted == unpreempted: the same requests served with
            # worst-case reservations (FIFO backpressure, no preemption)
            # must emit identical greedy tokens
            unpreempted_reqs = make_requests()
            run(args.scheduler, unpreempted_reqs, chunk=args.prefill_chunk,
                over_commit=False)
            compare("preempted (over-commit) vs unpreempted serving",
                    unpreempted_reqs,
                    f"preempted (over-commit, {stats.preemptions} "
                    f"preemptions) and unpreempted serving emit identical "
                    f"greedy tokens for all {len(requests)} requests "
                    f"(kv-bits {args.kv_bits})")
        if args.kv_bits == 4:
            # int4 vs int8 is lossy by construction — quantify the drift
            # (token match rate) rather than asserting exact equality
            int8_reqs = make_requests()
            run(args.scheduler, int8_reqs, chunk=args.prefill_chunk,
                kv_bits=8)
            matched = sum(
                1 for r, o in zip(requests, int8_reqs)
                for t4, t8 in zip(r.tokens_out, o.tokens_out) if t4 == t8)
            total = sum(min(len(r.tokens_out), len(o.tokens_out))
                        for r, o in zip(requests, int8_reqs))
            exact = sum(1 for r, o in zip(requests, int8_reqs)
                        if r.tokens_out == o.tokens_out)
            print(f"[parity] int4 vs int8 KV cache drift: "
                  f"{matched}/{total} greedy tokens match "
                  f"({matched / max(total, 1):.1%}), "
                  f"{exact}/{len(requests)} requests identical end-to-end")
    return stats


if __name__ == "__main__":
    main()
