"""seamless-m4t-medium [audio] — encoder-decoder multimodal backbone; the
speech frontend is a stub per the assignment (precomputed frame embeddings).
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,                # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    rope_theta=None,              # learned absolute positions (encdec.py)
    norm="layernorm",
    act="relu",
    ffn_type="mlp",
    tie_embeddings=True,
    frontend="audio",
    num_frontend_tokens=4096,     # default encoder frames (overridden per shape)
    max_seq_len=32768,
    sub_quadratic=False,          # full attention + 4k-positions family:
                                  # skips long_500k (DESIGN.md §5)
    source="arXiv:2308.11596; hf",
)
