"""Per-kernel validation: shape/dtype sweeps, allclose vs the ref.py oracles
(kernels run in interpret mode on CPU; same code lowers to Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _acts(key, t, d, outlier_cols=(), scale=40.0):
    x = jax.random.normal(key, (t, d))
    for c in outlier_cols:
        x = x.at[:, c].multiply(scale)
    return x


def _peg_params(x, k):
    """Per-group asymmetric int8 params from the data (groups contiguous)."""
    t, d = x.shape
    gs = d // k
    xg = x.reshape(t, k, gs)
    mn = jnp.minimum(jnp.min(xg, axis=(0, 2)), 0.0)
    mx = jnp.maximum(jnp.max(xg, axis=(0, 2)), 0.0)
    s = jnp.maximum((mx - mn) / 255.0, 1e-8)
    z = jnp.clip(jnp.round(-mn / s), 0, 255)
    return s, z


class TestPegQuantKernel:
    @pytest.mark.parametrize("t,d,k", [(256, 768, 6), (512, 512, 4),
                                       (128, 1024, 8), (256, 256, 1),
                                       (64, 128, 2)])
    def test_fake_quant_matches_ref(self, t, d, k):
        x = _acts(jax.random.PRNGKey(0), t, d, outlier_cols=(1, d - 2))
        s, z = _peg_params(x, k)
        got = ops.peg_fake_quant(x, s, z, block_t=min(128, t))
        want = ref.peg_fake_quant_ref(x, s, z, qmin=0, qmax=255)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = _acts(jax.random.PRNGKey(1), 128, 256).astype(dtype)
        s, z = _peg_params(x.astype(jnp.float32), 2)
        got = ops.peg_fake_quant(x, s, z, block_t=64)
        want = ref.peg_fake_quant_ref(x, s, z, qmin=0, qmax=255)
        assert got.dtype == dtype
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-2, atol=1e-2)

    def test_quantize_emits_int8(self):
        x = _acts(jax.random.PRNGKey(2), 128, 256)
        s, z = _peg_params(x, 2)
        # int8 path uses a symmetric-style signed grid shifted: emit [0,255]
        # does not fit int8 -> use qmax=127 grid for the emit variant
        s2 = s * (255.0 / 127.0)
        z2 = jnp.clip(jnp.round(z * 127.0 / 255.0), 0, 127)
        got = ops.peg_quantize(x, s2, z2, qmin=0, qmax=127, block_t=64)
        want = ref.peg_quantize_ref(x, s2, z2, qmin=0, qmax=127)
        assert got.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_outlier_isolation_property(self):
        """Grouped scales must keep clean-group precision independent of the
        outlier group — the kernel-level statement of the paper's Table 5."""
        d, k = 512, 4
        x = _acts(jax.random.PRNGKey(3), 256, d,
                  outlier_cols=tuple(range(d - d // k, d)), scale=100.0)
        s, z = _peg_params(x, k)
        out = ops.peg_fake_quant(x, s, z, block_t=128)
        clean = slice(0, d - d // k)
        err_clean = float(jnp.max(jnp.abs(x[:, clean] - out[:, clean])))
        assert err_clean <= float(jnp.max(s[:-1])) * 0.5 + 1e-5


class TestInt8Matmul:
    @pytest.mark.parametrize("m,k,n", [(256, 512, 256), (128, 1024, 512),
                                       (512, 256, 128)])
    def test_pertensor_matches_ref(self, m, k, n):
        kk = jax.random.split(jax.random.PRNGKey(0), 2)
        a = jax.random.randint(kk[0], (m, k), -127, 128, jnp.int8)
        w = jax.random.randint(kk[1], (k, n), -127, 128, jnp.int8)
        got = ops.int8_matmul(a, w, s_a=0.02, s_w=0.005,
                              block_m=128, block_n=128, block_k=128)
        want = ref.int8_matmul_ref(a, w, 0.02, 0.005)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("groups", [1, 2, 4, 8])
    def test_peg_matmul_matches_dequant_oracle(self, groups):
        """The fused K-rescaling path == dequantize-then-matmul in f32."""
        m, k, n = 128, 512, 256
        kk = jax.random.split(jax.random.PRNGKey(1), 4)
        a = jax.random.randint(kk[0], (m, k), 0, 256, jnp.int32) \
            .astype(jnp.uint8).view(jnp.int8)  # emulate asym uint8 payload
        a = jax.random.randint(kk[0], (m, k), -128, 128, jnp.int8)
        w = jax.random.randint(kk[1], (k, n), -127, 128, jnp.int8)
        s_g = jax.random.uniform(kk[2], (groups,), minval=0.005, maxval=0.05)
        z_g = jnp.round(jax.random.uniform(kk[3], (groups,), minval=-20,
                                           maxval=20))
        got = ops.int8_matmul_peg(a, w, s_g, z_g, w_scale=0.01,
                                  block_m=128, block_n=128)
        want = ref.int8_matmul_peg_ref(a, w, s_g, z_g, 0.01)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_accumulator_never_overflows_int32(self):
        """Worst-case |a|,|w| <= 127 over K=2048: max |acc| = 127*127*2048
        ~ 3.3e7 << 2^31 — the s32 accumulator is safe at our block sizes."""
        assert 127 * 127 * 2048 < 2 ** 31 - 1
        m = k = n = 256
        a = jnp.full((m, k), 127, jnp.int8)
        w = jnp.full((k, n), 127, jnp.int8)
        got = ops.int8_matmul(a, w, s_a=1.0, s_w=1.0, block_m=128,
                              block_n=128, block_k=128)
        assert float(got[0, 0]) == 127 * 127 * k


class TestLnQuant:
    @pytest.mark.parametrize("t,d", [(128, 768), (256, 512), (64, 2048)])
    def test_fused_matches_ref(self, t, d):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(ks[0], (t, d)) * 3.0
        g = jax.random.normal(ks[1], (d,)) * 0.2 + 1.0
        b = jax.random.normal(ks[2], (d,)) * 0.1
        got = ops.ln_fake_quant(x, g, b, 0.05, 128.0, block_t=64)
        want = ref.ln_fake_quant_ref(x, g, b, 0.05, 128.0, qmin=0, qmax=255)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_int8_emit(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 256))
        g = jnp.ones((256,))
        b = jnp.zeros((256,))
        got = ops.ln_quantize(x, g, b, 0.05, 64.0, qmin=0, qmax=127,
                              block_t=64)
        want = ref.ln_quantize_ref(x, g, b, 0.05, 64.0, qmin=0, qmax=127)
        assert got.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_ln_statistics(self):
        """Sanity: with identity affine + huge range (no clipping), output
        is ~zero-mean/unit-var per row."""
        x = jax.random.normal(jax.random.PRNGKey(2), (64, 512)) * 7 + 3
        out = ops.ln_fake_quant(x, jnp.ones((512,)), jnp.zeros((512,)),
                                0.001, 0.0, qmin=-(2**15), qmax=2**15 - 1,
                                block_t=64)
        assert abs(float(jnp.mean(out))) < 1e-2
        assert abs(float(jnp.std(out)) - 1.0) < 1e-2
