"""End-to-end serving driver (the paper-kind e2e example): serve a small
decoder LM with batched requests through prefill + KV-cache decode, FP32 vs
W8A8-PEG-quantized (simulated) vs the int8 deployment path (Pallas
kernels), and compare outputs + timings.

Run:  PYTHONPATH=src python examples/serve_quantized.py
      (add --arch gemma2-2b etc. to switch the reduced family)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Mode, QuantCtx, peg_policy
from repro.core.pipeline import ptq
from repro.models import transformer as tfm
from repro.runtime import Request, serve_batch
from repro.runtime.steps import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube3-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

    # --- W8A8 + PEG PTQ, calibrated on synthetic prompts -------------------
    pol = peg_policy(4)
    flat_params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=False,
                                  dtype=jnp.float32)
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10 + i),
                                           (2, args.prompt_len), 0,
                                           cfg.vocab_size)}
             for i in range(2)]

    def fwd(p, b, ctx):
        logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
        return logits

    qm = ptq(fwd, flat_params, calib, pol, collect_inputs=True)
    shared = {}
    for site, qp in qm.act_state.items():
        base = ("layer/" + site.split("/", 1)[1]
                if site.startswith("layer") else site)
        shared.setdefault(base, qp)

    def quant_ctx():
        return QuantCtx(policy=pol, mode=Mode.APPLY, act_state=dict(shared))

    # --- integer deployment: packed int8 weights + Pallas kernels ----------
    from repro.core import build_deploy
    packed_params, deploy_acts = build_deploy(cfg, params, pol, dict(shared))

    def deploy_ctx():
        return QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=dict(shared),
                        deploy_acts=deploy_acts)

    def make_requests():
        # fresh rng per run so every label serves IDENTICAL prompts (a
        # shared stateful rng would silently compare different requests)
        rng = np.random.RandomState(0)
        return [Request(rid=i, prompt=rng.randint(10, cfg.vocab_size,
                                                  size=args.prompt_len),
                        max_new_tokens=args.new_tokens)
                for i in range(args.requests)]

    def run(label, ctx_factory, serve_params=None):
        serve_params = params if serve_params is None else serve_params
        prefill = jax.jit(make_prefill_step(cfg, ctx_factory=ctx_factory))
        decode = jax.jit(make_decode_step(cfg, ctx_factory=ctx_factory),
                         donate_argnums=(3,))
        reqs = make_requests()
        stats = serve_batch(
            lambda t, pm, c: prefill(serve_params, t, c, pm),
            lambda t, p, c: decode(serve_params, t, p, c),
            lambda b: tfm.init_cache(cfg, b, 64, dtype=jnp.float32),
            reqs, batch_slots=4)
        tok_s = stats.tokens_generated / max(stats.wall_s, 1e-9)
        print(f"{label:<18s} {stats.tokens_generated} tokens in "
              f"{stats.wall_s:.2f}s ({tok_s:.1f} tok/s)")
        return [r.tokens_out for r in reqs]

    def agreement(a, b):
        return np.mean([np.mean(np.asarray(x) == np.asarray(y))
                        for x, y in zip(a, b)])

    out_fp = run("FP32", None)
    out_q = run("W8A8 PEG (K=4+P)", quant_ctx)
    out_d = run("int8 deploy", deploy_ctx, packed_params)
    print(f"\ngreedy-token agreement FP32 vs quantized: "
          f"{agreement(out_fp, out_q) * 100:.1f}% "
          "(an untrained model's logits are near-uniform, so small "
          "quantization noise can flip argmax — trained models agree far "
          "more; see benchmarks tables for task-metric impact)")
    print(f"greedy-token agreement simulated vs int8 deploy: "
          f"{agreement(out_q, out_d) * 100:.1f}% (same quantization math — "
          "differences are f32-associativity ties only)")


if __name__ == "__main__":
    main()
