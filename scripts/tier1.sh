#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md): the full fast test suite from the repo
# root with src/ on the path. Extra args pass through to pytest, e.g.
#   scripts/tier1.sh -m deploy        # just the integer-deployment tests
#   scripts/tier1.sh -m serve         # serving-runtime scheduler tests
#   scripts/tier1.sh -m "not slow"
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
