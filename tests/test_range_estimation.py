"""Tests for static range estimators (paper §2, App. B.2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Granularity, QuantizerConfig, RangeEstimator,
                        estimate_weight_params, fake_quant, finalize,
                        init_range_state, mse_search, observe,
                        params_from_range, quant_error)


def _cfg(estimator, **kw):
    return QuantizerConfig(bits=8, estimator=estimator, **kw)


class TestMinMax:
    def test_current_minmax_tracks_envelope(self):
        cfg = _cfg(RangeEstimator.CURRENT_MINMAX)
        st = init_range_state()
        st = observe(st, jnp.asarray([-1.0, 2.0]), cfg)
        st = observe(st, jnp.asarray([-3.0, 1.0]), cfg)
        assert float(st.x_min) == -3.0 and float(st.x_max) == 2.0

    def test_running_minmax_ema(self):
        cfg = _cfg(RangeEstimator.RUNNING_MINMAX, ema_momentum=0.9)
        st = init_range_state()
        st = observe(st, jnp.asarray([0.0, 10.0]), cfg)   # init: (0, 10)
        st = observe(st, jnp.asarray([0.0, 0.0]), cfg)    # EMA: max -> 9.0
        assert abs(float(st.x_max) - 9.0) < 1e-6

    def test_finalize_minmax(self):
        cfg = _cfg(RangeEstimator.CURRENT_MINMAX)
        st = init_range_state()
        x = jax.random.normal(jax.random.PRNGKey(0), (256,))
        st = observe(st, x, cfg)
        qp = finalize(st, cfg)
        xq = fake_quant(x, qp, cfg)
        assert float(jnp.max(jnp.abs(x - xq))) <= float(qp.scale) * 0.5 + 1e-5


class TestMSE:
    def test_mse_clips_outliers_at_low_bits(self):
        """With few levels and a moderate outlier, clipping the range beats
        covering it (Banner/Choukroun motivation). At 8-bit with an extreme
        outlier the optimum flips to not clipping — MSE must find both."""
        cfg = QuantizerConfig(bits=4, symmetric=True,
                              estimator=RangeEstimator.MSE)
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (4096,))
        x = x.at[0].set(10.0)     # moderate outlier, 4-bit budget
        mn, mx = jnp.min(x), jnp.max(x)
        qp = mse_search(x, mn, mx, cfg)
        assert float(qp.scale) * cfg.qmax < float(mx) / 2  # clipped hard

    def test_mse_keeps_extreme_outlier_at_8bit(self):
        """Dual of the above: one huge outlier among N(0,1) data — its clip
        error dominates, so the MSE optimum keeps (almost) the full range."""
        cfg = _cfg(RangeEstimator.MSE, symmetric=True)
        x = jax.random.normal(jax.random.PRNGKey(7), (4096,))
        x = x.at[0].set(500.0)
        qp = mse_search(x, jnp.min(x), jnp.max(x), cfg)
        assert float(qp.scale) * cfg.qmax > 250.0

    def test_mse_beats_minmax_on_outliers(self):
        cfg_mse = QuantizerConfig(bits=4, symmetric=True,
                                  estimator=RangeEstimator.MSE)
        cfg_mm = QuantizerConfig(bits=4, symmetric=True,
                                 estimator=RangeEstimator.CURRENT_MINMAX)
        x = jax.random.normal(jax.random.PRNGKey(2), (4096,))
        x = x.at[0].set(30.0)
        qp_mse = estimate_weight_params(x, cfg_mse)
        qp_mm = estimate_weight_params(x, cfg_mm)
        # gain bounded by the outlier's own clip error (~(30-c)^2/N): expect >3x
        assert float(quant_error(x, qp_mse, cfg_mse)) < \
            float(quant_error(x, qp_mm, cfg_mm)) / 3

    def test_mse_matches_minmax_on_uniform(self):
        """On bounded uniform data, clipping should stay near 1.0."""
        cfg = _cfg(RangeEstimator.MSE, symmetric=True)
        x = jax.random.uniform(jax.random.PRNGKey(3), (4096,), minval=-1, maxval=1)
        qp = estimate_weight_params(x, cfg)
        full = float(jnp.max(jnp.abs(x))) / cfg.qmax
        assert float(qp.scale) > 0.9 * full

    def test_mse_per_channel(self):
        cfg = QuantizerConfig(bits=4, symmetric=True,
                              granularity=Granularity.PER_CHANNEL,
                              estimator=RangeEstimator.MSE)
        w = jax.random.normal(jax.random.PRNGKey(4), (8192, 8))
        w = w.at[5, 0].set(10.0)    # moderate outlier only in channel 0
        qp = estimate_weight_params(w, cfg)
        assert qp.scale.shape == (8,)
        # channel 0 should be clipped well below the outlier; others near min-max
        assert float(qp.scale[0]) * cfg.qmax < 5.0


class TestWeightEstimation:
    def test_low_bit_prefers_mse(self):
        """Paper §5: for <8-bit weights always use the MSE estimator."""
        w = jax.random.normal(jax.random.PRNGKey(5), (2048,)) * \
            (1 + 10 * jax.random.bernoulli(jax.random.PRNGKey(6), 0.001, (2048,)))
        for bits in (2, 4, 6):
            cfg_mse = QuantizerConfig(bits=bits, symmetric=True,
                                      estimator=RangeEstimator.MSE)
            cfg_mm = QuantizerConfig(bits=bits, symmetric=True,
                                     estimator=RangeEstimator.CURRENT_MINMAX)
            e_mse = float(quant_error(w, estimate_weight_params(w, cfg_mse), cfg_mse))
            e_mm = float(quant_error(w, estimate_weight_params(w, cfg_mm), cfg_mm))
            assert e_mse <= e_mm + 1e-9
