"""Serving observability tests (runtime/telemetry.py + scheduler wiring).

Coverage:

* Lifecycle conservation on a preempting over-commit stub run: every
  admitted request either retires or is preempted-and-resumed (admissions
  == resumes + 1 per request), span preemption counts reconcile exactly
  with ServeStats.preemptions, and per-phase event counts reconcile with
  decode_steps / prefill_calls.
* Chrome-trace export schema: the JSON is Perfetto-loadable trace-event
  format (M/X/i phases, µs timestamps, lane thread naming, per-residency
  request spans that never dangle).
* MetricsLogger cadence (due/emit dedup per step), JSONL round-trip, and
  Prometheus text rendering.
* Quant-health: quantizer.telemetry_stats against an independent numpy
  oracle of the calibrated grid (exact clip counts, amax, cal_range),
  QuantCtx.act emitting the same counters from inside jit, and
  QuantHealth's stacked-scan fan-out + max/sum merge semantics.
* Recompile guard: serving with the tracer + metrics enabled reuses the
  exact jitted admit/decode executables traced by an untraced run (the
  traced step signatures are unchanged — tracing is host-side only).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Mode, QuantCtx, w8a8_policy
from repro.core.quantizer import (fake_quant, params_from_range,
                                  telemetry_stats)
from repro.runtime import (BlockPool, MetricsLogger, QuantHealth, Request,
                           ServeTelemetry, Tracer, serve_continuous)
from serve_testlib import golden as _golden
from serve_testlib import next_arr as _next_arr
from serve_testlib import onehot as _onehot

pytestmark = [pytest.mark.serve, pytest.mark.obs]


class Stub:
    """Deterministic next_token = (2 * tok + 1) % VOCAB (see
    serve_testlib), with the over-commit swap hooks so preemption paths
    are reachable."""

    def init_cache(self, batch):
        return {"kv": jnp.zeros((batch, 4), jnp.float32)}

    def admit(self, tokens, positions, admit_mask, cache):
        return _onehot(_next_arr(tokens)), cache

    def chunk(self, tokens, positions, reset_mask, cache):
        return _onehot(_next_arr(tokens)), cache

    def decode(self, tokens, pos, cache):
        return _onehot(_next_arr(tokens)), cache

    def swap_out(self, cache, ids):
        return {"blocks": jnp.zeros((int(ids.shape[0]), 1), jnp.float32)}

    def swap_in(self, cache, ids, payload):
        return cache


def _serve_oc(reqs, tel, *, swap=False, num_blocks=6):
    """Over-commit stub serve sized so the pool is below worst-case demand
    (preemptions happen); mirrors tests/test_preemption.py."""
    m = Stub()
    pool = BlockPool(num_blocks, 4, 2, 8)
    stats = serve_continuous(
        m.admit, m.decode, m.init_cache, reqs, batch_slots=2,
        block_pool=pool, chunk_fn=m.chunk, prefill_chunk=4,
        over_commit=True,
        swap_out_fn=m.swap_out if swap else None,
        swap_in_fn=m.swap_in if swap else None,
        telemetry=tel)
    return stats


def _oc_reqs():
    return [Request(rid=i, prompt=np.full(4, 3 + i, np.int32),
                    max_new_tokens=12) for i in range(4)]


class TestLifecycleConservation:
    @pytest.mark.parametrize("swap", [False, True])
    def test_spans_reconcile_with_serve_stats(self, swap):
        tel = ServeTelemetry.create(trace=True)
        reqs = _oc_reqs()
        stats = _serve_oc(reqs, tel, swap=swap)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 12)
        assert stats.preemptions > 0
        spans = tel.tracer.request_spans()
        assert sorted(spans) == [r.rid for r in reqs]
        for rid, s in spans.items():
            # conservation: every admission either retires or is
            # preempted-and-resumed; the final residency retires
            assert s["retired"], f"rid {rid} never retired"
            assert len(s["admits"]) == s["resumes"] + 1
            assert s["preempts"] == s["resumes"]
            assert s["enqueue_ts"] is not None
            assert s["enqueue_ts"] <= s["admits"][0][0] <= s["retire_ts"]
            assert [t for t, _ in s["admits"]] == sorted(
                t for t, _ in s["admits"])
        assert sum(s["preempts"] for s in spans.values()) \
            == stats.preemptions
        # phase/event counts reconcile with the scheduler's own counters
        names = [e.name for e in tel.tracer.events]
        assert names.count("decode_batch") == stats.decode_steps
        assert names.count("admit") + names.count("chunk") \
            - sum(len(s["admits"]) - s["resumes"]
                  for s in spans.values()) == stats.prefill_calls
        assert names.count("enqueue") == len(reqs)
        assert names.count("retire") == len(reqs)
        mode = "swap" if swap else "drop"
        preempts = [e for e in tel.tracer.events if e.name == "preempt"]
        assert preempts and all(e.args["mode"] == mode for e in preempts)
        if swap:
            assert any(e.name == "swap_out" for e in tel.tracer.events)
            assert any(e.name == "swap_in" for e in tel.tracer.events)
        hist = tel.tracer.latency_histograms()
        assert hist["decode_batch"]["n"] == stats.decode_steps
        assert all(h["p50"] <= h["p95"] <= h["p99"] for h in hist.values())

    def test_tokens_identical_with_and_without_tracing(self):
        traced = _oc_reqs()
        plain = _oc_reqs()
        _serve_oc(traced, ServeTelemetry.create(trace=True,
                                                metrics_every=2))
        _serve_oc(plain, None)
        for a, b in zip(traced, plain):
            assert a.tokens_out == b.tokens_out


class TestChromeTraceSchema:
    def test_trace_is_valid_chrome_trace_json(self, tmp_path):
        tel = ServeTelemetry.create(trace=True)
        _serve_oc(_oc_reqs(), tel, swap=True)
        path = tmp_path / "trace.json"
        tel.tracer.dump(str(path))
        doc = json.loads(path.read_text())
        assert set(doc) == {"traceEvents", "displayTimeUnit"}
        evs = doc["traceEvents"]
        assert evs
        for e in evs:
            assert {"name", "ph", "pid"} <= set(e)
            assert e["ph"] in ("M", "X", "i")
            if e["ph"] == "X":
                assert e["ts"] >= 0 and e["dur"] > 0
            if e["ph"] == "i":
                assert e["s"] == "t" and "ts" in e
        # lane tracks are named and every request span sits on one
        named = {e["tid"] for e in evs
                 if e["ph"] == "M" and e["name"] == "thread_name"}
        spans = [e for e in evs
                 if e["ph"] == "X" and e["name"].startswith("req")]
        assert spans
        assert all(e["tid"] in named and e["tid"] >= 1 for e in spans)
        # phase durations live on the steps track (tid 0)
        assert any(e["ph"] == "X" and e["tid"] == 0 for e in evs)


class TestMetrics:
    def test_due_fires_once_per_step(self):
        m = MetricsLogger(every=4)
        assert not m.due(3)
        assert m.due(4)
        m.emit(4, {"queue_depth": 1})
        assert not m.due(4)                      # same step: no re-emit
        assert m.due(8)
        assert not MetricsLogger(every=0).due(0)

    def test_snapshots_jsonl_and_prometheus(self):
        tel = ServeTelemetry.create(metrics_every=2)
        stats = _serve_oc(_oc_reqs(), tel)
        snaps = tel.metrics.snapshots
        assert snaps
        steps = [s["step"] for s in snaps]
        assert steps == sorted(set(steps))
        assert all(s % 2 == 0 for s in steps)
        assert {"queue_depth", "resident_lanes", "blocks_free",
                "refcount_total", "preemptions",
                "prefix_hit_rate"} <= set(snaps[0])
        lines = tel.metrics.jsonl().splitlines()
        assert len(lines) == len(snaps)
        last = json.loads(lines[-1])
        # the final snapshot lands on the last step divisible by `every`,
        # so its counters are a prefix of the final totals
        assert 0 < last["tokens_generated"] <= stats.tokens_generated
        assert last["preemptions"] <= stats.preemptions
        prom = tel.metrics.prometheus_text()
        assert "# TYPE serve_queue_depth gauge" in prom
        assert f"serve_tokens_generated {last['tokens_generated']:g}" in prom


class TestQuantHealthOracle:
    def _grid(self):
        pol = w8a8_policy()
        cfg = pol.act_config("x")
        qp = params_from_range(jnp.float32(-1.0), jnp.float32(1.0), cfg)
        return pol, cfg, qp

    def _oracle(self, x, qp, cfg):
        """Independent numpy recomputation of the calibrated grid."""
        s = max(float(qp.scale), np.finfo(np.float32).tiny)
        z = float(qp.zero_point)
        t = np.round(np.asarray(x, np.float64) / s) + z
        clipped = int(np.sum((t < cfg.qmin) | (t > cfg.qmax)))
        rng = max(abs(s * (cfg.qmin - z)), abs(s * (cfg.qmax - z)))
        return clipped, float(np.max(np.abs(x))), rng

    def test_telemetry_stats_matches_numpy_oracle(self):
        pol, cfg, qp = self._grid()
        x = np.asarray(
            jax.random.normal(jax.random.PRNGKey(0), (512,))) * 2.0
        vec = np.asarray(telemetry_stats(jnp.asarray(x), qp, cfg))
        clipped, amax, rng = self._oracle(x, qp, cfg)
        assert clipped > 0                       # range [-1,1] vs 2-sigma
        assert int(vec[0]) == clipped
        assert int(vec[1]) == x.size
        assert vec[2] == pytest.approx(amax, rel=1e-6)
        assert vec[3] == pytest.approx(rng, rel=1e-6)

    def test_ctx_act_emits_counters_from_inside_jit(self):
        pol, cfg, qp = self._grid()

        def f(x):
            ctx = QuantCtx(policy=pol, mode=Mode.APPLY,
                           act_state={"x": qp})
            ctx.telemetry = {}
            y = ctx.act("x", x)
            return y, ctx.telemetry

        x = jax.random.normal(jax.random.PRNGKey(1), (256,)) * 2.0
        y, tel = jax.jit(f)(x)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(fake_quant(x, qp, cfg)))
        vec = np.asarray(tel["x"])
        clipped, amax, rng = self._oracle(np.asarray(x), qp, cfg)
        assert int(vec[0]) == clipped and clipped > 0
        assert int(vec[1]) == x.size

    def test_quant_health_fanout_and_merge(self):
        q = QuantHealth()
        stacked = np.asarray([[1, 10, 0.5, 1.0], [3, 10, 2.0, 1.0]],
                             np.float32)
        q.update({"layer/site": stacked, "head": np.asarray(
            [2, 20, 4.0, 2.0], np.float32)})
        q.update({"layer/site": stacked})        # counts sum, amax maxes
        rep = q.report()
        assert set(rep["sites"]) == {"layer0/site", "layer1/site", "head"}
        s1 = rep["sites"]["layer1/site"]
        assert s1["clipped"] == 6 and s1["total"] == 20
        assert s1["clip_fraction"] == pytest.approx(0.3)
        assert s1["observed_amax"] == 2.0
        assert s1["amax_ratio"] == pytest.approx(2.0)
        assert rep["sites"]["head"]["clip_fraction"] == pytest.approx(0.1)
        assert rep["steps_observed"] == 2


class TestRecompileGuard:
    def test_tracing_reuses_untraced_executables(self):
        """Tracing is host-side only: serving with the tracer + metrics on
        must not retrace or change the jitted step signatures — the traced
        run reuses the executables the untraced run compiled."""
        traces = {"admit": 0, "decode": 0}
        stub = Stub()

        def admit_fn(t, pm, m, c):              # jit-traceable stub LM
            traces["admit"] += 1
            return _onehot((2 * t + 1) % 32), c

        def decode_fn(t, p, c):
            traces["decode"] += 1
            return _onehot((2 * t + 1) % 32), c

        admit_j = jax.jit(admit_fn)
        decode_j = jax.jit(decode_fn)

        def run(tel):
            reqs = [Request(rid=i, prompt=np.asarray([3 + i, 5 + i]),
                            max_new_tokens=4) for i in range(3)]
            serve_continuous(admit_j, decode_j, stub.init_cache, reqs,
                             batch_slots=2, prompt_pad_len=2,
                             telemetry=tel)
            return reqs

        plain = run(None)
        assert traces == {"admit": 1, "decode": 1}
        traced = run(ServeTelemetry.create(trace=True, metrics_every=2))
        assert traces == {"admit": 1, "decode": 1}   # zero new traces
        for a, b in zip(plain, traced):
            assert a.tokens_out == b.tokens_out

    def test_disabled_telemetry_returns_plain_step(self):
        """quant_telemetry=False hands back the ORIGINAL 2-output closure
        (not a wrapper), so existing jit caches keyed on it stay warm."""
        from repro.configs import get_config
        from repro.runtime.steps import make_admit_step, make_decode_step
        cfg = get_config("gemma2-2b").reduced()
        assert make_admit_step(cfg).__name__ == "admit"
        assert make_decode_step(cfg).__name__ == "decode"
        assert make_admit_step(cfg, quant_telemetry=True).__name__ \
            == "admit_t"
        assert make_decode_step(cfg, quant_telemetry=True).__name__ \
            == "decode_t"


class TestTracerUnit:
    def test_phase_timer_records_duration_and_args(self):
        tr = Tracer()
        with tr.phase("decode_batch", 3) as ph:
            ph.args["lanes"] = 2
        (e,) = tr.events
        assert e.name == "decode_batch" and e.step == 3
        assert e.dur >= 0.0 and e.args == {"lanes": 2}
        assert tr.latency_histograms()["decode_batch"]["n"] == 1

    def test_event_args_survive_export(self):
        tr = Tracer()
        tr.event("prefix_hit", 1, rid=7, lane=0, tokens=16)
        doc = tr.to_chrome_trace()
        (hit,) = [e for e in doc["traceEvents"]
                  if e["name"] == "prefix_hit"]
        assert hit["args"]["tokens"] == 16
        assert hit["args"]["rid"] == 7
        assert hit["tid"] == 1                   # lane 0 -> tid 1
        json.dumps(doc)                          # serializable end-to-end
