"""Pallas TPU kernel: LayerNorm fused with asymmetric quantization.

The paper's Fig.-4 rewriting puts a quantizer directly after each LayerNorm
(the FFN-input path). On TPU this is a single VPU pass per token row: compute
mean/variance, normalize+affine, quantize — the normalized f32 intermediate
never leaves VMEM.

Two variants:
  * ln_fake_quant — LN + quant + dequant (simulation / QAT forward)
  * ln_quantize   — LN + int8 emit (deployment; feeds int8_matmul)

Grid: (T / block_t,). Block: (block_t, d) — a full embedding row per token so
the mean/variance reduction stays in-block (d up to ~8k fits VMEM easily:
256 x 8192 x 4B = 8 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_fakequant_kernel(g_ref, b_ref, s_ref, z_ref, x_ref, o_ref, *,
                         qmin, qmax, eps):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]
    s = s_ref[0]
    z = z_ref[0]
    q = jnp.clip(jnp.round(y / s) + z, qmin, qmax)
    o_ref[...] = ((q - z) * s).astype(o_ref.dtype)


def _ln_quantize_kernel(g_ref, b_ref, s_ref, z_ref, x_ref, o_ref, *,
                        qmin, qmax, eps):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]
    s = s_ref[0]
    z = z_ref[0]
    o_ref[...] = jnp.clip(jnp.round(y / s) + z, qmin, qmax).astype(o_ref.dtype)


def _call(kernel, x, gamma, beta, scale, zp, out_dtype, block_t, interpret):
    t, d = x.shape
    bt = min(block_t, t)
    assert t % bt == 0
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((t, d), out_dtype),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        interpret=interpret,
    )(gamma.astype(jnp.float32), beta.astype(jnp.float32),
      jnp.atleast_1d(jnp.asarray(scale, jnp.float32)),
      jnp.atleast_1d(jnp.asarray(zp, jnp.float32)), x)


def ln_fake_quant(x, gamma, beta, scale, zp, *, qmin: int, qmax: int,
                  eps: float = 1e-6, block_t: int = 256,
                  interpret: bool = False):
    """x: (T, d) -> LN + fake-quant, same dtype."""
    kernel = functools.partial(_ln_fakequant_kernel, qmin=qmin, qmax=qmax,
                               eps=eps)
    return _call(kernel, x, gamma, beta, scale, zp, x.dtype, block_t,
                 interpret)


def ln_quantize(x, gamma, beta, scale, zp, *, qmin: int, qmax: int,
                eps: float = 1e-6, out_dtype=jnp.int8, block_t: int = 256,
                interpret: bool = False):
    """x: (T, d) -> LN + int8 emit."""
    kernel = functools.partial(_ln_quantize_kernel, qmin=qmin, qmax=qmax,
                               eps=eps)
    return _call(kernel, x, gamma, beta, scale, zp, out_dtype, block_t,
                 interpret)
