"""Divisibility-aware sharding rules (DESIGN.md §4).

Axes: ``pod`` (cross-pod DP), ``data`` (in-pod DP + FSDP for params/optimizer
state), ``model`` (TP for heads/FFN-hidden/vocab, EP for experts, SP for
long-context caches).

Every rule degrades gracefully: a dimension is sharded on an axis only if it
divides evenly, otherwise that dim is replicated (e.g. granite's single KV
head -> the 128-wide head_dim shards instead; gemma2's d_model=2304 is not
divisible by 16 -> the FSDP dim falls back to replication for those leaves).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import AbstractMesh, Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import DistContext


def make_abstract_mesh(shape: Tuple[int, ...],
                       axes: Tuple[str, ...]) -> AbstractMesh:
    """Device-free mesh for sharding-rule logic, across jax API revisions.

    Old jax took ``AbstractMesh(shape, axis_names)``; current versions take
    a single tuple of ``(name, size)`` pairs. Build the pairs form first and
    fall back for older installs.
    """
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axes))


def make_dist(mesh: Mesh) -> DistContext:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # FSDP spans the whole data-parallel group: on the multi-pod mesh the
    # parameters/optimizer state shard over (pod, data) = 32 ways, which is
    # what makes 235B/314B training fit 16 GB/chip (DESIGN.md §4).
    fsdp = dp if len(dp) > 1 else "data"
    return DistContext(mesh=mesh, tp_axis="model", fsdp_axis=fsdp,
                       dp_axes=dp)


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    return dim % _axis_size(mesh, axis) == 0


def _spec(mesh, shape, assignment) -> P:
    """assignment: tuple of axis-name/tuple-or-None per dim; drop
    non-divisible or already-used axes."""
    cleaned = []
    used = set()
    for dim, axis in zip(shape, assignment):
        names = axis if isinstance(axis, tuple) else (axis,)
        if axis is not None and not (set(names) & used) and \
                _fits(dim, mesh, axis):
            cleaned.append(axis)
            used.update(names)
        else:
            cleaned.append(None)
    while cleaned and cleaned[-1] is None:
        cleaned.pop()
    return P(*cleaned)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# name-pattern -> per-dim axis assignment for the TRAILING dims (the leading
# scan/stack dim, when present, is never sharded). "tp"/"fsdp" resolve to
# model/data.
_PARAM_RULES = [
    # attention projections
    (r".*attn.*wq$", ("fsdp", "tp")),
    (r".*attn.*wk$", ("fsdp", "tp")),
    (r".*attn.*wv$", ("fsdp", "tp")),
    (r".*attn.*wo$", ("tp", "fsdp")),
    (r".*(xattn).*w[qkv]$", ("fsdp", "tp")),
    (r".*(xattn).*wo$", ("tp", "fsdp")),
    # dense FFN
    (r".*ffn.*(w_gate|w_up|w_in)$", ("fsdp", "tp")),
    (r".*ffn.*w_out$", ("tp", "fsdp")),
    # MoE experts (params keyed "moe"): (E, D, F) / (E, F, D)
    (r".*moe.*(w_gate|w_up)$", ("tp", "fsdp", None)),
    (r".*moe.*w_out$", ("tp", None, "fsdp")),
    (r".*moe.*router$", ("fsdp", None)),
    # RG-LRU
    (r".*rec.*(w_rnn_in|w_gate_in)$", ("fsdp", "tp")),
    (r".*rec.*w_out$", ("tp", "fsdp")),
    (r".*rec.*(w_a|w_x)$", ("fsdp", "tp")),
    (r".*rec.*conv_w$", (None, "tp")),
    (r".*rec.*(b_a|b_x|conv_b|lam)$", ("tp",)),
    # RWKV
    (r".*tmix.*(w_r|w_k|w_v|w_g)$", ("fsdp", "tp")),
    (r".*tmix.*w_o$", ("tp", "fsdp")),
    (r".*tmix.*w_lora_a$", ("fsdp", None)),
    (r".*tmix.*w_lora_b$", (None, "tp")),
    (r".*cmix.*(w_ck|w_cr)$", ("fsdp", "tp")),
    (r".*cmix.*w_cv$", ("tp", "fsdp")),
    # embeddings / heads
    (r".*(embed|tok_embed)$", ("tp", "fsdp")),
    (r".*(enc_pos|dec_pos|pos_embed)$", (None, "fsdp")),
    (r".*lm_head$", ("fsdp", "tp")),
    (r".*(w_pool|w_cls)$", ("fsdp", None)),
]


def _resolve(axis: Optional[str], dist: DistContext) -> Optional[str]:
    if axis == "tp":
        return dist.tp_axis
    if axis == "fsdp":
        return dist.fsdp_axis
    return axis


def param_spec_for(path: str, shape: Tuple[int, ...], dist: DistContext,
                   *, has_scan_dim: bool) -> P:
    mesh = dist.mesh
    # MoE expert tensors whose E dim does not divide TP (grok-1): fall back
    # to TP on the d_ff dim (hybrid mode in transformer._moe_sharded)
    if re.search(r"moe.*(w_gate|w_up|w_out)$", path) and len(shape) >= 3:
        e_dim = shape[-3]
        if e_dim % mesh.shape[dist.tp_axis] != 0:
            if path.endswith("w_out"):     # (E, F, D): F on tp, D on fsdp
                assign = (None, dist.tp_axis, dist.fsdp_axis)
            else:                          # (E, D, F): D on fsdp, F on tp
                assign = (None, dist.fsdp_axis, dist.tp_axis)
            lead = len(shape) - 3
            return _spec(mesh, shape, (None,) * lead + assign)
    for pattern, assignment in _PARAM_RULES:
        if re.fullmatch(pattern, path):
            assign = tuple(_resolve(a, dist) for a in assignment)
            ndim = len(shape)
            lead = ndim - len(assign)
            if lead < 0:          # rule for more dims than leaf has: replicate
                return P()
            full = (None,) * lead + assign
            return _spec(mesh, shape, full)
    # default: replicate small leaves; fsdp-shard anything big on its largest
    # divisible dim
    if int(np.prod(shape)) >= 1 << 20:
        best = max(range(len(shape)), key=lambda i: shape[i])
        assign = [None] * len(shape)
        if _fits(shape[best], mesh, dist.fsdp_axis):
            assign[best] = dist.fsdp_axis
        return _spec(mesh, shape, tuple(assign))
    return P()


def _leaf_path(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def make_param_specs(params, dist: DistContext):
    """Pytree of PartitionSpec matching ``params``."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat[0]:
        p = _leaf_path(path)
        has_scan = "scan" in p
        specs.append(param_spec_for(p, leaf.shape, dist,
                                    has_scan_dim=has_scan))
    return jax.tree_util.tree_unflatten(flat[1], specs)


def make_param_shardings(params, dist: DistContext):
    specs = make_param_specs(params, dist)
    return jax.tree.map(lambda s: NamedSharding(dist.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation / batch / cache rules
# ---------------------------------------------------------------------------

def batch_spec(dist: DistContext) -> P:
    return P(dist.dp_axes)


def make_batch_shardings(batch, dist: DistContext):
    def spec(leaf):
        # shard leading (batch) dim over dp if divisible, else replicate
        bs = leaf.shape[0] if leaf.ndim else 1
        dp = int(np.prod([dist.mesh.shape[a] for a in dist.dp_axes]))
        s = P(dist.dp_axes, *([None] * (leaf.ndim - 1))) if bs % dp == 0 \
            else P()
        return NamedSharding(dist.mesh, s)
    return jax.tree.map(spec, batch)


def cache_spec_for(shape: Tuple[int, ...], dist: DistContext,
                   *, has_scan_dim: bool) -> P:
    """KV cache / recurrent state leaves.

    Layout (with scan dim): (L, B, S, KV, hd) or (L, B, ...state dims).
    Shard B over dp when divisible; otherwise shard the sequence dim over
    ``data`` (sequence parallelism for batch-1 long-context decode).

    Model-axis placement: by default the SEQUENCE dim shards over ``model``
    for 5-dim KV caches — decode attention then reduces tiny softmax
    partials over tp instead of all-gathering the cache every layer (the
    §Perf 'kvseq' finding: ~100 GiB/step of all-gather on internlm2
    decode_32k with head-sharded caches). Head/feature dims are the
    fallback when S does not divide.
    """
    mesh = dist.mesh
    dp = int(np.prod([mesh.shape[a] for a in dist.dp_axes]))
    lead = 1 if has_scan_dim else 0
    ndim = len(shape)
    assign = [None] * ndim
    bdim = lead
    if ndim <= bdim:
        return P()
    batch_shardable = shape[bdim] % dp == 0
    if batch_shardable:
        assign[bdim] = dist.dp_axes
    if ndim > bdim + 1:
        sdim = bdim + 1
        if not batch_shardable and shape[sdim] % _axis_size(mesh, dist.fsdp_axis) == 0:
            assign[sdim] = dist.fsdp_axis          # SP over 'data'
    # sequence-dim tp sharding for (L, B, S, KV, hd) KV caches
    tp_used = False
    if getattr(dist, "kv_seq_shard", True) and ndim - lead == 4:
        sdim = bdim + 1
        if assign[sdim] is None and shape[sdim] % mesh.shape[dist.tp_axis] == 0 \
                and shape[sdim] >= mesh.shape[dist.tp_axis]:
            assign[sdim] = dist.tp_axis
            tp_used = True
    # heads / feature dims on model axis: prefer KV-head dim, then features
    if not tp_used:
        for d in range(ndim - 2, ndim):
            if d > bdim and assign[d] is None and \
                    shape[d] % mesh.shape[dist.tp_axis] == 0 and \
                    dist.tp_axis not in [a for a in assign if a]:
                # avoid sharding tiny dims (e.g. kv=1, hd=64 < tp)
                if shape[d] >= mesh.shape[dist.tp_axis]:
                    assign[d] = dist.tp_axis
                    break
    cleaned = []
    used = set()
    for dim, axis in zip(shape, assign):
        if axis is None:
            cleaned.append(None)
        elif isinstance(axis, tuple):
            cleaned.append(axis)
            used.update(axis)
        elif axis not in used:
            cleaned.append(axis)
            used.add(axis)
        else:
            cleaned.append(None)
    while cleaned and cleaned[-1] is None:
        cleaned.pop()
    return P(*cleaned)


def make_cache_shardings(cache, dist: DistContext):
    flat = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat[0]:
        p = _leaf_path(path)
        has_scan = "scan" in p or "self_kv" in p or "cross" in p
        out.append(NamedSharding(dist.mesh,
                                 cache_spec_for(leaf.shape, dist,
                                                has_scan_dim=has_scan)))
    return jax.tree_util.tree_unflatten(flat[1], out)


def make_opt_shardings(opt_state, param_shardings, dist: DistContext):
    """Adam moments mirror the parameter shardings; step counter replicated."""
    from repro.optim.adam import AdamState
    return AdamState(
        step=NamedSharding(dist.mesh, P()),
        mu=param_shardings, nu=jax.tree.map(lambda s: s, param_shardings))


def constrain(x, dist: Optional[DistContext], spec: P):
    if dist is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(dist.mesh, spec))
