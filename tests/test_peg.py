"""Tests for per-embedding-group quantization (paper §4, Table 5, Fig. 4)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (Granularity, QuantizerConfig, RangeEstimator,
                        build_groups, fake_quant, group_index_natural_layout,
                        overhead_params, params_from_range, peg_config,
                        split_linear_for_per_tensor_hw)
from repro.core.peg import LANE, apply_permutation, fold_permutation_into_ffn
from repro.core.range_estimation import _group_reduce


def _outlier_acts(key, n=64, d=768, outlier_dims=(7, 421, 500), scale=50.0):
    """Synthetic activations with the paper's Fig.-2b structure: a few
    designated embedding dims carry consistent large-magnitude outliers."""
    x = jax.random.normal(key, (n, d))
    for dim in outlier_dims:
        x = x.at[:, dim].multiply(scale)
    return x


class TestGroupBuilding:
    def test_permutation_is_bijection(self):
        r = np.random.RandomState(0).rand(768)
        spec = build_groups(r, 6)
        assert sorted(spec.permutation.tolist()) == list(range(768))
        assert np.all(spec.permutation[spec.inverse_permutation] == np.arange(768))

    def test_outliers_land_in_same_group(self):
        r = np.ones(768)
        out_dims = [3, 100, 767]
        for d in out_dims:
            r[d] = 100.0
        spec = build_groups(r, 6, use_permutation=True)
        gi_nat = group_index_natural_layout(spec)
        groups = {gi_nat[d] for d in out_dims}
        assert len(groups) == 1
        assert groups.pop() == spec.num_groups - 1   # ascending sort: last group

    def test_lane_alignment(self):
        r = np.random.RandomState(1).rand(768)
        spec = build_groups(r, 6, lane_align=True)
        assert np.all(spec.group_sizes % LANE == 0)
        assert spec.group_sizes.sum() == 768

    def test_uneven_d_falls_back(self):
        r = np.random.RandomState(2).rand(100)
        spec = build_groups(r, 3, lane_align=True)
        assert spec.group_sizes.sum() == 100
        assert spec.num_groups == 3

    def test_tp_sharded_groups_stay_within_shards(self):
        r = np.random.RandomState(3).rand(1024)
        spec = build_groups(r, 8, tp_shards=4)
        per = 1024 // 4
        for s in range(4):
            chunk = spec.permutation[s * per:(s + 1) * per]
            assert chunk.min() >= s * per and chunk.max() < (s + 1) * per

    def test_overhead_matches_paper(self):
        # paper: d + 2*3*K extra params per attention layer, <0.04% of BERT-base
        extra = overhead_params(768, 6) * 12
        assert extra / 109e6 < 0.0004

    def test_bad_args(self):
        with pytest.raises(ValueError):
            build_groups(np.ones(10), 11)
        with pytest.raises(ValueError):
            build_groups(np.ones(12), 3, tp_shards=2)


class TestPEGQuantization:
    def _mse(self, x, cfg, gi=None):
        if gi is None:
            qp = params_from_range(jnp.min(x), jnp.max(x), cfg)
        else:
            gi = jnp.asarray(gi)
            k = int(gi.max()) + 1
            mn = jnp.min(x, axis=0)
            mx = jnp.max(x, axis=0)
            gmn, gmx = _group_reduce(mn, mx, gi, k)
            qp = params_from_range(gmn, gmx, cfg, group_index=gi)
        return float(jnp.mean(jnp.square(x - fake_quant(x, qp, cfg))))

    def test_peg_beats_per_tensor_on_outliers(self):
        """Reproduces the Table-5 mechanism: K=6 + permutation recovers most
        of the per-tensor quantization error caused by outlier dims."""
        x = _outlier_acts(jax.random.PRNGKey(0))
        ranges = np.asarray(jnp.max(x, 0) - jnp.min(x, 0))
        pt_cfg = QuantizerConfig(bits=8)
        peg_cfg_ = peg_config(6)
        spec = build_groups(ranges, 6, use_permutation=True)
        gi = group_index_natural_layout(spec)
        err_pt = self._mse(x, pt_cfg)
        err_peg = self._mse(x, peg_cfg_, gi)
        # Whole-tensor MSE gain is bounded by the clean dims that share the
        # outlier group (~d/K of them keep the coarse scale): expect > 4x.
        assert err_peg < err_pt / 4

        # The paper's actual mechanism: dims in the K-1 clean groups regain
        # fine resolution — error drops by orders of magnitude there.
        clean = np.asarray(gi) != int(np.max(gi))
        gi_j = jnp.asarray(gi)
        mn, mx = jnp.min(x, 0), jnp.max(x, 0)
        gmn, gmx = _group_reduce(mn, mx, gi_j, 6)
        qp_peg = params_from_range(gmn, gmx, peg_cfg_, group_index=gi_j)
        qp_pt = params_from_range(jnp.min(x), jnp.max(x), pt_cfg)
        e_peg = jnp.mean(jnp.square(x - fake_quant(x, qp_peg, peg_cfg_))[:, clean])
        e_pt = jnp.mean(jnp.square(x - fake_quant(x, qp_pt, pt_cfg))[:, clean])
        assert float(e_peg) < float(e_pt) / 100

    def test_permutation_matters_for_small_k(self):
        """Table 5: K=3 without permutation is poor, K=3+P recovers."""
        x = _outlier_acts(jax.random.PRNGKey(1),
                          outlier_dims=(0, 300, 700))  # spread over 3 chunks
        ranges = np.asarray(jnp.max(x, 0) - jnp.min(x, 0))
        cfg = peg_config(3)
        gi_perm = group_index_natural_layout(
            build_groups(ranges, 3, use_permutation=True))
        gi_noperm = group_index_natural_layout(
            build_groups(ranges, 3, use_permutation=False))
        err_p = self._mse(x, cfg, gi_perm)
        err_np = self._mse(x, cfg, gi_noperm)
        # no-perm: every chunk polluted -> ~per-tensor error; +P: 2 of 3
        # groups clean -> roughly a 3x whole-tensor win in the ideal case.
        # The measured ratio on this seed is ~1.99x: the un-permuted chunks
        # carry slightly smaller per-group scales than a true per-tensor
        # grid, eating into the ideal win. The property under test is that
        # permutation wins by a MULTIPLE (not a few percent), so assert
        # > 1.7x — comfortably above noise, below the seed's 1.99x.
        assert err_p < err_np / 1.7

    def test_k768_equals_per_embedding(self):
        x = _outlier_acts(jax.random.PRNGKey(2), n=16)
        ranges = np.asarray(jnp.max(x, 0) - jnp.min(x, 0))
        spec = build_groups(ranges, 768, lane_align=False)
        gi = group_index_natural_layout(spec)
        cfg_peg = peg_config(768)
        cfg_pe = QuantizerConfig(bits=8, granularity=Granularity.PER_EMBEDDING)
        mn, mx = jnp.min(x, 0), jnp.max(x, 0)
        qp_pe = params_from_range(mn, mx, cfg_pe)
        gmn, gmx = _group_reduce(mn, mx, jnp.asarray(gi), 768)
        qp_peg = params_from_range(gmn, gmx, cfg_peg, group_index=jnp.asarray(gi))
        np.testing.assert_allclose(fake_quant(x, qp_peg, cfg_peg),
                                   fake_quant(x, qp_pe, cfg_pe), atol=1e-6)


class TestPerTensorSimulation:
    """Paper Fig. 4: PEG == K split per-tensor matmuls (graph rewrite)."""

    def test_split_linear_equivalence(self):
        key = jax.random.PRNGKey(3)
        k1, k2, k3 = jax.random.split(key, 3)
        d, h, n = 256, 128, 8
        x = jax.random.normal(k1, (n, d))
        w_in = jax.random.normal(k2, (d, h)) / np.sqrt(d)
        w_out = jax.random.normal(k3, (h, d)) / np.sqrt(h)
        ranges = np.asarray(jnp.max(x, 0) - jnp.min(x, 0))
        spec = build_groups(ranges, 4, lane_align=False)

        # reference: permuted activations, single matmul
        xp = apply_permutation(x, spec.permutation)
        ref_h = xp @ w_in[spec.permutation, :]
        ref_out = (ref_h @ w_out)[:, spec.permutation]

        ins, outs = split_linear_for_per_tensor_hw(spec, w_in, w_out)
        bounds = np.concatenate([[0], np.cumsum(spec.group_sizes)])
        # sum of K per-group matmuls == full matmul
        h_sum = sum(xp[:, bounds[i]:bounds[i + 1]] @ ins[i]
                    for i in range(spec.num_groups))
        np.testing.assert_allclose(h_sum, ref_h, rtol=2e-4, atol=1e-4)
        # concatenation of K output slices == permuted output
        out_cat = jnp.concatenate([ref_h @ outs[i]
                                   for i in range(spec.num_groups)], axis=1)
        np.testing.assert_allclose(out_cat, ref_out, rtol=2e-4, atol=1e-4)

    def test_fold_permutation_layernorm_equivariance(self):
        """Permuting LN params == permuting LN output (paper §4)."""
        key = jax.random.PRNGKey(4)
        d = 64
        x = jax.random.normal(key, (8, d))
        gamma = jax.random.normal(jax.random.PRNGKey(5), (d,))
        beta = jax.random.normal(jax.random.PRNGKey(6), (d,))
        perm = np.random.RandomState(0).permutation(d)

        def ln(x, g, b):
            mu = jnp.mean(x, -1, keepdims=True)
            var = jnp.var(x, -1, keepdims=True)
            return (x - mu) * jax.lax.rsqrt(var + 1e-6) * g + b

        g2, b2, *_ = fold_permutation_into_ffn(
            perm, gamma, beta, jnp.zeros((d, d)), jnp.zeros(d),
            jnp.zeros((d, d)), jnp.zeros(d))
        # LN is permutation-equivariant: LN(x[perm]; g[perm]) == LN(x; g)[perm]
        np.testing.assert_allclose(ln(x[:, perm], g2, b2),
                                   ln(x, gamma, beta)[:, perm],
                                   rtol=1e-5, atol=1e-5)
