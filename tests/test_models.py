"""Model-substrate behaviour tests: forward/grad sanity, decode-vs-dense
consistency, family-specific invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.models import encdec, bert
from repro.models.attention import AttnConfig, _chunked_attend, _dense_attend
from repro.models.rglru import (init_recurrent_params, rg_lru_scan,
                                rg_lru_step)
from repro.models.rwkv6 import wkv_chunked, wkv_sequential, wkv_step

LM_ARCHS = ["h2o-danube3-4b", "internlm2-20b", "gemma2-2b", "granite-20b",
            "qwen3-moe-235b", "grok1-314b", "recurrentgemma-2b",
            "rwkv6-1p6b", "phi3-vision-4p2b"]


def _lm_batch(cfg, B=2, T=16, seed=1):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (B, T), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend:
        batch["embeds"] = jnp.zeros((B, cfg.num_frontend_tokens, cfg.d_model),
                                    jnp.float32)
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_loss_finite_and_grads_flow(arch):
    cfg = get_config(arch).reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = _lm_batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: tfm.train_loss(cfg, p, batch, remat=False))(params)
    assert np.isfinite(float(loss))
    total = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert total > 0


@pytest.mark.parametrize("arch", ["h2o-danube3-4b", "gemma2-2b",
                                  "recurrentgemma-2b", "rwkv6-1p6b",
                                  "qwen3-moe-235b", "granite-20b"])
def test_decode_matches_dense_forward(arch):
    """Prefill + T decode steps must equal the cache-free forward."""
    cfg = get_config(arch).reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    B, T, extra = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T + extra), 0,
                              cfg.vocab_size)
    full_logits, _ = tfm.forward(cfg, params, toks)
    cache = tfm.init_cache(cfg, B, 64, dtype=jnp.float32)
    logits_p, cache = tfm.prefill(cfg, params, toks[:, :T], cache)
    errs = [float(jnp.max(jnp.abs(logits_p[:, -1] - full_logits[:, T - 1])))]
    for t in range(extra):
        pos = jnp.full((B, 1), T + t, jnp.int32)
        lg, cache = tfm.decode_step(cfg, params, toks[:, T + t:T + t + 1],
                                    pos, cache)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, T + t]))))
    assert max(errs) < 5e-5


def test_unrolled_matches_scan():
    cfg = get_config("gemma2-2b").reduced()
    key = jax.random.PRNGKey(0)
    p_stacked = tfm.init_params(cfg, key, stacked=True, dtype=jnp.float32)
    p_flat = tfm.init_params(cfg, key, stacked=False, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    l1, _ = tfm.forward(cfg, p_stacked, toks)
    l2, _ = tfm.forward(cfg, p_flat, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_restricts_attention():
    """With ONE layer, a token further than `window` back must not influence
    the output (with L layers the receptive field grows to L*window)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("h2o-danube3-4b").reduced(),
                              num_layers=1)        # window=16
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    T = 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0, cfg.vocab_size)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 7) % cfg.vocab_size)
    l1, _ = tfm.forward(cfg, params, toks)
    l2, _ = tfm.forward(cfg, params, toks2)
    # last position is > window away from position 0: unaffected
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               atol=1e-5)
    # position 1 IS affected
    assert float(jnp.max(jnp.abs(l1[0, 1] - l2[0, 1]))) > 1e-4


def test_causality():
    cfg = get_config("internlm2-20b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0,
                              cfg.vocab_size)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 3) % cfg.vocab_size)
    l1, _ = tfm.forward(cfg, params, toks)
    l2, _ = tfm.forward(cfg, params, toks2)
    np.testing.assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]),
                               atol=1e-5)


class TestChunkedAttention:
    def test_matches_dense(self):
        key = jax.random.PRNGKey(0)
        B, T, H, KV, hd = 2, 64, 4, 2, 16
        cfg = AttnConfig(num_heads=H, num_kv_heads=KV, head_dim=hd)
        q = jax.random.normal(key, (B, T, H, hd))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, T, KV, hd))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, T, KV, hd))
        pos = jnp.broadcast_to(jnp.arange(T), (B, T)).astype(jnp.int32)
        out_d = _dense_attend(q, k, v, pos, pos, cfg)
        out_c = _chunked_attend(q, k, v, pos, pos, cfg, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_c),
                                   rtol=1e-4, atol=1e-5)

    def test_matches_dense_windowed_softcap(self):
        key = jax.random.PRNGKey(3)
        B, T, H, KV, hd = 1, 48, 2, 1, 8
        cfg = AttnConfig(num_heads=H, num_kv_heads=KV, head_dim=hd,
                         window=12, logit_softcap=20.0)
        q = jax.random.normal(key, (B, T, H, hd))
        k = jax.random.normal(jax.random.PRNGKey(4), (B, T, KV, hd))
        v = jax.random.normal(jax.random.PRNGKey(5), (B, T, KV, hd))
        pos = jnp.broadcast_to(jnp.arange(T), (B, T)).astype(jnp.int32)
        out_d = _dense_attend(q, k, v, pos, pos, cfg)
        out_c = _chunked_attend(q, k, v, pos, pos, cfg, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_c),
                                   rtol=1e-4, atol=1e-5)


class TestRWKV:
    def _make(self, B=2, H=3, T=96, dk=16, dv=16):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        r = jax.random.normal(ks[0], (B, H, T, dk))
        k = jax.random.normal(ks[1], (B, H, T, dk))
        v = jax.random.normal(ks[2], (B, H, T, dv))
        logw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, H, T, dk)) * .5),
                        -8.0, 0.0)
        u = jax.random.normal(ks[4], (H, dk)) * 0.1
        return r, k, v, logw, u

    def test_chunked_matches_sequential(self):
        r, k, v, logw, u = self._make()
        o1, s1 = wkv_sequential(r, k, v, logw, u)
        o2, s2 = wkv_chunked(r, k, v, logw, u, chunk=32)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-4, atol=1e-4)

    def test_step_matches_sequential(self):
        r, k, v, logw, u = self._make(T=8)
        o_ref, _ = wkv_sequential(r, k, v, logw, u)
        B, H, T, dk = k.shape
        s = jnp.zeros((B, H, dk, v.shape[-1]))
        outs = []
        for t in range(T):
            o, s = wkv_step(r[:, :, t], k[:, :, t], v[:, :, t],
                            logw[:, :, t], u, s)
            outs.append(o)
        np.testing.assert_allclose(np.asarray(jnp.stack(outs, 2)),
                                   np.asarray(o_ref), rtol=1e-5, atol=1e-5)


class TestRGLRU:
    def test_scan_matches_steps(self):
        d = 16
        p = init_recurrent_params(jax.random.PRNGKey(0), 32, d)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d))
        y_scan, h_fin = rg_lru_scan(p, x)
        h = jnp.zeros((2, d))
        ys = []
        for t in range(12):
            y, h = rg_lru_step(p, x[:, t], h)
            ys.append(y)
        np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                                   np.asarray(y_scan), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_fin),
                                   rtol=1e-4, atol=1e-5)

    def test_state_decays(self):
        """RG-LRU decay keeps |h| bounded (contraction)."""
        d = 8
        p = init_recurrent_params(jax.random.PRNGKey(0), 16, d)
        x = jnp.ones((1, 256, d))
        y, h = rg_lru_scan(p, x)
        assert np.all(np.isfinite(np.asarray(y)))
        assert float(jnp.max(jnp.abs(h))) < 100.0


class TestEncDec:
    def test_train_and_decode(self):
        cfg = get_config("seamless-m4t-medium").reduced()
        params = encdec.init_params(cfg, jax.random.PRNGKey(0),
                                    dtype=jnp.float32)
        B, S, T = 2, 8, 10
        frames = jax.random.normal(jax.random.PRNGKey(1),
                                   (B, S, cfg.d_model)) * 0.02
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                                  cfg.vocab_size)
        loss = encdec.train_loss(cfg, params,
                                 {"frames": frames, "tokens": toks,
                                  "labels": toks})
        assert np.isfinite(float(loss))
        mem = encdec.encode(cfg, params, frames)
        full_logits, _ = encdec.decode(cfg, params, toks, mem)
        logits0, cache = encdec.prefill_from_encoder(cfg, params, frames,
                                                     toks[:, :1], 32)
        errs = [float(jnp.max(jnp.abs(logits0[:, -1] - full_logits[:, 0])))]
        for t in range(1, 4):
            pos = jnp.full((B, 1), t, jnp.int32)
            lg, cache = encdec.decode_step(cfg, params, toks[:, t:t + 1],
                                           pos, cache)
            errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, t]))))
        assert max(errs) < 5e-5


class TestBert:
    def test_loss_and_predict(self):
        cfg = bert.tiny()
        params = bert.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.asarray([0, 1, 0, 1])}
        loss = bert.loss_fn(cfg, params, batch)
        assert np.isfinite(float(loss))
        preds = bert.predict(cfg, params, batch)
        assert preds.shape == (4,)

    def test_quantizer_census_scale(self):
        """Paper: 161 activation quantizers for BERT-base; our site layout
        counts 160 (the accounting granularity matches)."""
        n = len(bert.activation_sites(bert.BertConfig()))
        assert 150 <= n <= 170

    def test_padding_mask_blocks_attention(self):
        cfg = bert.tiny()
        params = bert.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                  cfg.vocab_size)
        mask = jnp.asarray([[1, 1, 1, 1, 0, 0, 0, 0]], bool)
        h1 = bert.encode(cfg, params, toks, pad_mask=mask)
        toks2 = toks.at[0, 5].set((toks[0, 5] + 3) % cfg.vocab_size)
        h2 = bert.encode(cfg, params, toks2, pad_mask=mask)
        # changing a padded token must not affect valid positions
        np.testing.assert_allclose(np.asarray(h1[0, :4]),
                                   np.asarray(h2[0, :4]), atol=1e-5)
