"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free time-mix with
data-dependent per-channel decay + squared-ReLU channel-mix.

Recurrence per head (dk = dv = head_size):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

TPU adaptation (DESIGN.md §3): training/prefill uses a *chunked* gated-
linear-attention formulation — intra-chunk pairwise decays via a masked
einsum (stable: exponents are differences of a non-increasing cumulative
log-decay, always <= 0), inter-chunk via the carried state — giving
matmul-dominated compute instead of a length-T sequential loop. The
sequential scan (`wkv_sequential`) is kept as the numerical oracle; decode
uses the O(1) single-step update.

Simplification vs the reference implementation (noted in DESIGN.md): the five
token-shift interpolations use static learned mu (the low-rank data-dependent
delta is applied to the decay w only), and the decay LoRA uses a single
down/up pair.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys

MIN_LOG_W = -8.0     # clamp per-step log-decay for numerical safety


class RWKVState(NamedTuple):
    s: jnp.ndarray          # (B, H, dk, dv) wkv state
    x_tm: jnp.ndarray       # (B, D) last input of time-mix (token shift)
    x_cm: jnp.ndarray       # (B, D) last input of channel-mix


def _token_shift(x, x_last, mu):
    """x: (B,T,D); returns mu-interpolated [x_{t-1}, x_t]."""
    prev = jnp.concatenate([x_last[:, None].astype(x.dtype), x[:, :-1]],
                           axis=1)
    return (x + (prev - x) * mu.astype(x.dtype)).astype(x.dtype)


def _decay(p, xw):
    """Data-dependent per-channel log-decay, clamped <= 0."""
    lora = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    logw = -jnp.exp(jnp.clip(p["w_base"] + lora, -20.0, 4.0))
    return jnp.clip(logw, MIN_LOG_W, 0.0)            # (B, T, D)


def wkv_sequential(r, k, v, logw, u, s0=None):
    """Oracle: step-by-step recurrence.
    r/k: (B,H,T,dk), v: (B,H,T,dv), logw: (B,H,T,dk), u: (H,dk)."""
    B, H, T, dk = k.shape
    dv = v.shape[-1]
    s = jnp.zeros((B, H, dk, dv), jnp.float32) if s0 is None else s0

    def step(s, inputs):
        r_t, k_t, v_t, lw_t = inputs
        w_t = jnp.exp(lw_t)                                    # (B,H,dk)
        kv = k_t[..., :, None] * v_t[..., None, :]             # (B,H,dk,dv)
        o = jnp.einsum("bhk,bhkv->bhv", r_t,
                       s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, o

    xs = (r.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), logw.transpose(2, 0, 1, 3))
    s, os_ = jax.lax.scan(step, s, xs)
    return os_.transpose(1, 2, 0, 3), s                        # (B,H,T,dv), state


def wkv_chunked(r, k, v, logw, u, s0=None, chunk: int = 32):
    """Chunked GLA form; matches wkv_sequential.
    Shapes as in wkv_sequential. T must be a multiple of ``chunk``
    (callers pad)."""
    B, H, T, dk = k.shape
    dv = v.shape[-1]
    n = T // chunk
    rc = r.reshape(B, H, n, chunk, dk).astype(jnp.float32)
    kc = k.reshape(B, H, n, chunk, dk).astype(jnp.float32)
    vc = v.reshape(B, H, n, chunk, dv).astype(jnp.float32)
    lw = logw.reshape(B, H, n, chunk, dk).astype(jnp.float32)

    # cumulative log decay *inclusive* of step t: cl_t = sum_{s<=t} logw_s
    cl = jnp.cumsum(lw, axis=3)                                # (B,H,n,C,dk)

    # Intra-chunk pairwise decays: for t > s, decay = exp(cl_{t-1} - cl_s)
    # (state used by o_t excludes step t's own decay — S_{t-1}).
    cl_tm1 = cl - lw                                           # cl_{t-1}
    diff = cl_tm1[..., :, None, :] - cl[..., None, :, :]       # (.., t, s, dk)
    tmask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)     # strict lower
    dec = jnp.where(tmask[..., None], jnp.exp(
        jnp.where(tmask[..., None], diff, 0.0)), 0.0)
    scores = jnp.einsum("bhntk,bhnsk,bhntsk->bhnts", rc, kc, dec)
    o_intra = jnp.einsum("bhnts,bhnsv->bhntv", scores, vc)
    # bonus diagonal term: r_t (u ⊙ k_t) v_t
    bonus = jnp.einsum("bhntk,hk,bhntk->bhnt", rc, u.astype(jnp.float32), kc)
    o_intra = o_intra + bonus[..., None] * vc

    # Inter-chunk: scan the state across chunks.
    s_init = jnp.zeros((B, H, dk, dv), jnp.float32) if s0 is None \
        else s0.astype(jnp.float32)
    # contribution of chunk to next state: sum_s exp(cl_C - cl_s) k_s v_s^T
    end_dec = jnp.exp(cl[..., -1:, :] - cl)                    # (B,H,n,C,dk)
    chunk_kv = jnp.einsum("bhnsk,bhnsv->bhnkv", kc * end_dec, vc)
    chunk_decay = jnp.exp(cl[..., -1, :])                      # (B,H,n,dk)

    def step(s, ins):
        ckv, cdec, r_chunk, cltm1 = ins
        # o_inter_t = (r_t ⊙ exp(cl_{t-1})) @ s
        o_inter = jnp.einsum("bhtk,bhkv->bhtv", r_chunk * jnp.exp(cltm1), s)
        s_new = cdec[..., None] * s + ckv
        return s_new, o_inter

    xs = (chunk_kv.transpose(2, 0, 1, 3, 4), chunk_decay.transpose(2, 0, 1, 3),
          rc.transpose(2, 0, 1, 3, 4), cl_tm1.transpose(2, 0, 1, 3, 4))
    s_fin, o_inter = jax.lax.scan(step, s_init, xs)
    o = o_intra + o_inter.transpose(1, 2, 0, 3, 4)
    return o.reshape(B, H, T, dv).astype(r.dtype), s_fin


def wkv_step(r_t, k_t, v_t, logw_t, u, s):
    """Decode: single token. r_t/k_t: (B,H,dk), v_t: (B,H,dv)."""
    kv = k_t[..., :, None] * v_t[..., None, :]
    o = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
    s_new = jnp.exp(logw_t)[..., None] * s + kv
    return o, s_new


# ---------------------------------------------------------------------------
# Full blocks
# ---------------------------------------------------------------------------

def _group_norm(x, gamma, beta, eps=1e-5):
    """Per-head layer norm of (B, H, T, dv)."""
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def time_mix(p, x, head_size: int, *, state: Optional[RWKVState] = None,
             ctx=None, prefix="tmix", chunk: int = 32):
    """RWKV6 time-mix. x: (B, T, D)."""
    B, T, D = x.shape
    H = D // head_size

    def w(name):
        return ctx.weight(f"{prefix}/{name}", p[name]) if ctx is not None else p[name]

    x_last = state.x_tm if state is not None else jnp.zeros((B, D), x.dtype)
    xr = _token_shift(x, x_last, p["mu_r"])
    xk = _token_shift(x, x_last, p["mu_k"])
    xv = _token_shift(x, x_last, p["mu_v"])
    xw = _token_shift(x, x_last, p["mu_w"])
    xg = _token_shift(x, x_last, p["mu_g"])

    r = (xr @ w("w_r")).reshape(B, T, H, head_size).transpose(0, 2, 1, 3)
    k = (xk @ w("w_k")).reshape(B, T, H, head_size).transpose(0, 2, 1, 3)
    v = (xv @ w("w_v")).reshape(B, T, H, head_size).transpose(0, 2, 1, 3)
    g = jax.nn.silu(xg @ w("w_g"))
    logw = _decay(p, xw).reshape(B, T, H, head_size).transpose(0, 2, 1, 3)
    if ctx is not None:
        r = ctx.act(f"{prefix}/r", r)
        k = ctx.act(f"{prefix}/k", k)
        v = ctx.act(f"{prefix}/v", v)

    s0 = state.s if state is not None else None
    if T == 1 and state is not None:
        o, s_new = wkv_step(r[:, :, 0].astype(jnp.float32),
                            k[:, :, 0].astype(jnp.float32),
                            v[:, :, 0].astype(jnp.float32),
                            logw[:, :, 0].astype(jnp.float32), p["u"], s0)
        o = o[:, :, None].astype(r.dtype)
        s_new = s_new.astype(s0.dtype)
    else:
        pad = (-T) % chunk
        if pad:
            rp = jnp.pad(r, ((0, 0), (0, 0), (0, pad), (0, 0)))
            kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            lp = jnp.pad(logw, ((0, 0), (0, 0), (0, pad), (0, 0)))
            o, s_new = wkv_chunked(rp, kp, vp, lp, p["u"], s0, chunk)
            o = o[:, :, :T]
        else:
            o, s_new = wkv_chunked(r, k, v, logw, p["u"], s0, chunk)
    o = _group_norm(o, p["gn_g"], p["gn_b"])
    o = o.transpose(0, 2, 1, 3).reshape(B, T, D)
    out = (o * g) @ w("w_o")
    if ctx is not None:
        out = ctx.act(f"{prefix}/out", out)
    new_state = None
    if state is not None:
        new_state = state._replace(s=s_new, x_tm=x[:, -1].astype(jnp.float32))
    return out, new_state


def channel_mix(p, x, *, state: Optional[RWKVState] = None, ctx=None,
                prefix="cmix"):
    """RWKV6 channel-mix (the FFN analogue — where the paper's PEG applies)."""
    B, T, D = x.shape

    def w(name):
        return ctx.weight(f"{prefix}/{name}", p[name]) if ctx is not None else p[name]

    x_last = state.x_cm if state is not None else jnp.zeros((B, D), x.dtype)
    xk = _token_shift(x, x_last, p["mu_ck"])
    xr = _token_shift(x, x_last, p["mu_cr"])
    if ctx is not None:
        xk = ctx.act(f"{prefix}/ffn_in", xk)
    k = jnp.square(jax.nn.relu(xk @ w("w_ck")))
    out = jax.nn.sigmoid(xr @ w("w_cr")) * (k @ w("w_cv"))
    if ctx is not None:
        out = ctx.act(f"{prefix}/ffn_out", out)
    new_state = None
    if state is not None:
        new_state = state._replace(x_cm=x[:, -1].astype(jnp.float32))
    return out, new_state


def init_rwkv_state(batch: int, d_model: int, head_size: int) -> RWKVState:
    H = d_model // head_size
    return RWKVState(s=jnp.zeros((batch, H, head_size, head_size), jnp.float32),
                     x_tm=jnp.zeros((batch, d_model), jnp.float32),
                     x_cm=jnp.zeros((batch, d_model), jnp.float32))


def init_rwkv_params(key, d_model: int, d_ff: int, head_size: int,
                     dtype=jnp.float32, lora_rank: int = 64):
    ks = split_keys(key, 12)
    H = d_model // head_size
    p = {
        "w_r": dense_init(ks[0], d_model, d_model, dtype),
        "w_k": dense_init(ks[1], d_model, d_model, dtype),
        "w_v": dense_init(ks[2], d_model, d_model, dtype),
        "w_g": dense_init(ks[3], d_model, d_model, dtype),
        "w_o": dense_init(ks[4], d_model, d_model, dtype),
        "w_lora_a": dense_init(ks[5], d_model, lora_rank, dtype),
        "w_lora_b": (jax.random.normal(ks[6], (lora_rank, d_model)) * 0.01).astype(dtype),
        "w_base": jnp.full((d_model,), 0.5, dtype),
        "u": (jax.random.normal(ks[7], (H, head_size)) * 0.1).astype(dtype),
        "gn_g": jnp.ones((head_size,), dtype),
        "gn_b": jnp.zeros((head_size,), dtype),
        "w_ck": dense_init(ks[8], d_model, d_ff, dtype),
        "w_cv": dense_init(ks[9], d_ff, d_model, dtype),
        "w_cr": dense_init(ks[10], d_model, d_model, dtype),
    }
    for name in ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g", "mu_ck", "mu_cr"):
        p[name] = jnp.full((d_model,), 0.5, dtype)
    return p
