"""bert-base-uncased — the paper's own model (Devlin et al. 2019), used by the
reproduction benchmarks. Encoder-only: no decode shapes; not part of the 40
assigned dry-run cells (it is dry-run-able via --arch bert-base for its
train/prefill shapes)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="bert-base",
    family="encoder",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=30522,
    rope_theta=None,              # learned absolute positions
    norm="layernorm",
    act="gelu",
    ffn_type="mlp",
    tie_embeddings=True,
    max_seq_len=512,
    skip_decode=True,
    sub_quadratic=False,
    source="Devlin et al. 2019 (paper's model)",
)
