"""Integer-path deployment (``Mode.DEPLOY``): run quantized serving through
the Pallas kernels instead of simulating quantization in f32.

The PTQ pipeline (pipeline.py) produces *fake-quant* parameters: scales and
zero-points that the ``Mode.APPLY`` context uses to round-trip f32 tensors
through the integer grid while the matmuls stay full-precision. This module
turns that artifact into a *deployable* fixed-point program (paper eq. 3-5):

  * weights are pre-quantized ONCE into packed int8 payloads — ``{"q": int8
    (K, N), "s": f32 (), "colsum": int32 (G, N)}`` — cached **in the param
    pytree**, so a lax.scan over stacked layers slices per-layer packed
    weights exactly like it slices f32 weights (scales are traced leaves:
    no recompile per layer / per calibration);
  * activations flow between matmuls as :class:`QTensor` int8 payloads; the
    FFN chain  LN -> quant -> W_in matmul -> GELU -> requant -> W_out matmul
    executes as  ``ln/rms_quantize`` -> ``int8_matmul_peg`` (fused epilogue:
    bias + activation + re-quantize) -> ``int8_matmul`` with the f32
    intermediates never leaving VMEM;
  * the paper's range-based PEG permutation is folded into the packed weight
    rows and the (tiny) norm affine at pack time, so groups are contiguous
    lane-aligned spans at runtime.

Sub-8-bit weight payloads (paper Tables 5-7): a 4-bit policy packs two int4
rows per int8 byte (``{"q4": int8 (K/2, N), "s", "colsum"}`` — see
repro.kernels.nibble) and the matmul kernels unpack to int8 in VMEM, so the
MXU path is unchanged while HBM weight reads halve.

Models dispatch on ``is_packed(weight)`` / ``isinstance(x, QTensor)``; sites
whose calibration is missing or whose grouping the kernels cannot express
(non-uniform groups, non-4/8-bit, odd-K 4-bit, per-channel hidden scales)
simply stay on the fake-quant path — deployment degrades gracefully site by
site.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.quant_config import (Granularity, QuantizationPolicy,
                                     QuantizerConfig)
from repro.core.quantizer import QuantParams
from repro.core.range_estimation import estimate_weight_params
from repro.kernels import nibble
from repro.kernels import ops
from repro.kernels import ref as kref

# int8 payload grid: asymmetric uint8 parameters are shifted by -128 so every
# integer tensor in HBM is int8 (the standard uint8 -> int8 re-centering:
# q8 = q - 128, z8 = z - 128 leaves s * (q - z) unchanged).
_SHIFT = 128


class QTensor(NamedTuple):
    """An int8 activation payload between kernels.

    q: (..., K) int8, already in the layout its consumer weight expects
       (PEG sites: permuted/group-sorted); scales/zps: (G,) f32 on the
       shifted int8 grid. G == 1 is the per-tensor case.
    """
    q: jnp.ndarray
    scales: jnp.ndarray
    zps: jnp.ndarray

    @property
    def shape(self):
        return self.q.shape


@dataclasses.dataclass(frozen=True)
class ActQuant:
    """Deploy-side quantizer for one matmul-input site (host-side constants +
    traced scale arrays; lives on the ctx, not in the param pytree)."""
    scales: jnp.ndarray            # (G,) f32
    zps: jnp.ndarray               # (G,) f32, shifted int8 grid
    qmin: int                      # shifted grid bounds
    qmax: int
    perm: Optional[jnp.ndarray]    # (d,) PEG permutation or None

    @property
    def per_tensor(self) -> bool:
        return int(self.scales.shape[0]) == 1 and self.perm is None


class KVQuant(NamedTuple):
    """Calibrated per-head grids for the int8 KV cache: the ``{prefix}/k`` /
    ``{prefix}/v`` sites' quantization step and zero-point (shifted onto the
    int8 grid; all f32, (KV,)). Registered in ``deploy_acts`` under the
    ``{prefix}/attn/kv`` site by :func:`build_deploy`.

    The cache write (repro.models.attention.quantize_kv) re-uses the site's
    own affine grid, so values the simulate path already snapped to that
    grid round-trip the int8 cache EXACTLY — deployment parity is limited by
    the attention arithmetic, not by cache storage. The zero-point is
    per-head STATIC (it lives here, not in the cache): the decode kernel
    folds it into per-program scalar corrections, keeping the per-slot
    payload zero-point-free and the S-loop free of zero-point gathers."""
    k_grid: jnp.ndarray
    v_grid: jnp.ndarray
    k_zp: jnp.ndarray
    v_zp: jnp.ndarray


def kv_quant_for(act_state, policy: QuantizationPolicy, attn_prefix: str,
                 num_kv_heads: int, bits: int = 8) -> Optional[KVQuant]:
    """Per-head k/v grids from the calibrated ``{prefix}/k``/``{prefix}/v``
    sites (paper Fig. 1): per-tensor scales broadcast over heads. Returns
    None for anything else — per-channel/PEG scales span (or permute) the
    head_dim axis, not the (KV, hd) head layout, and only the per-tensor
    grid gives the exact round-trip this packing exists for. The cache then
    quantizes purely dynamically per slot (or stays bf16, per the fallback
    rule).

    ``bits=4`` derives the same grids re-estimated on the int4 range: the
    calibrated site must itself be 4-bit for the exact-round-trip property,
    so a 4-bit request against an 8-bit calibration returns None and the
    cache quantizes dynamically on the [-7, 7] grid instead. Asymmetric
    grids shift by 2^(bits-1) (uint4 -> int4 re-centering, like _SHIFT)."""
    grids = []
    for name in ("k", "v"):
        site = f"{attn_prefix}/{name}"
        qp = act_state.get(site)
        if qp is None:
            return None
        cfg = policy.act_config(site)
        if not cfg.enabled or cfg.bits != bits or qp.group_index is not None \
                or jnp.size(qp.scale) != 1:
            return None
        scale = jnp.asarray(qp.scale, jnp.float32).reshape(())
        shift = 2 ** (bits - 1) if cfg.qmin == 0 else 0
        zp = jnp.asarray(qp.zero_point, jnp.float32).reshape(()) - shift
        grids.append((jnp.full((num_kv_heads,), scale),
                      jnp.full((num_kv_heads,), zp)))
    return KVQuant(k_grid=grids[0][0], v_grid=grids[1][0],
                   k_zp=grids[0][1], v_zp=grids[1][1])


def is_packed(w) -> bool:
    """True for a packed deployment weight: int8 (``q``) or nibble-packed
    int4 (``q4``) payload (vs f32 array / legacy {"q", "s"} storage, which
    lacks the colsum payload)."""
    return isinstance(w, dict) and ("q" in w or "q4" in w) and "colsum" in w


# ---------------------------------------------------------------------------
# Building the deployment artifact
# ---------------------------------------------------------------------------

def act_quant_for(qp: QuantParams, cfg: QuantizerConfig) -> Optional[ActQuant]:
    """Convert fake-quant activation params into a deployable ActQuant.
    Returns None when the kernels cannot express the site."""
    if cfg.bits != 8:
        return None
    shift = _SHIFT if cfg.qmin == 0 else 0
    qmin, qmax = cfg.qmin - shift, cfg.qmax - shift
    scale = jnp.atleast_1d(jnp.asarray(qp.scale, jnp.float32))
    zp = jnp.atleast_1d(jnp.asarray(qp.zero_point, jnp.float32)) - shift
    if qp.group_index is None:
        if scale.shape[0] != 1:          # per-channel/embedding: not packed
            return None
        return ActQuant(scales=scale, zps=zp, qmin=qmin, qmax=qmax, perm=None)
    gi = np.asarray(qp.group_index)
    counts = np.bincount(gi, minlength=scale.shape[0])
    if counts.min() != counts.max():     # kernel needs uniform groups
        return None
    perm = np.argsort(gi, kind="stable")
    perm_arr = None if np.array_equal(perm, np.arange(gi.shape[0])) \
        else jnp.asarray(perm)
    return ActQuant(scales=scale, zps=zp, qmin=qmin, qmax=qmax, perm=perm_arr)


def pack_linear(w, wcfg: QuantizerConfig, num_groups: int,
                perm: Optional[jnp.ndarray] = None) -> Optional[dict]:
    """Quantize one weight matrix (K, N) — or a stacked (L, K, N) — into the
    packed int + scale + per-group-colsum payload. Rows are permuted first
    when the consuming activation site uses the PEG permutation.

    8-bit configs emit ``{"q": int8 (K, N), ...}``; 4-bit configs emit
    ``{"q4": int8 (K/2, N), ...}`` with two int4 rows per byte
    (repro.kernels.nibble.pack_rows) — the colsum is always computed from
    the UNPACKED values, and the quantization grid is exactly the
    simulate-path fake-quant grid, so the payload round-trips bit-exactly.
    4-bit gating: K and the PEG group size must be even (else fall back)."""
    if not wcfg.enabled or wcfg.bits not in (4, 8) or not wcfg.symmetric \
            or wcfg.granularity != Granularity.PER_TENSOR:
        return None
    from repro.models.common import resolve_weight
    w = resolve_weight(w).astype(jnp.float32)
    k_dim = w.shape[-2]
    if wcfg.bits == 4 and (k_dim % 2 or (k_dim // num_groups) % 2):
        return None

    def _pack_one(w2):
        if perm is not None:
            w2 = jnp.take(w2, perm, axis=0)
        qp = estimate_weight_params(w2, wcfg)
        s = jnp.maximum(qp.scale.astype(jnp.float32),
                        jnp.finfo(jnp.float32).tiny)
        wq = jnp.clip(jnp.round(w2 / s), wcfg.qmin,
                      wcfg.qmax).astype(jnp.int8)
        colsum = kref.w_colsum_groups(wq, num_groups)
        if wcfg.bits == 4:
            return {"q4": nibble.pack_rows(wq), "s": s, "colsum": colsum}
        return {"q": wq, "s": s, "colsum": colsum}

    if w.ndim == 3:                      # stacked scan layout: per-layer pack
        return jax.vmap(_pack_one)(w)
    return _pack_one(w)


def _site(act_state, policy, name) -> Optional[ActQuant]:
    qp = act_state.get(name)
    if qp is None:
        return None
    return act_quant_for(qp, policy.act_config(name))


def _pack_ffn(bp: dict, prefix: str, policy: QuantizationPolicy,
              acts: Dict[str, ActQuant]) -> Optional[dict]:
    """Pack one block's FFN weights if every needed site deploys."""
    ffn = bp.get("ffn")
    if not isinstance(ffn, dict):
        return None
    in_aq = acts.get(f"{prefix}/ffn_in")
    hid_aq = acts.get(f"{prefix}/ffn/hidden")
    if in_aq is None or hid_aq is None or not hid_aq.per_tensor:
        return None
    g_in = int(in_aq.scales.shape[0])
    packed = dict(ffn)
    if "w_gate" in ffn:                  # GLU
        names = [("w_gate", g_in, in_aq.perm), ("w_up", g_in, in_aq.perm),
                 ("w_out", 1, None)]
    elif "w_in" in ffn:
        names = [("w_in", g_in, in_aq.perm), ("w_out", 1, None)]
    else:
        return None
    for name, g, perm in names:
        wcfg = policy.weight_config(f"{prefix}/ffn/{name}")
        pk = pack_linear(ffn[name], wcfg, g, perm)
        if pk is None:
            return None
        packed[name] = pk
    return packed


def _pack_attn(bp: dict, prefix: str, policy: QuantizationPolicy,
               acts: Dict[str, ActQuant]) -> Optional[dict]:
    attn = bp.get("attn")
    if not isinstance(attn, dict):
        return None
    in_aq = acts.get(f"{prefix}/attn_in")
    wo_aq = acts.get(f"{prefix}/attn/wo_in")
    if in_aq is None or wo_aq is None or not in_aq.per_tensor \
            or not wo_aq.per_tensor:
        return None
    packed = dict(attn)
    for name in ("wq", "wk", "wv", "wo"):
        wcfg = policy.weight_config(f"{prefix}/attn/{name}")
        pk = pack_linear(attn[name], wcfg, 1, None)
        if pk is None:
            return None
        packed[name] = pk
    return packed


def build_deploy(cfg, params, policy: QuantizationPolicy, act_state
                 ) -> Tuple[dict, Dict[str, ActQuant]]:
    """Pre-quantize every deployable linear in a transformer param pytree.

    Returns (packed_params, deploy_acts). ``packed_params`` replaces FFN /
    attention projection weights with packed payloads wherever the policy,
    the calibrated ``act_state`` and the kernel layout constraints allow;
    everything else is left untouched (those sites keep fake-quant APPLY
    behavior). ``deploy_acts`` maps input-site names to :class:`ActQuant`,
    plus ``{prefix}/attn/kv`` -> :class:`KVQuant` clip ranges for the int8
    KV cache. Works on both the stacked-scan and the unrolled param layouts.
    """
    acts: Dict[str, ActQuant] = {}
    for name, qp in act_state.items():
        aq = _site(act_state, policy, name)
        if aq is not None:
            acts[name] = aq

    def pack_block(bp, prefix):
        new = dict(bp)
        ffn = _pack_ffn(bp, prefix, policy, acts)
        if ffn is not None:
            new["ffn"] = ffn
        attn = _pack_attn(bp, prefix, policy, acts)
        if attn is not None:
            new["attn"] = attn
        if isinstance(bp.get("attn"), dict):
            # int8 KV cache clip ranges (independent of projection packing)
            kv = kv_quant_for(act_state, policy, f"{prefix}/attn",
                              cfg.num_kv_heads)
            if kv is not None:
                acts[f"{prefix}/attn/kv"] = kv
            # int4 grids under a separate site key: only present when the
            # k/v sites were themselves calibrated at 4 bits
            kv4 = kv_quant_for(act_state, policy, f"{prefix}/attn",
                               cfg.num_kv_heads, bits=4)
            if kv4 is not None:
                acts[f"{prefix}/attn/kv4"] = kv4
        return new

    packed = dict(params)
    if "scan" in params:
        packed["scan"] = [pack_block(bp, "layer") for bp in params["scan"]]
        packed["tail"] = [pack_block(bp, "tail") for bp in params["tail"]]
    if "layers" in params:
        packed["layers"] = [pack_block(bp, f"layer{i}")
                            for i, bp in enumerate(params["layers"])]
    return packed, acts


# ---------------------------------------------------------------------------
# Runtime entry points (called from repro.models)
# ---------------------------------------------------------------------------

def norm_quantize(norm_kind: str, p_norm: dict, x, aq: ActQuant) -> QTensor:
    """Fused norm + int8 emit for a matmul input: one VPU pass, the
    normalized f32 row never leaves VMEM. The PEG permutation (if any) is
    applied to the input and folded into the norm affine."""
    g = p_norm["g"]
    if aq.perm is not None:
        x = jnp.take(x, aq.perm, axis=-1)
        g = jnp.take(g, aq.perm, axis=0)
    if norm_kind == "layernorm":
        b = p_norm["b"]
        if aq.perm is not None:
            b = jnp.take(b, aq.perm, axis=0)
        q = ops.ln_quantize(x, g, b, aq.scales, aq.zps,
                            qmin=aq.qmin, qmax=aq.qmax)
    else:
        q = ops.rms_quantize(x, g, aq.scales, aq.zps,
                             qmin=aq.qmin, qmax=aq.qmax)
    return QTensor(q=q, scales=aq.scales, zps=aq.zps)


def quantize_act(x, aq: ActQuant) -> QTensor:
    """Plain fused quantize (no norm) — e.g. the Wo input after attention."""
    if aq.perm is not None:
        x = jnp.take(x, aq.perm, axis=-1)
    q = ops.peg_quantize(x, aq.scales, aq.zps, qmin=aq.qmin, qmax=aq.qmax)
    return QTensor(q=q, scales=aq.scales, zps=aq.zps)


def site_stats(x, aq: ActQuant) -> jnp.ndarray:
    """Quant-health vector ``[n_clipped, n_total, amax, cal_range]`` for a
    deploy-fused quantize site, computed from the f32 input the kernel is
    about to consume (mirrors quantizer.telemetry_stats on the shifted int8
    grid). Used only under ``--quant-telemetry``; the fused kernels
    themselves stay untouched."""
    xf = x.astype(jnp.float32)
    if aq.perm is not None:
        xf = jnp.take(xf, aq.perm, axis=-1)
    g = int(aq.scales.shape[0])
    if g > 1:                            # PEG: fold dims into (…, G, d/G)
        d = xf.shape[-1]
        xf = xf.reshape(xf.shape[:-1] + (g, d // g))
        s = aq.scales.reshape(g, 1)
        z = aq.zps.reshape(g, 1)
    else:
        s, z = aq.scales[0], aq.zps[0]
    s = jnp.maximum(s, jnp.finfo(jnp.float32).tiny)
    t = jnp.round(xf / s) + z
    clipped = jnp.sum((t < aq.qmin) | (t > aq.qmax))
    cal_range = jnp.max(jnp.maximum(jnp.abs(s * (aq.qmin - z)),
                                    jnp.abs(s * (aq.qmax - z))))
    return jnp.stack([clipped.astype(jnp.float32), jnp.float32(xf.size),
                      jnp.max(jnp.abs(xf)), cal_range.astype(jnp.float32)])


def qtensor_stats(qt: QTensor, aq: ActQuant) -> jnp.ndarray:
    """Saturation-only quant-health vector for a kernel-internal requant
    site (e.g. the FFN hidden emitted by the fused epilogue): the f32
    pre-quant values never leave VMEM, so ``n_clipped`` counts payload
    values sitting ON the grid edges and ``amax`` is the dequantized
    magnitude — capped at the grid edge, so ``amax_ratio`` tops out at ~1
    (docs/observability.md spells out the caveat)."""
    q = qt.q.astype(jnp.int32)
    sat = jnp.sum((q <= aq.qmin) | (q >= aq.qmax))
    # requant epilogues are per-tensor (enforced at pack time), so a scalar
    # grid suffices for the dequantized magnitude
    s = jnp.maximum(aq.scales[0], jnp.finfo(jnp.float32).tiny)
    z = aq.zps[0]
    deq_amax = jnp.max(jnp.abs((q.astype(jnp.float32) - z) * s))
    cal_range = jnp.maximum(jnp.abs(s * (aq.qmin - z)),
                            jnp.abs(s * (aq.qmax - z)))
    return jnp.stack([sat.astype(jnp.float32), jnp.float32(q.size),
                      deq_amax, cal_range.astype(jnp.float32)])


def matmul(x: QTensor, packed: dict, *, bias=None, mul=None,
           activation: str = "none", out_aq: Optional[ActQuant] = None):
    """Integer matmul against a packed weight, with the fused epilogue.

    G == 1 inputs take the per-tensor kernel (paper eq. 3), grouped inputs
    the PEG kernel (eq. 4->5). With ``out_aq`` the epilogue re-quantizes and
    the result is a :class:`QTensor`; otherwise f32.
    """
    kw = dict(bias=bias, mul=mul, activation=activation)
    if out_aq is not None:
        kw.update(out_scale=out_aq.scales[0], out_zp=out_aq.zps[0],
                  qmin=out_aq.qmin, qmax=out_aq.qmax)
    if "q4" in packed:                   # row-packed int4 payload
        w_q = packed["q4"]
        kw["w_bits"] = 4
    else:
        w_q = packed["q"]
    g = int(x.scales.shape[0])
    if g == 1:
        out = ops.int8_matmul(x.q, w_q, s_a=x.scales[0],
                              s_w=packed["s"], z_a=x.zps[0],
                              w_colsum=packed["colsum"][0], **kw)
    else:
        out = ops.int8_matmul_peg(x.q, w_q, x.scales, x.zps,
                                  w_scale=packed["s"],
                                  w_colsum=packed["colsum"], **kw)
    if out_aq is not None:
        return QTensor(q=out, scales=out_aq.scales, zps=out_aq.zps)
    return out
