"""Sub-8-bit deploy path (int4): nibble pack/unpack round-trips (both
layouts, odd dims, ragged tails — hypothesis when installed), kernel-vs-
oracle for the 4-bit attend/matmul paths, Quant4 cache invariants (payload
halving, subclass survives jit), paged == dense serving parity at
kv-bits 4, and bit-exact 4-bit weight payloads vs the simulate-path
fake-quant grid.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import QuantizerConfig, RangeEstimator
from repro.core.deploy import pack_linear
from repro.core.range_estimation import estimate_weight_params
from repro.kernels import nibble, ops, ref
from repro.models import attention as att
from repro.models import transformer as tfm
from repro.runtime import BlockPool, Request, serve
from repro.runtime.steps import (make_admit_step, make_decode_step,
                                 make_prefill_step)

pytestmark = pytest.mark.lowbit

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    from hypothesis import given
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Nibble layouts: pack o unpack == identity over the int4 range
# ---------------------------------------------------------------------------

class TestNibbleRoundTrip:
    @pytest.mark.parametrize("n", [1, 2, 3, 7, 8, 63, 64])
    def test_split_half_round_trip(self, n):
        """Odd n pads a spare high nibble; unpack drops it again."""
        rng = np.random.default_rng(n)
        x = rng.integers(-8, 8, size=(3, 5, n)).astype(np.int8)
        packed = nibble.pack_nibbles(jnp.asarray(x))
        assert packed.shape == (3, 5, nibble.packed_len(n))
        assert packed.dtype == jnp.int8
        out = nibble.unpack_nibbles(packed, n)
        np.testing.assert_array_equal(np.asarray(out), x)

    def test_split_half_inner_axis(self):
        rng = np.random.default_rng(0)
        x = rng.integers(-8, 8, size=(2, 9, 4)).astype(np.int8)
        packed = nibble.pack_nibbles(jnp.asarray(x), axis=1)
        assert packed.shape == (2, 5, 4)
        out = nibble.unpack_nibbles(packed, 9, axis=1)
        np.testing.assert_array_equal(np.asarray(out), x)

    def test_split_half_extremes(self):
        """-8 and 7 (the two's-complement corners) survive the sext."""
        x = jnp.asarray([[-8, 7, -1, 0, 1, -7]], jnp.int8)
        out = nibble.unpack_nibbles(nibble.pack_nibbles(x), 6)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))

    @pytest.mark.parametrize("k", [2, 6, 128])
    def test_pairwise_rows_round_trip(self, k):
        rng = np.random.default_rng(k)
        w = rng.integers(-8, 8, size=(k, 12)).astype(np.int8)
        packed = nibble.pack_rows(jnp.asarray(w))
        assert packed.shape == (k // 2, 12)
        out = nibble.unpack_rows(packed)
        np.testing.assert_array_equal(np.asarray(out), w)

    def test_pairwise_rows_odd_k_rejected(self):
        with pytest.raises(ValueError, match="even K"):
            nibble.pack_rows(jnp.zeros((5, 4), jnp.int8))

    def test_packed_bytes_halved(self):
        x = jnp.zeros((4, 64), jnp.int8)
        assert np.asarray(nibble.pack_nibbles(x)).nbytes * 2 == \
            np.asarray(x).nbytes


if HAS_HYPOTHESIS:
    hypothesis.settings.register_profile(
        "lowbit", deadline=None, max_examples=25,
        suppress_health_check=[hypothesis.HealthCheck.too_slow])
    hypothesis.settings.load_profile("lowbit")

    int4_arrays = hnp.arrays(
        np.int8, hnp.array_shapes(min_dims=1, max_dims=3, min_side=1,
                                  max_side=33),
        elements=st.integers(-8, 7))

    @given(int4_arrays, st.data())
    def test_nibble_round_trip_property(x, data):
        """pack o unpack == identity on any shape / any axis (odd lengths
        exercise the ragged-tail pad-and-drop path)."""
        axis = data.draw(st.integers(-x.ndim, x.ndim - 1))
        n = x.shape[axis]
        out = nibble.unpack_nibbles(
            nibble.pack_nibbles(jnp.asarray(x), axis=axis), n, axis=axis)
        np.testing.assert_array_equal(np.asarray(out), x)

    @given(st.integers(1, 24), st.integers(1, 16), st.integers(0, 2 ** 31))
    def test_row_pack_round_trip_property(half_k, n, seed):
        rng = np.random.default_rng(seed)
        w = rng.integers(-8, 8, size=(2 * half_k, n)).astype(np.int8)
        out = nibble.unpack_rows(nibble.pack_rows(jnp.asarray(w)))
        np.testing.assert_array_equal(np.asarray(out), w)
else:                              # keep the skip visible in test reports
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(see requirements-dev.txt)")
    def test_nibble_round_trip_property():
        pass


# ---------------------------------------------------------------------------
# Kernel vs oracle at kv_bits=4 / w_bits=4 (interpret mode)
# ---------------------------------------------------------------------------

def _int4_cache_operands(seed=0, B=2, S=64, KV=2, G=4, hd=64):
    rng = np.random.default_rng(seed)
    k4 = rng.integers(-8, 8, size=(B, S, KV, hd)).astype(np.int8)
    v4 = rng.integers(-8, 8, size=(B, S, KV, hd)).astype(np.int8)
    k_pk = np.asarray(nibble.pack_nibbles(jnp.asarray(k4)))
    v_pk = np.asarray(nibble.pack_nibbles(jnp.asarray(v4)))
    q = rng.integers(-127, 128, size=(B, KV, G, hd)).astype(np.int8)
    qs = rng.uniform(0.01, 0.02, size=(B, KV, G)).astype(np.float32)
    ks = rng.uniform(0.05, 0.1, size=(B, S, KV)).astype(np.float32)
    vs = rng.uniform(0.05, 0.1, size=(B, S, KV)).astype(np.float32)
    # shifted asymmetric grid (uint4 - 8): non-trivial zero points exercise
    # the rowsum/colsum corrections on the unpacked values
    kz = np.full((B, KV), -0.5, np.float32)
    vz = np.full((B, KV), 0.5, np.float32)
    k_pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S)).copy()
    k_pos[1, 50:] = -1                                  # ragged lane
    q_pos = np.array([S - 1, 49], np.int32)
    return q, qs, k_pk, ks, v_pk, vs, kz, vz, k_pos, q_pos, hd


@pytest.mark.deploy
class TestInt4AttendKernel:
    def test_dense_matches_ref(self):
        (q, qs, k_pk, ks, v_pk, vs, kz, vz, k_pos, q_pos,
         hd) = _int4_cache_operands()
        got = ops.int8_attend_decode(q, qs, k_pk, ks, v_pk, vs, k_pos,
                                     q_pos, k_zp=kz, v_zp=vz, kv_bits=4,
                                     chunk=32)
        want = ref.int8_attend_decode_ref(
            jnp.asarray(q), jnp.asarray(qs), jnp.asarray(k_pk),
            jnp.asarray(ks), jnp.asarray(v_pk), jnp.asarray(vs),
            jnp.asarray(k_pos), jnp.asarray(q_pos), k_zp=jnp.asarray(kz),
            v_zp=jnp.asarray(vz), kv_bits=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_dense_two_pass_matches_ref(self):
        """softmax_out quant forces the two-pass schedule: the packed V
        unpack sits inside the second pass's p@v closure."""
        (q, qs, k_pk, ks, v_pk, vs, kz, vz, k_pos, q_pos,
         hd) = _int4_cache_operands(seed=1)
        smo = jnp.asarray([1.0 / 255, 0.0], jnp.float32)
        got = ops.int8_attend_decode(q, qs, k_pk, ks, v_pk, vs, k_pos,
                                     q_pos, k_zp=kz, v_zp=vz,
                                     smo_quant=smo, kv_bits=4, chunk=32)
        want = ref.int8_attend_decode_ref(
            jnp.asarray(q), jnp.asarray(qs), jnp.asarray(k_pk),
            jnp.asarray(ks), jnp.asarray(v_pk), jnp.asarray(vs),
            jnp.asarray(k_pos), jnp.asarray(q_pos), k_zp=jnp.asarray(kz),
            v_zp=jnp.asarray(vz), smo_quant=smo, kv_bits=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_paged_matches_ref(self):
        (q, qs, k_pk, ks, v_pk, vs, kz, vz, k_pos, q_pos,
         hd) = _int4_cache_operands()
        B, S, KV = k_pk.shape[0], k_pk.shape[1], k_pk.shape[2]
        bs = 16
        nb = S // bs
        n_blocks = B * nb + 1
        k_arena = np.zeros((n_blocks, bs, KV, hd // 2), np.int8)
        v_arena = np.zeros((n_blocks, bs, KV, hd // 2), np.int8)
        ks_arena = np.ones((n_blocks, bs, KV), np.float32)
        vs_arena = np.ones((n_blocks, bs, KV), np.float32)
        table = np.full((B, nb), -1, np.int32)
        pb = 1
        for b in range(B):
            written = int(q_pos[b]) + 1
            for lb in range(-(-written // bs)):
                table[b, lb] = pb
                lo, hi = lb * bs, (lb + 1) * bs
                k_arena[pb] = k_pk[b, lo:hi]
                v_arena[pb] = v_pk[b, lo:hi]
                ks_arena[pb] = ks[b, lo:hi]
                vs_arena[pb] = vs[b, lo:hi]
                pb += 1
        got = ops.paged_int8_attend_decode(q, qs, k_arena, ks_arena,
                                           v_arena, vs_arena, table, q_pos,
                                           s_cap=S, k_zp=kz, v_zp=vz,
                                           kv_bits=4)
        want = ref.paged_int8_attend_decode_ref(
            jnp.asarray(q), jnp.asarray(qs), jnp.asarray(k_arena),
            jnp.asarray(ks_arena), jnp.asarray(v_arena),
            jnp.asarray(vs_arena), jnp.asarray(table), jnp.asarray(q_pos),
            s_cap=S, k_zp=jnp.asarray(kz), v_zp=jnp.asarray(vz), kv_bits=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.deploy
class TestInt4MatmulKernel:
    M, K, N, G = 32, 128, 128, 4

    def _weights(self, seed=0):
        rng = np.random.default_rng(seed)
        a_q = rng.integers(-128, 128, size=(self.M, self.K)).astype(np.int8)
        w4 = rng.integers(-7, 8, size=(self.K, self.N)).astype(np.int8)
        w_pk = np.asarray(nibble.pack_rows(jnp.asarray(w4)))
        return a_q, w4, w_pk

    def test_matmul_matches_ref(self):
        a_q, w4, w_pk = self._weights()
        colsum = np.sum(w4.astype(np.int32), axis=0)
        got = ops.int8_matmul(a_q, w_pk, s_a=0.02, s_w=0.01, z_a=3.0,
                              w_colsum=colsum, w_bits=4)
        want = ref.int8_matmul_fused_ref(jnp.asarray(a_q), jnp.asarray(w4),
                                         0.02, 0.01, z_a=3.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_peg_matmul_matches_ref(self):
        a_q, w4, w_pk = self._weights(seed=1)
        rng = np.random.default_rng(2)
        act_s = rng.uniform(0.01, 0.02, size=(self.G,)).astype(np.float32)
        act_z = rng.uniform(-2, 2, size=(self.G,)).astype(np.float32)
        wcs = ref.w_colsum_groups(jnp.asarray(w4), self.G)
        got = ops.int8_matmul_peg(a_q, w_pk, act_s, act_z, w_scale=0.01,
                                  w_colsum=wcs, w_bits=4)
        want = ref.int8_matmul_peg_fused_ref(
            jnp.asarray(a_q), jnp.asarray(w4), jnp.asarray(act_s),
            jnp.asarray(act_z), 0.01)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_auto_colsum_refused_for_packed_bytes(self):
        """Summing packed bytes would be silently wrong — the ops layer
        must demand the caller's unpacked colsum at w_bits=4."""
        a_q, w4, w_pk = self._weights()
        with pytest.raises(ValueError, match="w_colsum"):
            ops.int8_matmul(a_q, w_pk, s_a=0.02, s_w=0.01, z_a=3.0,
                            w_bits=4)


# ---------------------------------------------------------------------------
# Quant4 cache invariants
# ---------------------------------------------------------------------------

MAX_LEN = 32
BS = 8
NB_LANE = -(-MAX_LEN // BS)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    return cfg, params


class TestQuant4Cache:
    def test_payload_bytes_halved(self, tiny):
        cfg, _ = tiny
        c8 = tfm.init_cache(cfg, 2, MAX_LEN, dtype=jnp.float32, kv_bits=8)
        c4 = tfm.init_cache(cfg, 2, MAX_LEN, dtype=jnp.float32, kv_bits=4)

        def payload_bytes(cache):
            return sum(n.k_q.nbytes + n.v_q.nbytes
                       for n in list(cache["scan"]) + list(cache["tail"]))
        assert 2 * payload_bytes(c4) == payload_bytes(c8)

    def test_dynamic_quantize_round_trip_error_bound(self):
        """quantize_kv4 (dynamic symmetric, [-7, 7]) reconstructs within
        half a step of the per-head grid."""
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 2, 16))
        packed, s = att.quantize_kv4(x)
        assert packed.shape == (2, 6, 2, 8)
        vals = nibble.unpack_nibbles(packed, 16).astype(jnp.float32)
        recon = vals * s[..., None]
        err = np.abs(np.asarray(recon) - np.asarray(x))
        assert (err <= np.asarray(s)[..., None] * 0.5 + 1e-6).all()

    def test_dequantize_kv_unpacks(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 2, 8))
        packed, s = att.quantize_kv4(x)
        cache = att.Quant4KVCache(
            k_q=packed, v_q=packed, k_s=s, v_s=s,
            pos=jnp.zeros((1, 4), jnp.int32))
        k, v = att.dequantize_kv(cache)
        assert k.shape == x.shape
        np.testing.assert_allclose(np.asarray(k), np.asarray(v))

    def test_subclass_survives_jit_prefill(self, tiny):
        """The Quant4 type IS the bit-width marker — tracing through the
        jitted prefill step must hand it back intact."""
        cfg, params = tiny
        prefill = jax.jit(make_prefill_step(cfg))
        cache = tfm.init_cache(cfg, 2, MAX_LEN, dtype=jnp.float32,
                               kv_bits=4)
        toks = np.ones((2, 5), np.int32)
        posm = np.tile(np.arange(5, dtype=np.int32), (2, 1))
        _, cache = prefill(params, toks, cache, posm)
        nodes = list(cache["scan"]) + list(cache["tail"])
        assert nodes and all(isinstance(n, att.Quant4KVCache)
                             for n in nodes)


# ---------------------------------------------------------------------------
# Serving parity: paged == dense greedy tokens at kv-bits 4
# ---------------------------------------------------------------------------

def _mk_reqs(seed, cfg, lens_quotas):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, size=n)
                    .astype(np.int32),
                    max_new_tokens=q)
            for i, (n, q) in enumerate(lens_quotas)]


def _serve(cfg, params, reqs, *, paged, ctx_factory, num_blocks=None):
    admit = jax.jit(make_admit_step(cfg, ctx_factory=ctx_factory))
    decode = jax.jit(make_decode_step(cfg, ctx_factory=ctx_factory))
    prefill = jax.jit(make_prefill_step(cfg, ctx_factory=ctx_factory))
    pool = (BlockPool(num_blocks or 2 * NB_LANE, BS, 2, NB_LANE)
            if paged else None)

    def init(b):
        if not paged:
            return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                                  kv_bits=4)
        return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                              kv_bits=4, paged=True, block_size=BS,
                              num_blocks=num_blocks, mapped=False)
    serve(prefill, admit, decode, init, params, reqs,
          scheduler="continuous", batch_slots=2, max_len=MAX_LEN,
          block_pool=pool)
    return pool


@pytest.mark.deploy
@pytest.mark.serve
@pytest.mark.paged
class TestPagedDenseParityKv4:
    @pytest.fixture(scope="class")
    def deployed(self):
        from repro.core import Mode, QuantCtx, build_deploy, peg_policy
        from repro.core.pipeline import ptq
        cfg = get_config("gemma2-2b").reduced()
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(cfg, key, stacked=True, dtype=jnp.float32)
        pol = peg_policy(4)
        flat = tfm.init_params(cfg, key, stacked=False, dtype=jnp.float32)
        calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10),
                                               (2, 8), 0, cfg.vocab_size)}]

        def fwd(p, b, ctx):
            logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
            return logits

        qm = ptq(fwd, flat, calib, pol, collect_inputs=True)
        shared = {}
        for site, qp in qm.act_state.items():
            base = ("layer/" + site.split("/", 1)[1]
                    if site.startswith("layer") else site)
            shared.setdefault(base, qp)
        packed, acts = build_deploy(cfg, params, pol, shared)

        def ctx_factory():
            return QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=shared,
                            deploy_acts=acts)
        return cfg, packed, ctx_factory

    def test_paged_matches_dense_kv4(self, deployed):
        """int4 quantization is deterministic per write, so the packed
        paged and dense caches still agree token-for-token (the same
        exactness contract the int8 path asserts)."""
        cfg, packed, ctx_factory = deployed
        spec = [(4, 2), (8, 6), (3, 1), (6, 4)]
        dense = _mk_reqs(5, cfg, spec)
        paged = _mk_reqs(5, cfg, spec)
        _serve(cfg, packed, dense, paged=False, ctx_factory=ctx_factory)
        pool = _serve(cfg, packed, paged, paged=True, num_blocks=4,
                      ctx_factory=ctx_factory)
        for d, p in zip(dense, paged):
            assert d.tokens_out == p.tokens_out, f"rid {d.rid}"
            assert p.done
        assert pool.blocks_in_use == 0, "block leak after retirement"


# ---------------------------------------------------------------------------
# 4-bit weight payloads: bit-exact vs the simulate-path fake-quant grid
# ---------------------------------------------------------------------------

W4 = QuantizerConfig(bits=4, symmetric=True, estimator=RangeEstimator.MSE)


@pytest.mark.deploy
class TestWeightQ4Payload:
    def test_payload_round_trips_bit_exactly(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 48))
        payload = pack_linear(w, W4, num_groups=4)
        assert payload is not None and "q4" in payload
        assert payload["q4"].shape == (32, 48)
        # the exact grid the simulate path fake-quantizes on
        qp = estimate_weight_params(w, W4)
        s = jnp.maximum(qp.scale.astype(jnp.float32),
                        jnp.finfo(jnp.float32).tiny)
        wq = jnp.clip(jnp.round(w / s), W4.qmin, W4.qmax).astype(jnp.int8)
        np.testing.assert_array_equal(
            np.asarray(nibble.unpack_rows(payload["q4"])), np.asarray(wq))
        np.testing.assert_allclose(float(payload["s"]), float(s))
        np.testing.assert_array_equal(
            np.asarray(payload["colsum"]),
            np.asarray(ref.w_colsum_groups(wq, 4)))

    def test_stacked_layout_packs_per_layer(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 16, 8))
        payload = pack_linear(w, W4, num_groups=2)
        assert payload is not None
        assert payload["q4"].shape == (3, 8, 8)
        for layer in range(3):
            single = pack_linear(w[layer], W4, num_groups=2)
            np.testing.assert_array_equal(np.asarray(payload["q4"][layer]),
                                          np.asarray(single["q4"]))

    @pytest.mark.parametrize("k,groups", [(15, 1), (18, 6)])
    def test_inexpressible_sites_fall_back(self, k, groups):
        """Odd K (no whole bytes) or odd PEG group size (group boundary
        would straddle a byte) must decline to pack — the site then keeps
        fake-quant APPLY behavior, exactly as before this path existed."""
        w = jax.random.normal(jax.random.PRNGKey(2), (k, 8))
        assert pack_linear(w, W4, num_groups=groups) is None

    def test_unsupported_bits_fall_back(self):
        w = jax.random.normal(jax.random.PRNGKey(3), (16, 8))
        cfg3 = QuantizerConfig(bits=3, symmetric=True)
        assert pack_linear(w, cfg3, num_groups=1) is None
