"""Batched serving loop: fixed-slot continuous batching over a prefill step
and a decode step, with per-request positions and simple timeout-based
straggler handling for request admission."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 16
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeStats:
    prefill_calls: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    wall_s: float = 0.0
    cache_bytes: int = 0        # peak KV-cache footprint of one batch group
    tokens_per_s: float = 0.0


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))


def serve_batch(prefill_fn: Callable, decode_fn: Callable, init_cache_fn,
                requests: List[Request], *, batch_slots: int,
                greedy: bool = True) -> ServeStats:
    """Static-batch serving: pack up to ``batch_slots`` requests (padded to a
    common prompt length), prefill once, then decode in lockstep until every
    request has produced max_new_tokens.

    prefill_fn(params-bound): (tokens (B,T), cache) -> (logits, cache)
    decode_fn: (tokens (B,1), pos (B,1), cache) -> (logits, cache)
    """
    stats = ServeStats()
    t_start = time.perf_counter()
    for lo in range(0, len(requests), batch_slots):
        group = requests[lo:lo + batch_slots]
        B = len(group)
        T = max(len(r.prompt) for r in group)
        toks = np.zeros((B, T), np.int32)
        for i, r in enumerate(group):
            toks[i, T - len(r.prompt):] = r.prompt      # left-pad
        for r in group:                                 # empty-quota requests
            if r.max_new_tokens <= 0:
                r.done = True
        cache = init_cache_fn(B)
        stats.cache_bytes = max(stats.cache_bytes, _tree_bytes(cache))
        logits, cache = prefill_fn(jnp.asarray(toks), cache)
        stats.prefill_calls += 1
        pos = np.full((B, 1), T, np.int32)
        cur = np.asarray(jnp.argmax(logits[:, -1:], axis=-1), np.int32)
        steps = max((r.max_new_tokens for r in group), default=0)
        for _ in range(steps):
            for i, r in enumerate(group):
                if not r.done:
                    r.tokens_out.append(int(cur[i, 0]))
                    stats.tokens_generated += 1
                    if len(r.tokens_out) >= r.max_new_tokens:
                        r.done = True
            # check BEFORE decoding: once every request hit its quota the
            # group must not pay for (or emit tokens from) another step
            if all(r.done for r in group):
                break
            logits, cache = decode_fn(jnp.asarray(cur), jnp.asarray(pos),
                                      cache)
            stats.decode_steps += 1
            cur = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            pos = pos + 1
    stats.wall_s = time.perf_counter() - t_start
    stats.tokens_per_s = stats.tokens_generated / max(stats.wall_s, 1e-9)
    return stats
