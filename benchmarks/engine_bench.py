"""Engine-API decode microbenchmark (MaxText/JetStream style): per-call
wall times for the decomposed triad — ``prefill`` (scratch-cache prompt
pass + payload extract), ``insert`` (lane landing) and ``generate`` (one
batched decode step) — on gemma2-2b-reduced with every lane occupied,
i.e. the steady-state cost profile of a saturated continuous server.

Parity is asserted IN-BENCH before any row is written, both ways the
engine can drift:

* reference ``serve_engine`` FIFO tokens == the continuous Scheduler's
  greedy tokens on the same request set (the conformance contract of
  tests/test_engine.py, re-checked on the bench workload);
* sharded == unsharded: a CHILD-MODE subprocess (``--child-sharded``)
  re-runs the workload on 2 simulated CPU devices (tensor-parallel mesh
  (1, 2)), asserts token equality against its own unsharded run, and
  reports its per-call timings back as JSON — the subprocess is required
  because XLA_FLAGS must be set before jax imports.

Rows land in ``BENCH_serving.json`` as an ``engine_*`` section via
read-modify-write (the serving bench's workload header and rows are
preserved; stale engine rows are replaced).

  PYTHONPATH=src python -m benchmarks.engine_bench
  (or benchmarks/run.py --sections engine)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

JSON_PATH = "BENCH_serving.json"

BATCH_SLOTS = 8
PROMPT_PAD = 8
PROMPT_LEN = 6
MAX_LEN = 64
QUOTA = 8
WARMUP = 3
N_CALLS = 20         # timed calls per op
REPEATS = 3          # best mean-per-call wins (CPU wall jitter)
SHARDED_DEVICES = 2


def _build(dist=None):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.runtime.engine import make_engine

    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    eng = make_engine(cfg, params, batch_slots=BATCH_SLOTS,
                      prompt_pad_len=PROMPT_PAD, max_len=MAX_LEN,
                      dtype=jnp.float32, dist=dist)
    return cfg, params, eng


def _reqs(cfg, seed=0):
    from repro.runtime import Request
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, size=PROMPT_LEN)
                    .astype(np.int32),
                    max_new_tokens=QUOTA)
            for i in range(2 * BATCH_SLOTS)]


def _time_op(op, n=N_CALLS, repeats=REPEATS):
    """Best-of-repeats mean wall microseconds per call. Every engine op
    returns host numpy (the np conversion blocks on the device work), so
    plain perf_counter brackets are honest."""
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n):
            op()
        dt = (time.perf_counter() - t0) / n
        best = dt if best is None else min(best, dt)
    return best * 1e6


def _triad_timings(eng, cfg, seed=0):
    """Per-call µs for prefill / insert / generate with all lanes live."""
    rng = np.random.RandomState(seed)

    def prompt():
        return rng.randint(1, cfg.vocab_size,
                           size=PROMPT_LEN).astype(np.int32)

    state = eng.init_state()
    payloads = []
    for slot in range(BATCH_SLOTS):
        _, payload = eng.prefill(prompt())
        payloads.append(payload)
        state = eng.insert(payload, slot, state)
    for _ in range(WARMUP):
        _, cache = eng.generate(state)
        state = state._replace(cache=cache)

    us = {"prefill": _time_op(lambda: eng.prefill(prompt()))}

    def do_insert():
        nonlocal state
        state = eng.insert(payloads[0], 0, state)
    us["insert"] = _time_op(do_insert)

    def do_generate():
        nonlocal state
        toks, cache = eng.generate(state)
        state = DecodeStateHolder.set(state, toks, cache)
    us["generate"] = _time_op(do_generate)
    return us


class DecodeStateHolder:
    """Advance DecodeState between timed generate calls (tokens feed back,
    positions bump) so the loop measures a real decode chain, not the same
    step replayed on stale inputs."""

    @staticmethod
    def set(state, toks, cache):
        return state._replace(tokens=toks, pos=state.pos + 1, cache=cache)


def _parity_vs_scheduler(cfg, params, eng):
    """serve_engine == continuous Scheduler greedy tokens, asserted."""
    import jax

    from repro.models import transformer as tfm
    from repro.runtime import serve_continuous, serve_engine
    from repro.runtime.steps import make_admit_step, make_decode_step
    import jax.numpy as jnp

    eng_reqs = _reqs(cfg, seed=3)
    serve_engine(eng, eng_reqs)

    admit_j = jax.jit(make_admit_step(cfg))
    decode_j = jax.jit(make_decode_step(cfg))

    def init(b):
        return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32)

    sched_reqs = _reqs(cfg, seed=3)
    serve_continuous(lambda t, pm, m, c: admit_j(params, t, pm, m, c),
                     lambda t, p, c: decode_j(params, t, p, c),
                     init, sched_reqs, batch_slots=BATCH_SLOTS,
                     prompt_pad_len=PROMPT_PAD, max_len=MAX_LEN)
    for a, b in zip(eng_reqs, sched_reqs):
        assert a.tokens_out == b.tokens_out, \
            f"engine != scheduler greedy tokens (rid {a.rid})"
    return sum(len(r.tokens_out) for r in eng_reqs)


def _child_sharded():
    """Child mode: 2 simulated devices, sharded vs unsharded parity + the
    sharded triad timings, reported as one JSON line on stdout."""
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={SHARDED_DEVICES}")
    import jax

    from repro.parallel import make_dist
    from repro.runtime import serve_engine

    assert len(jax.devices()) == SHARDED_DEVICES, jax.devices()
    mesh = jax.make_mesh((1, SHARDED_DEVICES), ("data", "model"))
    dist = make_dist(mesh)

    cfg, params, eng_sh = _build(dist=dist)
    _, _, eng_un = _build(dist=None)
    sh_reqs, un_reqs = _reqs(cfg, seed=4), _reqs(cfg, seed=4)
    serve_engine(eng_sh, sh_reqs)
    serve_engine(eng_un, un_reqs)
    toks_sh = [r.tokens_out for r in sh_reqs]
    toks_un = [r.tokens_out for r in un_reqs]
    assert toks_sh == toks_un, "sharded != unsharded greedy tokens"

    us = _triad_timings(eng_sh, cfg, seed=5)
    print(json.dumps({"parity": True, "devices": SHARDED_DEVICES,
                      "tokens": sum(len(t) for t in toks_sh),
                      "us_per_call": us,
                      "trace_counts": eng_sh.trace_counts}))


def bench():
    cfg, params, eng = _build()
    tokens = _parity_vs_scheduler(cfg, params, eng)
    us = _triad_timings(eng, cfg, seed=1)
    rows = []
    for op in ("prefill", "insert", "generate"):
        rows.append({
            "name": f"engine_{op}",
            "op": op,
            "batch_slots": BATCH_SLOTS,
            "prompt_len": PROMPT_LEN,
            "prompt_pad_len": PROMPT_PAD,
            "max_len": MAX_LEN,
            "us_per_call": round(us[op], 1),
            "calls_timed": N_CALLS,
            "repeats": REPEATS,
            "parity_tokens_vs_scheduler": tokens,
        })
    rows[-1]["tokens_per_s"] = round(BATCH_SLOTS / (us["generate"] / 1e6), 1)
    rows.append(_sharded_row())
    return rows


def _sharded_row():
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep +
               os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.engine_bench", "--child-sharded"],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    child = json.loads(proc.stdout.strip().splitlines()[-1])
    assert child["parity"], "sharded parity assertion missing from child"
    row = {"name": "engine_sharded_generate",
           "op": "generate",
           "devices": child["devices"],
           "mesh": ["data", "model"],
           "batch_slots": BATCH_SLOTS,
           "max_len": MAX_LEN,
           "sharded_equals_unsharded": True,
           "parity_tokens": child["tokens"],
           "trace_counts": child["trace_counts"]}
    for op, v in child["us_per_call"].items():
        row[f"{op}_us_per_call"] = round(v, 1)
    return row


def report(rows) -> str:
    lines = ["name,op,us_per_call,tokens_per_s,devices,"
             "sharded_equals_unsharded"]
    for r in rows:
        lines.append(f"{r['name']},{r.get('op', '')},"
                     f"{r.get('us_per_call', r.get('generate_us_per_call', ''))},"
                     f"{r.get('tokens_per_s', '')},"
                     f"{r.get('devices', '')},"
                     f"{r.get('sharded_equals_unsharded', '')}")
    return "\n".join(lines)


def write_json(rows, path=JSON_PATH):
    """Read-modify-write: keep the serving bench's header + rows, replace
    any stale engine_* rows with this run's."""
    doc = {"workload": {}, "rows": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc["rows"] = [r for r in doc.get("rows", [])
                   if not r.get("name", "").startswith("engine_")] + rows
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path


if __name__ == "__main__":
    if "--child-sharded" in sys.argv:
        _child_sharded()
    else:
        rows = bench()
        print(report(rows))
        print(f"# wrote {write_json(rows)}")
