"""Roofline analysis from the dry-run's compiled artifacts (EXPERIMENTS.md
§Roofline).

Per (arch x shape x mesh) cell, from benchmarks/results/dryrun/*.json:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
  memory term     = HLO_bytes_per_device / HBM_bw_per_chip
  collective term = collective_bytes_per_device / link_bw

(cost_analysis is per-device after SPMD partitioning, so dividing the
per-device numbers by per-chip peaks equals total/(chips x peak).)

Hardware constants: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link
ICI. MODEL_FLOPS = 6·N·D (train, dense), 6·N_active·D (MoE), 2·N·D_active
per generated token (decode). The dominant term and one-line remedy are
emitted per cell.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def model_flops(rec: dict) -> float:
    """Global MODEL_FLOPS for the cell (the 'useful work' yardstick)."""
    from repro.configs import SHAPES
    sh = SHAPES[rec["shape"]]
    B, T = sh["global_batch"], sh["seq_len"]
    n_active = rec.get("active_params", rec["num_params"])
    if rec["kind"] == "train":
        return 6.0 * n_active * B * T
    if rec["kind"] == "prefill":
        return 2.0 * n_active * B * T
    return 2.0 * n_active * B * 1          # decode: one token per sequence


def chips(mesh: str) -> int:
    n = 1
    for d in mesh.split("x"):
        n *= int(d)
    return n


def analyze(rec: dict) -> dict:
    flops_dev = rec.get("flops_per_device", 0.0)
    bytes_dev = rec.get("bytes_per_device", 0.0)
    coll = rec.get("collective_bytes_per_device", {})
    coll_dev = coll.get("total", 0)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    n_chips = chips(rec["mesh"])
    hlo_total = flops_dev * n_chips
    bound = max(terms.values())
    # roofline fraction: useful model flops per achievable second
    ideal_t = mf / (n_chips * PEAK_FLOPS)
    frac = ideal_t / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("variant", "baseline"),
        "kind": rec["kind"],
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "roofline_fraction": frac,
        "peak_hbm_gib": rec["memory"]["peak_hbm_estimate"] / 2**30,
        "fits_hbm": rec["memory"]["peak_hbm_estimate"] < 16 * 2**30,
        "microbatches": rec.get("microbatches"),
    }


REMEDIES = {
    "compute": "compute-bound: raise MXU utilization (larger per-chip tiles,"
               " int8 matmuls, fewer remat recomputes)",
    "memory": "HBM-bound: cut activation round-trips (fused/flash attention"
              " blocks, fp8/int8 activations, better layouts)",
    "collective": "ICI-bound: overlap collectives with compute, shrink"
                  " payloads (int8 gradient compression), reorder schedule",
}


def load_all(pattern: str = "*.json") -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(variant: str = "baseline") -> List[dict]:
    return [analyze(r) for r in load_all()
            if r.get("variant", "baseline") == variant
            and "flops_per_device" in r]


def report() -> str:
    rows = table()
    lines = ["arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
             "useful_ratio,roofline_fraction,peak_hbm_gib,fits"]
    for r in rows:
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{r['compute_s']:.4f},{r['memory_s']:.4f},"
            f"{r['collective_s']:.4f},{r['dominant']},"
            f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f},"
            f"{r['peak_hbm_gib']:.2f},{int(r['fits_hbm'])}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
