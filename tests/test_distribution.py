"""Distribution tests: sharding-rule logic (AbstractMesh, no devices needed)
plus end-to-end multi-device checks in a subprocess with 8 host devices
(the main pytest process must keep seeing 1 CPU device)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.transformer import DistContext
from repro.parallel.sharding import (cache_spec_for, make_abstract_mesh,
                                     param_spec_for)


def _dist(shape=(16, 16), axes=("data", "model")):
    mesh = make_abstract_mesh(shape, axes)
    dp = tuple(a for a in ("pod", "data") if a in axes)
    fsdp = dp if len(dp) > 1 else "data"
    return DistContext(mesh=mesh, tp_axis="model", fsdp_axis=fsdp,
                       dp_axes=dp)


class TestParamRules:
    def test_attention_projections(self):
        d = _dist()
        assert param_spec_for("scan/0/attn/wq", (24, 3840, 3840), d,
                              has_scan_dim=True) == P(None, "data", "model")
        assert param_spec_for("scan/0/attn/wo", (24, 3840, 3840), d,
                              has_scan_dim=True) == P(None, "model", "data")

    def test_mqa_kv_falls_back_to_head_dim(self):
        """granite kv=1: wk is (D, 128); 128 divides 16 so TP shards it."""
        d = _dist()
        spec = param_spec_for("scan/0/attn/wk", (52, 6144, 128), d,
                              has_scan_dim=True)
        assert spec == P(None, "data", "model")

    def test_indivisible_dim_replicates(self):
        """gemma2 d_model=2304 fsdp-shards (2304/16=144) but a hypothetical
        odd dim must replicate."""
        d = _dist()
        spec = param_spec_for("scan/0/attn/wq", (26, 2305, 2048), d,
                              has_scan_dim=True)
        assert spec == P(None, None, "model")

    def test_moe_experts_ep_on_model(self):
        d = _dist()
        spec = param_spec_for("scan/0/moe/w_gate", (94, 128, 4096, 1536), d,
                              has_scan_dim=True)
        assert tuple(spec) == (None, "model", "data")   # trailing None dropped
        spec = param_spec_for("scan/0/moe/w_out", (94, 128, 1536, 4096), d,
                              has_scan_dim=True)
        assert spec == P(None, "model", None, "data")

    def test_embed_vocab_tp(self):
        d = _dist()
        assert param_spec_for("embed", (256000, 2304), d,
                              has_scan_dim=False) == P("model", "data")

    def test_multipod_fsdp_spans_pod(self):
        d = _dist((2, 16, 16), ("pod", "data", "model"))
        spec = param_spec_for("scan/0/attn/wq", (94, 4096, 8192), d,
                              has_scan_dim=True)
        assert spec == P(None, ("pod", "data"), "model")

    def test_norms_replicated(self):
        d = _dist()
        assert param_spec_for("scan/0/ln1/g", (24, 3840), d,
                              has_scan_dim=True) == P()


class TestCacheRules:
    def test_kv_cache_batch_and_sequence(self):
        d = _dist()
        # (L, B, S, KV, hd): B=128 shards over data; S shards over model
        # (the kvseq rule — EXPERIMENTS.md §Perf A2: sequence-sharded caches
        # avoid the per-layer cache all-gather that head-sharding causes)
        spec = cache_spec_for((48, 128, 32768, 8, 128), d, has_scan_dim=True)
        assert spec == P(None, ("data",), "model")

    def test_batch1_long_context_sp(self):
        d = _dist()
        # (L, B=1, S, KV, hd): batch unshardable -> S shards over data (SP);
        # with kvseq S would also take model, but data wins first -> the
        # model axis is left for heads/features if divisible
        spec = cache_spec_for((13, 1, 524288, 4, 256), d, has_scan_dim=True)
        assert spec[1] is None and spec[2] == "data"

    def test_rwkv_state(self):
        d = _dist()
        spec = cache_spec_for((24, 128, 32, 64, 64), d, has_scan_dim=True)
        assert spec[1] in ("data", ("data",))   # P normalizes 1-tuples


MULTI_DEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

    # 1) compressed cross-pod all-reduce ~= plain mean
    from repro.core.grad_compression import (make_crosspod_allreduce,
                                             init_error_feedback)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.01}
    specs = {"w": P()}
    err = init_error_feedback(g, n_pod=2)
    fn = make_crosspod_allreduce(mesh, specs, group_size=64)
    avg, err2 = jax.jit(fn)(g, err)
    # with identical replicas the mean == the input (quantization error only)
    diff = float(jnp.max(jnp.abs(avg["w"] - g["w"])))
    assert diff < 5e-4, diff

    # 2) tiny model trains under the mesh with our shardings
    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel import make_dist, make_param_shardings
    from repro.optim import linear_warmup_linear_decay
    from repro.optim.adam import adam_init
    from repro.runtime.steps import make_train_step

    cfg = get_config("qwen3-moe-235b").reduced()   # exercises MoE shard_map
    dist = make_dist(mesh)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    shardings = make_param_shardings(params, dist)
    params = jax.tree.map(jax.device_put, params, shardings)
    opt = adam_init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    step = jax.jit(make_train_step(
        cfg, lr_schedule=linear_warmup_linear_decay(1e-3, 10),
        microbatches=2, dist=dist), donate_argnums=(0, 1))
    losses = []
    for i in range(3):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses   # overfits one batch

    # 3) sharded MoE == single-device MoE (numerical equivalence)
    from repro.models.moe import moe_apply
    p_flat = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=False,
                             dtype=jnp.float32)
    l_sharded, _ = tfm.forward(cfg, p_flat, toks[:2], dist=dist)
    l_local, _ = tfm.forward(cfg, p_flat, toks[:2], dist=None)
    err = float(jnp.max(jnp.abs(l_sharded - l_local)))
    assert err < 2e-3, err
    print("MULTIDEV OK")
""")


@pytest.mark.slow
def test_multi_device_end_to_end(tmp_path):
    script = tmp_path / "multidev.py"
    script.write_text(MULTI_DEV_SCRIPT)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep +
               os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MULTIDEV OK" in proc.stdout
