"""Feed-forward blocks with the paper's quantization sites.

The residual connection *after* the FFN is the paper's headline bottleneck
(Table 2); the transformer block in transformer.py therefore taps
``{prefix}/ffn_in`` (FFN input = LN output feeding the residual),
``{prefix}/ffn_out`` (FFN output before the residual add) and
``{prefix}/residual_ffn`` (the sum) — the three tensors PEG-PTQ targets.

Deployment (Mode.DEPLOY): when the block's weights are packed int8 payloads
and the input arrives as a :class:`repro.core.deploy.QTensor` (emitted by the
fused norm+quantize kernel), the MLP runs entirely on the integer kernels —
``int8_matmul_peg`` with the fused bias+activation+re-quantize epilogue into
``int8_matmul`` — so the hidden activation crosses HBM as int8.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.models.common import ACTIVATIONS, dense_init, split_keys


def _mlp_int8(p, x, *, activation: str, ctx, prefix: str):
    """Integer MLP: W_in matmul + bias + act + requant fused, then W_out."""
    from repro.core import deploy
    hid = ctx.deploy_act(f"{prefix}/hidden")
    h_q = deploy.matmul(x, p["w_in"], bias=p.get("b_in"),
                        activation=activation, out_aq=hid)
    if ctx.telemetry is not None:
        ctx.telem_site(f"{prefix}/hidden", deploy.qtensor_stats(h_q, hid))
    return deploy.matmul(h_q, p["w_out"], bias=p.get("b_out"))


def _glu_mlp_int8(p, x, *, activation: str, ctx, prefix: str):
    """Integer GLU: the up matmul stays f32; the gate matmul fuses
    act(gate) * up + re-quantize in its epilogue; W_out consumes int8."""
    from repro.core import deploy
    hid = ctx.deploy_act(f"{prefix}/hidden")
    up = deploy.matmul(x, p["w_up"])
    h_q = deploy.matmul(x, p["w_gate"], activation=activation, mul=up,
                        out_aq=hid)
    if ctx.telemetry is not None:
        ctx.telem_site(f"{prefix}/hidden", deploy.qtensor_stats(h_q, hid))
    return deploy.matmul(h_q, p["w_out"])


def _deployed(p, x) -> bool:
    from repro.core import deploy
    return isinstance(x, deploy.QTensor) and \
        deploy.is_packed(p.get("w_in", p.get("w_gate")))


def mlp(p, x, *, activation: str = "gelu", ctx=None, prefix: str = "ffn"):
    """Classic 2-layer MLP (BERT-style). p: w_in (D,F), b_in, w_out (F,D), b_out."""
    if _deployed(p, x):
        return _mlp_int8(p, x, activation=activation, ctx=ctx, prefix=prefix)
    act = ACTIVATIONS[activation]

    def w(name):
        from repro.models.common import resolve_weight
        wm = resolve_weight(p[name])
        return ctx.weight(f"{prefix}/{name}", wm) if ctx is not None else wm

    h = x @ w("w_in")
    if "b_in" in p:
        h = h + p["b_in"]
    h = act(h)
    if ctx is not None:
        h = ctx.act(f"{prefix}/hidden", h)
    out = h @ w("w_out")
    if "b_out" in p:
        out = out + p["b_out"]
    return out


def glu_mlp(p, x, *, activation: str = "silu", ctx=None, prefix: str = "ffn"):
    """Gated MLP (SwiGLU/GeGLU). p: w_gate (D,F), w_up (D,F), w_out (F,D)."""
    if _deployed(p, x):
        return _glu_mlp_int8(p, x, activation=activation, ctx=ctx,
                             prefix=prefix)
    act = ACTIVATIONS[activation]

    def w(name):
        from repro.models.common import resolve_weight
        wm = resolve_weight(p[name])
        return ctx.weight(f"{prefix}/{name}", wm) if ctx is not None else wm

    g = act(x @ w("w_gate")) * (x @ w("w_up"))
    if ctx is not None:
        g = ctx.act(f"{prefix}/hidden", g)
    return g @ w("w_out")


def init_mlp_params(key, d_model: int, d_ff: int, dtype=jnp.float32,
                    bias: bool = True):
    k1, k2 = split_keys(key, 2)
    p = {"w_in": dense_init(k1, d_model, d_ff, dtype),
         "w_out": dense_init(k2, d_ff, d_model, dtype)}
    if bias:
        p["b_in"] = jnp.zeros((d_ff,), dtype)
        p["b_out"] = jnp.zeros((d_model,), dtype)
    return p


def init_glu_params(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = split_keys(key, 3)
    return {"w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_out": dense_init(k3, d_ff, d_model, dtype)}
