"""Unit tests for the uniform affine quantizer (paper eq. 1-2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Granularity, QuantParams, QuantizerConfig,
                        RangeEstimator, dequantize, fake_quant,
                        params_from_range, quantize, reduce_range)

jax.config.update("jax_enable_x64", False)


def _rand(shape, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape)


class TestGridProperties:
    def test_asymmetric_levels(self):
        cfg = QuantizerConfig(bits=8, symmetric=False)
        assert cfg.qmin == 0 and cfg.qmax == 255 and cfg.num_levels == 255

    def test_symmetric_levels(self):
        cfg = QuantizerConfig(bits=8, symmetric=True)
        assert cfg.qmin == -127 and cfg.qmax == 127

    def test_invalid_bits(self):
        with pytest.raises(ValueError):
            QuantizerConfig(bits=0)

    def test_quantize_hits_integer_grid(self):
        cfg = QuantizerConfig(bits=4, symmetric=False)
        x = _rand((64,), scale=3.0)
        qp = params_from_range(jnp.min(x), jnp.max(x), cfg)
        q = quantize(x, qp, cfg)
        assert q.dtype == jnp.int32
        assert int(q.min()) >= cfg.qmin and int(q.max()) <= cfg.qmax

    def test_roundtrip_error_bounded_by_half_step(self):
        cfg = QuantizerConfig(bits=8, symmetric=False)
        x = _rand((1024,), scale=2.0)
        qp = params_from_range(jnp.min(x), jnp.max(x), cfg)
        xq = fake_quant(x, qp, cfg)
        # inside the clipping range, error <= scale/2 (+ float eps)
        assert float(jnp.max(jnp.abs(x - xq))) <= float(qp.scale) * 0.5 + 1e-5

    def test_dequantize_matches_fake_quant(self):
        cfg = QuantizerConfig(bits=8, symmetric=True)
        x = _rand((128,))
        qp = params_from_range(*reduce_range(x, cfg), cfg)
        assert np.allclose(dequantize(quantize(x, qp, cfg), qp, cfg),
                           fake_quant(x, qp, cfg), atol=1e-6)

    def test_zero_is_representable(self):
        # classic requirement: real 0.0 must map to an exact grid point
        cfg = QuantizerConfig(bits=8, symmetric=False)
        x = jnp.asarray([0.3, 5.0, 9.7])  # all-positive range
        qp = params_from_range(jnp.min(x), jnp.max(x), cfg)
        zero = fake_quant(jnp.zeros(()), qp, cfg)
        assert abs(float(zero)) < 1e-7

    def test_wide_dynamic_range_hurts_small_values(self):
        """The paper's core phenomenon: one outlier destroys precision for
        the rest of the tensor under per-tensor quantization."""
        cfg = QuantizerConfig(bits=8, symmetric=False)
        base = _rand((1000,), scale=0.1)
        outlier = jnp.asarray([100.0])
        x = jnp.concatenate([base, outlier])
        qp = params_from_range(jnp.min(x), jnp.max(x), cfg)
        err_with = float(jnp.mean(jnp.square(base - fake_quant(base, qp, cfg))))
        qp0 = params_from_range(jnp.min(base), jnp.max(base), cfg)
        err_without = float(jnp.mean(jnp.square(base - fake_quant(base, qp0, cfg))))
        assert err_with > 50 * err_without


class TestGranularity:
    def test_per_channel_shapes(self):
        cfg = QuantizerConfig(bits=8, symmetric=True,
                              granularity=Granularity.PER_CHANNEL,
                              channel_axis=-1)
        w = _rand((32, 16))
        mn, mx = reduce_range(w, cfg)
        assert mn.shape == (16,)
        qp = params_from_range(mn, mx, cfg)
        out = fake_quant(w, qp, cfg)
        assert out.shape == w.shape

    def test_per_channel_better_than_per_tensor(self):
        # scale one channel way up; per-channel must win
        w = _rand((256, 8))
        w = w.at[:, 3].multiply(100.0)
        pc = QuantizerConfig(bits=8, symmetric=True,
                             granularity=Granularity.PER_CHANNEL)
        pt = QuantizerConfig(bits=8, symmetric=True)
        qp_pc = params_from_range(*reduce_range(w, pc), pc)
        qp_pt = params_from_range(*reduce_range(w, pt), pt)
        err_pc = float(jnp.mean(jnp.square(w - fake_quant(w, qp_pc, pc))))
        err_pt = float(jnp.mean(jnp.square(w - fake_quant(w, qp_pt, pt))))
        # the outlier channel keeps its own coarse scale either way, so the
        # achievable gain is bounded by the 7 clean channels: expect >5x.
        assert err_pc < err_pt / 5

    def test_peg_group_index_expansion(self):
        cfg = QuantizerConfig(bits=8, granularity=Granularity.PER_EMBEDDING_GROUP,
                              num_groups=2)
        # dims 0-1 group 0 (small), dims 2-3 group 1 (large)
        gi = jnp.asarray([0, 0, 1, 1])
        qp = QuantParams(scale=jnp.asarray([0.01, 1.0]),
                         zero_point=jnp.asarray([0.0, 0.0]),
                         group_index=gi)
        x = jnp.asarray([[0.5, -0.5, 100.0, -100.0]])
        out = fake_quant(x, qp, cfg)
        assert out.shape == x.shape
        # small dims quantized with the fine scale
        assert abs(float(out[0, 0]) - 0.5) < 0.01


class TestGradients:
    def test_ste_identity_inside_range(self):
        cfg = QuantizerConfig(bits=8, symmetric=False)
        x = _rand((64,))
        qp = params_from_range(jnp.min(x) - 1, jnp.max(x) + 1, cfg)
        g = jax.grad(lambda t: jnp.sum(fake_quant(t, qp, cfg)))(x)
        assert np.allclose(g, 1.0)

    def test_ste_zero_outside_range(self):
        cfg = QuantizerConfig(bits=8, symmetric=True)
        qp = QuantParams(scale=jnp.asarray(0.01), zero_point=jnp.asarray(0.0))
        x = jnp.asarray([100.0, -100.0, 0.001])
        g = jax.grad(lambda t: jnp.sum(fake_quant(t, qp, cfg)))(x)
        assert abs(float(g[0])) < 1e-6 and abs(float(g[1])) < 1e-6
        assert abs(float(g[2]) - 1.0) < 1e-6

    def test_lsq_scale_gradient_nonzero(self):
        cfg = QuantizerConfig(bits=4, symmetric=True)
        x = _rand((128,))

        def loss(log_s):
            qp = QuantParams(scale=jnp.exp(log_s), zero_point=jnp.asarray(0.0))
            return jnp.mean(jnp.square(x - fake_quant(x, qp, cfg)))

        g = jax.grad(loss)(jnp.asarray(-2.0))
        assert np.isfinite(float(g)) and abs(float(g)) > 0

    def test_scale_gradient_descends_to_better_mse(self):
        cfg = QuantizerConfig(bits=4, symmetric=True)
        x = _rand((512,))
        log_s = jnp.asarray(1.0)   # deliberately way too coarse

        def loss(ls):
            qp = QuantParams(scale=jnp.exp(ls), zero_point=jnp.asarray(0.0))
            return jnp.mean(jnp.square(x - fake_quant(x, qp, cfg)))

        l0 = float(loss(log_s))
        for _ in range(200):
            log_s = log_s - 0.1 * jax.grad(loss)(log_s)
        assert float(loss(log_s)) < l0 / 5
