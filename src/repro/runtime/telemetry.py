"""Serving observability: request-lifecycle tracing, metrics snapshots, and
quantization-health telemetry (docs/observability.md has the full guide).

Three independent collectors, bundled by :class:`ServeTelemetry` and threaded
through the scheduler as a single optional handle (``telemetry=None`` keeps
every hot-loop callsite a no-op):

- :class:`Tracer` — structured lifecycle events (enqueue, admit, prefix-hit,
  chunk, decode-batch, block grow, COW, preempt/swap/drop, resume,
  radix-evict, retire) with the scheduler step index plus a wall-clock
  timestamp, exported as Chrome-trace-event JSON (load the file in
  https://ui.perfetto.dev). Phases (admit/chunk/decode/swap) are duration
  events on a "steps" track; each request becomes a span on its lane's
  track, so the Perfetto timeline shows lane occupancy directly. Per-phase
  step-latency histograms (p50/p95/p99) ride along.
- :class:`MetricsLogger` — periodic gauge snapshots (queue depth, resident
  lanes, free/evictable blocks, refcount totals, prefix hit rate,
  preemption counters) appended as JSON-lines, plus a final Prometheus
  text-format exposition.
- :class:`QuantHealth` — host-side aggregation of the fixed-shape
  ``[n_clipped, n_total, amax, cal_range]`` site vectors the jitted steps
  emit under ``quant_telemetry=True`` (see runtime/steps.py), keyed
  ``{layer}/site``, plus kv-cache scale distribution stats walked off the
  quantized cache pytree.

The tracer's event record is append-to-a-list cheap; everything expensive
(span assembly, percentile math, serialization) happens once at export.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Dict, List, Optional, TextIO, Tuple

import numpy as np

# Event names (the taxonomy in docs/observability.md). Phase events carry a
# duration; the rest are instants on the emitting request's lane track.
PHASES = ("admit", "chunk", "decode_batch", "swap_out", "swap_in")
EVENTS = ("enqueue", "admit", "prefix_hit", "chunk", "decode_batch",
          "block_grow", "cow", "preempt", "swap_out", "drop", "resume",
          "radix_evict", "retire")


@dataclasses.dataclass
class TraceEvent:
    """One lifecycle event. ``ts`` is seconds since tracer start (exported
    as µs); ``step`` is the scheduler's monotonic step index."""
    name: str
    step: int
    ts: float
    rid: Optional[int] = None      # request id, when request-scoped
    lane: Optional[int] = None     # decode lane (slot), when resident
    dur: float = 0.0               # seconds; > 0 only for phase events
    args: Optional[Dict[str, Any]] = None


def _percentiles(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"n": 0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
    a = np.asarray(xs, dtype=np.float64)
    p50, p95, p99 = np.percentile(a, [50, 95, 99])
    return {"n": int(a.size), "p50": float(p50), "p95": float(p95),
            "p99": float(p99), "mean": float(a.mean()),
            "max": float(a.max())}


class Tracer:
    """Low-overhead lifecycle event recorder with Chrome-trace export.

    Record with :meth:`event` (instant) and :meth:`phase` (timed context
    manager around a jitted call). The scheduler holds ``tracer=None`` when
    tracing is off, so the disabled path never constructs one of these.
    """

    def __init__(self) -> None:
        self.events: List[TraceEvent] = []
        self._t0 = time.perf_counter()
        self._phase_s: Dict[str, List[float]] = {p: [] for p in PHASES}

    # -- recording ---------------------------------------------------------
    def now(self) -> float:
        return time.perf_counter() - self._t0

    def event(self, name: str, step: int, *, rid: Optional[int] = None,
              lane: Optional[int] = None, **args: Any) -> None:
        self.events.append(TraceEvent(name, step, self.now(), rid=rid,
                                      lane=lane, args=args or None))

    def phase(self, name: str, step: int) -> "_PhaseTimer":
        return _PhaseTimer(self, name, step)

    def _end_phase(self, name: str, step: int, t_start: float,
                   dur: float, args: Optional[Dict[str, Any]]) -> None:
        self.events.append(TraceEvent(name, step, t_start, dur=dur,
                                      args=args))
        self._phase_s.setdefault(name, []).append(dur)

    # -- analysis ----------------------------------------------------------
    def latency_histograms(self) -> Dict[str, Dict[str, float]]:
        """Per-phase step-latency percentiles, in milliseconds."""
        return {p: _percentiles([s * 1e3 for s in xs])
                for p, xs in self._phase_s.items() if xs}

    def request_spans(self) -> Dict[int, Dict[str, Any]]:
        """Reconstruct per-request lifecycles from the event list.

        Returns {rid: {enqueue_ts, admits, lanes, preempts, resumes,
        retire_ts, retired}} — the reconciliation surface test_telemetry.py
        checks against ServeStats.
        """
        spans: Dict[int, Dict[str, Any]] = {}

        def rec(rid):
            return spans.setdefault(rid, {
                "enqueue_ts": None, "admits": [], "lanes": [],
                "preempts": 0, "resumes": 0, "retire_ts": None,
                "retired": False})

        for e in self.events:
            if e.rid is None:
                continue
            r = rec(e.rid)
            if e.name == "enqueue":
                r["enqueue_ts"] = e.ts
            elif e.name in ("admit", "resume"):
                r["admits"].append((e.ts, e.lane))
                if e.lane is not None and e.lane not in r["lanes"]:
                    r["lanes"].append(e.lane)
                if e.name == "resume":
                    r["resumes"] += 1
            elif e.name in ("preempt", "swap_out", "drop"):
                if e.name == "preempt":
                    r["preempts"] += 1
            elif e.name == "retire":
                r["retire_ts"] = e.ts
                r["retired"] = True
        return spans

    # -- export ------------------------------------------------------------
    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace event format (Perfetto-loadable).

        pid 1 / tid 0 is the scheduler "steps" track carrying phase duration
        events; each decode lane gets its own tid (lane + 1) carrying the
        request spans plus request-scoped instants. Timestamps are µs.
        """
        out: List[Dict[str, Any]] = [
            {"name": "process_name", "ph": "M", "pid": 1,
             "args": {"name": "serve"}},
            {"name": "thread_name", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "steps"}},
        ]
        lanes_seen = set()
        for e in self.events:
            if e.lane is not None and e.lane not in lanes_seen:
                lanes_seen.add(e.lane)
                out.append({"name": "thread_name", "ph": "M", "pid": 1,
                            "tid": e.lane + 1,
                            "args": {"name": f"lane{e.lane}"}})
        # request spans: one "X" per residency (admit/resume -> preempt or
        # retire) on the lane track
        spans = self.request_spans()
        ends: Dict[int, List[Tuple[float, str]]] = {}
        for e in self.events:
            if e.rid is not None and e.name in ("preempt", "retire"):
                ends.setdefault(e.rid, []).append((e.ts, e.name))
        for rid, r in spans.items():
            rends = sorted(ends.get(rid, []))
            for ts, lane in r["admits"]:
                end = next(((t, n) for t, n in rends if t >= ts), None)
                if end is None or lane is None:
                    continue
                out.append({"name": f"req{rid}", "ph": "X", "pid": 1,
                            "tid": lane + 1, "ts": ts * 1e6,
                            "dur": max((end[0] - ts) * 1e6, 1.0),
                            "args": {"rid": rid, "end": end[1]}})
        for e in self.events:
            base = {"name": e.name, "pid": 1,
                    "ts": e.ts * 1e6, "args": dict(e.args or {})}
            base["args"]["step"] = e.step
            if e.rid is not None:
                base["args"]["rid"] = e.rid
            if e.dur > 0.0:                       # phase duration event
                base.update(ph="X", tid=0, dur=e.dur * 1e6)
            else:                                 # instant
                base.update(ph="i", s="t",
                            tid=0 if e.lane is None else e.lane + 1)
            out.append(base)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


class _PhaseTimer:
    """Times one jitted phase call; use as a context manager. The caller is
    expected to block_until_ready inside the ``with`` so the duration covers
    device time, not just dispatch."""

    def __init__(self, tracer: Tracer, name: str, step: int) -> None:
        self._tracer, self._name, self._step = tracer, name, step
        self.args: Dict[str, Any] = {}

    def __enter__(self) -> "_PhaseTimer":
        self._start = self._tracer.now()
        return self

    def __exit__(self, *exc) -> None:
        dur = self._tracer.now() - self._start
        self._tracer._end_phase(self._name, self._step, self._start, dur,
                                self.args or None)


class MetricsLogger:
    """Periodic scheduler gauge snapshots.

    ``emit(step, gauges)`` appends one JSON line per snapshot;
    :meth:`prometheus_text` renders the latest snapshot (plus counters) in
    Prometheus text exposition format for scrape-style consumption.
    """

    def __init__(self, every: int = 0,
                 sink: Optional[TextIO] = None) -> None:
        self.every = every
        self.sink = sink
        self.snapshots: List[Dict[str, Any]] = []
        self._last_step = -1

    def due(self, step: int) -> bool:
        """True at most once per scheduler step (a loop iteration without a
        model call leaves the step unchanged and must not re-emit)."""
        return (self.every > 0 and step % self.every == 0
                and step != self._last_step)

    def emit(self, step: int, gauges: Dict[str, Any]) -> None:
        self._last_step = step
        snap = {"step": step, "ts": time.time()}
        snap.update(gauges)
        self.snapshots.append(snap)
        if self.sink is not None:
            self.sink.write(json.dumps(snap) + "\n")

    def jsonl(self) -> str:
        return "".join(json.dumps(s) + "\n" for s in self.snapshots)

    def prometheus_text(self) -> str:
        """Latest snapshot as Prometheus gauges (serve_* namespace)."""
        if not self.snapshots:
            return ""
        latest = self.snapshots[-1]
        lines = []
        for k, v in latest.items():
            if k == "ts" or not isinstance(v, (int, float, np.integer,
                                               np.floating)):
                continue
            name = f"serve_{k}"
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name} {float(v):g}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Quantization health
# ---------------------------------------------------------------------------

class QuantHealth:
    """Aggregates the per-site telemetry vectors the jitted steps emit.

    Each site vector is ``[n_clipped, n_total, amax, cal_range]`` (f32):
    counts accumulate by summing, ``amax``/``cal_range`` by max. Stacked
    scan sites arrive as (L, 4) arrays keyed ``layer/<site>`` and fan out
    to ``layer{i}/<site>``. Derived per-site metrics:

    - ``clip_fraction`` = n_clipped / n_total — the fraction of values
      landing ON or OUTSIDE the calibrated grid edges (paper §3: outliers
      past the fixed-point range are what break int8 transformers).
    - ``amax_ratio`` = observed amax / calibrated representable range —
      > 1 means live traffic exceeds what calibration saw.
    """

    def __init__(self) -> None:
        # site -> [clipped_sum, total_sum, amax_max, range_max]
        self.sites: Dict[str, np.ndarray] = {}
        self.kv_scale_stats: Dict[str, Dict[str, float]] = {}
        self.steps_observed = 0

    def update(self, telemetry: Optional[Dict[str, Any]]) -> None:
        """Fold one step's telemetry dict (host transfer happens here)."""
        if not telemetry:
            return
        self.steps_observed += 1
        for site, vec in telemetry.items():
            arr = np.asarray(vec, dtype=np.float64)
            if arr.ndim == 2:                     # stacked scan: (L, 4)
                for i in range(arr.shape[0]):
                    self._fold(site.replace("layer/", f"layer{i}/", 1)
                               if site.startswith("layer/")
                               else f"{site}[{i}]", arr[i])
            else:
                self._fold(site, arr)

    def _fold(self, site: str, vec: np.ndarray) -> None:
        cur = self.sites.get(site)
        if cur is None:
            self.sites[site] = vec.copy()
        else:
            cur[0] += vec[0]
            cur[1] += vec[1]
            cur[2] = max(cur[2], vec[2])
            cur[3] = max(cur[3], vec[3])

    def update_kv_scales(self, cache: Any) -> None:
        """Distribution stats over the quantized KV cache's per-slot scale
        leaves (``k_s``/``v_s`` on QuantKVCache / PagedQuantKVCache and the
        int4 subclasses). Zero-valued scales (unwritten slots) are
        excluded."""
        import jax
        for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
            keys = [getattr(p, "key", getattr(p, "name", None))
                    for p in path]
            tail = next((k for k in keys[::-1] if k in ("k_s", "v_s")), None)
            if tail is None:
                continue
            a = np.asarray(leaf, dtype=np.float64).ravel()
            a = a[a != 0.0]
            name = f"kv/{tail}"
            if a.size == 0:
                continue
            self.kv_scale_stats[name] = {
                "n": int(a.size), "min": float(a.min()),
                "max": float(a.max()), "mean": float(a.mean()),
                "p50": float(np.percentile(a, 50)),
                "p99": float(np.percentile(a, 99)),
            }

    def report(self) -> Dict[str, Any]:
        per_site = {}
        for site, v in sorted(self.sites.items()):
            total = v[1]
            per_site[site] = {
                "clipped": int(v[0]), "total": int(total),
                "clip_fraction": float(v[0] / total) if total else 0.0,
                "observed_amax": float(v[2]),
                "calibrated_range": float(v[3]),
                "amax_ratio": float(v[2] / v[3]) if v[3] else 0.0,
            }
        return {"steps_observed": self.steps_observed, "sites": per_site,
                "kv_scales": self.kv_scale_stats}


@dataclasses.dataclass
class ServeTelemetry:
    """The one handle the scheduler threads around. Any member may be None;
    ``telemetry=None`` on the scheduler means fully disabled."""
    tracer: Optional[Tracer] = None
    metrics: Optional[MetricsLogger] = None
    quant: Optional[QuantHealth] = None

    @classmethod
    def create(cls, *, trace: bool = False, metrics_every: int = 0,
               quant: bool = False,
               metrics_sink: Optional[TextIO] = None) -> "ServeTelemetry":
        return cls(
            tracer=Tracer() if trace else None,
            metrics=MetricsLogger(metrics_every, metrics_sink)
            if metrics_every > 0 else None,
            quant=QuantHealth() if quant else None)
