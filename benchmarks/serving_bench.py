"""Serving-scheduler benchmark: static group batching vs continuous
(slot-scheduled) batching on a skewed-quota workload.

The workload is the scheduling worst case the paper's deployment story runs
into in production: ``max_new_tokens`` drawn from {SHORT_QUOTA, LONG_QUOTA}
(interleaved), so under static batching every group decodes in lockstep at
the pace of its slowest request while the short requests' lanes idle.
Continuous batching retires those lanes immediately and admits queued
requests mid-flight, so the measured tokens/s ratio is (mostly) the
slot-utilization ratio.

Both schedulers serve the IDENTICAL request set through the same jitted
steps (warmed up before timing) on gemma2-2b-reduced, for the f32 KV cache
and the int8 QuantKVCache (``kv_bits=8``, dynamic per-slot scales +
``int8_attend_decode``). Greedy parity between the schedulers is asserted
as part of the bench — a speedup with diverging tokens would be a bug, not
a result.

A second section benches PAGED vs dense caches on a skewed-LENGTH
workload (most requests short, a few long): dense lanes must each carry
the worst-case ``max_len`` segment, so peak cache bytes are
``batch_slots x max_len`` regardless of what is actually live, while the
block pool (``runtime.block_pool``) maps blocks per LIVE token — the
paged rows record peak allocated bytes + tokens/s for both the f32 and
int8 block pools, with paged == dense greedy parity asserted in-bench.

A third section benches CHUNKED prefill on a long-prompt/short-quota
mixed workload: short-prompt residents decode while a long-prompt request
is admitted mid-flight. Unchunked, that admission is one monolithic
prefill call and every resident decode lane stalls for its full wall
time; chunked, the prompt lands in ``CHUNK``-token chunk steps
interleaved 1:1 with resident decode steps. The rows record the max /
mean wall-clock gap between consecutive decode steps (the resident-lane
stall this PR removes) and the long request's time-to-first-token in
model-call steps, with chunked == unchunked greedy parity asserted
in-bench.

A fourth section benches the PREFIX CACHE on the workload it targets: N
requests sharing a K-token prompt prefix (system-prompt traffic), served
sequentially through a small lane pool. Unshared, every admission
prefills its full prompt and allocates its full block span; with the
radix cache, retiring lanes donate their prompt blocks and every
admission after the first wave maps the shared K_aligned tokens read-only
and prefills only its novel suffix — the rows assert prefill tokens
processed == N * (prompt - K_aligned) + first_wave * K_aligned and that
fresh block allocations scale with the suffix only, with shared ==
unshared greedy parity asserted in-bench.

``python -m benchmarks.serving_bench`` (or benchmarks/run.py --sections
serving) also writes machine-readable ``BENCH_serving.json``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.runtime import (BlockPool, RadixCache, Request, blocks_for_tokens,
                           serve)
from repro.runtime.steps import (make_admit_step, make_chunk_prefill_step,
                                 make_decode_step, make_prefill_step)

JSON_PATH = "BENCH_serving.json"

BATCH_SLOTS = 8
N_REQUESTS = 16
PROMPT_LEN = 8
SHORT_QUOTA = 4
LONG_QUOTA = 96
MAX_LEN = 128
REPEATS = 3          # timed repeats; best tokens/s wins (CPU wall jitter)

# paged-vs-dense section: skewed LENGTHS — every 4th request is long, so
# dense worst-case sizing (every lane carries PAGED_MAX_LEN slots) is ~4x
# the live footprint the block pool actually maps
PAGED_BLOCK_SIZE = 8
PAGED_MAX_LEN = 96
PAGED_SHORT = (6, 10)        # (prompt_len, quota) for short requests
PAGED_LONG = (48, 40)
PAGED_NUM_BLOCKS = 40        # vs dense worst case 8 * ceil(96/8) = 96

# chunked-prefill section: residents with short prompts decode long quotas
# while a LONG prompt is admitted into the lane a quota-CHUNK_EARLY
# request frees — unchunked, its monolithic prefill stalls every resident
# decode lane for the call's full wall time
CHUNK_SLOTS = 4
CHUNK_MAX_LEN = 320
CHUNK_RESIDENT = (8, 80)     # (prompt_len, quota) for the 3 residents
CHUNK_EARLY = (8, 4)         # retires early, freeing a lane mid-flight
CHUNK_LONG = (256, 16)       # the long-prompt late arrival
CHUNK = 16                   # tokens per chunk step

# prefix-cache section: N requests opening with the SAME system prefix,
# drained through a small lane pool so later admissions hit the blocks the
# first wave donated. Sizes keep every request under the reduced local
# window (prompt + quota - 2 < 16), so retiring lanes are donation-eligible
PREFIX_SLOTS = 2
PREFIX_N = 10
PREFIX_BLOCK_SIZE = 4
PREFIX_MAX_LEN = 16
PREFIX_PROMPT = 12           # tokens; first PREFIX_SHARED are common
PREFIX_SHARED = 8            # == K_aligned (block-aligned by construction)
PREFIX_QUOTA = 4
PREFIX_NUM_BLOCKS = 12       # small enough to exercise LRU eviction


def _requests(cfg):
    rng = np.random.RandomState(0)
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size,
                                       size=PROMPT_LEN).astype(np.int32),
                    max_new_tokens=LONG_QUOTA if i % 2 else SHORT_QUOTA)
            for i in range(N_REQUESTS)]


def _serve(cfg, params, steps, reqs, scheduler, kv_bits):
    admit, decode, prefill = steps

    def init(b):
        return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                              kv_bits=kv_bits)

    return serve(prefill, admit, decode, init, params, reqs,
                 scheduler=scheduler, batch_slots=BATCH_SLOTS,
                 max_len=MAX_LEN)


def bench():
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    rows = []
    for kv_bits in (16, 8):
        # donate the cache operand exactly as launch/serve.py does, so the
        # bench measures the in-place-update configuration production runs
        steps = (jax.jit(make_admit_step(cfg), donate_argnums=(4,)),
                 jax.jit(make_decode_step(cfg), donate_argnums=(3,)),
                 jax.jit(make_prefill_step(cfg)))
        # warm-up: compile admit/prefill/decode outside the timed runs, at
        # the SAME shapes the timed runs use (a full group of batch_slots);
        # fresh Request objects per run — serving mutates done/tokens_out
        def warm():
            return [Request(rid=0, prompt=np.ones(PROMPT_LEN, np.int32),
                            max_new_tokens=2)
                    for _ in range(BATCH_SLOTS)]
        _serve(cfg, params, steps, warm(), "continuous", kv_bits)
        _serve(cfg, params, steps, warm(), "static", kv_bits)

        outs = {}
        for scheduler in ("static", "continuous"):
            stats = None
            for _ in range(REPEATS):
                reqs = _requests(cfg)
                s = _serve(cfg, params, steps, reqs, scheduler, kv_bits)
                if stats is None or s.tokens_per_s > stats.tokens_per_s:
                    stats = s
            outs[scheduler] = [r.tokens_out for r in reqs]
            rows.append({
                "name": f"serve_{scheduler}_kv{kv_bits}",
                "scheduler": scheduler,
                "kv_bits": kv_bits,
                "batch_slots": BATCH_SLOTS,
                "requests": N_REQUESTS,
                "quotas": [SHORT_QUOTA, LONG_QUOTA],
                "tokens": stats.tokens_generated,
                "prefill_calls": stats.prefill_calls,
                "decode_steps": stats.decode_steps,
                "wall_s": round(stats.wall_s, 3),
                "tokens_per_s": round(stats.tokens_per_s, 1),
                "slot_utilization": round(stats.slot_utilization, 3),
                "peak_cache_bytes": stats.cache_bytes,
            })
        assert outs["static"] == outs["continuous"], \
            "scheduler parity violated under benchmark workload"
        stat, cont = rows[-2], rows[-1]
        cont["speedup_vs_static"] = round(
            cont["tokens_per_s"] / max(stat["tokens_per_s"], 1e-9), 2)
    rows += bench_paged()
    rows += bench_chunked()
    rows += bench_prefix()
    return rows


def _paged_requests(cfg):
    rng = np.random.RandomState(1)
    reqs = []
    for i in range(N_REQUESTS):
        plen, quota = PAGED_LONG if i % 4 == 3 else PAGED_SHORT
        reqs.append(Request(
            rid=i,
            prompt=rng.randint(1, cfg.vocab_size, size=plen)
            .astype(np.int32),
            max_new_tokens=quota))
    return reqs


def bench_paged():
    """Paged vs dense caches, continuous scheduler, skewed-length
    workload. Records peak cache bytes (dense: the whole pytree; paged:
    allocated blocks only) + tokens/s for f32 and int8 pools."""
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    nb_lane = blocks_for_tokens(PAGED_MAX_LEN, PAGED_BLOCK_SIZE)
    rows = []
    for kv_bits in (16, 8):
        steps = (jax.jit(make_admit_step(cfg), donate_argnums=(4,)),
                 jax.jit(make_decode_step(cfg), donate_argnums=(3,)),
                 jax.jit(make_prefill_step(cfg)))
        admit, decode, prefill = steps

        def run(reqs, paged):
            pool = None
            if paged:
                pool = BlockPool(PAGED_NUM_BLOCKS, PAGED_BLOCK_SIZE,
                                 BATCH_SLOTS, nb_lane)

            def init(b):
                if not paged:
                    return tfm.init_cache(cfg, b, PAGED_MAX_LEN,
                                          dtype=jnp.float32,
                                          kv_bits=kv_bits)
                return tfm.init_cache(cfg, b, PAGED_MAX_LEN,
                                      dtype=jnp.float32, kv_bits=kv_bits,
                                      paged=True,
                                      block_size=PAGED_BLOCK_SIZE,
                                      num_blocks=PAGED_NUM_BLOCKS,
                                      mapped=False)
            return serve(prefill, admit, decode, init, params, reqs,
                         scheduler="continuous", batch_slots=BATCH_SLOTS,
                         max_len=PAGED_MAX_LEN, block_pool=pool)

        def warm(paged):
            reqs = [Request(rid=0, prompt=np.ones(4, np.int32),
                            max_new_tokens=2) for _ in range(BATCH_SLOTS)]
            run(reqs, paged)

        outs = {}
        for paged in (False, True):
            warm(paged)
            stats = None
            for _ in range(REPEATS):
                reqs = _paged_requests(cfg)
                s = run(reqs, paged)
                if stats is None or s.tokens_per_s > stats.tokens_per_s:
                    stats = s
            name = "paged" if paged else "dense"
            outs[name] = [r.tokens_out for r in reqs]
            rows.append({
                "name": f"serve_{name}_cache_kv{kv_bits}",
                "cache": name,
                "kv_bits": kv_bits,
                "batch_slots": BATCH_SLOTS,
                "requests": N_REQUESTS,
                "prompt_lens": [PAGED_SHORT[0], PAGED_LONG[0]],
                "quotas": [PAGED_SHORT[1], PAGED_LONG[1]],
                "max_len": PAGED_MAX_LEN,
                "tokens": stats.tokens_generated,
                "decode_steps": stats.decode_steps,
                "wall_s": round(stats.wall_s, 3),
                "tokens_per_s": round(stats.tokens_per_s, 1),
                "slot_utilization": round(stats.slot_utilization, 3),
                "peak_cache_bytes": stats.cache_bytes,
                **({"block_size": PAGED_BLOCK_SIZE,
                    "num_blocks": PAGED_NUM_BLOCKS,
                    "peak_blocks_in_use": stats.blocks_in_use,
                    "block_fragmentation":
                        round(stats.block_fragmentation, 3)}
                   if paged else {}),
            })
        assert outs["dense"] == outs["paged"], \
            "paged == dense greedy parity violated under benchmark workload"
        dense_row, paged_row = rows[-2], rows[-1]
        paged_row["cache_bytes_vs_dense"] = round(
            paged_row["peak_cache_bytes"]
            / max(dense_row["peak_cache_bytes"], 1), 3)
    return rows


def _chunk_requests(cfg):
    rng = np.random.RandomState(2)

    def req(rid, plen, quota):
        return Request(rid=rid,
                       prompt=rng.randint(1, cfg.vocab_size, size=plen)
                       .astype(np.int32),
                       max_new_tokens=quota)
    reqs = [req(0, *CHUNK_EARLY)]
    reqs += [req(1 + i, *CHUNK_RESIDENT) for i in range(CHUNK_SLOTS - 1)]
    reqs.append(req(CHUNK_SLOTS, *CHUNK_LONG))       # queued long arrival
    return reqs


def bench_chunked():
    """Chunked vs monolithic prefill, continuous scheduler, long-prompt
    arrival into a busy slot pool. Records the max/mean wall gap between
    consecutive decode steps (resident-lane stall) and the long request's
    first-token latency in model-call steps."""
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    admit = jax.jit(make_admit_step(cfg), donate_argnums=(4,))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(3,))
    chunkstep = jax.jit(make_chunk_prefill_step(cfg), donate_argnums=(4,))
    long_rid = CHUNK_SLOTS

    def run(reqs, chunk, decode_times):
        def timed_decode(params_, t, p, c):
            out = decode(params_, t, p, c)
            jax.block_until_ready(out[0])
            decode_times.append(time.perf_counter())
            return out

        def init(b):
            return tfm.init_cache(cfg, b, CHUNK_MAX_LEN, dtype=jnp.float32)

        return serve(None, admit, timed_decode, init, params, reqs,
                     scheduler="continuous", batch_slots=CHUNK_SLOTS,
                     max_len=CHUNK_MAX_LEN,
                     chunk_step=chunkstep if chunk else None,
                     prefill_chunk=chunk or None)

    def warm(chunk):
        reqs = [Request(rid=0, prompt=np.ones(CHUNK_LONG[0], np.int32),
                        max_new_tokens=2) for _ in range(CHUNK_SLOTS)]
        run(reqs, chunk, [])

    rows, outs = [], {}
    for chunk in (0, CHUNK):
        warm(chunk)
        best = None
        for _ in range(REPEATS):
            times = []
            reqs = _chunk_requests(cfg)
            stats = run(reqs, chunk, times)
            gaps = np.diff(np.asarray(times)) * 1e3          # ms
            if best is None or stats.tokens_per_s > best[0].tokens_per_s:
                best = (stats, gaps, reqs)
        stats, gaps, reqs = best
        name = f"chunk{chunk}" if chunk else "monolithic"
        outs[name] = [r.tokens_out for r in reqs]
        rows.append({
            "name": f"serve_prefill_{name}",
            "prefill_chunk": chunk,
            "batch_slots": CHUNK_SLOTS,
            "requests": len(reqs),
            "resident": list(CHUNK_RESIDENT),
            "long_request": list(CHUNK_LONG),
            "tokens": stats.tokens_generated,
            "prefill_calls": stats.prefill_calls,
            "chunk_steps": stats.chunk_steps,
            "decode_steps": stats.decode_steps,
            "wall_s": round(stats.wall_s, 3),
            "tokens_per_s": round(stats.tokens_per_s, 1),
            # resident-lane stall: wall gap between consecutive decode
            # steps — the monolithic long prefill sits inside one gap
            "max_decode_gap_ms": round(float(gaps.max()), 2),
            "mean_decode_gap_ms": round(float(gaps.mean()), 2),
            "long_req_first_token_step":
                stats.request_latency[long_rid].first_token_step,
        })
    assert outs["monolithic"] == outs[f"chunk{CHUNK}"], \
        "chunked == unchunked greedy parity violated under benchmark workload"
    mono, chk = rows[-2], rows[-1]
    chk["stall_reduction_vs_monolithic"] = round(
        mono["max_decode_gap_ms"] / max(chk["max_decode_gap_ms"], 1e-9), 2)
    return rows


def _prefix_requests(cfg):
    rng = np.random.RandomState(3)
    shared = rng.randint(1, cfg.vocab_size, size=PREFIX_SHARED)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [shared,
                         rng.randint(1, cfg.vocab_size,
                                     size=PREFIX_PROMPT - PREFIX_SHARED)]
                    ).astype(np.int32),
                    max_new_tokens=PREFIX_QUOTA)
            for i in range(PREFIX_N)]


class _CountingPool(BlockPool):
    """BlockPool that counts fresh block draws (novel allocations + COW
    copies) — the bench's O(suffix) allocation evidence."""

    def reset(self):
        self.popped = 0
        super().reset()

    def _pop_free(self, n):
        self.popped += n
        return super()._pop_free(n)


def bench_prefix():
    """Radix prefix cache vs unshared paged serving on a shared-prefix
    workload. Asserts the O(suffix) claims in-bench: after the first wave
    of misses, every admission maps K_aligned shared tokens and prefills /
    allocates its novel suffix only."""
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    admit = jax.jit(make_admit_step(cfg), donate_argnums=(4,))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(3,))
    chunkstep = jax.jit(make_chunk_prefill_step(cfg), donate_argnums=(4,))
    copyblock = jax.jit(tfm.cache_copy_block, donate_argnums=(0,))
    nb_lane = tfm.paged_lane_blocks(cfg, PREFIX_MAX_LEN, PREFIX_BLOCK_SIZE)
    caps = tfm.attn_write_caps(cfg, PREFIX_MAX_LEN, PREFIX_BLOCK_SIZE)

    def run(reqs, prefix):
        pool = _CountingPool(PREFIX_NUM_BLOCKS, PREFIX_BLOCK_SIZE,
                             PREFIX_SLOTS, nb_lane)

        def init(b):
            return tfm.init_cache(cfg, b, PREFIX_MAX_LEN, dtype=jnp.float32,
                                  paged=True, block_size=PREFIX_BLOCK_SIZE,
                                  num_blocks=PREFIX_NUM_BLOCKS, mapped=False)
        stats = serve(None, admit, decode, init, params, reqs,
                      scheduler="continuous", batch_slots=PREFIX_SLOTS,
                      max_len=PREFIX_MAX_LEN, block_pool=pool,
                      chunk_step=chunkstep,
                      radix_cache=RadixCache(PREFIX_BLOCK_SIZE) if prefix
                      else None,
                      write_caps=caps, copy_block_fn=copyblock)
        return stats, pool.popped

    def warm(prefix):
        reqs = [Request(rid=0, prompt=np.ones(PREFIX_PROMPT, np.int32),
                        max_new_tokens=2) for _ in range(PREFIX_SLOTS)]
        run(reqs, prefix)

    total_cols = blocks_for_tokens(PREFIX_PROMPT + PREFIX_QUOTA - 1,
                                   PREFIX_BLOCK_SIZE)
    k_blocks = PREFIX_SHARED // PREFIX_BLOCK_SIZE
    rows, outs = [], {}
    for prefix in (False, True):
        warm(prefix)
        best = None
        for _ in range(REPEATS):
            reqs = _prefix_requests(cfg)
            stats, popped = run(reqs, prefix)
            if best is None or stats.tokens_per_s > best[0].tokens_per_s:
                best = (stats, popped, reqs)
        stats, popped, reqs = best
        name = "shared" if prefix else "unshared"
        outs[name] = [r.tokens_out for r in reqs]
        prompt_tokens = PREFIX_N * PREFIX_PROMPT
        prefilled = prompt_tokens - stats.prefill_tokens_saved
        rows.append({
            "name": f"serve_prefix_{name}",
            "prefix_cache": prefix,
            "batch_slots": PREFIX_SLOTS,
            "requests": PREFIX_N,
            "prompt_len": PREFIX_PROMPT,
            "shared_prefix_tokens": PREFIX_SHARED,
            "quota": PREFIX_QUOTA,
            "block_size": PREFIX_BLOCK_SIZE,
            "num_blocks": PREFIX_NUM_BLOCKS,
            "tokens": stats.tokens_generated,
            "decode_steps": stats.decode_steps,
            "wall_s": round(stats.wall_s, 3),
            "tokens_per_s": round(stats.tokens_per_s, 1),
            "prefill_tokens_processed": prefilled,
            "prefill_tokens_saved": stats.prefill_tokens_saved,
            "prefix_hit_tokens": stats.prefix_hit_tokens,
            "prefix_hit_rate": round(stats.prefix_hit_rate, 3),
            "peak_shared_blocks": stats.shared_blocks,
            "blocks_allocated": popped,
            "peak_blocks_in_use": stats.blocks_in_use,
        })
    assert outs["unshared"] == outs["shared"], \
        "shared == unshared greedy parity violated under benchmark workload"
    unshared, shared = rows[-2], rows[-1]
    # O(suffix) prefill: the first wave (PREFIX_SLOTS misses on an empty
    # cache) prefills fully; every later admission hits K_aligned tokens
    hits = PREFIX_N - PREFIX_SLOTS
    assert shared["prefill_tokens_saved"] == hits * PREFIX_SHARED, \
        "every post-first-wave admission should hit the shared prefix"
    assert shared["prefill_tokens_processed"] == \
        PREFIX_N * (PREFIX_PROMPT - PREFIX_SHARED) \
        + PREFIX_SLOTS * PREFIX_SHARED, \
        "prefill tokens should be N * suffix + first_wave * K_aligned"
    # O(suffix) allocation: misses draw their full span, hits only their
    # novel suffix columns (the K_aligned columns are mapped, not drawn)
    assert unshared["blocks_allocated"] == PREFIX_N * total_cols
    assert shared["blocks_allocated"] == \
        PREFIX_SLOTS * total_cols + hits * (total_cols - k_blocks), \
        "hit admissions should allocate suffix blocks only"
    shared["prefill_tokens_vs_unshared"] = round(
        shared["prefill_tokens_processed"]
        / max(unshared["prefill_tokens_processed"], 1), 3)
    shared["blocks_allocated_vs_unshared"] = round(
        shared["blocks_allocated"]
        / max(unshared["blocks_allocated"], 1), 3)
    return rows


def report(rows) -> str:
    hdr = ("name,kv_bits,tokens,decode_steps,wall_s,tokens_per_s,"
           "slot_utilization,peak_cache_bytes,speedup_vs_static,"
           "cache_bytes_vs_dense,max_decode_gap_ms,"
           "stall_reduction_vs_monolithic,prefill_tokens_processed,"
           "blocks_allocated")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r['name']},{r.get('kv_bits', '')},{r['tokens']},"
            f"{r['decode_steps']},"
            f"{r['wall_s']},{r['tokens_per_s']},"
            f"{r.get('slot_utilization', '')},"
            f"{r.get('peak_cache_bytes', '')},"
            f"{r.get('speedup_vs_static', '')},"
            f"{r.get('cache_bytes_vs_dense', '')},"
            f"{r.get('max_decode_gap_ms', '')},"
            f"{r.get('stall_reduction_vs_monolithic', '')},"
            f"{r.get('prefill_tokens_processed', '')},"
            f"{r.get('blocks_allocated', '')}")
    return "\n".join(lines)


def write_json(rows, path=JSON_PATH):
    with open(path, "w") as f:
        json.dump({"workload": {
            "batch_slots": BATCH_SLOTS, "requests": N_REQUESTS,
            "prompt_len": PROMPT_LEN,
            "max_new_tokens": [SHORT_QUOTA, LONG_QUOTA],
            "arch": "gemma2-2b-reduced"}, "rows": rows}, f, indent=1)
        f.write("\n")
    return path


if __name__ == "__main__":
    rows = bench()
    print(report(rows))
    print(f"# wrote {write_json(rows)}")
